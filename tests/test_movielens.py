"""Chunked rating-file ingestion: identical output to a one-shot parse,
bounded peak memory (no dense ``np.genfromtxt`` over the whole file)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.data.movielens import _parse_ratings_csv, _parse_udata, load_movielens
from repro.data.sparse import RatingsCOO

_CSV_ROWS = [
    # userId, movieId, rating, timestamp — ids sparse and unsorted on purpose
    (7, 31, 4.0), (2, 17, 3.5), (7, 17, 5.0), (900, 31, 1.0),
    (2, 1000, 2.0), (3, 17, 4.5), (7, 1000, 0.5),
]


@pytest.fixture
def ratings_csv(tmp_path):
    path = tmp_path / "ratings.csv"
    lines = ["userId,movieId,rating,timestamp"]
    lines += [f"{u},{m},{r},11{i}" for i, (u, m, r) in enumerate(_CSV_ROWS)]
    path.write_text("\n".join(lines) + "\n")
    return str(path)


def _expected() -> RatingsCOO:
    users_raw = np.array([u for u, _, _ in _CSV_ROWS], np.int64)
    movies_raw = np.array([m for _, m, _ in _CSV_ROWS], np.int64)
    vals = np.array([r for _, _, r in _CSV_ROWS], np.float32)
    _, users = np.unique(users_raw, return_inverse=True)
    _, movies = np.unique(movies_raw, return_inverse=True)
    return RatingsCOO(users.astype(np.int32), movies.astype(np.int32), vals,
                      int(users.max()) + 1, int(movies.max()) + 1)


@pytest.mark.parametrize("chunk_rows", [1, 3, 1000])
def test_csv_chunked_matches_oneshot(ratings_csv, chunk_rows):
    """Every chunk size (including chunks smaller than the file and a
    single-row chunk, which exercises the 1-D genfromtxt edge) yields the
    same RatingsCOO as parsing everything at once."""
    got = _parse_ratings_csv(ratings_csv, chunk_rows=chunk_rows)
    want = _expected()
    assert (got.num_users, got.num_movies, got.nnz) == (
        want.num_users, want.num_movies, want.nnz
    )
    np.testing.assert_array_equal(got.rows, want.rows)
    np.testing.assert_array_equal(got.cols, want.cols)
    np.testing.assert_array_equal(got.vals, want.vals)
    assert got.rows.dtype == np.int32 and got.vals.dtype == np.float32


def test_csv_ids_are_compacted(ratings_csv):
    """Raw ml-20m ids (sparse, e.g. user 900) compact to dense 0..N-1."""
    coo = _parse_ratings_csv(ratings_csv, chunk_rows=2)
    assert coo.num_users == 4  # users {2, 3, 7, 900}
    assert coo.num_movies == 3  # movies {17, 31, 1000}
    assert set(coo.rows.tolist()) == {0, 1, 2, 3}


def test_udata_chunked(tmp_path):
    path = tmp_path / "u.data"
    path.write_text("1\t5\t3.0\t881250949\n2\t3\t4.0\t881250950\n1\t3\t1.0\t881250951\n")
    coo = _parse_udata(str(path), chunk_rows=2)
    assert (coo.num_users, coo.num_movies, coo.nnz) == (2, 5, 3)
    np.testing.assert_array_equal(coo.rows, [0, 1, 0])
    np.testing.assert_array_equal(coo.cols, [4, 2, 2])
    np.testing.assert_array_equal(coo.vals, np.array([3, 4, 1], np.float32))


def test_trailing_blank_lines(tmp_path):
    """A blank-only final chunk (trailing newlines aligned with chunk_rows)
    must be skipped, not crash the column slice."""
    path = tmp_path / "ratings.csv"
    lines = ["userId,movieId,rating,timestamp"]
    lines += [f"{u},{m},{r},11{i}" for i, (u, m, r) in enumerate(_CSV_ROWS)]
    path.write_text("\n".join(lines) + "\n\n\n")
    got = _parse_ratings_csv(str(path), chunk_rows=len(_CSV_ROWS))
    assert got.nnz == len(_CSV_ROWS)
    np.testing.assert_array_equal(got.vals, _expected().vals)


def test_empty_csv_raises_clean(tmp_path):
    path = tmp_path / "ratings.csv"
    path.write_text("userId,movieId,rating,timestamp\n")
    with pytest.raises(ValueError, match="no ratings"):
        _parse_ratings_csv(str(path))


def test_load_movielens_dispatch(ratings_csv):
    coo = load_movielens(ratings_csv)
    assert isinstance(coo, RatingsCOO) and coo.nnz == len(_CSV_ROWS)
