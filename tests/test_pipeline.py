"""Overlapped block pipeline + donated carries + async checkpoints (§13).

The correctness bar of the overlap work: at any ``pipeline_blocks`` depth
the engine dispatches the same jitted blocks in the same order on the same
carries, so samples, metric history, checkpoint cadence and exported
artifacts are **bitwise** equal to the synchronous depth-1 loop on every
backend — including runs interrupted and resumed from a mid-pipeline
checkpoint, runs with the donation fallback off, and user ``save()`` calls
issued while blocks are still in flight. Async checkpoint writes must
commit by process exit and never expose a torn checkpoint, even when the
process dies before the writer thread drains.

These tests run in-process on the tier-1 forced 8-device host mesh except
the crash/exit tests, which need a fresh interpreter per scenario.
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from conftest import run_with_devices
from repro.bpmf import BPMFConfig, BPMFEngine, load_dataset
from repro.serve import load_artifact

ARRAY_KEYS = ("U_mean", "V_mean", "U_samples", "V_samples")
BACKENDS = ("sequential", "ring", "ring_async", "allgather", "posterior_merge")


def _cfg(**kw) -> BPMFConfig:
    base = dict(
        K=6, num_sweeps=7, burn_in=2, sweeps_per_block=2,
        bucket_pads=(8, 32, 128), keep_factor_samples=3,
    )
    base.update(kw)
    return BPMFConfig().replace(**base)


def _coo(seed: int = 3):
    return load_dataset(
        "synthetic", num_users=90, num_movies=45, nnz=1000, noise_std=0.3, seed=seed
    )


def _artifact_equal(a, b, msg=""):
    meta_a, arrs_a = a
    meta_b, arrs_b = b
    assert meta_a == meta_b, (msg, meta_a, meta_b)
    for k in ARRAY_KEYS:
        np.testing.assert_array_equal(arrs_a[k], arrs_b[k], err_msg=f"{msg}:{k}")


# ---------- bitwise parity across pipeline depths ----------


@pytest.mark.parametrize("name", BACKENDS)
def test_pipeline_depths_bitwise_identical(tmp_path, name):
    """pipeline_blocks ∈ {1, 2, 4}: factors, per-sweep history and the
    exported artifact are bitwise identical on every backend — pipelining
    only changes when block metrics reach the host, never the samples."""
    coo = _coo()
    outs = {}
    for depth in (1, 2, 4):
        e = BPMFEngine(_cfg(name=name, pipeline_blocks=depth)).fit(coo)
        art = load_artifact(e.export(str(tmp_path / f"{name}-{depth}")))
        outs[depth] = (e.factors(), [tuple(m) for m in e.history], art)
    (U0, V0), hist0, art0 = outs[1]
    assert [int(m[2]) for m in hist0] == list(range(1, 8))
    for depth in (2, 4):
        (U, V), hist, art = outs[depth]
        np.testing.assert_array_equal(U, U0, err_msg=f"{name}@d{depth}")
        np.testing.assert_array_equal(V, V0, err_msg=f"{name}@d{depth}")
        assert hist == hist0, f"{name}@d{depth}: history diverged"
        _artifact_equal(art, art0, msg=f"{name}@d{depth}")


def test_donation_fallback_bitwise_identical():
    """donate_blocks="off" routes through the non-donating jit variants and
    draws the same samples — the fallback path is a pure perf toggle."""
    coo = _coo(seed=5)
    ref = BPMFEngine(_cfg(name="ring", pipeline_blocks=2)).fit(coo)
    off = BPMFEngine(_cfg(name="ring", pipeline_blocks=2, donate_blocks="off")).fit(coo)
    np.testing.assert_array_equal(ref.factors()[0], off.factors()[0])
    np.testing.assert_array_equal(ref.factors()[1], off.factors()[1])
    assert [tuple(m) for m in ref.history] == [tuple(m) for m in off.history]


def test_pipeline_checkpoint_cadence_depth_invariant(tmp_path):
    """``sample()`` still yields exactly one SweepMetrics per sweep in sweep
    order, and ``checkpoint_every`` auto-saves land on the same steps, at
    every depth — the dispatch queue drains at boundaries rather than
    checkpointing a stale carry."""
    coo = _coo(seed=6)
    cadences = {}
    for depth in (1, 2, 4):
        cfg = _cfg(
            pipeline_blocks=depth, num_sweeps=8, checkpoint_every=3,
            checkpoint_dir=str(tmp_path / f"d{depth}"), keep_checkpoints=99,
        )
        engine = BPMFEngine(cfg)
        yielded = list(engine.sample(coo))
        assert [int(m.sweep) for m in yielded] == list(range(1, 9))
        assert yielded == engine.history
        cadences[depth] = (engine._manager().all_steps(), [tuple(m) for m in yielded])
    steps0, hist0 = cadences[1]
    assert steps0 == [3, 6]  # 8 is not a checkpoint_every multiple
    for depth, (steps, hist) in cadences.items():
        assert steps == steps0, (depth, steps)
        assert hist == hist0, f"depth={depth}: metrics diverged"


# ---------- interruption / drain ----------


def test_mid_pipeline_interruption_resumes_bitwise(tmp_path):
    """Checkpoint mid-run at depth 2, restore in a fresh engine, finish:
    samples, history and the exported artifact are bitwise identical to an
    uninterrupted depth-2 run AND to the synchronous depth-1 run."""
    coo = _coo(seed=5)
    cfg = _cfg(
        name="ring", num_sweeps=8, sweeps_per_block=3, pipeline_blocks=2,
        checkpoint_every=4, checkpoint_dir=str(tmp_path / "ckpt"),
    )

    full = BPMFEngine(cfg).fit(coo)
    full_art = load_artifact(full.export(str(tmp_path / "full")))
    sync = BPMFEngine(
        cfg.replace(pipeline_blocks=1, checkpoint_dir=None, checkpoint_every=0)
    ).fit(coo)
    np.testing.assert_array_equal(full.factors()[0], sync.factors()[0])

    resumed = BPMFEngine(cfg)
    assert resumed.restore(coo, step=4) == 4  # 4 % 3 != 0: mid-block sweep
    resumed.fit()
    res_art = load_artifact(resumed.export(str(tmp_path / "resumed")))
    _artifact_equal(res_art, full_art, msg="mid-pipeline resume")
    np.testing.assert_array_equal(resumed.factors()[0], full.factors()[0])
    np.testing.assert_array_equal(resumed.factors()[1], full.factors()[1])
    assert [tuple(m) for m in resumed.history] == [tuple(m) for m in full.history]


def test_save_while_blocks_in_flight_drains(tmp_path):
    """A user ``save()`` issued while the dispatch queue holds undrained
    blocks is a pipeline barrier: it drains them all, checkpoints the
    complete history, and the paused iterator still yields every remaining
    sweep exactly once, in order."""
    coo = _coo(seed=7)
    cfg = _cfg(
        num_sweeps=12, sweeps_per_block=2, pipeline_blocks=4,
        checkpoint_dir=str(tmp_path / "ckpt"),
    )
    engine = BPMFEngine(cfg)
    it = engine.sample(coo)
    seen = [next(it) for _ in range(3)]
    assert engine._inflight  # blocks genuinely in flight at the pause point
    step = engine.save()
    assert not engine._inflight
    assert step == engine.num_sweeps_done == len(engine.history)
    seen.extend(it)
    assert [int(m.sweep) for m in seen] == list(range(1, 13))
    assert seen == engine.history

    ref = BPMFEngine(_cfg(num_sweeps=12, sweeps_per_block=2)).fit(coo)
    assert [tuple(m) for m in engine.history] == [tuple(m) for m in ref.history]
    np.testing.assert_array_equal(engine.factors()[0], ref.factors()[0])

    restored = BPMFEngine(cfg)
    assert restored.restore(coo) == step
    assert [tuple(m) for m in restored.history] == [tuple(m) for m in engine.history[:step]]


# ---------- async checkpoint writes: exit + crash semantics ----------


@pytest.mark.multidevice
def test_async_save_commits_by_process_exit(tmp_path):
    """``save()`` returns before the filesystem commit; a process that then
    exits normally still commits — the manager's atexit hook joins the
    writer thread."""
    ckpt = str(tmp_path / "ckpt")
    run_with_devices(
        f"""
        from repro.bpmf import BPMFConfig, BPMFEngine, load_dataset
        coo = load_dataset("synthetic", num_users=60, num_movies=30, nnz=600, seed=1)
        cfg = BPMFConfig().replace(
            K=4, num_sweeps=4, burn_in=1, sweeps_per_block=2,
            bucket_pads=(8, 32, 128), checkpoint_dir={ckpt!r},
            async_checkpoint_writes=True,
        )
        engine = BPMFEngine(cfg).fit(coo)
        engine.save()
        # NO wait()/close(): the atexit drain must commit the pending write
        """,
        num_devices=2,
    )
    assert os.path.exists(os.path.join(ckpt, "LATEST"))
    assert os.path.exists(os.path.join(ckpt, "step_00000004"))


@pytest.mark.multidevice
def test_crash_before_drain_never_exposes_torn_checkpoint(tmp_path):
    """``os._exit`` right after async ``save()`` returns skips the atexit
    drain and can kill the writer thread mid-write. Whatever survives must
    be atomic: no committed step dir is torn, and if LATEST exists it
    restores fully in a fresh process."""
    ckpt = str(tmp_path / "ckpt")
    run_with_devices(
        f"""
        import os
        from repro.bpmf import BPMFConfig, BPMFEngine, load_dataset
        coo = load_dataset("synthetic", num_users=60, num_movies=30, nnz=600, seed=1)
        cfg = BPMFConfig().replace(
            K=4, num_sweeps=4, burn_in=1, sweeps_per_block=2,
            bucket_pads=(8, 32, 128), checkpoint_dir={ckpt!r},
            async_checkpoint_writes=True,
        )
        engine = BPMFEngine(cfg).fit(coo)
        engine.save()
        os._exit(0)  # crash before the background write necessarily drains
        """,
        num_devices=2,
    )
    # both outcomes are legal: nothing committed, or a complete checkpoint.
    # what is ILLEGAL is a partial commit — a visible step dir or LATEST
    # that a fresh process cannot restore.
    run_with_devices(
        f"""
        import os
        from repro.bpmf import BPMFConfig, BPMFEngine, load_dataset
        coo = load_dataset("synthetic", num_users=60, num_movies=30, nnz=600, seed=1)
        cfg = BPMFConfig().replace(
            K=4, num_sweeps=4, burn_in=1, sweeps_per_block=2,
            bucket_pads=(8, 32, 128), checkpoint_dir={ckpt!r},
        )
        steps = [n for n in os.listdir({ckpt!r})
                 if n.startswith("step_") and ".tmp" not in n]
        if os.path.exists(os.path.join({ckpt!r}, "LATEST")):
            engine = BPMFEngine(cfg)
            engine.prepare(coo)
            assert engine.restore() == 4, "LATEST points at a torn checkpoint"
            print("RESTORED")
        else:
            assert not steps, f"committed steps without LATEST: {{steps}}"
            print("NOTHING_COMMITTED")
        """,
        num_devices=2,
    )


# ---------- config / plumbing ----------


def test_pipeline_blocks_validated():
    with pytest.raises(ValueError, match="pipeline_blocks"):
        _cfg(pipeline_blocks=0)


def test_donate_blocks_validated():
    with pytest.raises(ValueError, match="donate_blocks"):
        _cfg(donate_blocks="bogus")


def test_pipeline_metrics_single_transfer_and_blocked_time():
    """Pipelining keeps the one-[block,3]-f32-fetch-per-block contract (12
    bytes/sweep at any depth) and accounts the host-blocked drain time it
    is meant to shrink."""
    coo = _coo(seed=2)
    for depth in (1, 4):
        engine = BPMFEngine(_cfg(pipeline_blocks=depth, num_sweeps=6)).fit(coo)
        assert engine.host_metric_bytes == 6 * 3 * 4
        assert engine.host_blocked_s >= 0.0
