"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.kernels import ops, ref
from repro.kernels.bpmf_gram import bpmf_gram_pallas


def _case(rng, Ns, K, B, P):
    X = jnp.asarray(rng.normal(size=(Ns, K)), jnp.float32)
    nnz = jnp.asarray(rng.integers(0, P + 1, B), jnp.int32)
    nbr = jnp.asarray(rng.integers(0, Ns, (B, P)), jnp.int32)
    val = jnp.asarray(rng.normal(size=(B, P)), jnp.float32)
    mask = np.arange(P)[None] < np.asarray(nnz)[:, None]
    val = jnp.where(mask, val, 0.0)
    return X, nbr, val, nnz


SHAPES = [
    # (Ns, K, B, P) — sweep neighbor counts, shard sizes, item counts
    (16, 8, 1, 8),
    (64, 32, 13, 70),
    (128, 32, 8, 128),
    (100, 16, 5, 300),
    (256, 64, 4, 512),
    (32, 128, 3, 17),
    (300, 32, 2, 1024),
]


@pytest.mark.parametrize("Ns,K,B,P", SHAPES)
def test_gram_kernel_matches_ref_shapes(Ns, K, B, P):
    rng = np.random.default_rng(Ns * 1000 + K * 100 + B * 10 + P)
    X, nbr, val, nnz = _case(rng, Ns, K, B, P)
    G0, g0 = ref.bpmf_gram_ref(X, nbr, val, nnz)
    G1, g1 = ops.bpmf_gram(X, nbr, val, nnz, force_pallas=True)
    np.testing.assert_allclose(np.asarray(G0), np.asarray(G1), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("compute_dtype", [jnp.float32, jnp.bfloat16])
def test_gram_kernel_dtypes(compute_dtype):
    rng = np.random.default_rng(7)
    X, nbr, val, nnz = _case(rng, 64, 32, 9, 96)
    G0, g0 = ref.bpmf_gram_ref(X, nbr, val, nnz, compute_dtype=compute_dtype)
    G1, g1 = ops.bpmf_gram(X, nbr, val, nnz, compute_dtype=compute_dtype, force_pallas=True)
    tol = 1e-5 if compute_dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(G0), np.asarray(G1), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), rtol=tol, atol=tol)


@pytest.mark.parametrize("tb,pc", [(1, 128), (2, 128), (4, 256), (8, 512)])
def test_gram_kernel_tilings(tb, pc):
    """Different (TB, PC) tilings must be bit-identical math in f32."""
    rng = np.random.default_rng(tb * 31 + pc)
    B = tb * 3
    P = pc * 2
    X, nbr, val, nnz = _case(rng, 80, 32, B, P)
    G0, g0 = ref.bpmf_gram_ref(X, nbr, val, nnz)
    G1, g1 = bpmf_gram_pallas(X, nbr, val, nnz, tb=tb, pc=pc, interpret=True)
    # fp32 accumulation order differs between chunkings -> 1e-4 tolerance
    np.testing.assert_allclose(np.asarray(G0), np.asarray(G1), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), rtol=1e-4, atol=1e-4)


@given(
    Ns=st.integers(4, 80),
    K=st.sampled_from([4, 16, 32]),
    B=st.integers(1, 12),
    P=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_gram_kernel_property(Ns, K, B, P, seed):
    """Property sweep: arbitrary raggedness, duplicate neighbors, empty items."""
    rng = np.random.default_rng(seed)
    X, nbr, val, nnz = _case(rng, Ns, K, B, P)
    G0, g0 = ref.bpmf_gram_ref(X, nbr, val, nnz)
    G1, g1 = ops.bpmf_gram(X, nbr, val, nnz, force_pallas=True)
    np.testing.assert_allclose(np.asarray(G0), np.asarray(G1), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), rtol=2e-5, atol=2e-5)


def test_gram_kernel_G_is_psd_and_symmetric():
    rng = np.random.default_rng(3)
    X, nbr, val, nnz = _case(rng, 50, 16, 6, 64)
    G, _ = ops.bpmf_gram(X, nbr, val, nnz, force_pallas=True)
    Gn = np.asarray(G)
    np.testing.assert_allclose(Gn, np.swapaxes(Gn, -1, -2), atol=1e-5)
    for b in range(Gn.shape[0]):
        eig = np.linalg.eigvalsh(Gn[b])
        assert eig.min() >= -1e-4


def test_ops_fallback_large_shard():
    """When the shard exceeds the VMEM budget, ops falls back to the jnp path."""
    rng = np.random.default_rng(11)
    X, nbr, val, nnz = _case(rng, 200_000, 8, 4, 16)
    G0, g0 = ref.bpmf_gram_ref(X, nbr, val, nnz)
    G1, g1 = ops.bpmf_gram(X, nbr, val, nnz)  # auto dispatch
    np.testing.assert_allclose(np.asarray(G0), np.asarray(G1), rtol=1e-5, atol=1e-5)
