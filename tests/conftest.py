"""Shared pytest config + helpers for multi-device subprocess tests."""
import os
import re
import subprocess
import sys
import textwrap

import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running end-to-end test")
    config.addinivalue_line("markers", "multidevice: runs a subprocess with forced host devices")


def pytest_collection_modifyitems(config, items):
    """Every multidevice (subprocess) test is also ``slow``, so
    ``pytest -m "not slow"`` / ``scripts/test.sh -m "not slow"`` deselects
    the whole fresh-interpreter tier in one flag."""
    for item in items:
        if item.get_closest_marker("multidevice") and not item.get_closest_marker("slow"):
            item.add_marker(pytest.mark.slow)


def optional_hypothesis():
    """``(given, settings, st)`` — real hypothesis, or skipping stubs.

    hypothesis is an optional dependency: when it is missing, property
    tests are skipped (not errored at collection) and the rest of the
    module still runs. Usage in a test module::

        from conftest import optional_hypothesis
        given, settings, st = optional_hypothesis()
    """
    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st

        return given, settings, st
    except ModuleNotFoundError:
        skip = pytest.mark.skip(reason="hypothesis not installed")

        class _AnyStrategy:
            """Accepts any strategy construction; values are never drawn."""

            def __getattr__(self, name):
                return lambda *a, **k: None

        def given(*a, **k):
            return lambda fn: skip(fn)

        def settings(*a, **k):
            return lambda fn: fn

        return given, settings, _AnyStrategy()


def run_with_devices(code: str, num_devices: int, timeout: int = 600) -> str:
    """Run ``code`` in a fresh python with N forced host devices.

    The main test process keeps its device count (jax locks it at first
    backend init), so anything needing a different mesh runs out of
    process. Any inherited device-count flag is stripped so the requested
    count always wins. Raises on non-zero exit; returns stdout.
    """
    env = dict(os.environ)
    flags = re.sub(
        r"--xla_force_host_platform_device_count=\d+", "", env.get("XLA_FLAGS", "")
    ).strip()
    env["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={num_devices}"
    ).strip()
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
        )
    return proc.stdout
