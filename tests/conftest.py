"""Shared pytest config + helpers for multi-device subprocess tests."""
import os
import subprocess
import sys
import textwrap

import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running end-to-end test")
    config.addinivalue_line("markers", "multidevice: runs a subprocess with forced host devices")


def run_with_devices(code: str, num_devices: int, timeout: int = 600) -> str:
    """Run ``code`` in a fresh python with N forced host devices.

    The main test process keeps its single CPU device (jax locks the device
    count at first backend init), so anything multi-device runs out of
    process. Raises on non-zero exit; returns stdout.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + f" --xla_force_host_platform_device_count={num_devices}"
    ).strip()
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
        )
    return proc.stdout
