"""Attention unit + property tests: flash vs dense oracle, masks, caches."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.models.attention import (
    _attend_dense,
    _attend_flash,
    attention_mask,
    rolling_slot_positions,
)


def _rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


@settings(max_examples=20, deadline=None)
@given(
    lq=st.integers(1, 33),
    s_extra=st.integers(0, 20),
    kv=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 4]),
    causal=st.booleans(),
    window=st.sampled_from([None, 7, 16]),
    qc=st.sampled_from([4, 8, 64]),
    kc=st.sampled_from([4, 16, 64]),
)
def test_flash_matches_dense_property(lq, s_extra, kv, g, causal, window, qc, kc):
    """Property: the chunked two-level-scan attention equals the dense oracle
    for every (shape, mask, chunking) combination."""
    key = jax.random.key(lq * 1000 + s_extra * 31 + kv * 7 + g)
    B, H, dh = 2, kv * g, 8
    S = lq + s_extra
    ks = jax.random.split(key, 3)
    q = _rand(ks[0], B, lq, H, dh)
    k = _rand(ks[1], B, S, kv, dh)
    v = _rand(ks[2], B, S, kv, dh)
    q_pos = jnp.arange(s_extra, S, dtype=jnp.int32)
    kv_pos = jnp.arange(S, dtype=jnp.int32)

    mask = attention_mask(q_pos, kv_pos, causal, window)
    # guard: fully-masked rows are defined as 0 output in both paths
    ref = _attend_dense(q, k, v, mask, 0.3)
    out = _attend_flash(q, k, v, q_pos, kv_pos, causal, window, 0.3, qc, kc)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), atol=3e-5, rtol=3e-5)


def test_mask_semantics():
    q_pos = jnp.asarray([3, 4], jnp.int32)
    kv_pos = jnp.asarray([0, 1, 2, 3, 4, -1], jnp.int32)
    m = attention_mask(q_pos, kv_pos, causal=True, window=None)
    assert m.tolist() == [
        [True, True, True, True, False, False],
        [True, True, True, True, True, False],
    ]
    mw = attention_mask(q_pos, kv_pos, causal=True, window=2)
    assert mw.tolist() == [
        [False, False, True, True, False, False],
        [False, False, False, True, True, False],
    ]


def test_rolling_slot_positions():
    # window 4, next_pos 6: slots hold positions [4, 5, 2, 3]
    pos = rolling_slot_positions(jnp.asarray(6, jnp.int32), 4)
    assert pos.tolist() == [4, 5, 2, 3]
    # empty cache
    pos0 = rolling_slot_positions(jnp.asarray(0, jnp.int32), 4)
    assert pos0.tolist() == [-1, -1, -1, -1]
    # exactly full
    pos4 = rolling_slot_positions(jnp.asarray(4, jnp.int32), 4)
    assert pos4.tolist() == [0, 1, 2, 3]


def test_rolling_cache_decode_matches_full_attention():
    """SWA decode with a rolling W-slot cache == attention over the last W
    tokens of an unbounded cache."""
    from repro.configs import get_config
    from repro.models.model import build_model

    cfg = (
        get_config("mixtral-8x22b")
        .reduced()
        .replace(activation_dtype="float32", num_experts=0, mlp="swiglu")
    )
    W = cfg.sliding_window
    assert W is not None and W == 64
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    T = 100  # > window so the ring buffer wraps
    tokens = jax.random.randint(jax.random.key(1), (1, T), 0, cfg.vocab_size)

    full, _ = jax.jit(model.forward)(params, tokens)  # oracle (mask handles SWA)

    cache = model.init_cache(1, T)
    Lp = 8
    lg, cache = jax.jit(model.prefill)(params, tokens[:, :Lp], cache)
    decode = jax.jit(model.decode)
    outs = [lg[:, -1]]
    for t in range(Lp, T):
        lg, cache = decode(params, tokens[:, t : t + 1], cache, jnp.asarray([t], jnp.int32))
        outs.append(lg[:, -1])
    stepwise = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(stepwise[:, :-1]),
        np.asarray(full[:, Lp - 1 : -1]),
        atol=2e-3,
        rtol=2e-3,
    )


@pytest.mark.parametrize("q_lora", [0, 64])
def test_mla_flash_paths_match_dense(q_lora):
    from repro.configs import get_config
    from repro.models.attention import apply_mla, desc_attention
    from repro.models.module import init_params

    cfg = (
        get_config("minicpm3-4b")
        .reduced()
        .replace(activation_dtype="float32", q_lora_rank=q_lora)
    )
    params = init_params(jax.random.key(0), desc_attention(cfg))
    B, L = 2, 48
    x = _rand(jax.random.key(1), B, L, cfg.d_model)
    pos = jnp.arange(L, dtype=jnp.int32)

    dense, _ = apply_mla(params, x, pos, cfg)  # L=48 < chunks: dense
    flash, _ = apply_mla(params, x, pos, cfg.replace(attn_q_chunk=8, attn_kv_chunk=16))
    np.testing.assert_allclose(np.asarray(dense), np.asarray(flash), atol=5e-5, rtol=5e-5)
