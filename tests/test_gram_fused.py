"""Fused multi-bucket Gram kernel + autotune dispatch tests (DESIGN.md §8).

Bit-parity contract: with single-chunk buckets (``P <= pc``) the fused
kernel's per-item contribution is the *same* f32 dot the reference computes,
scattered as ``x + alpha*partial`` (exact for one contribution per item), so
``assert_array_equal`` holds. Multi-chunk rows (``P > pc``) accumulate chunk
partials in a different order than the single einsum and get tolerances.
"""
from __future__ import annotations

import functools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import Bucket
from repro.kernels import autotune, ops, ref
from repro.kernels.bpmf_gram import bpmf_gram_pallas, vmem_bytes_estimate


def _bucket(rng, Ns, B, P, cap, dead_rows=(), nnz=None):
    """Random bucket with distinct scatter rows in [0, cap) (-1 for dead)."""
    if nnz is None:
        nnz = rng.integers(0, P + 1, B).astype(np.int32)
    nbr = rng.integers(0, Ns, (B, P)).astype(np.int32)
    val = rng.normal(size=(B, P)).astype(np.float32)
    val[np.arange(P)[None, :] >= nnz[:, None]] = 0.0
    item_ids = rng.permutation(cap)[:B].astype(np.int32)
    item_ids[list(dead_rows)] = -1
    return Bucket(
        item_ids=jnp.asarray(item_ids),
        nbr=jnp.asarray(nbr),
        val=jnp.asarray(val),
        nnz=jnp.asarray(nnz),
    )


def _emulate_step(G, g, X, buckets, alpha):
    """NumPy oracle: scatter-add ref.bpmf_gram_ref per bucket into (G, g)."""
    Ge = np.array(G, np.float32).copy()
    ge = np.array(g, np.float32).copy()
    a = np.float32(alpha)
    for b in buckets:
        Gb, gb = ref.bpmf_gram_ref(X, b.nbr, b.val, b.nnz)
        ids = np.asarray(b.item_ids)
        for r in range(b.B):
            if ids[r] >= 0:
                Ge[ids[r]] += a * np.asarray(Gb)[r]
                ge[ids[r]] += a * np.asarray(gb)[r]
    return Ge, ge


def _accs(rng, cap, K):
    G = jnp.asarray(rng.normal(size=(cap, K, K)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(cap, K)), jnp.float32)
    return G, g


def _fused(G, g, X, buckets, alpha=2.0, **kw):
    return ops.bpmf_gram_step(
        G, g, X, tuple(buckets), alpha=alpha, gram_impl="pallas_fused", **kw
    )


# ---------- bit-parity edge shapes (single-chunk: P <= pc) ----------


def test_fused_bit_parity_multibucket_step():
    """Three buckets, one pallas_call, bit-identical to the ref scatter."""
    rng = np.random.default_rng(0)
    Ns, K, cap = 96, 16, 64
    X = jnp.asarray(rng.normal(size=(Ns, K)), jnp.float32)
    buckets = [_bucket(rng, Ns, 16, 8, cap), _bucket(rng, Ns, 9, 32, cap),
               _bucket(rng, Ns, 4, 128, cap)]
    G, g = _accs(rng, cap, K)
    Gf, gf = _fused(G, g, X, buckets)
    Ge, ge = _emulate_step(G, g, X, buckets, 2.0)
    np.testing.assert_array_equal(np.asarray(Gf), Ge)
    np.testing.assert_array_equal(np.asarray(gf), ge)


def test_fused_bit_parity_B_not_multiple_of_tb():
    """B=13 with tb=8: flatten pads with dead chunks; output is untouched."""
    rng = np.random.default_rng(1)
    Ns, K, cap = 64, 8, 24
    X = jnp.asarray(rng.normal(size=(Ns, K)), jnp.float32)
    buckets = [_bucket(rng, Ns, 13, 64, cap)]
    G, g = _accs(rng, cap, K)
    Gf, gf = _fused(G, g, X, buckets, tb=8, pc=128)
    Ge, ge = _emulate_step(G, g, X, buckets, 2.0)
    np.testing.assert_array_equal(np.asarray(Gf), Ge)
    np.testing.assert_array_equal(np.asarray(gf), ge)


def test_fused_bit_parity_all_padding_bucket():
    """A bucket with nnz == 0 everywhere contributes exact zeros."""
    rng = np.random.default_rng(2)
    Ns, K, cap = 32, 8, 16
    X = jnp.asarray(rng.normal(size=(Ns, K)), jnp.float32)
    empty = _bucket(rng, Ns, 8, 16, cap, nnz=np.zeros(8, np.int32))
    live = _bucket(rng, Ns, 8, 16, cap)
    G, g = _accs(rng, cap, K)
    Gf, gf = _fused(G, g, X, [empty, live])
    Ge, ge = _emulate_step(G, g, X, [empty, live], 2.0)
    np.testing.assert_array_equal(np.asarray(Gf), Ge)
    np.testing.assert_array_equal(np.asarray(gf), ge)
    # the empty bucket alone must leave (G, g) bitwise untouched
    G2, g2 = _fused(G, g, X, [empty])
    np.testing.assert_array_equal(np.asarray(G2), np.asarray(G))
    np.testing.assert_array_equal(np.asarray(g2), np.asarray(g))


def test_fused_bit_parity_item_ids_minus_one_dropped():
    rng = np.random.default_rng(3)
    Ns, K, cap = 48, 16, 20
    X = jnp.asarray(rng.normal(size=(Ns, K)), jnp.float32)
    buckets = [_bucket(rng, Ns, 10, 32, cap, dead_rows=(0, 3, 9))]
    G, g = _accs(rng, cap, K)
    Gf, gf = _fused(G, g, X, buckets)
    Ge, ge = _emulate_step(G, g, X, buckets, 2.0)
    np.testing.assert_array_equal(np.asarray(Gf), Ge)
    np.testing.assert_array_equal(np.asarray(gf), ge)


def test_fused_bit_parity_ns_chunked():
    """Streaming the shard in ns_chunk slices is exact: every neighbor hits
    one chunk, all other chunks add exact zeros to the gather accumulator."""
    rng = np.random.default_rng(4)
    Ns, K, cap = 96, 16, 32
    X = jnp.asarray(rng.normal(size=(Ns, K)), jnp.float32)
    buckets = [_bucket(rng, Ns, 8, 64, cap), _bucket(rng, Ns, 8, 16, cap)]
    G, g = _accs(rng, cap, K)
    Gr, gr = _fused(G, g, X, buckets)  # resident shard
    Gc, gc = _fused(G, g, X, buckets, ns_chunk=32)  # 3 slices
    np.testing.assert_array_equal(np.asarray(Gr), np.asarray(Gc))
    np.testing.assert_array_equal(np.asarray(gr), np.asarray(gc))
    Ge, ge = _emulate_step(G, g, X, buckets, 2.0)
    np.testing.assert_array_equal(np.asarray(Gc), Ge)


def test_fused_multichunk_rows_tolerance():
    """P > pc accumulates chunk partials; order differs from one einsum."""
    rng = np.random.default_rng(5)
    Ns, K, cap = 64, 16, 16
    X = jnp.asarray(rng.normal(size=(Ns, K)), jnp.float32)
    buckets = [_bucket(rng, Ns, 8, 300, cap)]
    G, g = _accs(rng, cap, K)
    Gf, gf = _fused(G, g, X, buckets, pc=128)
    Ge, ge = _emulate_step(G, g, X, buckets, 2.0)
    np.testing.assert_allclose(np.asarray(Gf), Ge, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gf), ge, rtol=1e-4, atol=1e-4)


# ---------- per-bucket kernel: Ns streaming + large-P tiling ----------


def test_per_bucket_kernel_ns_chunked_bit_identical():
    rng = np.random.default_rng(6)
    Ns, K, B, P = 96, 16, 8, 64
    X = jnp.asarray(rng.normal(size=(Ns, K)), jnp.float32)
    b = _bucket(rng, Ns, B, P, cap=B)
    G0, g0 = bpmf_gram_pallas(X, b.nbr, b.val, b.nnz, tb=4, pc=64, interpret=True)
    G1, g1 = bpmf_gram_pallas(
        X, b.nbr, b.val, b.nnz, tb=4, pc=64, ns_chunk=32, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(G0), np.asarray(G1))
    np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))


def test_ops_bpmf_gram_explicit_ns_chunk_matches_ref():
    rng = np.random.default_rng(7)
    Ns, K, B, P = 100, 8, 5, 40  # Ns not a multiple: ops pads the shard
    X = jnp.asarray(rng.normal(size=(Ns, K)), jnp.float32)
    b = _bucket(rng, Ns, B, P, cap=B)
    G0, g0 = ref.bpmf_gram_ref(X, b.nbr, b.val, b.nnz)
    G1, g1 = ops.bpmf_gram(
        X, b.nbr, b.val, b.nnz, impl="pallas", ns_chunk=32
    )
    np.testing.assert_allclose(np.asarray(G0), np.asarray(G1), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), rtol=1e-5, atol=1e-5)


def test_pick_tiling_large_P_fits_budget():
    """Satellite fix: the VMEM estimate must reflect the real block shapes.

    Pre-restructure, nbr/val blocks padded to the full P while the estimate
    capped P at 4096, so P > 4096 could select an overflowing tiling. The P
    axis is a grid dimension now — blocks are (tb, pc) — and the chosen
    tiling's estimate must fit the budget for any P.
    """
    for P in (4096, 8192, 32768, 1 << 20):
        tiling = ops.pick_tiling(8, P, 2048, 32)
        assert tiling is not None, P
        tb, pc = tiling
        assert vmem_bytes_estimate(tb, pc, 2048, 32) <= ops._VMEM_BUDGET


def test_per_bucket_kernel_beyond_old_P_cap_matches_ref():
    """P just above the old 4096 estimate cap still runs and agrees."""
    rng = np.random.default_rng(8)
    Ns, K, B, P = 32, 8, 2, 4224
    X = jnp.asarray(rng.normal(size=(Ns, K)), jnp.float32)
    b = _bucket(rng, Ns, B, P, cap=B)
    G0, g0 = ref.bpmf_gram_ref(X, b.nbr, b.val, b.nnz)
    G1, g1 = ops.bpmf_gram(X, b.nbr, b.val, b.nnz, force_pallas=True)
    np.testing.assert_allclose(np.asarray(G0), np.asarray(G1), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), rtol=1e-4, atol=1e-4)


# ---------- autotune: cache, heuristic, dispatch ----------


@pytest.fixture
def tmp_cache(tmp_path):
    cache = autotune.AutotuneCache(str(tmp_path / "gram.json"))
    autotune.set_cache(cache)
    yield cache
    autotune.set_cache(None)


def test_autotune_cache_roundtrip(tmp_cache):
    key = autotune.step_key([(16, 32), (8, 128)], 96, 16, 64)
    dec = autotune.Decision("pallas_fused", 8, 128, 32)
    tmp_cache.record(key, dec, timings_us={"xla": 10.0, "pallas_fused_tb8_pc128": 5.0})
    reloaded = autotune.AutotuneCache(tmp_cache.path)
    assert reloaded.lookup(key) == dec
    raw = json.load(open(tmp_cache.path))
    assert raw["version"] == 1 and key.encode() in raw["entries"]


def test_autotune_decide_prefers_cache_over_heuristic(tmp_cache):
    key = autotune.step_key([(8, 8)], 32, 8, 8)
    assert autotune.decide(key).impl == "xla"  # CPU heuristic: never Pallas
    tmp_cache.record(key, autotune.Decision("pallas_fused", 8, 128, None))
    assert autotune.decide(key) == autotune.Decision("pallas_fused", 8, 128, None)


def test_autotune_heuristic_off_tpu_is_xla():
    for kind in ("bucket", "step"):
        key = autotune.ShapeKey(kind, 64, 128, 256, 32, "float32", "cpu", cap=64)
        assert autotune.heuristic(key) == autotune.Decision("xla")


def test_autotune_heuristic_tpu_decision_tree():
    """On TPU: fused for step keys / per-bucket for bucket keys when the
    shard fits; ns-streaming when it doesn't; xla when the cost model says
    the one-hot gather loses (huge Ns/K ratio)."""
    step = autotune.ShapeKey("step", 64, 128, 512, 32, "float32", "tpu", cap=64)
    d = autotune.heuristic(step)
    assert d.impl == "pallas_fused" and d.tb and d.pc and d.ns_chunk is None
    bucket = autotune.ShapeKey("bucket", 64, 128, 512, 32, "float32", "tpu")
    assert autotune.heuristic(bucket).impl == "pallas"
    big = autotune.ShapeKey("step", 64, 128, 400_000, 128, "float32", "tpu", cap=64)
    d = autotune.heuristic(big)
    assert d.impl in ("pallas_fused", "pallas", "xla")
    if d.impl != "xla":  # streaming decision must carry a chunk size
        assert d.ns_chunk is not None and d.ns_chunk < 400_000
    # a scatter capacity too large for the fused accumulator windows
    # degrades to the per-bucket kernel, not straight to xla
    huge_cap = autotune.ShapeKey("step", 64, 128, 512, 32, "float32", "tpu", cap=8192)
    d = autotune.heuristic(huge_cap)
    assert d.impl == "pallas" and d.tb and d.pc
    huge_ratio = autotune.ShapeKey("bucket", 64, 2048, 1 << 22, 4, "float32", "tpu")
    assert autotune.heuristic(huge_ratio).impl == "xla"


def test_autotune_malformed_cache_ignored(tmp_path):
    path = tmp_path / "gram.json"
    path.write_text("{not json")
    cache = autotune.AutotuneCache(str(path))
    assert cache.lookup(autotune.bucket_key(8, 8, 32, 8)) is None
    path.write_text(json.dumps({"version": 999, "entries": {"x": {"impl": "pallas"}}}))
    assert autotune.AutotuneCache(str(path)).entries() == {}


def _iter_subjaxprs(v):
    from jax.core import ClosedJaxpr, Jaxpr

    if isinstance(v, ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _iter_subjaxprs(x)


def _count_pallas_calls(jaxpr) -> int:
    """pallas_call eqns per invocation path (jit dedup-safe, unlike str())."""
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            total += 1
        for v in eqn.params.values():
            for sub in _iter_subjaxprs(v):
                total += _count_pallas_calls(sub)
    return total


def test_warm_cache_auto_issues_single_pallas_call_per_step(tmp_cache):
    """Acceptance: gram_impl="auto" + warm cache -> exactly one pallas_call
    per ring step (no per-bucket dispatch), verified on the jaxpr."""
    rng = np.random.default_rng(9)
    Ns, K, cap = 64, 8, 40
    X = jnp.asarray(rng.normal(size=(Ns, K)), jnp.float32)
    buckets = tuple(
        [_bucket(rng, Ns, 16, 8, cap), _bucket(rng, Ns, 8, 32, cap),
         _bucket(rng, Ns, 8, 64, cap)]
    )
    G, g = _accs(rng, cap, K)
    key = autotune.step_key([(b.B, b.P) for b in buckets], Ns, K, cap, jnp.float32)
    tmp_cache.record(key, autotune.Decision("pallas_fused", 8, 128, None))

    def trace(impl):
        fn = functools.partial(
            ops.bpmf_gram_step, alpha=2.0, gram_impl=impl
        )
        closed = jax.make_jaxpr(lambda G, g, X: fn(G, g, X, buckets))(G, g, X)
        return _count_pallas_calls(closed.jaxpr)

    assert trace("auto") == 1
    assert trace("pallas") == len(buckets)
    assert trace("xla") == 0
    # and the warm-cache auto result equals the xla result bitwise here
    Ga, ga = ops.bpmf_gram_step(G, g, X, buckets, alpha=2.0, gram_impl="auto")
    Gx, gx = ops.bpmf_gram_step(G, g, X, buckets, alpha=2.0, gram_impl="xla")
    np.testing.assert_array_equal(np.asarray(Ga), np.asarray(Gx))
    np.testing.assert_array_equal(np.asarray(ga), np.asarray(gx))


def test_workload_keys_engage_in_engine_trace(tmp_cache):
    """Keys from autotune.workload_step_keys are EXACTLY the keys
    ops.bpmf_gram_step builds inside the shard_map trace: warming the cache
    for a workload routes the real distributed sweep through the fused
    kernel (one pallas_call per ring step), with samples unchanged."""
    import functools

    from repro.bpmf import load_dataset
    from repro.core import distributed as dist
    from repro.core.prediction import PredictionState
    from repro.core.types import BPMFConfig as CoreConfig

    K = 6
    coo = load_dataset("synthetic", num_users=40, num_movies=30, nnz=400, seed=0)
    data, _ = dist.build_distributed_data(coo, num_shards=1)
    keys = autotune.workload_step_keys(data, K)
    num_steps = len(keys)  # S=1: one step per side
    for key, _shapes in keys:
        tmp_cache.record(key, autotune.Decision("pallas_fused", 8, 128, None))

    mesh = dist.make_ring_mesh(jax.devices()[:1])
    data = dist.shard_data(data, mesh)
    cfg = CoreConfig(K=K, comm_mode="ring", gram_impl="auto")
    state = dist.init_dist_state(jax.random.key(0), data, cfg, mesh)
    pred = PredictionState.init(int(data.test.rows.shape[0]))

    def sweep(cfg):
        fn = functools.partial(dist.dist_gibbs_sweep, cfg=cfg, mesh=mesh)
        return jax.make_jaxpr(fn)(jax.random.key(1), state, pred, data)

    assert _count_pallas_calls(sweep(cfg).jaxpr) == num_steps
    # cold cache (different dtype key) on CPU: pure XLA sweep
    cold = CoreConfig(K=K, comm_mode="ring", gram_impl="xla")
    assert _count_pallas_calls(sweep(cold).jaxpr) == 0
    # and the fused-dispatched sweep draws the same samples
    s1, p1, _ = dist.dist_gibbs_sweep(jax.random.key(1), state, pred, data, cfg, mesh)
    s2, p2, _ = dist.dist_gibbs_sweep(jax.random.key(1), state, pred, data, cold, mesh)
    np.testing.assert_array_equal(np.asarray(s1.U), np.asarray(s2.U))
    np.testing.assert_array_equal(np.asarray(s1.V), np.asarray(s2.V))


def test_cold_cache_auto_on_cpu_is_xla(tmp_cache):
    """No cache entry + CPU heuristic -> pure XLA step (CI never pays
    interpret-mode Pallas by default)."""
    rng = np.random.default_rng(10)
    Ns, K, cap = 32, 8, 16
    X = jnp.asarray(rng.normal(size=(Ns, K)), jnp.float32)
    buckets = (_bucket(rng, Ns, 8, 16, cap),)
    G, g = _accs(rng, cap, K)
    fn = functools.partial(ops.bpmf_gram_step, alpha=2.0, gram_impl="auto")
    closed = jax.make_jaxpr(lambda G, g, X: fn(G, g, X, buckets))(G, g, X)
    assert _count_pallas_calls(closed.jaxpr) == 0


def test_warm_bucket_cache_mixes_impls_within_step(tmp_cache):
    """Per-bucket-class keys: with no step-key entry, a warmed bucket cache
    routes each pad class independently — here the (16, 8) class through the
    Pallas kernel while the (8, 32) class falls to the CPU heuristic (XLA) —
    so ONE traced step mixes impls (exactly one pallas_call), and the mixed
    step agrees numerically with the pure-XLA step."""
    rng = np.random.default_rng(11)
    Ns, K, cap = 64, 8, 40
    X = jnp.asarray(rng.normal(size=(Ns, K)), jnp.float32)
    buckets = (_bucket(rng, Ns, 16, 8, cap), _bucket(rng, Ns, 8, 32, cap))
    G, g = _accs(rng, cap, K)
    tmp_cache.record(
        autotune.bucket_key(16, 8, Ns, K), autotune.Decision("pallas", 8, 128, None)
    )
    fn = functools.partial(ops.bpmf_gram_step, alpha=2.0, gram_impl="auto")
    closed = jax.make_jaxpr(lambda G, g, X: fn(G, g, X, buckets))(G, g, X)
    assert _count_pallas_calls(closed.jaxpr) == 1
    Gm, gm = ops.bpmf_gram_step(G, g, X, buckets, alpha=2.0, gram_impl="auto")
    Gx, gx = ops.bpmf_gram_step(G, g, X, buckets, alpha=2.0, gram_impl="xla")
    np.testing.assert_allclose(np.asarray(Gm), np.asarray(Gx), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gm), np.asarray(gx), rtol=1e-4, atol=1e-4)
    # an exact *step*-key entry still pins the whole step, overriding the
    # bucket entries — measured measure_step decisions keep their meaning
    skey = autotune.step_key([(b.B, b.P) for b in buckets], Ns, K, cap, jnp.float32)
    tmp_cache.record(skey, autotune.Decision("xla"))
    closed = jax.make_jaxpr(lambda G, g, X: fn(G, g, X, buckets))(G, g, X)
    assert _count_pallas_calls(closed.jaxpr) == 0
