"""Statistical regression harness for the posterior across all backends.

One seeded synthetic reference task (150 x 80, nnz=4000, noise_std=0.3,
data seed 7 — also the workload of ``benchmarks/fig_merge_comm.py``),
tier-1 fast and hypothesis-free. Per full-data backend (sequential / ring
/ allgather / ring_async):

1. the posterior-predictive RMSE beats the column-mean baseline — the
   sampler must extract low-rank structure, not just the per-movie bias;
2. the RMSE sits inside a recorded tolerance band, so silent numerical
   regressions (a broken prior update, a dropped burn-in gate) fail loudly
   rather than drifting — failures print the observed value next to the
   recorded band;
3. served predictions (export -> PosteriorPredictor) agree with
   ``engine.predict()`` on a held-out batch to fp tolerance — the
   acceptance bar for the serving round-trip.

The limited-communication ``posterior_merge`` backend gets its own gates,
on the *merged artifact* (its per-chain engine RMSE is not the claim):

4. the merged artifact beats the column-mean baseline with real margin
   and lands inside the recorded per-partition-count band
   (:data:`repro.core.subset_merge.MERGE_RMSE_BAND`);
5. partitioning degrades RMSE by at most the recorded bound vs the
   full-data sequential chain's artifact
   (:data:`repro.core.subset_merge.MERGE_DEGRADATION_MAX`);
6. the merge is stable across sampler seeds (spread bound, every seed
   inside the band);
7. posterior-width sanity: the predictive std must roughly calibrate the
   held-out residuals — rms of z = (y - mean) / sqrt(std^2 + 1/alpha)
   inside a recorded band, for sequential and for the merged posterior.
   Overconfident subset posteriors (a classic consensus-MC failure mode)
   push rms(z) up and fail loudly.

The runs execute in-process on whatever device count the main process has
(scripts/test.sh forces 8); the recorded bands carry the cross-backend /
cross-mesh reduction-order slack observed in the parity tests.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.bpmf import BPMFConfig, BPMFEngine, load_dataset
from repro.core import subset_merge
from repro.data.sparse import train_test_split

BACKENDS = ("sequential", "ring", "allgather", "ring_async")

# recorded on the seeded problem below (identical at 1 and 8 host devices);
# the band is ~25x wider than the observed cross-backend spread (<=1e-3)
RMSE_BAND = (0.70, 0.82)
_RECORDED_RMSE = 0.7602  # for the failure message

MERGE_PARTITIONS = (2, 4)
# cross-seed artifact-RMSE spread bound for the merged posterior (recorded
# spread 0.067 at P=2 over seeds 0..2; the bound leaves ~2x headroom)
MERGE_SEED_SPREAD_MAX = 0.15

# recorded rms(z) of held-out residuals standardized by the predictive
# std (z = (y - mean) / sqrt(std^2 + 1/alpha)); 1.0 = perfectly
# calibrated. Recorded on the reference task: sequential 0.97,
# merged posterior 1.03 at P=2 (1.15 at P=4) — the merge is mildly
# overconfident (fewer effective samples per item + precision-product
# narrowing), and a real posterior collapse would blow far past the hi.
CALIBRATION_RMS_Z_BAND = {
    "sequential": (0.75, 1.25),
    "posterior_merge": (0.80, 1.45),
}


def _cfg(**kw) -> BPMFConfig:
    base = dict(
        K=8, num_sweeps=10, burn_in=3, bucket_pads=(8, 32, 128),
        keep_factor_samples=4,
    )
    base.update(kw)
    return BPMFConfig().replace(**base)


def _coo():
    return load_dataset(
        "synthetic", num_users=150, num_movies=80, nnz=4000, noise_std=0.3, seed=7
    )


def _heldout(coo, cfg):
    """The engine's own held-out split for this config."""
    _, test = train_test_split(coo, cfg.run.test_fraction, cfg.run.seed)
    return test


def _artifact_rmse(engine, test) -> float:
    """RMSE of the exported predictor (merged posterior for posterior_merge)
    over the held-out points."""
    preds = engine.predict(test.rows, test.cols)
    return float(np.sqrt(np.mean((preds - test.vals) ** 2)))


@pytest.fixture(scope="module")
def sequential_reference():
    """One full-data sequential fit shared by the merge gates:
    (artifact RMSE, baseline RMSE) on the reference task."""
    coo = _coo()
    cfg = _cfg(name="sequential")
    engine = BPMFEngine(cfg).fit(coo)
    baseline = subset_merge.column_mean_rmse(
        coo, cfg.run.test_fraction, cfg.run.seed
    )
    return _artifact_rmse(engine, _heldout(coo, cfg)), baseline


# --------------------------------------------------------------------------
# full-data backends
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name", BACKENDS)
def test_posterior_quality_and_serving_agreement(tmp_path, name):
    coo = _coo()
    cfg = _cfg(name=name)
    engine = BPMFEngine(cfg).fit(coo)
    baseline = subset_merge.column_mean_rmse(
        coo, cfg.run.test_fraction, cfg.run.seed
    )
    test = _heldout(coo, cfg)

    # 1. beats the column-mean baseline with real margin
    assert engine.rmse < 0.95 * baseline, (
        f"{name}: posterior-predictive RMSE {engine.rmse:.4f} does not beat "
        f"the column-mean baseline {baseline:.4f}"
    )

    # 2. inside the recorded tolerance band
    lo, hi = RMSE_BAND
    assert lo < engine.rmse < hi, (
        f"{name}: observed RMSE {engine.rmse:.4f} left the recorded band "
        f"[{lo}, {hi}] (recorded {_RECORDED_RMSE})"
    )

    # 3. served == in-process on a held-out batch (acceptance: <= 1e-6)
    artifact = engine.export(str(tmp_path / name))
    from repro.serve import PosteriorPredictor

    served = PosteriorPredictor.load(artifact).predict(test.rows, test.cols)
    want = engine.predict(test.rows, test.cols)
    np.testing.assert_allclose(served, want, atol=1e-6, rtol=0)
    # same jitted program + bit-identical round-tripped arrays: exact
    np.testing.assert_array_equal(served, want)


def test_backends_agree_on_final_rmse():
    """The band is shared across backends because the samplers agree; pin
    that premise so a single-backend drift can't hide inside the band."""
    coo = _coo()
    rmses = {n: BPMFEngine(_cfg(name=n)).fit(coo).rmse for n in BACKENDS}
    spread = max(rmses.values()) - min(rmses.values())
    assert spread < 1e-3, rmses


# --------------------------------------------------------------------------
# posterior_merge: merged-artifact quality gates
# --------------------------------------------------------------------------


@pytest.mark.parametrize("num_partitions", MERGE_PARTITIONS)
def test_posterior_merge_quality(tmp_path, num_partitions, sequential_reference):
    """Gates 4 + 5 + the serving round-trip, per partition count."""
    seq_artifact_rmse, baseline = sequential_reference
    coo = _coo()
    cfg = _cfg(name="posterior_merge", num_partitions=num_partitions)
    engine = BPMFEngine(cfg).fit(coo)
    test = _heldout(coo, cfg)
    observed = _artifact_rmse(engine, test)

    # 4a. the merged artifact beats the column-mean baseline with margin
    assert observed < 0.95 * baseline, (
        f"posterior_merge P={num_partitions}: merged-artifact RMSE "
        f"{observed:.4f} does not beat 0.95 x column-mean baseline "
        f"({baseline:.4f})"
    )

    # 4b. inside the recorded per-partition-count band
    lo, hi = subset_merge.MERGE_RMSE_BAND[num_partitions]
    assert lo < observed < hi, (
        f"posterior_merge P={num_partitions}: observed merged-artifact RMSE "
        f"{observed:.4f} left the recorded band [{lo}, {hi}]"
    )

    # 5. bounded degradation vs the full-data sequential chain
    degradation = observed - seq_artifact_rmse
    bound = subset_merge.MERGE_DEGRADATION_MAX[num_partitions]
    assert degradation <= bound, (
        f"posterior_merge P={num_partitions}: merged-artifact RMSE "
        f"{observed:.4f} degrades {degradation:.4f} over the sequential "
        f"artifact ({seq_artifact_rmse:.4f}); recorded bound {bound}"
    )

    # the existing export/serve surface consumes the merged artifact
    # unchanged: served == in-process, exactly
    artifact = engine.export(str(tmp_path / f"merge_p{num_partitions}"))
    from repro.serve import PosteriorPredictor

    served = PosteriorPredictor.load(artifact).predict(test.rows, test.cols)
    np.testing.assert_array_equal(served, engine.predict(test.rows, test.cols))


def test_posterior_merge_cross_seed_stability(sequential_reference):
    """Gate 6: the merge must not be a lucky seed — artifact RMSE across
    sampler seeds stays inside the band with bounded spread."""
    _, baseline = sequential_reference
    coo = _coo()
    observed = []
    for seed in (0, 1, 2):
        cfg = _cfg(name="posterior_merge", num_partitions=2, seed=seed)
        engine = BPMFEngine(cfg).fit(coo)
        observed.append(_artifact_rmse(engine, _heldout(coo, cfg)))
    lo, hi = subset_merge.MERGE_RMSE_BAND[2]
    spread = max(observed) - min(observed)
    assert spread < MERGE_SEED_SPREAD_MAX, (
        f"posterior_merge P=2: cross-seed artifact RMSE spread {spread:.4f} "
        f"exceeds {MERGE_SEED_SPREAD_MAX} (observed "
        f"{[f'{r:.4f}' for r in observed]})"
    )
    for seed, r in enumerate(observed):
        assert lo < r < hi and r < baseline, (
            f"posterior_merge P=2 seed {seed}: observed artifact RMSE "
            f"{r:.4f} left the recorded band [{lo}, {hi}] "
            f"(baseline {baseline:.4f})"
        )


@pytest.mark.parametrize(
    "name,num_partitions", [("sequential", 0), ("posterior_merge", 2)]
)
def test_predictive_std_calibration(name, num_partitions):
    """Gate 7: posterior-width sanity on data with known noise. The
    synthetic generator adds N(0, 0.3^2) observation noise; if the
    posterior widths are sane, standardized held-out residuals
    z = (y - mean) / sqrt(std^2 + 1/alpha) have rms near 1. A collapsed
    posterior (std -> 0) or an overconfident merge inflates rms(z) far
    past the recorded band; an inflated posterior deflates it."""
    coo = _coo()
    cfg = _cfg(name=name, num_partitions=num_partitions)
    engine = BPMFEngine(cfg).fit(coo)
    test = _heldout(coo, cfg)
    preds, std = engine.predict(test.rows, test.cols, return_std=True)
    z = (test.vals - preds) / np.sqrt(std**2 + 1.0 / engine.cfg.model.alpha)
    rms_z = float(np.sqrt(np.mean(z**2)))
    lo, hi = CALIBRATION_RMS_Z_BAND[name]
    assert lo < rms_z < hi, (
        f"{name}: observed rms(z) {rms_z:.4f} left the recorded calibration "
        f"band [{lo}, {hi}] (mean predictive std {float(std.mean()):.4f}, "
        f"noise_std 0.3, 1/sqrt(alpha) {1.0 / np.sqrt(engine.cfg.model.alpha):.4f})"
    )
