"""Statistical regression harness for the posterior across all backends.

Three invariants per backend (sequential / ring / allgather / ring_async),
on one seeded synthetic problem, tier-1 fast and hypothesis-free:

1. the posterior-predictive RMSE beats the column-mean baseline — the
   sampler must extract low-rank structure, not just the per-movie bias;
2. the RMSE sits inside a recorded tolerance band, so silent numerical
   regressions (a broken prior update, a dropped burn-in gate) fail loudly
   rather than drifting;
3. served predictions (export -> PosteriorPredictor) agree with
   ``engine.predict()`` on a held-out batch to fp tolerance — the
   acceptance bar for the serving round-trip.

The runs execute in-process on whatever device count the main process has
(scripts/test.sh forces 8); the recorded band carries the cross-backend /
cross-mesh reduction-order slack observed in the parity tests.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.bpmf import BPMFConfig, BPMFEngine, load_dataset
from repro.data.sparse import train_test_split

BACKENDS = ("sequential", "ring", "allgather", "ring_async")

# recorded on the seeded problem below (identical at 1 and 8 host devices);
# the band is ~25x wider than the observed cross-backend spread (<=1e-3)
RMSE_BAND = (0.70, 0.82)
_RECORDED_RMSE = 0.7602  # for the failure message


def _cfg(**kw) -> BPMFConfig:
    base = dict(
        K=8, num_sweeps=10, burn_in=3, bucket_pads=(8, 32, 128),
        keep_factor_samples=4,
    )
    base.update(kw)
    return BPMFConfig().replace(**base)


def _coo():
    return load_dataset(
        "synthetic", num_users=150, num_movies=80, nnz=4000, noise_std=0.3, seed=7
    )


def _column_mean_baseline(coo, cfg) -> tuple[float, np.ndarray, np.ndarray]:
    """(baseline RMSE, test rows, test cols) on the engine's own split."""
    train, test = train_test_split(coo, cfg.run.test_fraction, cfg.run.seed)
    gmean = float(train.vals.mean())
    col_sum = np.zeros(coo.num_movies)
    col_cnt = np.zeros(coo.num_movies)
    np.add.at(col_sum, train.cols, train.vals.astype(np.float64))
    np.add.at(col_cnt, train.cols, 1)
    col_mean = np.where(col_cnt > 0, col_sum / np.maximum(col_cnt, 1), gmean)
    rmse = float(np.sqrt(np.mean((col_mean[test.cols] - test.vals) ** 2)))
    return rmse, test.rows, test.cols


@pytest.mark.parametrize("name", BACKENDS)
def test_posterior_quality_and_serving_agreement(tmp_path, name):
    coo = _coo()
    cfg = _cfg(name=name)
    engine = BPMFEngine(cfg).fit(coo)
    baseline, rows, cols = _column_mean_baseline(coo, cfg)

    # 1. beats the column-mean baseline with real margin
    assert engine.rmse < 0.95 * baseline, (
        f"{name}: posterior-predictive RMSE {engine.rmse:.4f} does not beat "
        f"the column-mean baseline {baseline:.4f}"
    )

    # 2. inside the recorded tolerance band
    lo, hi = RMSE_BAND
    assert lo < engine.rmse < hi, (
        f"{name}: RMSE {engine.rmse:.4f} left the recorded band "
        f"[{lo}, {hi}] (recorded {_RECORDED_RMSE})"
    )

    # 3. served == in-process on a held-out batch (acceptance: <= 1e-6)
    artifact = engine.export(str(tmp_path / name))
    from repro.serve import PosteriorPredictor

    served = PosteriorPredictor.load(artifact).predict(rows, cols)
    want = engine.predict(rows, cols)
    np.testing.assert_allclose(served, want, atol=1e-6, rtol=0)
    # same jitted program + bit-identical round-tripped arrays: exact
    np.testing.assert_array_equal(served, want)


def test_backends_agree_on_final_rmse():
    """The band is shared across backends because the samplers agree; pin
    that premise so a single-backend drift can't hide inside the band."""
    coo = _coo()
    rmses = {n: BPMFEngine(_cfg(name=n)).fit(coo).rmse for n in BACKENDS}
    spread = max(rmses.values()) - min(rmses.values())
    assert spread < 1e-3, rmses
