"""Elastic runtime: failure injection, restart layout policy, watchdog."""
from __future__ import annotations

import numpy as np
import pytest

from repro.runtime.elastic import FailureInjector, NodeFailure, RestartPolicy, StepTimer


def test_step_timer_flags_stragglers():
    t = StepTimer(window=10, threshold=2.0)
    for i in range(8):
        t.record(i, 0.1)
    assert t.record(8, 0.5) is True
    assert t.straggler_steps == [8]
    assert t.record(9, 0.1) is False


def test_failure_injector():
    inj = FailureInjector({3: 2})
    inj.check(2)
    try:
        inj.check(3)
        assert False, "should have raised"
    except NodeFailure as e:
        assert e.lost_devices == 2
    inj.check(3)  # consumed — does not re-fire


def test_restart_policy_preserves_global_device_count():
    pol = RestartPolicy(total_devices=8, max_restarts=3)
    # 4 procs x 2 dev -> 2 procs x 4 dev (3 does not divide 8)
    assert pol.next_layout(4) == (2, 4)
    assert pol.next_layout(2) == (1, 8)
    # every layout re-splits the same 8 global devices
    assert pol.restarts_done == 2


def test_restart_policy_budget_and_floor():
    pol = RestartPolicy(total_devices=4, max_restarts=1)
    assert pol.next_layout(2) == (1, 4)
    assert pol.next_layout(1) is None  # budget spent

    pol = RestartPolicy(total_devices=4, min_processes=2, max_restarts=5)
    assert pol.next_layout(2) is None  # below the process floor

    pol = RestartPolicy(total_devices=7, max_restarts=5)
    assert pol.next_layout(7) == (1, 7)  # only 1 divides 7


def test_restart_policy_restart_continues_bitwise(tmp_path):
    """Kill a run mid-stream; restore from the last committed checkpoint and
    finish — samples must match an uninterrupted run (the in-process half of
    the elastic story; the cross-process-count half is tests/test_multiproc)."""
    from repro.bpmf import BPMFConfig, BPMFEngine
    from repro.data.synthetic import SyntheticSpec, synthetic_ratings

    coo, _ = synthetic_ratings(
        SyntheticSpec(num_users=48, num_movies=32, nnz=600, discretize=False)
    )

    def cfg(ckdir):
        return BPMFConfig().replace(
            name="sequential", K=4, num_sweeps=6, burn_in=2,
            sweeps_per_block=1, checkpoint_dir=str(ckdir),
            checkpoint_every=2, async_checkpoint_writes=False,
        )

    ref = BPMFEngine(cfg(tmp_path / "ref"))
    ref.prepare(coo)
    for _ in ref.sample():
        pass
    U_ref, V_ref = ref.factors()

    inj = FailureInjector({4: 1})
    eng = BPMFEngine(cfg(tmp_path / "elastic"))
    eng.prepare(coo)
    with pytest.raises(NodeFailure):
        for m in eng.sample():
            inj.check(int(m.sweep))

    eng2 = BPMFEngine(cfg(tmp_path / "elastic"))
    eng2.prepare(coo)
    resumed = eng2.restore()
    assert 0 < resumed < 6
    for _ in eng2.sample():
        pass
    U, V = eng2.factors()
    np.testing.assert_array_equal(np.asarray(U), np.asarray(U_ref))
    np.testing.assert_array_equal(np.asarray(V), np.asarray(V_ref))
