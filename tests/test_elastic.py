"""Elastic runtime: failure injection -> mesh shrink -> restore -> continue."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.runtime.elastic import ElasticRunner, FailureInjector, NodeFailure, StepTimer


def test_step_timer_flags_stragglers():
    t = StepTimer(window=10, threshold=2.0)
    for i in range(8):
        t.record(i, 0.1)
    assert t.record(8, 0.5) is True
    assert t.straggler_steps == [8]
    assert t.record(9, 0.1) is False


def test_failure_injector():
    inj = FailureInjector({3: 2})
    inj.check(2)
    try:
        inj.check(3)
        assert False, "should have raised"
    except NodeFailure as e:
        assert e.lost_devices == 2
    inj.check(3)  # consumed — does not re-fire


def test_elastic_runner_survives_failure(tmp_path):
    """Train a toy model; kill 'devices' mid-run; resume from checkpoint."""
    from jax.sharding import Mesh

    def make_mesh(devices):
        return Mesh(np.array(devices), ("data",))

    w0 = jnp.zeros((4, 4))

    def make_step(mesh):
        @jax.jit
        def step(state, batch):
            w, n = state
            grad = (w - batch).mean() * jnp.ones_like(w)
            return (w - 0.1 * grad, n + 1), {"loss": jnp.mean((w - batch) ** 2)}

        return step

    abstract = jax.eval_shape(lambda: (w0, jnp.zeros((), jnp.int32)))
    manager = CheckpointManager(str(tmp_path), keep=3, async_writes=False)
    runner = ElasticRunner(
        make_mesh=make_mesh,
        make_step=make_step,
        abstract_state=abstract,
        shardings_for=lambda mesh: None,
        make_batch=lambda step, mesh: jnp.full((4, 4), float(step % 3)),
        init_state=lambda mesh: (w0, jnp.zeros((), jnp.int32)),
        manager=manager,
        checkpoint_every=5,
        injector=FailureInjector({12: 0}),  # lose 0 devices (still restarts from ckpt)
    )
    state, info = runner.run(20)
    assert int(state[1]) == 20
    assert len(info["events"]) == 1
    assert "step 12" in info["events"][0]
    assert manager.latest() == 20
