"""Per-architecture smoke tests: reduced configs, one fwd/train/decode step.

Every assigned arch instantiates a REDUCED family-preserving config and runs
on CPU; full configs are exercised only by the dry-run (ShapeDtypeStructs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, get_config
from repro.configs.registry import cell_runnable, runnable_cells
from repro.models.model import build_model
from repro.training.optimizer import AdamW
from repro.training.train import init_train_state, make_train_step

B, L = 2, 64


def _batch(key, cfg):
    k1, k2 = jax.random.split(key)
    if cfg.input_mode == "tokens":
        inputs = jax.random.randint(k1, (B, L), 0, cfg.vocab_size)
    else:
        inputs = jax.random.normal(k1, (B, L, cfg.frame_dim), jnp.bfloat16)
    labels = jax.random.randint(k2, (B, L), 0, cfg.vocab_size)
    return {"inputs": inputs, "labels": labels, "mask": jnp.ones((B, L), jnp.float32)}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    # spot-check the published numbers survived
    expected = {
        "chameleon-34b": (48, 8192, 22016, 65536),
        "nemotron-4-340b": (96, 18432, 73728, 256000),
        "yi-6b": (32, 4096, 11008, 64000),
        "minicpm3-4b": (62, 2560, 6400, 73448),
        "gemma-2b": (18, 2048, 16384, 256000),
        "hubert-xlarge": (48, 1280, 5120, 504),
        "grok-1-314b": (64, 6144, 32768, 131072),
        "mixtral-8x22b": (56, 6144, 16384, 32768),
        "mamba2-130m": (24, 768, 0, 50280),
        "zamba2-2.7b": (54, 2560, 10240, 32000),
    }[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size) == expected
    if arch in ("grok-1-314b", "mixtral-8x22b"):
        assert cfg.num_experts == 8 and cfg.num_experts_per_tok == 2
    if arch in ("mamba2-130m", "zamba2-2.7b"):
        assert cfg.ssm_state in (128, 64)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    key = jax.random.key(0)
    params = model.init(key)
    batch = _batch(jax.random.key(1), cfg)

    logits, _ = jax.jit(model.forward)(params, batch["inputs"])
    assert logits.shape == (B, L, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits))), "NaN logits"

    opt = AdamW(learning_rate=1e-3)
    state = init_train_state(key, model, opt)
    step = jax.jit(make_train_step(model, opt))
    state2, metrics = step(state, batch)
    assert not bool(jnp.isnan(metrics["loss"])), "NaN loss"
    assert int(state2.step) == 1
    # params changed
    p0 = jax.tree.leaves(state.params)[0]
    p1 = jax.tree.leaves(state2.params)[0]
    assert not bool(jnp.allclose(p0, p1))


@pytest.mark.parametrize("arch", [a for a in ARCHS if not get_config(a).is_encoder])
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    key = jax.random.key(0)
    params = model.init(key)
    prompt = jax.random.randint(key, (B, L // 2), 0, cfg.vocab_size)

    cache = model.init_cache(B, L)
    logits, cache = jax.jit(model.prefill)(params, prompt, cache)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
    logits2, cache = jax.jit(model.decode)(
        params, tok, cache, jnp.asarray([L // 2], jnp.int32)
    )
    assert logits2.shape == (B, 1, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits2))), "NaN decode logits"


@pytest.mark.parametrize("arch", [a for a in ARCHS if not get_config(a).is_encoder])
def test_prefill_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the stateless forward logits."""
    cfg = get_config(arch).reduced().replace(activation_dtype="float32")
    model = build_model(cfg)
    key = jax.random.key(0)
    params = model.init(key)
    T = 16
    tokens = jax.random.randint(key, (1, T), 0, cfg.vocab_size)

    full, _ = jax.jit(model.forward)(params, tokens)

    cache = model.init_cache(1, T)
    Lp = T // 2
    lg, cache = jax.jit(model.prefill)(params, tokens[:, :Lp], cache)
    outs = [lg[:, -1]]
    decode = jax.jit(model.decode)
    for t in range(Lp, T):
        lg, cache = decode(params, tokens[:, t : t + 1], cache, jnp.asarray([t], jnp.int32))
        outs.append(lg[:, -1])
    stepwise = jnp.stack(outs, axis=1)  # positions Lp-1 .. T-1
    want = full[:, Lp - 1 :]
    import numpy as np

    np.testing.assert_allclose(
        np.asarray(stepwise[:, :-1]), np.asarray(want[:, :-1]), atol=2e-3, rtol=2e-3
    )


def test_runnable_cells_count():
    cells = runnable_cells()
    # 40 total - 6 long_500k skips - 2 hubert decode skips = 32
    assert len(cells) == 32
    for arch, shape in cells:
        ok, why = cell_runnable(get_config(arch), SHAPES[shape])
        assert ok, why
