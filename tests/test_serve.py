"""Serving subsystem tests: artifact round-trip, predictor semantics,
typed load failures, and the train -> export -> serve CLI round-trip.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.bpmf import BPMFConfig, BPMFEngine, load_dataset
from repro.serve import (
    ARRAY_KEYS,
    SERVE_ARTIFACT_VERSION,
    ArtifactCorruptError,
    ArtifactMeta,
    ArtifactNotFoundError,
    ArtifactSchemaError,
    PosteriorPredictor,
    load_artifact,
    save_artifact,
)


def _cfg(**kw) -> BPMFConfig:
    base = dict(K=6, num_sweeps=5, burn_in=1, bucket_pads=(8, 32, 128),
                keep_factor_samples=3)
    base.update(kw)
    return BPMFConfig().replace(**base)


def _coo(seed: int = 3):
    return load_dataset(
        "synthetic", num_users=90, num_movies=45, nnz=1000, noise_std=0.3, seed=seed
    )


@pytest.fixture(scope="module")
def fitted(tmp_path_factory):
    """One fitted engine + exported artifact shared by the read-only tests."""
    engine = BPMFEngine(_cfg()).fit(_coo())
    path = str(tmp_path_factory.mktemp("serve") / "artifact")
    engine.export(path)
    return engine, path


# ---------- round-trip + predictor semantics ----------


def test_artifact_roundtrip_bitwise(fitted):
    engine, path = fitted
    meta, arrays = load_artifact(path)
    want_meta, want_arrays = engine._artifact_payload()
    assert meta == want_meta
    assert meta.version == SERVE_ARTIFACT_VERSION
    assert meta.num_mean_samples == 4  # sweeps 2..5 post burn-in
    assert meta.num_kept_samples == 3
    for k in ARRAY_KEYS:
        np.testing.assert_array_equal(arrays[k], want_arrays[k])


def test_served_predictions_match_engine(fitted):
    engine, path = fitted
    rng = np.random.default_rng(0)
    rows = rng.integers(0, 90, 33)
    cols = rng.integers(0, 45, 33)
    served = PosteriorPredictor.load(path).predict(rows, cols)
    want = engine.predict(rows, cols)
    np.testing.assert_array_equal(served, want)  # same jitted program
    lo, hi = engine.backend.rating_range
    assert served.shape == (33,)
    assert np.all(served >= lo) and np.all(served <= hi)


def test_predictive_std(fitted):
    engine, path = fitted
    predictor = PosteriorPredictor.load(path)
    preds, std = predictor.predict([0, 1, 2], [3, 4, 5], return_std=True)
    assert preds.shape == std.shape == (3,)
    assert np.all(np.isfinite(std)) and np.all(std >= 0)
    p2, s2 = engine.predict([0, 1, 2], [3, 4, 5], return_std=True)
    np.testing.assert_array_equal(preds, p2)
    np.testing.assert_array_equal(std, s2)


def test_top_k(fitted):
    engine, path = fitted
    predictor = PosteriorPredictor.load(path)
    ids, scores = predictor.top_k(7, 5)
    assert ids.shape == scores.shape == (5,)
    assert np.all(scores[:-1] >= scores[1:])  # descending
    # top-k scores are the predictions for those movies (matmul vs
    # multiply-reduce contraction: fp tolerance, not bitwise)
    np.testing.assert_allclose(
        scores, predictor.predict(np.full(5, 7), ids), atol=1e-6, rtol=0
    )
    # batched form agrees with the scalar form row-wise
    ids_b, scores_b = predictor.top_k(np.array([7, 11]), 5)
    assert ids_b.shape == scores_b.shape == (2, 5)
    np.testing.assert_array_equal(ids_b[0], ids)
    # k is clamped to the catalog
    ids_all, _ = predictor.top_k(7, 10_000)
    assert ids_all.shape == (45,)
    assert sorted(ids_all.tolist()) == list(range(45))


def test_predict_validates_queries(fitted):
    _, path = fitted
    predictor = PosteriorPredictor.load(path)
    with pytest.raises(ValueError, match="user ids"):
        predictor.predict([90], [0])
    with pytest.raises(ValueError, match="movie ids"):
        predictor.predict([0], [-1])
    with pytest.raises(ValueError, match="batch mismatch"):
        predictor.predict([0, 1], [0])
    with pytest.raises(ValueError, match="k >= 1"):
        predictor.top_k(0, 0)


def test_std_requires_kept_samples(tmp_path):
    engine = BPMFEngine(_cfg(keep_factor_samples=0)).fit(_coo())
    path = engine.export(str(tmp_path / "nostd"))
    meta, arrays = load_artifact(path)
    assert meta.num_kept_samples == 0 and arrays["U_samples"].shape[0] == 0
    predictor = PosteriorPredictor.load(path)
    with pytest.raises(ValueError, match="keep_factor_samples"):
        predictor.predict([0], [0], return_std=True)
    predictor.predict([0], [0])  # mean path unaffected


def test_export_before_burn_in_falls_back_to_sample(tmp_path):
    engine = BPMFEngine(_cfg(num_sweeps=1, burn_in=5)).fit(_coo())
    path = engine.export(str(tmp_path / "raw"))
    meta, arrays = load_artifact(path)
    assert meta.num_mean_samples == 0 and meta.num_kept_samples == 0
    U, _ = engine.factors()
    np.testing.assert_array_equal(arrays["U_mean"], U)


def test_resumed_run_exports_identical_artifact(tmp_path):
    """Checkpoint save/restore must not perturb the accumulated posterior:
    an interrupted+resumed run exports bitwise the artifact of an
    uninterrupted one."""
    coo = _coo(seed=5)
    # sweeps_per_block=3: the mid-run save below lands at the end of the
    # first executed block (sweep 3), not at a sweeps_per_block multiple
    cfg = _cfg(num_sweeps=6, sweeps_per_block=3, checkpoint_dir=str(tmp_path / "ckpt"))
    full = BPMFEngine(cfg).fit(coo)
    full_path = full.export(str(tmp_path / "full"))

    interrupted = BPMFEngine(cfg)
    it = interrupted.sample(coo)
    for _ in range(3):
        next(it)
    interrupted.save()
    del interrupted, it

    resumed = BPMFEngine(cfg)
    resumed.restore(coo)
    resumed.fit()
    resumed_path = resumed.export(str(tmp_path / "resumed"))

    m1, a1 = load_artifact(full_path)
    m2, a2 = load_artifact(resumed_path)
    assert m1 == m2
    for k in ARRAY_KEYS:
        np.testing.assert_array_equal(a1[k], a2[k], err_msg=k)


def test_restore_pre_serving_checkpoint(tmp_path):
    """Checkpoints written before the serving subsystem (no 'posterior'
    subtree) must still resume; the accumulator restarts empty and export
    reflects only post-resume sweeps."""
    coo = _coo(seed=8)
    # blocks of 2 so the simulated old-schema save below happens at sweep 2
    cfg = _cfg(num_sweeps=4, sweeps_per_block=2, checkpoint_dir=str(tmp_path / "ckpt"))
    engine = BPMFEngine(cfg)
    it = engine.sample(coo)
    for _ in range(2):
        next(it)
    hist = np.asarray(
        [[m.rmse_sample, m.rmse_avg, m.sweep] for m in engine.history], np.float32
    )
    # simulate the old checkpoint schema: state/pred/history only
    engine._manager().save(
        2, {"state": engine._state, "pred": engine._pred, "history": hist}
    )
    del engine, it

    resumed = BPMFEngine(cfg)
    assert resumed.restore(coo) == 2
    resumed.fit()
    meta, arrays = load_artifact(resumed.export(str(tmp_path / "art")))
    assert meta.num_mean_samples == 2  # sweeps 3..4 only (pre-resume lost)
    assert np.all(np.isfinite(arrays["U_mean"]))


# ---------- typed load failures ----------


def _tamper(path: str, name: str, mutate) -> None:
    full = os.path.join(path, "step_00000000", name)
    mutate(full)


def test_missing_artifact_raises(tmp_path):
    with pytest.raises(ArtifactNotFoundError):
        load_artifact(str(tmp_path / "nope"))


def test_corrupt_artifact_json(fitted, tmp_path):
    _, path = fitted
    import shutil

    broken = str(tmp_path / "broken")
    shutil.copytree(path, broken)
    with open(os.path.join(broken, "artifact.json"), "w") as f:
        f.write("{not json")
    with pytest.raises(ArtifactCorruptError, match="unreadable"):
        load_artifact(broken)


def test_version_drift_raises(fitted, tmp_path):
    _, path = fitted
    import shutil

    drift = str(tmp_path / "drift")
    shutil.copytree(path, drift)
    meta_path = os.path.join(drift, "artifact.json")
    with open(meta_path) as f:
        payload = json.load(f)
    payload["version"] = SERVE_ARTIFACT_VERSION + 1
    with open(meta_path, "w") as f:
        json.dump(payload, f)
    with pytest.raises(ArtifactSchemaError, match="version"):
        load_artifact(drift)
    # missing metadata key is schema drift too
    del payload["version"], payload["mean_rating"]
    payload["version"] = SERVE_ARTIFACT_VERSION
    with open(meta_path, "w") as f:
        json.dump(payload, f)
    with pytest.raises(ArtifactSchemaError, match="mean_rating"):
        load_artifact(drift)


def test_truncated_array_raises_typed(fitted, tmp_path):
    _, path = fitted
    import shutil

    broken = str(tmp_path / "trunc")
    shutil.copytree(path, broken)
    _tamper(broken, "U_mean.npy", lambda p: open(p, "r+b").truncate(16))
    with pytest.raises(ArtifactCorruptError, match="U_mean"):
        load_artifact(broken)


def test_missing_array_raises_typed(fitted, tmp_path):
    _, path = fitted
    import shutil

    broken = str(tmp_path / "gone")
    shutil.copytree(path, broken)
    _tamper(broken, "V_mean.npy", os.remove)
    with pytest.raises(ArtifactCorruptError, match="V_mean"):
        load_artifact(broken)


def test_shape_drift_raises_schema(fitted, tmp_path):
    _, path = fitted
    import shutil

    broken = str(tmp_path / "shape")
    shutil.copytree(path, broken)
    _tamper(broken, "U_mean.npy", lambda p: np.save(p, np.zeros((2, 2), np.float32)))
    with pytest.raises(ArtifactSchemaError, match="U_mean"):
        load_artifact(broken)


def test_save_artifact_validates_payload(tmp_path):
    meta = ArtifactMeta(
        num_users=4, num_movies=3, K=2, mean_rating=0.0, min_rating=0.0,
        max_rating=1.0, num_mean_samples=1, num_kept_samples=0, backend="sequential",
        num_sweeps_done=1, seed=0,
    )
    arrays = {
        "U_mean": np.zeros((4, 2), np.float32),
        "V_mean": np.zeros((3, 2), np.float32),
        "U_samples": np.zeros((0, 4, 2), np.float32),
        "V_samples": np.zeros((0, 3, 2), np.float32),
    }
    save_artifact(str(tmp_path / "ok"), meta, arrays)
    with pytest.raises(ValueError, match="shape"):
        save_artifact(
            str(tmp_path / "bad"), meta, {**arrays, "U_mean": np.zeros((5, 2), np.float32)}
        )
    with pytest.raises(ValueError, match="exactly"):
        save_artifact(str(tmp_path / "bad2"), meta, {"U_mean": arrays["U_mean"]})


# ---------- CLI round-trip (train -> export -> serve) ----------


def _run_cli(argv: list[str], stdin: str | None = None) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return subprocess.run(
        [sys.executable, "-m", *argv],
        env=env, capture_output=True, text=True, timeout=600, input=stdin,
    )


@pytest.mark.slow
def test_cli_train_export_serve_roundtrip(tmp_path):
    """python -m repro.launch.bpmf --export-artifact -> python -m
    repro.launch.serve returns finite predictions matching an in-process
    restore of the same artifact."""
    artifact = str(tmp_path / "artifact")
    train = _run_cli([
        "repro.launch.bpmf", "--backend", "sequential", "--dataset", "synthetic",
        "--sweeps", "3", "--burn-in", "1", "--K", "4",
        "--users", "80", "--movies", "40", "--nnz", "800",
        "--export-artifact", artifact,
    ])
    assert train.returncode == 0, train.stderr
    assert "exported serving artifact" in train.stdout

    rows, cols = [0, 5, 11], [1, 7, 39]
    one_shot = _run_cli([
        "repro.launch.serve", "--artifact", artifact,
        "--rows", ",".join(map(str, rows)), "--cols", ",".join(map(str, cols)),
        "--std",
    ])
    assert one_shot.returncode == 0, one_shot.stderr
    resp = json.loads(one_shot.stdout)
    got = np.asarray(resp["predictions"], np.float32)
    assert np.all(np.isfinite(got)) and np.all(np.isfinite(resp["std"]))

    want, want_std = PosteriorPredictor.load(artifact).predict(
        rows, cols, return_std=True
    )
    np.testing.assert_allclose(got, want, atol=1e-6, rtol=0)
    np.testing.assert_allclose(np.asarray(resp["std"], np.float32), want_std,
                               atol=1e-6, rtol=0)

    jsonl = _run_cli(
        ["repro.launch.serve", "--artifact", artifact, "--jsonl"],
        stdin=json.dumps({"rows": rows, "cols": cols}) + "\n"
        + json.dumps({"user": 3, "k": 4}) + "\n"
        + "definitely not json\n",
    )
    assert jsonl.returncode == 0, jsonl.stderr
    lines = [json.loads(l) for l in jsonl.stdout.splitlines() if l.strip()]
    assert len(lines) == 3
    np.testing.assert_allclose(
        np.asarray(lines[0]["predictions"], np.float32), want, atol=1e-6, rtol=0
    )
    assert len(lines[1]["items"]) == 4 and lines[1]["user"] == 3
    assert "error" in lines[2]  # malformed request does not kill the loop


def test_serve_cli_missing_artifact(tmp_path):
    proc = _run_cli([
        "repro.launch.serve", "--artifact", str(tmp_path / "none"),
        "--rows", "0", "--cols", "0",
    ])
    assert proc.returncode == 1
    assert "cannot load artifact" in proc.stderr


@pytest.mark.slow
def test_serve_cli_invalid_query_is_clean(fitted):
    """One-shot mode turns invalid queries into an error JSON + exit 1,
    never a traceback (same contract as the JSONL loop)."""
    _, artifact = fitted
    proc = _run_cli([
        "repro.launch.serve", "--artifact", artifact,
        "--rows", "0,99999", "--cols", "0,1",
    ])
    assert proc.returncode == 1, proc.stderr
    assert "Traceback" not in proc.stderr
    err = json.loads(proc.stderr.splitlines()[-1])
    assert "user ids" in err["error"]
