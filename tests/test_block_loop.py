"""Device-resident blocked sweep loop (DESIGN.md §10).

The correctness bar of the block refactor: at any ``sweeps_per_block`` the
sampler draws identical randomness, so samples, metric history, checkpoint
cadence and exported artifacts are **bitwise** equal to a per-sweep run on
every backend — including runs interrupted and resumed at a sweep that is
not a block boundary, and blocks that straddle burn-in.

These tests run in-process on the tier-1 forced 8-device host mesh, so the
distributed backends exercise a real ring.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.bpmf import BPMFConfig, BPMFEngine, load_dataset
from repro.serve import load_artifact

ARRAY_KEYS = ("U_mean", "V_mean", "U_samples", "V_samples")
BACKENDS = ("sequential", "ring", "ring_async", "allgather")


def _cfg(**kw) -> BPMFConfig:
    base = dict(
        K=6, num_sweeps=7, burn_in=2, bucket_pads=(8, 32, 128),
        keep_factor_samples=3,
    )
    base.update(kw)
    return BPMFConfig().replace(**base)


def _coo(seed: int = 3):
    return load_dataset(
        "synthetic", num_users=90, num_movies=45, nnz=1000, noise_std=0.3, seed=seed
    )


def _artifact_equal(a, b, msg=""):
    meta_a, arrs_a = a
    meta_b, arrs_b = b
    assert meta_a == meta_b, (msg, meta_a, meta_b)
    for k in ARRAY_KEYS:
        np.testing.assert_array_equal(arrs_a[k], arrs_b[k], err_msg=f"{msg}:{k}")


# ---------- bitwise parity across block sizes ----------


@pytest.mark.parametrize("name", BACKENDS)
def test_block_sizes_bitwise_identical(tmp_path, name):
    """sweeps_per_block ∈ {1, 4, 8}: factors, per-sweep history and the
    exported artifact are bitwise identical — blocking only changes how many
    sweeps run per host round-trip, never the samples. Blocks straddle
    burn-in (burn_in=2 < 4) so the on-device gate is exercised."""
    coo = _coo()
    outs = {}
    for spb in (1, 4, 8):
        e = BPMFEngine(_cfg(name=name, sweeps_per_block=spb)).fit(coo)
        art = load_artifact(e.export(str(tmp_path / f"{name}-{spb}")))
        outs[spb] = (e.factors(), [tuple(m) for m in e.history], art)
    (U0, V0), hist0, art0 = outs[1]
    for spb in (4, 8):
        (U, V), hist, art = outs[spb]
        np.testing.assert_array_equal(U, U0, err_msg=f"{name}@{spb}")
        np.testing.assert_array_equal(V, V0, err_msg=f"{name}@{spb}")
        assert hist == hist0, f"{name}@{spb}: history diverged"
        _artifact_equal(art, art0, msg=f"{name}@{spb}")


def test_history_ordering_and_checkpoint_cadence_block_invariant(tmp_path):
    """Deprecation hygiene: ``sample()`` still yields exactly one
    SweepMetrics per sweep in sweep order, and ``checkpoint_every``
    auto-saves land on the same steps, at every block size (blocks shrink to
    checkpoint boundaries rather than skipping them)."""
    coo = _coo(seed=6)
    cadences = {}
    for spb in (1, 3, 8):
        cfg = _cfg(
            sweeps_per_block=spb, num_sweeps=8, checkpoint_every=3,
            checkpoint_dir=str(tmp_path / f"spb{spb}"), keep_checkpoints=99,
        )
        engine = BPMFEngine(cfg)
        yielded = [m for m in engine.sample(coo)]
        assert [int(m.sweep) for m in yielded] == list(range(1, 9))
        assert yielded == engine.history
        cadences[spb] = (engine._manager().all_steps(), [tuple(m) for m in yielded])
    steps0, hist0 = cadences[1]
    assert steps0 == [3, 6]  # 8 is not a checkpoint_every multiple
    for spb, (steps, hist) in cadences.items():
        assert steps == steps0, (spb, steps)
        assert hist == hist0, f"spb={spb}: metrics diverged"


# ---------- mid-block interruption (the satellite's headline case) ----------


@pytest.mark.parametrize("name", ["ring", "ring_async"])
def test_mid_block_interruption_resumes_bitwise(tmp_path, name):
    """Checkpoint at a sweep that is *not* a block boundary (checkpoint_every=3
    shrinks the 4-sweep blocks), restore in a fresh engine, finish: samples
    and the exported artifact are bitwise identical both to an uninterrupted
    blocked run and to a per-sweep (sweeps_per_block=1) run."""
    coo = _coo(seed=5)
    extra = {"pipeline_depth": 2} if name == "ring_async" else {}
    cfg = _cfg(
        name=name, num_sweeps=8, sweeps_per_block=4, checkpoint_every=3,
        checkpoint_dir=str(tmp_path / "ckpt"), **extra,
    )

    full = BPMFEngine(cfg).fit(coo)
    full_art = load_artifact(full.export(str(tmp_path / "full")))
    ref = BPMFEngine(
        cfg.replace(sweeps_per_block=1, checkpoint_dir=None, checkpoint_every=0)
    ).fit(coo)
    np.testing.assert_array_equal(full.factors()[0], ref.factors()[0])

    resumed = BPMFEngine(cfg)
    assert resumed.restore(coo, step=3) == 3  # 3 % 4 != 0: mid-block sweep
    resumed.fit()
    res_art = load_artifact(resumed.export(str(tmp_path / "resumed")))
    _artifact_equal(res_art, full_art, msg=name)
    np.testing.assert_array_equal(resumed.factors()[0], full.factors()[0])
    np.testing.assert_array_equal(resumed.factors()[1], full.factors()[1])
    assert [tuple(m) for m in resumed.history] == [tuple(m) for m in full.history]


# ---------- on-device accumulator semantics ----------


def test_device_accumulator_matches_host_reference():
    """The on-device posterior sums and rotating window reproduce exactly
    what the old host accumulator computed: fold every post-burn-in sample
    on the host from a per-sweep run and compare with export()."""
    coo = _coo(seed=7)
    cfg = _cfg(num_sweeps=8, burn_in=2, keep_factor_samples=3, sweeps_per_block=1)
    engine = BPMFEngine(cfg)
    samples = []
    for m in engine.sample(coo):
        if int(m.sweep) > cfg.run.burn_in:
            samples.append(tuple(np.asarray(x, np.float32) for x in engine.factors()))
    U_sum = np.zeros_like(samples[0][0])
    V_sum = np.zeros_like(samples[0][1])
    for U, V in samples:
        U_sum += U
        V_sum += V
    n = np.float32(len(samples))

    meta, arrays = engine._artifact_payload()
    assert meta.num_mean_samples == len(samples) == 6
    np.testing.assert_array_equal(arrays["U_mean"], U_sum / n)
    np.testing.assert_array_equal(arrays["V_mean"], V_sum / n)
    # window = the 3 most recent draws, oldest first
    np.testing.assert_array_equal(
        arrays["U_samples"], np.stack([u for u, _ in samples[-3:]])
    )
    np.testing.assert_array_equal(
        arrays["V_samples"], np.stack([v for _, v in samples[-3:]])
    )


def test_keep_zero_disables_window():
    coo = _coo(seed=4)
    engine = BPMFEngine(_cfg(keep_factor_samples=0, sweeps_per_block=4)).fit(coo)
    meta, arrays = engine._artifact_payload()
    assert meta.num_kept_samples == 0
    assert arrays["U_samples"].shape[0] == 0
    assert meta.num_mean_samples == 5  # sums still accumulate


def test_pre_block_posterior_checkpoint_restores(tmp_path):
    """A 'posterior' subtree in the PR-4 host-accumulator schema (built by
    hand from per-sweep factors) restores into the device accumulator and
    the finished run exports bitwise what an uninterrupted run exports."""
    from repro.checkpoint import save_checkpoint

    coo = _coo(seed=9)
    cfg = _cfg(num_sweeps=6, burn_in=1, sweeps_per_block=3,
               checkpoint_dir=str(tmp_path / "ckpt"))
    full = BPMFEngine(cfg).fit(coo)
    full_art = load_artifact(full.export(str(tmp_path / "full")))

    # re-run the first 3 sweeps per-sweep, emulating the old host accumulator
    probe = BPMFEngine(cfg.replace(sweeps_per_block=1, checkpoint_dir=None))
    it = probe.sample(coo)
    samples = []
    for _ in range(3):
        m = next(it)
        if int(m.sweep) > cfg.run.burn_in:
            samples.append(tuple(np.asarray(x, np.float32) for x in probe.factors()))
    hist = np.asarray(
        [[m.rmse_sample, m.rmse_avg, m.sweep] for m in probe.history], np.float32
    )
    old_posterior = {
        "U_sum": sum(u for u, _ in samples),
        "V_sum": sum(v for _, v in samples),
        "count": np.asarray(len(samples), np.int32),
        "U_samples": np.stack([u for u, _ in samples]),
        "V_samples": np.stack([v for _, v in samples]),
    }
    save_checkpoint(
        str(tmp_path / "ckpt"), 3,
        {"state": probe._state, "pred": probe._pred, "history": hist,
         "posterior": old_posterior},
    )
    del probe, it

    resumed = BPMFEngine(cfg)
    assert resumed.restore(coo) == 3
    resumed.fit()
    res_art = load_artifact(resumed.export(str(tmp_path / "resumed")))
    _artifact_equal(res_art, full_art, msg="pre-block posterior restore")


def test_restore_with_larger_keep_reports_only_real_samples(tmp_path):
    """A checkpoint that retained fewer window samples than the resuming
    run's ``keep_factor_samples`` (here: keep=0 -> keep=3) must not surface
    zero-filled buffer slots as posterior samples: the window refills from
    real post-resume draws and ``num_kept_samples`` counts only those."""
    coo = _coo(seed=11)
    ckpt = str(tmp_path / "ckpt")
    cfg0 = _cfg(num_sweeps=4, burn_in=1, sweeps_per_block=4,
                keep_factor_samples=0, checkpoint_dir=ckpt)
    engine = BPMFEngine(cfg0).fit(coo)
    engine.save()
    del engine

    cfg1 = cfg0.replace(num_sweeps=6, keep_factor_samples=3)
    resumed = BPMFEngine(cfg1)
    assert resumed.restore(coo) == 4
    meta, arrays = resumed._artifact_payload()
    assert meta.num_kept_samples == 0  # nothing materialized yet
    resumed.fit()  # sweeps 5..6, both post-burn-in
    meta, arrays = resumed._artifact_payload()
    assert meta.num_mean_samples == 5  # sums survived the keep change
    assert meta.num_kept_samples == 2
    assert not np.any(np.all(arrays["U_samples"] == 0, axis=(1, 2)))


# ---------- config / plumbing ----------


def test_sweeps_per_block_validated():
    with pytest.raises(ValueError, match="sweeps_per_block"):
        _cfg(sweeps_per_block=0)


def test_block_metrics_single_transfer_counter():
    """The engine fetches one [block, 3] f32 metrics array per block — the
    byte counter sees 12 bytes/sweep regardless of block size, and no other
    per-sweep host traffic exists in the loop."""
    coo = _coo(seed=2)
    for spb in (1, 4):
        engine = BPMFEngine(_cfg(sweeps_per_block=spb, num_sweeps=6)).fit(coo)
        assert engine.host_metric_bytes == 6 * 3 * 4
