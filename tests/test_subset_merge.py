"""Unit tier for the ``posterior_merge`` backend's partition/merge core.

Covers the pieces the statistical harness (tests/test_posterior_quality.py)
takes for granted: the precision-weighted merge against the closed form,
the pooling fallback's shapes/dtypes, disjoint deterministic per-chain RNG
streams, the partition round-trip (every rating lands in exactly one
chain — a hypothesis property test), and checkpoint resume-export bitwise
parity for a partitioned run.
"""
from __future__ import annotations

import numpy as np
import pytest

from conftest import optional_hypothesis
from repro.bpmf import BPMFConfig, BPMFEngine, load_dataset
from repro.core import subset_merge
from repro.data.sparse import RatingsCOO
from repro.serve import ARRAY_KEYS, load_artifact

given, settings, st = optional_hypothesis()


def _cfg(**kw) -> BPMFConfig:
    base = dict(name="posterior_merge", num_partitions=2, K=6, num_sweeps=6,
                burn_in=2, bucket_pads=(8, 32, 128), keep_factor_samples=3)
    base.update(kw)
    return BPMFConfig().replace(**base)


def _coo(seed: int = 3) -> RatingsCOO:
    return load_dataset(
        "synthetic", num_users=90, num_movies=45, nnz=1000, noise_std=0.3, seed=seed
    )


# --------------------------------------------------------------------------
# merge math
# --------------------------------------------------------------------------


def test_precision_merge_closed_form():
    """Hand-computed 2-chain product of Gaussians: N(1,1) x N(3,1/2) has
    precision 3 and mean (1*1 + 2*3)/3 = 7/3."""
    means = np.array([[1.0], [3.0]])
    variances = np.array([[1.0], [0.5]])
    mean, var = subset_merge.precision_merge(means, variances, eps=0.0)
    np.testing.assert_allclose(mean, [7.0 / 3.0], rtol=1e-6)
    np.testing.assert_allclose(var, [1.0 / 3.0], rtol=1e-6)


def test_merge_weights_match_closed_form():
    """``merge_chain_trees`` with window-estimated precisions must combine
    the chain means exactly as the closed-form product of the window
    Gaussians does."""
    rng = np.random.default_rng(0)
    C, S, N, K = 2, 5, 4, 3
    windows = rng.normal(size=(C, S, N, K)) * np.array([0.5, 2.0])[:, None, None, None]
    trees = []
    count = 7
    for c in range(C):
        trees.append({
            "U_sum": rng.normal(size=(2, K)).astype(np.float32) * count,
            "V_sum": (windows[c].mean(axis=0) * count).astype(np.float32),
            "count": np.asarray(count, np.int32),
            "U_samples": rng.normal(size=(S, 2, K)).astype(np.float32),
            "V_samples": windows[c].astype(np.float32),
        })
    user_sets = [np.array([0, 2]), np.array([1, 3])]
    # align=False: the synthetic chains share no rotation to undo, and the
    # closed form below is computed in the trees' own coordinates
    out = subset_merge.merge_chain_trees(trees, user_sets, num_users=4, align=False)

    var = windows.astype(np.float64).var(axis=1, ddof=1)
    means = np.stack([np.asarray(t["V_sum"], np.float64) / count for t in trees])
    ref_mean, _ = subset_merge.precision_merge(means, var, eps=subset_merge.MERGE_EPS)
    np.testing.assert_allclose(out["V_mean"], ref_mean, rtol=1e-4)
    assert out["count"] == count


def test_pool_fallback_shapes_and_dtypes():
    """``method="pool"`` (and precision with < 2 window samples) must
    produce uniform weights and artifact-schema float32 shapes."""
    rng = np.random.default_rng(1)
    C, S, N, K, M = 3, 1, 5, 2, 6
    user_sets = [np.array([0, 1]), np.array([2, 3]), np.array([4, 5])]
    trees = []
    for c in range(C):
        trees.append({
            "U_sum": rng.normal(size=(2, K)).astype(np.float32),
            "V_sum": rng.normal(size=(N, K)).astype(np.float32),
            "count": np.asarray(2, np.int32),
            "U_samples": rng.normal(size=(S, 2, K)).astype(np.float32),
            "V_samples": rng.normal(size=(S, N, K)).astype(np.float32),
        })
    for method in ("pool", "precision"):  # S=1: precision must fall back
        out = subset_merge.merge_chain_trees(
            trees, user_sets, num_users=M, method=method, align=False
        )
        assert out["U_mean"].shape == (M, K) and out["U_mean"].dtype == np.float32
        assert out["V_mean"].shape == (N, K) and out["V_mean"].dtype == np.float32
        assert out["U_samples"].shape == (S, M, K)
        assert out["V_samples"].shape == (S, N, K)
        # uniform weights: merged mean == plain mean of chain means
        ref = np.mean([t["V_sum"] / np.float32(2) for t in trees], axis=0)
        np.testing.assert_allclose(out["V_mean"], ref, rtol=1e-6)
        # U scatters from the owning chain, unweighted
        np.testing.assert_allclose(
            out["U_mean"][user_sets[1]], trees[1]["U_sum"] / np.float32(2), rtol=1e-6
        )


def test_procrustes_alignment_recovers_rotation():
    """A chain whose factors are an exact orthogonal rotation of the
    reference chain must be rotated back onto it, without changing that
    chain's own predictions (U R)(V R)^T = U V^T."""
    rng = np.random.default_rng(2)
    N, K, S = 8, 3, 2
    base = {
        "U_sum": rng.normal(size=(4, K)).astype(np.float32),
        "V_sum": rng.normal(size=(N, K)).astype(np.float32),
        "count": np.asarray(5, np.int32),
        "U_samples": rng.normal(size=(S, 4, K)).astype(np.float32),
        "V_samples": rng.normal(size=(S, N, K)).astype(np.float32),
    }
    R0, _ = np.linalg.qr(rng.normal(size=(K, K)))
    R0 = R0.astype(np.float32)
    rotated = {
        k: (v if k == "count" else v @ R0) for k, v in base.items()
    }
    aligned = subset_merge.align_chain_trees([base, rotated])
    # chain 0 aligns onto itself (Procrustes of A onto A is the identity);
    # chain 1's rotation is undone exactly (up to f32 round-trip)
    for k in ("U_sum", "V_sum", "U_samples", "V_samples"):
        np.testing.assert_allclose(aligned[0][k], base[k], atol=1e-5)
        np.testing.assert_allclose(aligned[1][k], base[k], atol=1e-5)
    # prediction invariance of the alignment map on the rotated chain
    np.testing.assert_allclose(
        aligned[1]["U_sum"] @ aligned[1]["V_sum"].T,
        rotated["U_sum"] @ rotated["V_sum"].T,
        atol=1e-4,
    )


def test_merge_weights_validation():
    windows = np.zeros((2, 3, 4, 2), np.float32)
    with pytest.raises(ValueError, match="merge_method"):
        subset_merge.merge_weights(windows, method="bogus")
    w = subset_merge.merge_weights(windows, method="precision")
    # constant windows: precisions equal -> uniform
    np.testing.assert_allclose(w.sum(axis=0), 1.0, rtol=1e-6)
    with pytest.raises(ValueError, match="lock-step"):
        subset_merge.merge_chain_trees(
            [
                {"count": np.asarray(1, np.int32), "V_samples": np.zeros((0, 0, 0))},
                {"count": np.asarray(2, np.int32), "V_samples": np.zeros((0, 0, 0))},
            ],
            [np.array([0]), np.array([1])],
            num_users=2,
        )


# --------------------------------------------------------------------------
# partitioning
# --------------------------------------------------------------------------


def test_partition_round_trip():
    """Every rating must land in exactly one chain, keyed by its user."""
    coo = _coo()
    user_sets = subset_merge.partition_users(coo, 4)
    assert np.array_equal(
        np.sort(np.concatenate(user_sets)), np.arange(coo.num_users)
    )
    subs = subset_merge.split_by_users(coo, user_sets)
    assert sum(s.nnz for s in subs) == coo.nnz
    merged = sorted(
        zip(
            np.concatenate([s.rows for s in subs]).tolist(),
            np.concatenate([s.cols for s in subs]).tolist(),
            np.concatenate([s.vals for s in subs]).tolist(),
        )
    )
    original = sorted(zip(coo.rows.tolist(), coo.cols.tolist(), coo.vals.tolist()))
    assert merged == original


@given(
    num_users=st.integers(min_value=1, max_value=20),
    num_partitions=st.integers(min_value=1, max_value=5),
    ratings=st.lists(
        st.tuples(
            st.integers(min_value=0, max_value=19),
            st.integers(min_value=0, max_value=9),
            st.floats(min_value=1.0, max_value=5.0, allow_nan=False),
        ),
        max_size=60,
    ),
    strategy=st.sampled_from(["lpt", "block", "naive"]),
)
@settings(max_examples=50, deadline=None)
def test_partition_round_trip_property(num_users, num_partitions, ratings, strategy):
    """Property: for any COO and any chain count <= num_users, the
    partition covers every user once and the split covers every rating
    exactly once (as a multiset)."""
    num_partitions = min(num_partitions, num_users)
    rows = np.asarray([r[0] % num_users for r in ratings], np.int32)
    cols = np.asarray([r[1] for r in ratings], np.int32)
    vals = np.asarray([r[2] for r in ratings], np.float32)
    coo = RatingsCOO(rows, cols, vals, num_users, 10)
    user_sets = subset_merge.partition_users(coo, num_partitions, strategy=strategy)
    covered = np.concatenate(user_sets) if user_sets else np.zeros(0, np.int64)
    assert np.array_equal(np.sort(covered), np.arange(num_users))
    subs = subset_merge.split_by_users(coo, user_sets)
    merged = sorted(
        (int(r), int(c), float(v))
        for s in subs
        for r, c, v in zip(s.rows, s.cols, s.vals)
    )
    original = sorted(
        (int(r), int(c), float(v)) for r, c, v in zip(rows, cols, vals)
    )
    assert merged == original
    # localization round-trips through the per-chain id space
    for s, uids in zip(subs, user_sets):
        local = subset_merge.localize_users(s, uids)
        assert local.num_users == len(uids)
        np.testing.assert_array_equal(uids[local.rows], s.rows)


def test_partition_users_validation():
    coo = _coo()
    with pytest.raises(ValueError, match="num_partitions"):
        subset_merge.partition_users(coo, 0)
    with pytest.raises(ValueError, match="num_partitions"):
        subset_merge.partition_users(coo, coo.num_users + 1)


# --------------------------------------------------------------------------
# chain RNG streams
# --------------------------------------------------------------------------


def test_chain_rng_disjoint_and_deterministic():
    """Chains must evolve under distinct randomness (their V factors see
    the same data side, so identical streams would be an aliasing bug) and
    the whole partitioned run must be bitwise reproducible."""
    coo = _coo()
    e1 = BPMFEngine(_cfg()).fit(coo)
    e2 = BPMFEngine(_cfg()).fit(coo)
    # deterministic: same seed -> bitwise identical factors and artifact
    for a, b in zip(e1.factors(), e2.factors()):
        np.testing.assert_array_equal(a, b)
    s1, s2 = e1.state
    # disjoint streams: both chains sample the full movie side from the
    # same init, so equal V's would mean shared randomness
    assert not np.array_equal(np.asarray(s1.V), np.asarray(s2.V))
    # and the streams are the documented fold_in(run_key, chain)
    import jax

    k1 = subset_merge.chain_key(e1._k_run, 0)
    k2 = subset_merge.chain_key(e1._k_run, 1)
    assert not np.array_equal(jax.random.key_data(k1), jax.random.key_data(k2))


def test_chain_init_matches_sequential_rows():
    """U rows are initialized by original user id, so each chain's init is
    the sequential backend's rows for its partition (same seed)."""
    coo = _coo()
    merge = BPMFEngine(_cfg())
    merge.prepare(coo)
    merge._ensure_state()
    seq = BPMFEngine(_cfg(name="sequential"))
    seq.prepare(coo)
    seq._ensure_state()
    seq_U = np.asarray(seq.state.U)
    for st_c, uids in zip(merge.state, merge.backend.user_sets):
        np.testing.assert_array_equal(np.asarray(st_c.U), seq_U[uids])
        np.testing.assert_array_equal(np.asarray(st_c.V), np.asarray(seq.state.V))


# --------------------------------------------------------------------------
# checkpoint / resume
# --------------------------------------------------------------------------


def test_resumed_merge_run_exports_identical_artifact(tmp_path):
    """Mirror of the PR-4/PR-5 parity tests for the partitioned backend:
    interrupting mid-run (between blocks) and resuming must export bitwise
    the artifact of an uninterrupted run — per-chain states, accumulators
    and RNG all restore exactly."""
    coo = _coo(seed=5)
    cfg = _cfg(num_sweeps=6, sweeps_per_block=3,
               checkpoint_dir=str(tmp_path / "ckpt"))
    full = BPMFEngine(cfg).fit(coo)
    full_path = full.export(str(tmp_path / "full"))

    interrupted = BPMFEngine(cfg)
    it = interrupted.sample(coo)
    for _ in range(3):
        next(it)
    interrupted.save()
    del interrupted, it

    resumed = BPMFEngine(cfg)
    resumed.restore(coo)
    resumed.fit()
    resumed_path = resumed.export(str(tmp_path / "resumed"))

    m1, a1 = load_artifact(full_path)
    m2, a2 = load_artifact(resumed_path)
    assert m1 == m2
    for k in ARRAY_KEYS:
        np.testing.assert_array_equal(a1[k], a2[k], err_msg=k)
