"""Distributed BPMF: parity with the sequential sampler + balance behaviour.

The paper's §V-B claim — every parallel version reaches the same RMSE — is
strengthened here to near-bitwise sample parity: identical keys, per-item
noise keyed by original ids, and psum'd hyper statistics mean the only
divergence source is float reduction order.

Multi-device runs happen in subprocesses (conftest.run_with_devices) because
the main process has already locked jax to a single CPU device.
"""
import numpy as np
import pytest

from conftest import run_with_devices

PARITY_CODE = """
import jax, numpy as np
import jax.numpy as jnp
from repro.core import gibbs
from repro.core.types import BPMFConfig
from repro.core.distributed import (
    build_distributed_data, make_ring_mesh, run_distributed, gather_factors,
    init_dist_state, shard_data, dist_gibbs_sweep,
)
from repro.core.prediction import PredictionState
from repro.data.sparse import build_bpmf_data
from repro.data.synthetic import small_test_ratings

S = {S}
coo, _ = small_test_ratings(num_users=120, num_movies=45, nnz=1080, true_rank=4, seed=3)
cfg = BPMFConfig(K=8, num_sweeps=4, burn_in=1, comm_mode="{mode}",
                 bucket_pads=(8, 32, 128))

# sequential oracle on the identical split (same seed -> same train/test)
data_seq = build_bpmf_data(coo, pads=cfg.bucket_pads, test_fraction=0.1, seed=0)
key = jax.random.PRNGKey(7)
k_init, k_run = jax.random.split(key)
state = gibbs.init_state(k_init, coo.num_users, coo.num_movies, cfg)
pred = PredictionState.init(data_seq.test.rows.shape[0])
for _ in range(cfg.num_sweeps):
    state, pred, m_seq = gibbs.gibbs_sweep(k_run, state, pred, data_seq, cfg)

# distributed on S shards
ddata, plan = build_distributed_data(coo, S, pads=cfg.bucket_pads,
                                     test_fraction=0.1, seed=0,
                                     strategy="{strategy}")
mesh = make_ring_mesh()
dstate, dpred, hist = run_distributed(key, ddata, cfg, mesh)
U_d, V_d = gather_factors(dstate, plan)

err_u = float(np.max(np.abs(U_d - np.asarray(state.U))))
err_v = float(np.max(np.abs(V_d - np.asarray(state.V))))
print("ERRU", err_u)
print("ERRV", err_v)
print("RMSE_SEQ", float(m_seq.rmse_avg))
print("RMSE_DIST", float(hist[-1].rmse_avg))
"""


def _parse(out: str) -> dict:
    vals = {}
    for line in out.splitlines():
        parts = line.split()
        if len(parts) == 2 and parts[0] in ("ERRU", "ERRV", "RMSE_SEQ", "RMSE_DIST"):
            vals[parts[0]] = float(parts[1])
    return vals


@pytest.mark.multidevice
@pytest.mark.parametrize("mode", ["ring", "allgather"])
@pytest.mark.parametrize("shards,strategy", [(4, "lpt"), (4, "block"), (6, "lpt")])
def test_distributed_matches_sequential(mode, shards, strategy):
    out = run_with_devices(
        PARITY_CODE.format(S=shards, mode=mode, strategy=strategy), num_devices=shards
    )
    vals = _parse(out)
    # reduction order is the only divergence; 4 sweeps keeps chaos bounded
    assert vals["ERRU"] < 2e-3, vals
    assert vals["ERRV"] < 2e-3, vals
    assert abs(vals["RMSE_SEQ"] - vals["RMSE_DIST"]) < 1e-3, vals


RING_VS_ALLGATHER_CODE = """
import jax, numpy as np
from repro.core.types import BPMFConfig
from repro.core.distributed import build_distributed_data, make_ring_mesh, run_distributed, gather_factors
from repro.data.synthetic import small_test_ratings

coo, _ = small_test_ratings(num_users=90, num_movies=40, nnz=900, true_rank=3, seed=11)
key = jax.random.PRNGKey(0)
mesh = make_ring_mesh()
ddata, plan = build_distributed_data(coo, 4, pads=(8, 32, 128), seed=0)
out = {}
for mode in ("ring", "allgather"):
    cfg = BPMFConfig(K=6, num_sweeps=3, burn_in=0, comm_mode=mode, bucket_pads=(8, 32, 128))
    st, _, _ = run_distributed(key, ddata, cfg, mesh)
    out[mode] = gather_factors(st, plan)
du = np.max(np.abs(out["ring"][0] - out["allgather"][0]))
dv = np.max(np.abs(out["ring"][1] - out["allgather"][1]))
print("DU", float(du)); print("DV", float(dv))
"""


RING_ASYNC_BITWISE_CODE = """
import jax, numpy as np
from repro.core.types import BPMFConfig
from repro.core.distributed import (
    build_distributed_data, make_ring_mesh, run_distributed, gather_factors,
)
from repro.data.synthetic import small_test_ratings

coo, _ = small_test_ratings(num_users=120, num_movies=45, nnz=1080, true_rank=4, seed=3)
key = jax.random.PRNGKey(7)
mesh = make_ring_mesh()
ddata, plan = build_distributed_data(coo, 8, pads=(8, 32, 128), seed=0)
base = dict(K=8, num_sweeps=3, burn_in=1, bucket_pads=(8, 32, 128))
st, _, _ = run_distributed(key, ddata, BPMFConfig(comm_mode="ring", **base), mesh)
U0, V0 = gather_factors(st, plan)
for d in (1, 2, 4):
    cfg = BPMFConfig(comm_mode="ring_async", pipeline_depth=d, **base)
    st, _, _ = run_distributed(key, ddata, cfg, mesh)
    U, V = gather_factors(st, plan)
    err = float(np.max(np.abs(U - U0))) + float(np.max(np.abs(V - V0)))
    print("DEPTH%d" % d, err)
"""


@pytest.mark.multidevice
def test_ring_async_bitwise_vs_ring():
    """DESIGN.md §7: the pipelined ring draws *bit-identical* samples to the
    synchronous ring at every depth, on a real 8-device mesh."""
    out = run_with_devices(RING_ASYNC_BITWISE_CODE, num_devices=8, timeout=900)
    vals = {
        p[0]: float(p[1])
        for p in (l.split() for l in out.splitlines())
        if len(p) == 2 and p[0].startswith("DEPTH")
    }
    assert set(vals) == {"DEPTH1", "DEPTH2", "DEPTH4"}, out
    for k, v in vals.items():
        assert v == 0.0, (k, v)  # exact equality, not a tolerance


@pytest.mark.multidevice
def test_ring_equals_allgather():
    out = run_with_devices(RING_VS_ALLGATHER_CODE, num_devices=4)
    vals = dict(
        (p[0], float(p[1]))
        for p in (l.split() for l in out.splitlines())
        if len(p) == 2 and p[0] in ("DU", "DV")
    )
    assert vals["DU"] < 1e-3 and vals["DV"] < 1e-3, vals


CONVERGENCE_CODE = """
import jax
from repro.core.types import BPMFConfig
from repro.core.distributed import build_distributed_data, make_ring_mesh, run_distributed
from repro.data.synthetic import small_test_ratings

coo, _ = small_test_ratings(num_users=200, num_movies=80, nnz=2400, true_rank=4,
                            noise_std=0.3, seed=5)
cfg = BPMFConfig(K=8, num_sweeps=12, burn_in=3, comm_mode="ring", bucket_pads=(8, 32, 128))
ddata, _ = build_distributed_data(coo, 4, pads=cfg.bucket_pads, seed=0)
_, _, hist = run_distributed(jax.random.PRNGKey(1), ddata, cfg, make_ring_mesh())
print("FIRST", hist[0].rmse_sample)
print("LAST", hist[-1].rmse_avg)
"""


@pytest.mark.multidevice
@pytest.mark.slow
def test_distributed_convergence():
    out = run_with_devices(CONVERGENCE_CODE, num_devices=4)
    vals = dict(
        (p[0], float(p[1]))
        for p in (l.split() for l in out.splitlines())
        if len(p) == 2 and p[0] in ("FIRST", "LAST")
    )
    assert vals["LAST"] < vals["FIRST"] * 0.8, vals
    assert vals["LAST"] < 0.8, vals  # noise floor ~0.3 on this synthetic


def test_build_distributed_data_shapes():
    """Host-side structure invariants on a single process (no devices needed)."""
    from repro.core.distributed import build_distributed_data
    from repro.data.synthetic import small_test_ratings

    S = 4
    coo, _ = small_test_ratings(num_users=50, num_movies=30, nnz=450, true_rank=3, seed=2)
    ddata, plan = build_distributed_data(coo, S, pads=(8, 32), seed=0)

    for side, part in ((ddata.users, plan.part_users), (ddata.movies, plan.part_movies)):
        assert side.num_steps == S
        assert side.orig_ids.shape[0] == S * side.cap
        # every real item appears exactly once in orig_ids
        orig = np.asarray(side.orig_ids)
        real = orig[orig >= 0]
        assert sorted(real.tolist()) == list(range(side.num_items))
        # bucket leading axes are divisible by S (one equal slice per device)
        for bs in side.steps:
            for b in bs:
                assert b.item_ids.shape[0] % S == 0
                assert b.nbr.shape[0] == b.item_ids.shape[0]
    # every training rating is represented exactly once across movie-side steps
    total = sum(
        int(np.asarray(b.nnz).sum()) for bs in ddata.movies.steps for b in bs
    )
    total_u = sum(
        int(np.asarray(b.nnz).sum()) for bs in ddata.users.steps for b in bs
    )
    assert total == total_u  # same ratings seen from both sides


def test_lpt_beats_block_on_skewed_nnz():
    """LPT's greedy balance is at least as tight as the contiguous block
    partition on a skewed (power-law-ish) nnz profile — the reason it is the
    default ``partition_strategy``. Also pins the module-level ``heapq``
    import (it used to live mid-function)."""
    import repro.core.balance as balance

    assert "heapq" in dir(balance) or hasattr(balance, "heapq")

    rng = np.random.default_rng(0)
    # heavy head: a few items own most of the ratings
    nnz = np.sort(rng.zipf(1.3, size=400).astype(np.int64))[::-1].copy()
    nnz = np.minimum(nnz, 5000)
    for S in (4, 8):
        lpt = balance.partition_items(nnz, S, strategy="lpt")
        blk = balance.partition_items(nnz, S, strategy="block")
        assert lpt.balance_ratio() <= blk.balance_ratio() + 1e-9, (
            S, lpt.balance_ratio(), blk.balance_ratio()
        )
        # ratios are max/mean >= 1 by construction
        assert lpt.balance_ratio() >= 1.0 - 1e-12
