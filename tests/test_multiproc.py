"""Multi-process training (DESIGN.md §14): parity, loading, elasticity.

Every test here spawns a gang of OS processes joined into one jax job via
the ``REPRO_*`` environment (the same wiring ``scripts/launch_multiproc.py``
uses) and asserts the tentpole claims of the multi-process refactor:

  * a ring mesh spanning 2 processes x 4 devices draws bitwise the samples
    of 1 process x 8 devices (``ring`` and ``ring_async``);
  * per-host data loading computes the identical global plan on every
    process while materializing only the local shards (allocation guard:
    ``local_nnz < total_nnz`` on every process of a multi-process job);
  * a checkpoint written at one process count restores at another, both
    directions, with bitwise-continued sweeps;
  * killing one process mid-run triggers the launcher's elastic restart at
    a smaller process count that finishes from the last committed
    checkpoint with the same samples.
"""
from __future__ import annotations

import json
import os
import re
import signal
import socket
import subprocess
import sys
import textwrap

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = [pytest.mark.multidevice]

# Worker run by every gang member: engine run / checkpoint phases, RESULT
# line (hashes of gathered factors, history, exported artifact) from p0.
ENGINE_WORKER = """
import hashlib, json, os, sys

pid, nproc, port, ndev = int(sys.argv[1]), int(sys.argv[2]), sys.argv[3], int(sys.argv[4])
ckdir, phase, backend, depth = sys.argv[5], sys.argv[6], sys.argv[7], int(sys.argv[8])

if nproc > 1:
    os.environ["REPRO_COORDINATOR"] = f"127.0.0.1:{port}"
    os.environ["REPRO_NUM_PROCESSES"] = str(nproc)
    os.environ["REPRO_PROCESS_ID"] = str(pid)
from repro.launch.hostdevices import init_multiprocess
init_multiprocess(local_devices=ndev)
import jax
import numpy as np
from repro.bpmf import BPMFConfig, BPMFEngine
from repro.data.synthetic import SyntheticSpec, synthetic_ratings

assert len(jax.devices()) == nproc * ndev, (len(jax.devices()), nproc, ndev)
coo, _ = synthetic_ratings(
    SyntheticSpec(num_users=96, num_movies=64, nnz=1500, discretize=False)
)
cfg = BPMFConfig().replace(
    name=backend, K=8, num_sweeps=4 if phase == "start" else 8, burn_in=2,
    sweeps_per_block=2, pipeline_depth=depth, checkpoint_dir=ckdir,
    checkpoint_every=2, keep_factor_samples=2,
)
eng = BPMFEngine(cfg)
eng.prepare(coo)
if phase == "resume":
    resumed = eng.restore()
    assert 0 < resumed < 8, resumed
for _ in eng.sample():
    pass

def h(a):
    return hashlib.md5(np.ascontiguousarray(a).tobytes()).hexdigest()[:12]

U, V = eng.factors()
hist = np.asarray(
    [[m.rmse_sample, m.rmse_avg, m.sweep] for m in eng.history], np.float32
)
art = os.path.join(ckdir, f"art-{phase}")
eng.export(art)  # collective: every process joins the export barrier
if pid == 0:
    arth = {}
    for root, _, files in sorted(os.walk(art)):
        for f in sorted(files):
            p = os.path.join(root, f)
            with open(p, "rb") as fh:
                arth[os.path.relpath(p, art)] = hashlib.md5(fh.read()).hexdigest()[:12]
    print("RESULT", json.dumps({
        "U": h(U), "V": h(V), "hist": h(hist), "rmse": float(eng.rmse),
        "art": arth,
    }), flush=True)
"""

# Worker asserting the per-host loading contract: every process prints its
# own PLAN line (global-plan fingerprint + local materialization counts).
PLAN_WORKER = """
import hashlib, json, os, sys

pid, nproc, port, ndev = int(sys.argv[1]), int(sys.argv[2]), sys.argv[3], int(sys.argv[4])
if nproc > 1:
    os.environ["REPRO_COORDINATOR"] = f"127.0.0.1:{port}"
    os.environ["REPRO_NUM_PROCESSES"] = str(nproc)
    os.environ["REPRO_PROCESS_ID"] = str(pid)
from repro.launch.hostdevices import init_multiprocess
init_multiprocess(local_devices=ndev)
import numpy as np
from repro.bpmf import BPMFConfig, BPMFEngine
from repro.data.synthetic import SyntheticSpec, synthetic_ratings

coo, _ = synthetic_ratings(
    SyntheticSpec(num_users=96, num_movies=64, nnz=1500, discretize=False)
)
eng = BPMFEngine(BPMFConfig().replace(name="ring", K=8, num_sweeps=2, burn_in=1))
eng.prepare(coo)
plan = eng.backend.plan

def h(a):
    return hashlib.md5(np.ascontiguousarray(np.asarray(a)).tobytes()).hexdigest()[:12]

print("PLAN", json.dumps({
    "pid": pid,
    "u_perm": h(plan.part_users.perm), "v_perm": h(plan.part_movies.perm),
    "u_cap": int(plan.part_users.cap), "v_cap": int(plan.part_movies.cap),
    "num_shards": int(plan.num_shards),
    "local_shards": list(plan.local_shards) if plan.local_shards else None,
    "local_nnz": int(plan.local_nnz), "total_nnz": int(plan.total_nnz),
}), flush=True)
"""


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _spawn_gang(worker: str, nproc: int, ndev: int, args: list[str],
                tmp_path, timeout: int = 900) -> list[str]:
    """Run ``worker`` as an nproc-gang; return per-process stdout."""
    script = tmp_path / "worker.py"
    script.write_text(textwrap.dedent(worker))
    port = str(_free_port())
    env = dict(os.environ)
    env.pop("REPRO_COORDINATOR", None)
    env.pop("REPRO_NUM_PROCESSES", None)
    env.pop("REPRO_PROCESS_ID", None)
    env["XLA_FLAGS"] = re.sub(
        r"--xla_force_host_platform_device_count=\d+", "", env.get("XLA_FLAGS", "")
    ).strip()
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    procs = [
        subprocess.Popen(
            [sys.executable, str(script), str(i), str(nproc), port, str(ndev), *args],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        )
        for i in range(nproc)
    ]
    outs = []
    try:
        for p in procs:
            out, _ = p.communicate(timeout=timeout)
            outs.append(out)
    finally:
        for p in procs:
            if p.poll() is None:
                p.send_signal(signal.SIGKILL)
                p.wait()
    bad = [(i, p.returncode) for i, p in enumerate(procs) if p.returncode != 0]
    if bad:
        dump = "\n".join(f"--- p{i} ---\n{o[-3000:]}" for i, o in enumerate(outs))
        raise AssertionError(f"gang members failed {bad}:\n{dump}")
    return outs


def _result(outs: list[str]) -> dict:
    for out in outs:
        for line in out.splitlines():
            if line.startswith("RESULT "):
                return json.loads(line[len("RESULT "):])
    raise AssertionError(f"no RESULT line in\n{outs[0][-3000:]}")


@pytest.fixture(scope="module")
def ring_single_ref(tmp_path_factory):
    """Uninterrupted 1-proc x 8-dev ring run — the parity/restore oracle."""
    tmp = tmp_path_factory.mktemp("ring-ref")
    return _result(_spawn_gang(
        ENGINE_WORKER, 1, 8, [str(tmp / "ck"), "run", "ring", "1"], tmp))


@pytest.mark.parametrize("backend,depth", [("ring", 1), ("ring_async", 2)])
def test_bitwise_parity_2x4_vs_1x8(backend, depth, tmp_path, ring_single_ref):
    """The tentpole claim: one global program, any process split — a
    2-proc x 4-dev gang draws bitwise the samples of 1 proc x 8 devs,
    down to the exported artifact bytes."""
    args = ["run", backend, str(depth)]
    if backend == "ring":
        single = ring_single_ref
    else:
        single = _result(_spawn_gang(
            ENGINE_WORKER, 1, 8, [str(tmp_path / "ck1"), *args], tmp_path))
    multi = _result(_spawn_gang(
        ENGINE_WORKER, 2, 4, [str(tmp_path / "ck2"), *args], tmp_path))
    assert multi["U"] == single["U"]
    assert multi["V"] == single["V"]
    assert multi["hist"] == single["hist"]
    assert multi["rmse"] == single["rmse"]
    assert multi["art"] == single["art"]


def test_per_host_loading_identical_global_plans(tmp_path):
    """Every process derives the same global partition plan from its own
    pass over the data, while materializing only its local shards — no
    process holds the full training set (the allocation guard)."""
    outs = _spawn_gang(PLAN_WORKER, 2, 4, [], tmp_path, timeout=600)
    plans = []
    for out in outs:
        lines = [l for l in out.splitlines() if l.startswith("PLAN ")]
        assert len(lines) == 1, out[-2000:]
        plans.append(json.loads(lines[0][len("PLAN "):]))
    plans.sort(key=lambda p: p["pid"])

    single = json.loads(
        [l for l in _spawn_gang(PLAN_WORKER, 1, 8, [], tmp_path, timeout=600)[0]
         .splitlines() if l.startswith("PLAN ")][0][len("PLAN "):]
    )
    for p in plans:
        # the global plan (permutations, capacities, shard count) is
        # process-invariant and equals the single-process build's
        for k in ("u_perm", "v_perm", "u_cap", "v_cap", "num_shards"):
            assert p[k] == single[k], (k, p, single)
        # per-host materialization: only the local half of the ring ...
        assert p["local_shards"] == list(range(p["pid"] * 4, p["pid"] * 4 + 4))
        # ... and strictly fewer than the global ratings resident
        assert 0 < p["local_nnz"] < p["total_nnz"]
    assert plans[0]["local_nnz"] + plans[1]["local_nnz"] >= plans[0]["total_nnz"]


@pytest.mark.parametrize("start,finish", [((2, 4), (1, 8)), ((1, 8), (2, 4))])
def test_checkpoint_restores_across_process_counts(start, finish, tmp_path,
                                                   ring_single_ref):
    """A checkpoint written at one process count restores at another (both
    directions) and the continued run is bitwise the uninterrupted one."""
    ck = str(tmp_path / "ck")
    _spawn_gang(ENGINE_WORKER, start[0], start[1], [ck, "start", "ring", "1"], tmp_path)
    resumed = _result(_spawn_gang(
        ENGINE_WORKER, finish[0], finish[1], [ck, "resume", "ring", "1"], tmp_path))
    assert resumed["U"] == ring_single_ref["U"]
    assert resumed["V"] == ring_single_ref["V"]
    assert resumed["rmse"] == ring_single_ref["rmse"]
    assert resumed["art"] == ring_single_ref["art"]


def test_elastic_restart_after_killed_process(tmp_path):
    """End-to-end preemption drill through scripts/launch_multiproc.py: an
    injected failure hard-kills process 0 mid-run, the launcher's restart
    policy respawns at a smaller process count over the same global device
    total, and the resumed run finishes from the last committed checkpoint
    with the same final posterior as an undisturbed run."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    common = [
        "--backend", "ring", "--dataset", "synthetic",
        "--users", "96", "--movies", "64", "--nnz", "1500", "--K", "8",
        "--sweeps", "6", "--burn-in", "2", "--sweeps-per-block", "2",
    ]

    def launch(extra_own, extra_fwd):
        r = subprocess.run(
            [sys.executable, os.path.join(REPO_ROOT, "scripts", "launch_multiproc.py"),
             *extra_own, "--", *common, *extra_fwd],
            env=env, capture_output=True, text=True, timeout=900,
        )
        return r

    ref = launch(["--num-processes", "1", "--devices-per-process", "8",
                  "--timeout", "600"], [])
    assert ref.returncode == 0, ref.stdout[-3000:]
    ref_final = re.search(r"final rmse\(avg\)=([0-9.]+)", ref.stdout)
    assert ref_final, ref.stdout[-2000:]

    ck = str(tmp_path / "ck")
    r = launch(
        ["--num-processes", "2", "--devices-per-process", "4",
         "--elastic", "--max-restarts", "2", "--timeout", "600"],
        ["--checkpoint-dir", ck, "--checkpoint-every", "2",
         "--inject-failure", "4"],
    )
    assert r.returncode == 0, r.stdout[-4000:]
    assert "injected failure at sweep 4" in r.stdout
    assert "elastic restart: 1 processes x 8 devices" in r.stdout
    final = re.search(r"final rmse\(avg\)=([0-9.]+)(?!.*final rmse)", r.stdout, re.S)
    assert final, r.stdout[-2000:]
    # the restarted run finishes with the undisturbed run's posterior
    assert final.group(1) == ref_final.group(1), r.stdout[-2000:]
