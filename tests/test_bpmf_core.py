"""Unit + property tests for the single-device BPMF core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core import BPMFConfig, run
from repro.core import posterior
from repro.core.hyper import hyper_sufficient_stats, sample_hyper, sample_hyper_from_stats
from repro.core.types import Bucket, HyperParams, NormalWishartPrior
from repro.data.sparse import RatingsCOO, bucketize_side, build_bpmf_data, csr_from_coo
from repro.data.synthetic import small_test_ratings


# ---------- sparse / bucketing ----------


@given(
    num_items=st.integers(3, 40),
    num_opp=st.integers(3, 40),
    nnz=st.integers(0, 300),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_bucketize_roundtrip(num_items, num_opp, nnz, seed):
    """Every (item, nbr, val) triple survives bucketing exactly once."""
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, num_items, nnz).astype(np.int32)
    cols = rng.integers(0, num_opp, nnz).astype(np.int32)
    # dedupe pairs
    keys, idx = np.unique(rows.astype(np.int64) * num_opp + cols, return_index=True)
    rows, cols = rows[idx], cols[idx]
    vals = rng.normal(size=len(rows)).astype(np.float32)

    indptr, indices, values = csr_from_coo(rows, cols, vals, num_items)
    side = bucketize_side(indptr, indices, values, pads=(4, 16, 64))

    got = set()
    for b in side.buckets:
        ids = np.asarray(b.item_ids)
        nbr = np.asarray(b.nbr)
        val = np.asarray(b.val)
        nz = np.asarray(b.nnz)
        for r in range(len(ids)):
            for p in range(nz[r]):
                got.add((int(ids[r]), int(nbr[r, p]), float(val[r, p])))
        # padding must be zeroed
        mask = np.arange(b.P)[None, :] >= nz[:, None]
        assert np.all(val[mask] == 0.0)
    want = {(int(r), int(c), float(v)) for r, c, v in zip(rows, cols, vals)}
    assert got == want
    # every item appears exactly once across buckets
    all_ids = np.concatenate([np.asarray(b.item_ids) for b in side.buckets])
    assert sorted(all_ids.tolist()) == list(range(num_items))


def test_csr_sorted_and_complete():
    rng = np.random.default_rng(0)
    rows = rng.integers(0, 10, 100).astype(np.int32)
    cols = rng.integers(0, 15, 100).astype(np.int32)
    vals = rng.normal(size=100).astype(np.float32)
    indptr, indices, values = csr_from_coo(rows, cols, vals, 10)
    assert indptr[-1] == 100
    for i in range(10):
        assert np.all(np.diff(indices[indptr[i]:indptr[i + 1]]) >= 0) or (indptr[i + 1] - indptr[i]) <= 1


# ---------- hyper sampling ----------


def test_normal_wishart_moments():
    """E[Lambda] = nu* W*, E[mu] = mu* — check by Monte Carlo."""
    K = 4
    rng_key = jax.random.key(0)
    X = jax.random.normal(jax.random.key(1), (500, K)) * 2.0 + 1.0
    prior = NormalWishartPrior.default(K)

    keys = jax.random.split(rng_key, 3000)
    hypers = jax.vmap(lambda k: sample_hyper(k, X, prior))(keys)

    n, sx, sxx = hyper_sufficient_stats(X)
    xbar = sx / n
    S = sxx / n - jnp.outer(xbar, xbar)
    beta_star = prior.beta0 + n
    nu_star = prior.nu0 + n
    mu_star = (prior.beta0 * prior.mu0 + n * xbar) / beta_star
    dm = prior.mu0 - xbar
    Wstar_inv = jnp.linalg.inv(prior.W0) + n * S + (prior.beta0 * n / beta_star) * jnp.outer(dm, dm)
    Wstar = jnp.linalg.inv(Wstar_inv)

    mean_Lam = jnp.mean(hypers.Lam, axis=0)
    expect_Lam = nu_star * Wstar
    np.testing.assert_allclose(np.asarray(mean_Lam), np.asarray(expect_Lam), rtol=0.15)
    np.testing.assert_allclose(np.asarray(jnp.mean(hypers.mu, axis=0)), np.asarray(mu_star), atol=0.05)


def test_hyper_weighted_matches_unweighted():
    """Padding rows with weight 0 must not change the sufficient stats."""
    K = 5
    X = jax.random.normal(jax.random.key(2), (40, K))
    Xpad = jnp.concatenate([X, 99.0 * jnp.ones((7, K))])
    w = jnp.concatenate([jnp.ones(40), jnp.zeros(7)])
    n0, sx0, sxx0 = hyper_sufficient_stats(X)
    n1, sx1, sxx1 = hyper_sufficient_stats(Xpad, w)
    np.testing.assert_allclose(float(n0), float(n1))
    np.testing.assert_allclose(np.asarray(sx0), np.asarray(sx1), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(sxx0), np.asarray(sxx1), rtol=1e-5)
    prior = NormalWishartPrior.default(K)
    h0 = sample_hyper_from_stats(jax.random.key(3), n0, sx0, sxx0, prior)
    h1 = sample_hyper_from_stats(jax.random.key(3), n1, sx1, sxx1, prior)
    np.testing.assert_allclose(np.asarray(h0.Lam), np.asarray(h1.Lam), rtol=1e-4, atol=1e-5)


# ---------- posterior updates ----------


@given(
    B=st.integers(1, 8),
    P=st.sampled_from([4, 16, 64]),
    K=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_bucket_update_matches_naive(B, P, K, seed):
    """Bucketed (padded, batched) update == textbook per-item update."""
    rng = np.random.default_rng(seed)
    n_opp = max(30, P)
    X_opp = jnp.asarray(rng.normal(size=(n_opp, K)), jnp.float32)
    nnz = rng.integers(0, P + 1, B).astype(np.int32)
    nbr = np.zeros((B, P), np.int32)
    val = np.zeros((B, P), np.float32)
    for i in range(B):
        nbr[i, : nnz[i]] = rng.choice(n_opp, nnz[i], replace=False)
        val[i, : nnz[i]] = rng.normal(size=nnz[i])
    item_ids = rng.choice(100, B, replace=False).astype(np.int32)
    bucket = Bucket(jnp.asarray(item_ids), jnp.asarray(nbr), jnp.asarray(val), jnp.asarray(nnz))
    hyper = HyperParams(
        mu=jnp.asarray(rng.normal(size=K), jnp.float32),
        Lam=jnp.eye(K) * 2.0,
    )
    key = jax.random.key(7)
    G, g = posterior.gram_terms(X_opp, bucket, alpha=1.7)
    new = posterior.sample_from_terms(key, bucket.item_ids, G, g, hyper)
    for i in range(B):
        ref = posterior.update_item_naive(
            key, int(item_ids[i]), jnp.asarray(nbr[i, : nnz[i]]),
            jnp.asarray(val[i, : nnz[i]]), X_opp, hyper, alpha=1.7,
        )
        np.testing.assert_allclose(np.asarray(new[i]), np.asarray(ref), rtol=2e-3, atol=2e-4)


def test_item_noise_layout_independent():
    """Noise depends on the global item id only, not batch position."""
    key = jax.random.key(0)
    ids_a = jnp.asarray([5, 9, 2], jnp.int32)
    ids_b = jnp.asarray([9, 2, 5, 7], jnp.int32)
    za = posterior.item_noise(key, ids_a, 6)
    zb = posterior.item_noise(key, ids_b, 6)
    np.testing.assert_allclose(np.asarray(za[1]), np.asarray(zb[0]))
    np.testing.assert_allclose(np.asarray(za[2]), np.asarray(zb[1]))
    np.testing.assert_allclose(np.asarray(za[0]), np.asarray(zb[2]))


def test_zero_rating_item_samples_from_prior_conditional():
    K = 4
    bucket = Bucket(
        item_ids=jnp.asarray([0], jnp.int32),
        nbr=jnp.zeros((1, 8), jnp.int32),
        val=jnp.zeros((1, 8), jnp.float32),
        nnz=jnp.zeros((1,), jnp.int32),
    )
    X_opp = jnp.ones((5, K))
    hyper = HyperParams(mu=jnp.full((K,), 3.0), Lam=jnp.eye(K) * 1e6)
    G, g = posterior.gram_terms(X_opp, bucket, alpha=2.0)
    new = posterior.sample_from_terms(jax.random.key(0), bucket.item_ids, G, g, hyper)
    # precision huge -> sample ~= prior mean
    np.testing.assert_allclose(np.asarray(new[0]), 3.0 * np.ones(K), atol=0.05)


# ---------- end-to-end convergence ----------


@pytest.mark.slow
def test_gibbs_converges_to_noise_floor():
    from repro.bpmf import BPMFConfig as EngineConfig, BPMFEngine

    coo, truth = small_test_ratings(num_users=200, num_movies=120, nnz=8000)
    cfg = EngineConfig().replace(K=8, num_sweeps=50, burn_in=10, bucket_pads=(8, 32, 128))
    engine = BPMFEngine(cfg).fit(coo)
    final = engine.rmse
    assert final < 1.5 * truth["noise_std"], f"rmse {final} vs floor {truth['noise_std']}"
    # RMSE must improve over the first sweep substantially
    assert final < 0.6 * engine.history[0].rmse_sample


def test_gibbs_deterministic():
    from repro.bpmf import BPMFConfig as EngineConfig, BPMFEngine

    coo, _ = small_test_ratings(num_users=60, num_movies=40, nnz=1200)
    cfg = EngineConfig().replace(K=4, num_sweeps=3, burn_in=1, bucket_pads=(8, 32))
    h1 = BPMFEngine(cfg).fit(coo).history
    h2 = BPMFEngine(cfg).fit(coo).history
    assert [m.rmse_sample for m in h1] == [m.rmse_sample for m in h2]


def test_predictions_clipped_to_rating_range():
    coo, _ = small_test_ratings(num_users=60, num_movies=40, nnz=1200)
    data = build_bpmf_data(coo, pads=(8, 32), test_fraction=0.2, seed=0)
    from repro.core.prediction import predict

    U = 100.0 * jnp.ones((60, 4))
    V = jnp.ones((40, 4))
    preds = predict(U, V, data.test, data.mean_rating, data.min_rating, data.max_rating)
    assert float(jnp.max(preds)) <= data.max_rating + 1e-6
