"""Checkpoint + elastic-restore tests: atomicity, retention, resharding."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointCorruptError,
    CheckpointError,
    CheckpointManager,
    CheckpointSchemaError,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {
        "params": {"w": jax.random.normal(k1, (16, 8)), "b": jnp.zeros((8,))},
        "opt": {"mu": jax.random.normal(k2, (16, 8))},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    tree = _tree(jax.random.key(0))
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    out = restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_commit_no_partial_latest(tmp_path):
    tree = _tree(jax.random.key(0))
    save_checkpoint(str(tmp_path), 1, tree)
    # a stale tmp dir (simulated crash) must not be visible as a checkpoint
    os.makedirs(tmp_path / "step_00000002.tmp-dead", exist_ok=True)
    assert latest_step(str(tmp_path)) == 1
    out = restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: tree))
    assert int(out["step"]) == 7  # saved value, not the crashed one


def test_manager_retention_and_async(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2, async_writes=True)
    tree = _tree(jax.random.key(1))
    for s in (10, 20, 30, 40):
        m.save(s, tree)
    m.wait()
    m._retain()
    assert m.all_steps() == [30, 40]
    assert m.latest() == 40
    m.close()


def test_elastic_restore_across_meshes(tmp_path):
    """Save on one sharding layout, restore onto a different mesh shape."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    devs = jax.devices()
    tree = _tree(jax.random.key(2))
    save_checkpoint(str(tmp_path), 5, tree)

    mesh = Mesh(np.array(devs[:1]).reshape(1, 1), ("data", "model"))
    shardings = jax.tree.map(
        lambda x: NamedSharding(mesh, P()), jax.eval_shape(lambda: tree)
    )
    out = restore_checkpoint(
        str(tmp_path), jax.eval_shape(lambda: tree), mesh=mesh, shardings=shardings
    )
    np.testing.assert_array_equal(
        np.asarray(out["params"]["w"]), np.asarray(tree["params"]["w"])
    )


def test_missing_leaf_raises(tmp_path):
    tree = _tree(jax.random.key(0))
    save_checkpoint(str(tmp_path), 1, tree)
    bigger = {**tree, "extra": jnp.zeros((3,))}
    with pytest.raises(ValueError, match="missing leaves"):
        restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: bigger))
    # the typed spelling of the same failure (schema drift)
    with pytest.raises(CheckpointSchemaError):
        restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: bigger))


# ---------- damage surfaces as typed errors, not raw np/json tracebacks ----------


def test_truncated_leaf_raises_typed(tmp_path):
    tree = _tree(jax.random.key(0))
    save_checkpoint(str(tmp_path), 1, tree)
    with open(tmp_path / "step_00000001" / "params__w.npy", "r+b") as f:
        f.truncate(16)
    with pytest.raises(CheckpointCorruptError, match="params__w"):
        restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: tree))


def test_deleted_leaf_raises_typed(tmp_path):
    tree = _tree(jax.random.key(0))
    save_checkpoint(str(tmp_path), 1, tree)
    os.remove(tmp_path / "step_00000001" / "opt__mu.npy")
    with pytest.raises(CheckpointCorruptError, match="opt__mu"):
        restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: tree))


def test_garbage_manifest_raises_typed(tmp_path):
    tree = _tree(jax.random.key(0))
    save_checkpoint(str(tmp_path), 1, tree)
    with open(tmp_path / "step_00000001" / "manifest.json", "w") as f:
        f.write("]]not json[[")
    with pytest.raises(CheckpointCorruptError, match="manifest"):
        restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: tree))


def test_manifest_without_leaf_table_raises_typed(tmp_path):
    tree = _tree(jax.random.key(0))
    save_checkpoint(str(tmp_path), 1, tree)
    with open(tmp_path / "step_00000001" / "manifest.json", "w") as f:
        f.write('{"step": 1}')
    with pytest.raises(CheckpointCorruptError, match="leaf table"):
        restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: tree))


def test_garbage_latest_pointer_raises_typed(tmp_path):
    tree = _tree(jax.random.key(0))
    save_checkpoint(str(tmp_path), 1, tree)
    with open(tmp_path / "LATEST", "w") as f:
        f.write("not-a-step")
    with pytest.raises(CheckpointCorruptError, match="LATEST"):
        latest_step(str(tmp_path))
    # all typed errors share one catchable base
    assert issubclass(CheckpointCorruptError, CheckpointError)
    assert issubclass(CheckpointSchemaError, CheckpointError)


def test_absent_step_dir_still_not_found(tmp_path):
    """A directory with no checkpoint at the requested step stays a plain
    FileNotFoundError — 'not there' is not 'damaged'."""
    tree = _tree(jax.random.key(0))
    save_checkpoint(str(tmp_path), 1, tree)
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: tree), step=9)
