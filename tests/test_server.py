"""Persistent serving server tests: micro-batcher semantics, coalesced
bitwise parity, item-sharded top-k, artifact hot-swap atomicity, and the
HTTP round-trip (DESIGN.md §11).
"""
from __future__ import annotations

import json
import threading
import time

import numpy as np
import pytest

from repro.serve import (
    ArtifactMeta,
    BPMFServer,
    MicroBatcher,
    PosteriorPredictor,
    PredictorHandle,
    RequestError,
    ServeClient,
    ServeRequestError,
    parse_request,
    run_request,
    save_artifact,
)
from repro.serve.client import parse_address
from repro.serve.schema import PredictRequest, TopKRequest

USERS, MOVIES, K = 64, 37, 4  # 37 items: not a multiple of the 8-dev mesh


def _meta(**kw) -> ArtifactMeta:
    base = dict(
        num_users=USERS, num_movies=MOVIES, K=K, mean_rating=3.5,
        min_rating=1.0, max_rating=5.0, num_mean_samples=4,
        num_kept_samples=0, backend="synthetic", num_sweeps_done=5, seed=0,
    )
    base.update(kw)
    return ArtifactMeta(**base)


def _arrays(seed: int) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    return {
        "U_mean": rng.normal(scale=0.5, size=(USERS, K)).astype(np.float32),
        "V_mean": rng.normal(scale=0.5, size=(MOVIES, K)).astype(np.float32),
        "U_samples": np.zeros((0, USERS, K), np.float32),
        "V_samples": np.zeros((0, MOVIES, K), np.float32),
    }


@pytest.fixture()
def artifact(tmp_path):
    return save_artifact(str(tmp_path / "artifact"), _meta(), _arrays(seed=1))


# ---------- request schema ----------


@pytest.mark.parametrize("payload", [
    "not a dict",
    {},
    {"rows": [0, 1], "cols": [0]},          # length mismatch
    {"rows": [], "cols": []},               # empty batch
    {"rows": [0], "cols": ["x"]},           # non-integer ids
    {"user": 0, "users": [1], "k": 3},      # both scalar and batch form
    {"user": [0, 1], "k": 3},               # scalar form with a batch
    {"users": [], "k": 3},                  # empty users
    {"users": [0], "k": 0},                 # non-positive k
    {"users": [0], "k": True},              # bool is not an int here
])
def test_parse_request_rejects(payload):
    with pytest.raises(RequestError):
        parse_request(payload)


def test_parse_request_shapes():
    req = parse_request({"rows": [0, 1], "cols": [2, 3], "std": True})
    assert isinstance(req, PredictRequest)
    assert req.std and req.size == 2 and req.batch_key() == ("predict", True)
    req = parse_request({"user": 7, "k": 3})
    assert isinstance(req, TopKRequest)
    assert req.scalar and req.size == 1 and req.batch_key() == ("top_k", 3)
    req = parse_request({"users": [7, 8]})  # k defaults to 10
    assert not req.scalar and req.batch_key() == ("top_k", 10)


# ---------- micro-batcher (no device code) ----------


def _echo_group(key, requests):
    return [(key, r) for r in requests]


def test_batcher_groups_by_key_and_preserves_order():
    calls = []

    def run_group(key, requests):
        calls.append((key, len(requests)))
        return [(key, r) for r in requests]

    b = MicroBatcher(run_group, deadline_ms=80.0, adaptive=False)
    try:
        reqs = [
            parse_request({"rows": [0], "cols": [1]}),
            parse_request({"user": 2, "k": 3}),
            parse_request({"rows": [4, 5], "cols": [6, 7]}),
            parse_request({"user": 8, "k": 3}),
        ]
        tickets = [b.submit(r) for r in reqs]
        results = [t.wait(timeout=10) for t in tickets]
    finally:
        b.stop()
    # one cycle, one group call per distinct key, members in submit order
    assert sorted(calls) == [(("predict", False), 2), (("top_k", 3), 2)]
    for r, (key, got) in zip(reqs, results):
        assert key == r.batch_key() and got is r
    s = b.stats()
    assert s["cycles"] == 1 and s["requests"] == 4 and s["coalesced_requests"] == 4


def test_batcher_max_batch_dispatches_early():
    # deadline is far away: only the row cap can release the batch in time
    b = MicroBatcher(_echo_group, deadline_ms=60_000.0, max_batch=4, adaptive=False)
    try:
        t1 = b.submit(parse_request({"rows": [0, 1], "cols": [0, 1]}))
        t2 = b.submit(parse_request({"rows": [2, 3], "cols": [2, 3]}))
        t1.wait(timeout=10)
        t2.wait(timeout=10)
    finally:
        b.stop()


def test_batcher_adaptive_skips_deadline_when_idle():
    b = MicroBatcher(_echo_group, deadline_ms=60_000.0, adaptive=True)
    try:
        t0 = time.monotonic()
        b.submit(parse_request({"user": 0, "k": 1})).wait(timeout=10)
        assert time.monotonic() - t0 < 5.0  # did not wait out the deadline
    finally:
        b.stop()


def test_batcher_error_fans_out_to_every_ticket():
    def boom(key, requests):
        raise RuntimeError("device fell over")

    b = MicroBatcher(boom, deadline_ms=40.0, adaptive=False)
    try:
        tickets = [b.submit(parse_request({"user": u, "k": 2})) for u in (0, 1)]
        for t in tickets:
            with pytest.raises(RuntimeError, match="device fell over"):
                t.wait(timeout=10)
    finally:
        b.stop()


def test_batcher_stop_flushes_queue_and_rejects_new_submits():
    release = threading.Event()

    def slow_group(key, requests):
        release.wait(5)
        return [None] * len(requests)

    b = MicroBatcher(slow_group, deadline_ms=0.0)
    tickets = [b.submit(parse_request({"user": u, "k": 2})) for u in range(6)]
    release.set()
    b.stop()  # must flush everything still queued
    for t in tickets:
        assert t.wait(timeout=0) is None  # resolved, not dropped
    with pytest.raises(RuntimeError):
        b.submit(parse_request({"user": 0, "k": 2}))


# ---------- coalesced vs isolated: bitwise ----------


def test_coalesced_responses_bitwise_equal_isolated(artifact):
    reference = PosteriorPredictor.load(artifact)
    rng = np.random.default_rng(0)
    payloads = []
    for size in (1, 2, 3, 5, 8, 1, 4, 2):
        payloads.append({
            "rows": rng.integers(0, USERS, size).tolist(),
            "cols": rng.integers(0, MOVIES, size).tolist(),
        })
    for _ in range(4):
        payloads.append({"user": int(rng.integers(0, USERS)), "k": 5})
    payloads.append({"users": rng.integers(0, USERS, 3).tolist(), "k": 5})
    expected = [run_request(reference, parse_request(p)) for p in payloads]

    # adaptive off: every request waits the full deadline, so a barrier of
    # concurrent submitters is guaranteed to coalesce
    with BPMFServer(artifact, deadline_ms=300.0, adaptive=False, watch=False) as srv:
        barrier = threading.Barrier(len(payloads))
        results: list = [None] * len(payloads)

        def client(i):
            barrier.wait()
            results[i] = srv.handle_request(payloads[i], timeout=30)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(len(payloads))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = srv.batcher.stats()

    assert stats["coalesced_requests"] > 0, "nothing actually coalesced"
    for (status, got), want in zip(results, expected):
        assert status == 200
        assert got == want  # dict equality on floats == bitwise f32 parity


# ---------- item-sharded top-k ----------


@pytest.mark.parametrize("k", [1, 5, MOVIES])
def test_sharded_topk_matches_replicated(artifact, k):
    p = PosteriorPredictor.load(artifact)
    users = np.arange(USERS, dtype=np.int32)
    ids_r, scores_r = p.top_k(users, k, sharded=False)
    ids_s, scores_s = p.top_k(users, k, sharded=True)
    np.testing.assert_array_equal(ids_s, ids_r)
    np.testing.assert_array_equal(
        scores_s.view(np.uint32), scores_r.view(np.uint32)  # bitwise
    )


def test_topk_mode_validation(artifact):
    with pytest.raises(ValueError, match="topk_mode"):
        PosteriorPredictor.load(artifact, topk_mode="blocked")


def test_predictor_handle_swap_bumps_generation(artifact):
    p1 = PosteriorPredictor.load(artifact)
    p2 = PosteriorPredictor.load(artifact)
    h = PredictorHandle(p1)
    assert h.get() is p1 and h.generation == 0
    assert h.swap(p2) == 1
    got, gen = h.get_with_generation()
    assert got is p2 and gen == 1


# ---------- hot-swap ----------


def test_hot_swap_is_batch_atomic_under_concurrent_clients(artifact, tmp_path):
    old = PosteriorPredictor.load(artifact)
    new_arrays = _arrays(seed=2)
    staged = save_artifact(str(tmp_path / "staged"), _meta(seed=1), new_arrays)
    new = PosteriorPredictor.load(staged)

    rng = np.random.default_rng(3)
    rows = rng.integers(0, USERS, 8).tolist()
    cols = rng.integers(0, MOVIES, 8).tolist()
    payload = {"rows": rows, "cols": cols}
    p_old = run_request(old, parse_request(payload))["predictions"]
    p_new = run_request(new, parse_request(payload))["predictions"]
    assert p_old != p_new  # the swap must be observable

    with BPMFServer(artifact, deadline_ms=1.0, watch=False) as srv:
        stop = threading.Event()
        bad: list = []
        seen = {"old": 0, "new": 0}

        def hammer():
            while not stop.is_set():
                status, resp = srv.handle_request(payload, timeout=30)
                preds = resp.get("predictions")
                if status != 200:
                    bad.append((status, resp))
                elif preds == p_old:
                    seen["old"] += 1
                elif preds == p_new:
                    seen["new"] += 1
                else:
                    bad.append(("torn", preds))  # mixed old/new batch

        threads = [threading.Thread(target=hammer) for _ in range(6)]
        for t in threads:
            t.start()
        time.sleep(0.3)
        # re-export over the live artifact dir, then force a watcher poll
        save_artifact(artifact, _meta(seed=1), new_arrays)
        assert srv.poll_artifact_now() is True
        time.sleep(0.3)
        stop.set()
        for t in threads:
            t.join()

        assert not bad, bad[:3]
        assert seen["old"] > 0 and seen["new"] > 0, seen
        assert srv.generation == 1
        # every request after the swap serves the new posterior
        status, resp = srv.handle_request(payload, timeout=30)
        assert status == 200 and resp["predictions"] == p_new


def test_watcher_rejects_torn_export_and_keeps_serving(artifact):
    payload = {"rows": [0, 1], "cols": [2, 3]}
    with BPMFServer(artifact, watch=False) as srv:
        _, want = srv.handle_request(payload, timeout=30)
        # corrupt the metadata in place: signature changes, load must fail
        meta_path = f"{artifact}/artifact.json"
        good = open(meta_path).read()
        with open(meta_path, "w") as f:
            f.write('{"truncated": ')
        assert srv.poll_artifact_now() is False
        assert srv._swap_failures == 1 and srv.generation == 0
        status, got = srv.handle_request(payload, timeout=30)
        assert status == 200 and got == want  # old posterior still serving
        # a later good export (here: restore + fresh arrays) swaps cleanly
        with open(meta_path, "w") as f:
            f.write(good)
        save_artifact(artifact, _meta(seed=1), _arrays(seed=4))
        assert srv.poll_artifact_now() is True
        assert srv.generation == 1


# ---------- HTTP round-trip ----------


def test_http_roundtrip_bitwise_and_health(artifact):
    reference = PosteriorPredictor.load(artifact)
    rng = np.random.default_rng(5)
    rows = rng.integers(0, USERS, 7)
    cols = rng.integers(0, MOVIES, 7)
    with BPMFServer(artifact, watch=False) as srv:
        host, port = srv.address
        c = ServeClient(f"{host}:{port}")

        preds = c.predict(rows, cols)
        np.testing.assert_array_equal(preds, reference.predict(rows, cols))

        ids, scores = c.top_k(3, k=5)
        want_ids, want_scores = reference.top_k(np.asarray([3], np.int32), 5)
        np.testing.assert_array_equal(ids, want_ids[0])
        np.testing.assert_array_equal(scores, want_scores[0])

        h = c.health()
        assert h["status"] == "ok" and h["generation"] == 0
        assert h["artifact"]["num_movies"] == MOVIES
        s = c.stats()
        assert s["batcher"]["requests"] >= 2 and s["swap_failures"] == 0

        with pytest.raises(ServeRequestError):
            c.predict([USERS + 5], [0])  # out-of-range id -> 400 error body
        resp = c.request({"nonsense": 1})
        assert "error" in resp
        c.close()


def test_parse_address_forms():
    assert parse_address("127.0.0.1:8642") == ("127.0.0.1", 8642)
    assert parse_address("http://localhost:80/") == ("localhost", 80)
    assert parse_address(":8642") == ("127.0.0.1", 8642)
    for bad in ("nope", "host:", "host:http", ""):
        with pytest.raises(ValueError):
            parse_address(bad)


def test_serve_cli_server_mode(artifact, capsys):
    from repro.launch import serve as serve_cli

    reference = PosteriorPredictor.load(artifact)
    with BPMFServer(artifact, watch=False) as srv:
        host, port = srv.address
        rc = serve_cli.main(
            ["--server", f"{host}:{port}", "--user", "3", "--top-k", "4"]
        )
        assert rc == 0
        out = json.loads(capsys.readouterr().out)
        want_ids, want_scores = reference.top_k(np.asarray([3], np.int32), 4)
        assert out["items"] == want_ids[0].tolist()
        assert out["scores"] == want_scores[0].tolist()

    # with the server gone the CLI reports the connection failure
    rc = serve_cli.main(["--server", f"{host}:{port}", "--user", "3"])
    assert rc == 1
    assert "cannot reach server" in capsys.readouterr().err


def test_serve_cli_requires_exactly_one_source(capsys):
    from repro.launch import serve as serve_cli

    assert serve_cli.main(["--user", "0"]) == 2
    assert serve_cli.main(
        ["--artifact", "/tmp/x", "--server", "h:1", "--user", "0"]
    ) == 2
