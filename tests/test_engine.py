"""Engine facade tests: registry dispatch, cross-backend parity (paper
§V-B as an API-level property), checkpoint round-trip, CLI smoke.

Multi-device runs go through conftest.run_with_devices subprocesses; the
in-process tests use whatever device count the main process has (ring and
allgather degrade gracefully to one shard).
"""
from __future__ import annotations

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from conftest import run_with_devices
from repro.bpmf import (
    BPMFConfig,
    BPMFEngine,
    available_backends,
    available_datasets,
    load_dataset,
)
from repro.data.sparse import RatingsCOO


def _small_cfg(**kw) -> BPMFConfig:
    base = dict(K=6, num_sweeps=4, burn_in=1, bucket_pads=(8, 32, 128))
    base.update(kw)
    return BPMFConfig().replace(**base)


def _small_coo(seed: int = 3) -> RatingsCOO:
    return load_dataset(
        "synthetic", num_users=90, num_movies=45, nnz=1000, noise_std=0.3, seed=seed
    )


# ---------- registries / config ----------


def test_backend_registry():
    assert {"sequential", "ring", "ring_async", "allgather"} <= set(available_backends())
    with pytest.raises(ValueError, match="unknown backend"):
        BPMFEngine(BPMFConfig().replace(name="mpi"))


def test_dataset_registry():
    assert {"synthetic", "movielens", "chembl"} <= set(available_datasets())
    coo = _small_coo()
    assert isinstance(coo, RatingsCOO) and coo.nnz > 0
    with pytest.raises(ValueError, match="unknown dataset"):
        load_dataset("netflix-prize")


def test_config_replace_routes_to_subconfigs():
    cfg = BPMFConfig().replace(name="ring", K=12, num_sweeps=9, gram_impl="pallas", seed=5)
    assert cfg.backend.name == "ring" and cfg.backend.gram_impl == "pallas"
    assert cfg.model.K == 12
    assert cfg.run.num_sweeps == 9 and cfg.run.seed == 5
    with pytest.raises(TypeError, match="unknown"):
        cfg.replace(warp_drive=True)


def test_gram_impl_validated_and_lowered():
    cfg = _small_cfg(name="ring", gram_impl="pallas_fused")
    core = cfg.core()
    assert core.gram_impl == "pallas_fused"
    hash(core)
    with pytest.raises(ValueError, match="gram_impl"):
        _small_cfg(gram_impl="cuda")


def test_use_pallas_shim_warns_once_and_maps(monkeypatch):
    """The deprecated use_pallas boolean warns exactly once per process and
    maps True -> gram_impl="pallas", False -> "xla"."""
    import warnings

    from repro.bpmf import config as config_mod

    monkeypatch.setattr(config_mod, "_USE_PALLAS_WARNED", False)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        a = config_mod.BackendConfig(use_pallas=True)
        b = config_mod.BackendConfig(use_pallas=False)
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)
           and "use_pallas" in str(x.message)]
    assert len(dep) == 1, [str(x.message) for x in w]
    assert a.gram_impl == "pallas" and b.gram_impl == "xla"
    # engine-level replace() goes through the same shim
    cfg = BPMFConfig().replace(use_pallas=True)
    assert cfg.backend.gram_impl == "pallas"
    # the legacy flag is consumed on mapping: a later explicit gram_impl
    # must win, not be clobbered by a retained stale boolean
    assert cfg.replace(gram_impl="xla").backend.gram_impl == "xla"
    assert config_mod.BackendConfig(use_pallas=True) == config_mod.BackendConfig(
        gram_impl="pallas"
    )
    # untouched configs don't warn and default to measured dispatch
    assert config_mod.BackendConfig().gram_impl == "auto"
    # conflicting old + new spellings is an error, not a silent override
    with pytest.raises(ValueError, match="use_pallas"):
        config_mod.BackendConfig(gram_impl="pallas_fused", use_pallas=True)


def test_config_lowers_to_core():
    cfg = _small_cfg(name="allgather", alpha=1.5)
    core = cfg.core()
    assert core.comm_mode == "allgather"
    assert core.K == 6 and core.alpha == 1.5 and core.num_sweeps == 4
    hash(core)  # must stay hashable for jit static args


def test_config_lowers_pipeline_depth():
    cfg = _small_cfg(name="ring_async", pipeline_depth=3)
    core = cfg.core()
    assert core.comm_mode == "ring_async" and core.pipeline_depth == 3
    hash(core)
    with pytest.raises(ValueError, match="pipeline_depth"):
        _small_cfg(name="ring_async", pipeline_depth=0)


# ---------- cross-backend parity (the paper's §V-B claim, facade-level) ----------


def test_cross_backend_parity_in_process():
    """Same (seed, data) through every exact-parity backend via config
    alone. Approximate backends (``posterior_merge``) opt out via
    ``Backend.exact_parity`` and are gated statistically in
    tests/test_posterior_quality.py instead."""
    from repro.bpmf.backends import BACKENDS

    coo = _small_coo()
    results = {}
    for name in available_backends():
        if not BACKENDS[name].exact_parity:
            continue
        engine = BPMFEngine(_small_cfg(name=name)).fit(coo)
        results[name] = (engine.history, engine.factors())
    assert len(results) >= 4  # the parity family itself must not shrink
    ref_hist, (ref_U, ref_V) = results["sequential"]
    for name, (hist, (U, V)) in results.items():
        np.testing.assert_allclose(U, ref_U, atol=2e-3, err_msg=name)
        np.testing.assert_allclose(V, ref_V, atol=2e-3, err_msg=name)
        for m, mr in zip(hist, ref_hist):
            assert abs(m.rmse_avg - mr.rmse_avg) < 1e-3, (name, m, mr)


ENGINE_PARITY_CODE = """
import numpy as np
from repro.bpmf import BPMFConfig, BPMFEngine, load_dataset

coo = load_dataset("synthetic", num_users=120, num_movies=45, nnz=1080,
                   noise_std=0.3, seed=3)
cfg = BPMFConfig().replace(K=8, num_sweeps=4, burn_in=1, bucket_pads=(8, 32, 128))
variants = [("SEQUENTIAL", dict(name="sequential")),
            ("RING", dict(name="ring")),
            ("ALLGATHER", dict(name="allgather"))]
variants += [("RINGASYNC%d" % d, dict(name="ring_async", pipeline_depth=d))
             for d in (1, 2, 4)]
out = {}
for label, kw in variants:
    e = BPMFEngine(cfg.replace(**kw)).fit(coo)
    out[label] = (e.factors(), e.rmse)
(U0, V0), r0 = out["SEQUENTIAL"]
for label, ((U, V), r) in out.items():
    if label == "SEQUENTIAL":
        continue
    print(label + "_ERRU", float(np.max(np.abs(U - U0))))
    print(label + "_ERRV", float(np.max(np.abs(V - V0))))
    print(label + "_DRMSE", abs(r - r0))
"""


@pytest.mark.multidevice
def test_cross_backend_parity_multidevice():
    """Facade parity with the distributed backends on a real 4-device mesh,
    including ring_async at pipeline_depth 1/2/4."""
    out = run_with_devices(ENGINE_PARITY_CODE, num_devices=4, timeout=900)
    vals = {}
    for line in out.splitlines():
        parts = line.split()
        if len(parts) == 2 and ("ERR" in parts[0] or "DRMSE" in parts[0]):
            vals[parts[0]] = float(parts[1])
    assert vals, out
    assert any(k.startswith("RINGASYNC4") for k in vals), vals
    for k, v in vals.items():
        tol = 1e-3 if "DRMSE" in k else 2e-3
        assert v < tol, (k, v, vals)


GRAM_PARITY_CODE = """
import numpy as np
from repro.bpmf import BPMFConfig, BPMFEngine, load_dataset

coo = load_dataset("synthetic", num_users=120, num_movies=45, nnz=1080,
                   noise_std=0.3, seed=3)
cfg = BPMFConfig().replace(K=8, num_sweeps=3, burn_in=1, bucket_pads=(8, 32, 128))
out = {}
for backend in ("ring", "ring_async"):
    extra = {"pipeline_depth": 2} if backend == "ring_async" else {}
    for impl in ("xla", "auto", "pallas_fused"):
        e = BPMFEngine(cfg.replace(name=backend, gram_impl=impl, **extra)).fit(coo)
        out[(backend, impl)] = e.factors()
for backend in ("ring", "ring_async"):
    U0, V0 = out[(backend, "xla")]
    for impl in ("auto", "pallas_fused"):
        U, V = out[(backend, impl)]
        print(backend.upper() + "_" + impl.upper() + "_ERRU", float(np.max(np.abs(U - U0))))
        print(backend.upper() + "_" + impl.upper() + "_ERRV", float(np.max(np.abs(V - V0))))
"""


@pytest.mark.multidevice
def test_gram_impl_parity_multidevice():
    """gram_impl "auto" and "pallas_fused" draw the same samples as "xla"
    through the engine on a real 4-device mesh (ring and ring_async): the
    fused kernel's in-kernel scatter accumulation is a pure implementation
    detail of the Gram hot path."""
    out = run_with_devices(GRAM_PARITY_CODE, num_devices=4, timeout=900)
    vals = {}
    for line in out.splitlines():
        parts = line.split()
        if len(parts) == 2 and "ERR" in parts[0]:
            vals[parts[0]] = float(parts[1])
    assert len(vals) == 8, out
    assert any("PALLAS_FUSED" in k for k in vals), vals
    for k, v in vals.items():
        assert v < 2e-3, (k, v, vals)


def test_ring_async_depths_bitwise_parity_in_process():
    """ring_async must equal ring *exactly* for every depth (DESIGN.md §7):
    pipelining reorders transfer issue times, never the accumulated values."""
    coo = _small_coo()
    ref = BPMFEngine(_small_cfg(name="ring")).fit(coo)
    U0, V0 = ref.factors()
    for depth in (1, 2, 4):
        e = BPMFEngine(_small_cfg(name="ring_async", pipeline_depth=depth)).fit(coo)
        U, V = e.factors()
        np.testing.assert_array_equal(U, U0, err_msg=f"depth={depth}")
        np.testing.assert_array_equal(V, V0, err_msg=f"depth={depth}")
        assert [m.rmse_avg for m in e.history] == [m.rmse_avg for m in ref.history]


def test_legacy_run_wrapper_matches_engine():
    """core.gibbs.run stays alive as a thin wrapper over the sequential backend."""
    from repro.core.gibbs import run as legacy_run
    from repro.data.sparse import build_bpmf_data

    coo = _small_coo(seed=9)
    cfg = _small_cfg()
    engine = BPMFEngine(cfg).fit(coo)
    data = build_bpmf_data(
        coo, pads=cfg.backend.bucket_pads, test_fraction=cfg.run.test_fraction,
        seed=cfg.run.seed,
    )
    _, _, hist = legacy_run(jax.random.key(cfg.run.seed), data, cfg.core())
    assert [m.rmse_sample for m in hist] == [m.rmse_sample for m in engine.history]


# ---------- checkpoint round-trip ----------


@pytest.mark.parametrize("name", ["sequential", "ring", "ring_async"])
def test_checkpoint_roundtrip_resumes_identically(tmp_path, name):
    """save() mid-run -> restore() in a fresh engine -> identical metrics.

    For ring_async (depth 2) this is the mid-sweep resume the pipelined
    schedule must survive: the queue is rebuilt from the restored factor
    shards, so no in-flight buffer state needs checkpointing.

    ``sweeps_per_block=3`` makes the manual save land at the end of an
    executed block (the blocked engine advances a whole block at a time);
    the resumed run then continues with the default block schedule.
    """
    coo = _small_coo(seed=5)
    extra = {"pipeline_depth": 2} if name == "ring_async" else {}
    cfg = _small_cfg(
        name=name, num_sweeps=6, sweeps_per_block=3,
        checkpoint_dir=str(tmp_path / name), **extra
    )

    full = BPMFEngine(cfg).fit(coo)

    interrupted = BPMFEngine(cfg)
    it = interrupted.sample(coo)
    for _ in range(3):
        next(it)
    saved_at = interrupted.save()
    assert saved_at == 3
    del interrupted, it

    resumed = BPMFEngine(cfg)
    assert resumed.restore(coo) == 3
    assert len(resumed.history) == 3  # metric history travels with the checkpoint
    resumed.fit()
    assert resumed.num_sweeps_done == cfg.run.num_sweeps
    got = [m.rmse_avg for m in resumed.history]
    want = [m.rmse_avg for m in full.history]
    assert got == want, (got, want)
    np.testing.assert_array_equal(resumed.factors()[0], full.factors()[0])


def test_checkpoint_every_autosaves(tmp_path):
    coo = _small_coo(seed=6)
    cfg = _small_cfg(num_sweeps=4, checkpoint_dir=str(tmp_path), checkpoint_every=2)
    engine = BPMFEngine(cfg).fit(coo)
    assert engine._manager().all_steps() == [2, 4]
    # fit(resume=True) on a fresh engine picks up the final checkpoint,
    # including the metric history (so .rmse works on a completed run)
    again = BPMFEngine(cfg)
    again.prepare(coo)
    again.fit(resume=True)
    assert again.num_sweeps_done == 4
    assert [m.rmse_avg for m in again.history] == [m.rmse_avg for m in engine.history]
    assert again.rmse == engine.rmse


def test_num_shards_exceeding_devices_raises():
    cfg = _small_cfg(name="ring", num_shards=len(jax.devices()) + 1)
    with pytest.raises(ValueError, match="num_shards"):
        BPMFEngine(cfg).prepare(_small_coo())


def test_prepare_rejects_different_data():
    engine = BPMFEngine(_small_cfg())
    engine.prepare(_small_coo())
    engine.prepare(_small_coo())  # same dataset: fine
    other = load_dataset("synthetic", num_users=30, num_movies=20, nnz=200)
    with pytest.raises(ValueError, match="different data"):
        engine.prepare(other)


def test_restore_without_checkpoint_raises(tmp_path):
    cfg = _small_cfg(checkpoint_dir=str(tmp_path))
    engine = BPMFEngine(cfg)
    with pytest.raises(FileNotFoundError):
        engine.restore(_small_coo())


# ---------- predictions ----------


def test_predict_clipped_and_shaped():
    coo = _small_coo()
    engine = BPMFEngine(_small_cfg()).fit(coo)
    rows = np.arange(10, dtype=np.int32)
    cols = np.arange(10, dtype=np.int32)
    preds = engine.predict(rows, cols)
    lo, hi = engine.backend.rating_range
    assert preds.shape == (10,)
    assert np.all(preds >= lo - 1e-6) and np.all(preds <= hi + 1e-6)


# ---------- CLI ----------


def test_cli_smoke():
    """python -m repro.launch.bpmf completes and prints per-sweep RMSE."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.bpmf",
            "--backend", "sequential", "--dataset", "synthetic",
            "--sweeps", "3", "--burn-in", "1", "--K", "4",
            "--users", "80", "--movies", "40", "--nnz", "800",
        ],
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr
    assert "rmse(avg)" in proc.stdout
    assert "final rmse(avg)=" in proc.stdout
