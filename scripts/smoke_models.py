"""Dev script: one fwd + train + prefill/decode per reduced arch on CPU."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models.model import build_model
from repro.training.optimizer import AdamW
from repro.training.train import init_train_state, make_train_step

B, L = 2, 64

for arch in ARCHS if len(sys.argv) < 2 else sys.argv[1:]:
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    key = jax.random.key(0)
    params = model.init(key)

    if cfg.input_mode == "tokens":
        inputs = jax.random.randint(key, (B, L), 0, cfg.vocab_size)
    else:
        inputs = jax.random.normal(key, (B, L, cfg.frame_dim), jnp.bfloat16)
    labels = jax.random.randint(key, (B, L), 0, cfg.vocab_size)
    mask = jnp.ones((B, L), jnp.float32)

    logits, metrics = jax.jit(model.forward)(params, inputs)
    assert logits.shape == (B, L, cfg.padded_vocab), logits.shape
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaN logits"

    opt = AdamW(learning_rate=1e-3)
    state = init_train_state(key, model, opt)
    step = jax.jit(make_train_step(model, opt))
    state, m = step(state, {"inputs": inputs, "labels": labels, "mask": mask})
    assert not bool(jnp.isnan(m["loss"])), f"{arch}: NaN loss"

    decode_info = "no-decode"
    if not cfg.is_encoder:
        cache = model.init_cache(B, L + 8)
        lg, cache = jax.jit(model.prefill)(params, inputs[:, : L // 2], cache)
        tok = jnp.argmax(lg[:, -1, :], -1)[:, None].astype(jnp.int32)
        lg2, cache = jax.jit(model.decode)(params, tok, cache, jnp.asarray([L // 2], jnp.int32))
        assert lg2.shape == (B, 1, cfg.padded_vocab)
        assert not bool(jnp.any(jnp.isnan(lg2))), f"{arch}: NaN decode"
        decode_info = "decode-ok"

    print(f"[ok] {arch:18s} loss={float(m['loss']):.3f} {decode_info}")
