#!/usr/bin/env python
"""Validate experiments/bench JSON artifacts against the documented schema.

Usage: ``python scripts/check_bench_schema.py <name> [<name> ...]`` where
``<name>`` is an artifact basename (``fig2_item_update``, ``fig5_overlap``).
Checks the structural invariants documented in ``experiments/bench/README.md``
— required keys, entry shapes, value domains — and exits non-zero with a
list of violations. ``scripts/test.sh --autotune-smoke`` runs it after the
fig2 driver.
"""
from __future__ import annotations

import json
import os
import sys

BENCH_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "experiments", "bench")

IMPLS = ("pallas_fused", "pallas", "xla")


def check_fig2_item_update(payload: dict) -> list[str]:
    """Schema of fig2_item_update.json (cost-model fit + kernel sweep)."""
    errs: list[str] = []
    rows = payload.get("rows")
    if not isinstance(rows, list) or not rows:
        errs.append("rows: missing or empty")
    else:
        for i, r in enumerate(rows):
            for k in ("nnz", "t_naive_s", "t_single_chol_s", "t_batched_per_item_s"):
                if not isinstance(r.get(k), (int, float)):
                    errs.append(f"rows[{i}].{k}: missing or non-numeric")
    cm = payload.get("cost_model")
    if not isinstance(cm, dict) or not all(
        isinstance(cm.get(k), (int, float)) for k in ("fixed_us", "per_rating_us")
    ):
        errs.append("cost_model: needs numeric fixed_us and per_rating_us")
    if payload.get("device") not in ("cpu", "gpu", "tpu"):
        errs.append(f"device: unexpected {payload.get('device')!r}")
    sweep = payload.get("kernel_sweep")
    if not isinstance(sweep, dict) or not sweep:
        errs.append("kernel_sweep: missing or empty")
        return errs
    for name, e in sweep.items():
        where = f"kernel_sweep[{name}]"
        if e.get("winner") not in IMPLS:
            errs.append(f"{where}.winner: {e.get('winner')!r} not in {IMPLS}")
        t = e.get("timings_us")
        if not isinstance(t, dict) or not set(IMPLS) <= set(t):
            errs.append(f"{where}.timings_us: needs all of {IMPLS}")
        elif any(not isinstance(t[k], (int, float)) or t[k] <= 0 for k in IMPLS):
            errs.append(f"{where}.timings_us: non-positive or non-numeric entry")
        if not isinstance(e.get("buckets"), list) or not e["buckets"]:
            errs.append(f"{where}.buckets: missing or empty")
        for k in ("Ns", "K"):
            if not isinstance(e.get(k), int):
                errs.append(f"{where}.{k}: missing or non-int")
    ws = payload.get("workload_sweep")
    if ws:  # optional: full runs only (smoke merges preserve an existing one)
        for name, e in ws.get("entries", {}).items():
            if e.get("winner") not in IMPLS:
                errs.append(f"workload_sweep.entries[{name}].winner: {e.get('winner')!r}")
            if not isinstance(e.get("cap"), int):
                errs.append(f"workload_sweep.entries[{name}].cap: missing or non-int")
    return errs


def check_fig5_overlap(payload: dict) -> list[str]:
    """Schema of fig5_overlap.json (overlap modes + parity flags)."""
    errs: list[str] = []
    modes = payload.get("modes")
    if not isinstance(modes, dict) or not modes:
        errs.append("modes: missing or empty")
        return errs
    for name, m in modes.items():
        for k in ("seconds", "seconds_per_sweep", "rmse"):
            if not isinstance(m.get(k), (int, float)):
                errs.append(f"modes[{name}].{k}: missing or non-numeric")
    if not isinstance(payload.get("parity_ok"), bool):
        errs.append("parity_ok: missing or non-bool")
    return errs


def check_serve_latency(payload: dict) -> list[str]:
    """Schema of serve_latency.json (posterior-serving batch-size sweep)."""
    errs: list[str] = []
    if payload.get("device") not in ("cpu", "gpu", "tpu"):
        errs.append(f"device: unexpected {payload.get('device')!r}")
    if not isinstance(payload.get("repeats"), int) or payload.get("repeats", 0) < 1:
        errs.append("repeats: missing or < 1")
    art = payload.get("artifact")
    if not isinstance(art, dict) or not all(
        isinstance(art.get(k), int)
        for k in ("num_users", "num_movies", "K", "num_mean_samples", "num_kept_samples")
    ):
        errs.append("artifact: needs int num_users/num_movies/K/"
                    "num_mean_samples/num_kept_samples")
    batches = payload.get("batches")
    if not isinstance(batches, dict) or not batches:
        errs.append("batches: missing or empty")
        return errs
    lat_keys = ("p50_ms", "p99_ms", "mean_ms", "qps")
    for name, e in batches.items():
        where = f"batches[{name}]"
        if not name.isdigit() or int(name) < 1:
            errs.append(f"{where}: key must be a positive batch size")
        if not isinstance(e, dict) or any(
            not isinstance(e.get(k), (int, float)) or e.get(k, 0) <= 0 for k in lat_keys
        ):
            errs.append(f"{where}: needs positive numeric {lat_keys}")
        elif e["p50_ms"] > e["p99_ms"] + 1e-9:
            errs.append(f"{where}: p50_ms > p99_ms")
    tk = payload.get("top_k")
    if not isinstance(tk, dict) or not isinstance(tk.get("k"), int) or any(
        not isinstance(tk.get(k), (int, float)) or tk.get(k, 0) <= 0 for k in lat_keys
    ):
        errs.append(f"top_k: needs int k and positive numeric {lat_keys}")
    return errs


def check_sweep_throughput(payload: dict) -> list[str]:
    """Schema of sweep_throughput.json (blocked run-loop host traffic)."""
    errs: list[str] = []
    if not isinstance(payload.get("devices"), int) or payload.get("devices", 0) < 1:
        errs.append("devices: missing or < 1")
    gather = payload.get("factor_gather_bytes")
    if not isinstance(gather, (int, float)) or gather <= 0:
        errs.append("factor_gather_bytes: missing or non-positive")
    for k in ("parity_ok", "block_transfer_drop_ok"):
        if not isinstance(payload.get(k), bool):
            errs.append(f"{k}: missing or non-bool")
        elif not payload[k]:
            errs.append(f"{k}: False — blocked loop regressed")
    backends = payload.get("backends")
    if not isinstance(backends, dict) or not backends:
        errs.append("backends: missing or empty")
        return errs
    needed = ("seconds", "sweeps_per_sec", "host_bytes_per_sweep", "rmse")
    for name, entries in backends.items():
        if not isinstance(entries, dict) or "legacy_emulated" not in entries:
            errs.append(f"backends[{name}]: needs block_* and legacy_emulated entries")
            continue
        blocks = [k for k in entries if k.startswith("block_")]
        if not any(k != "block_1" for k in blocks):
            errs.append(f"backends[{name}]: needs at least one block_>1 entry")
        for label, e in entries.items():
            where = f"backends[{name}].{label}"
            for k in needed:
                if not isinstance(e.get(k), (int, float)) or e.get(k, 0) <= 0:
                    errs.append(f"{where}.{k}: missing or non-positive")
        legacy = entries["legacy_emulated"]
        if not isinstance(
            legacy.get("host_bytes_per_post_burn_in_sweep"), (int, float)
        ):
            errs.append(
                f"backends[{name}].legacy_emulated."
                "host_bytes_per_post_burn_in_sweep: missing or non-numeric"
            )
    return errs


CHECKERS = {
    "fig2_item_update": check_fig2_item_update,
    "fig5_overlap": check_fig5_overlap,
    "serve_latency": check_serve_latency,
    "sweep_throughput": check_sweep_throughput,
}


def main(argv: list[str]) -> int:
    if not argv:
        print(f"usage: {sys.argv[0]} <artifact-name> [...]; known: {sorted(CHECKERS)}")
        return 2
    rc = 0
    for name in argv:
        if name not in CHECKERS:
            print(f"{name}: no schema checker (known: {sorted(CHECKERS)})")
            rc = 1
            continue
        path = os.path.normpath(os.path.join(BENCH_DIR, f"{name}.json"))
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError) as e:
            print(f"{name}: cannot load {path}: {e}")
            rc = 1
            continue
        errs = CHECKERS[name](payload)
        if errs:
            print(f"{name}: schema FAILED ({len(errs)} violation(s))")
            for e in errs:
                print(f"  - {e}")
            rc = 1
        else:
            print(f"{name}: schema OK ({path})")
    return rc


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
