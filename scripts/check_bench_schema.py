#!/usr/bin/env python
"""Validate experiments/bench JSON artifacts against the documented schema.

Usage: ``python scripts/check_bench_schema.py <name> [<name> ...]`` where
``<name>`` is an artifact basename (``fig2_item_update``, ``fig5_overlap``).
Checks the structural invariants documented in ``experiments/bench/README.md``
— required keys, entry shapes, value domains — and exits non-zero with a
list of violations. ``scripts/test.sh`` smoke stanzas run it after each
benchmark.

With ``--path FILE`` (one name only) the payload is read from ``FILE``
instead of the committed ``experiments/bench/<name>.json`` — how the smoke
stanzas validate their temp-path outputs. Committed artifacts (no
``--path``) are additionally held to the smoke regression contract: any
payload that defines ``"smoke"`` must have it ``false``, and the
benchmarks that stamp the flag (:data:`SMOKE_STAMPED`) must define it — a
smoke run that clobbered a committed JSON fails here.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BENCH_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "experiments", "bench")

IMPLS = ("pallas_fused", "pallas", "xla")

# benchmarks whose payloads always carry a "smoke" flag: their committed
# JSON must define it (and, like every committed file, have it false)
SMOKE_STAMPED = ("serve_latency", "serve_load", "sweep_throughput", "fig_merge_comm",
                 "fig4_scaling")


def check_fig2_item_update(payload: dict) -> list[str]:
    """Schema of fig2_item_update.json (cost-model fit + kernel sweep)."""
    errs: list[str] = []
    rows = payload.get("rows")
    if not isinstance(rows, list) or not rows:
        errs.append("rows: missing or empty")
    else:
        for i, r in enumerate(rows):
            for k in ("nnz", "t_naive_s", "t_single_chol_s", "t_batched_per_item_s"):
                if not isinstance(r.get(k), (int, float)):
                    errs.append(f"rows[{i}].{k}: missing or non-numeric")
    cm = payload.get("cost_model")
    if not isinstance(cm, dict) or not all(
        isinstance(cm.get(k), (int, float)) for k in ("fixed_us", "per_rating_us")
    ):
        errs.append("cost_model: needs numeric fixed_us and per_rating_us")
    if payload.get("device") not in ("cpu", "gpu", "tpu"):
        errs.append(f"device: unexpected {payload.get('device')!r}")
    sweep = payload.get("kernel_sweep")
    if not isinstance(sweep, dict) or not sweep:
        errs.append("kernel_sweep: missing or empty")
        return errs
    for name, e in sweep.items():
        where = f"kernel_sweep[{name}]"
        if e.get("winner") not in IMPLS:
            errs.append(f"{where}.winner: {e.get('winner')!r} not in {IMPLS}")
        t = e.get("timings_us")
        if not isinstance(t, dict) or not set(IMPLS) <= set(t):
            errs.append(f"{where}.timings_us: needs all of {IMPLS}")
        elif any(not isinstance(t[k], (int, float)) or t[k] <= 0 for k in IMPLS):
            errs.append(f"{where}.timings_us: non-positive or non-numeric entry")
        if not isinstance(e.get("buckets"), list) or not e["buckets"]:
            errs.append(f"{where}.buckets: missing or empty")
        for k in ("Ns", "K"):
            if not isinstance(e.get(k), int):
                errs.append(f"{where}.{k}: missing or non-int")
    ws = payload.get("workload_sweep")
    if ws:  # optional: full runs only (smoke merges preserve an existing one)
        for name, e in ws.get("entries", {}).items():
            if e.get("winner") not in IMPLS:
                errs.append(f"workload_sweep.entries[{name}].winner: {e.get('winner')!r}")
            if not isinstance(e.get("cap"), int):
                errs.append(f"workload_sweep.entries[{name}].cap: missing or non-int")
    return errs


def check_fig5_overlap(payload: dict) -> list[str]:
    """Schema of fig5_overlap.json (overlap modes + parity flags)."""
    errs: list[str] = []
    modes = payload.get("modes")
    if not isinstance(modes, dict) or not modes:
        errs.append("modes: missing or empty")
        return errs
    for name, m in modes.items():
        for k in ("seconds", "seconds_per_sweep", "rmse"):
            if not isinstance(m.get(k), (int, float)):
                errs.append(f"modes[{name}].{k}: missing or non-numeric")
    if not isinstance(payload.get("parity_ok"), bool):
        errs.append("parity_ok: missing or non-bool")
    return errs


def check_serve_latency(payload: dict) -> list[str]:
    """Schema of serve_latency.json (posterior-serving batch-size sweep)."""
    errs: list[str] = []
    if payload.get("device") not in ("cpu", "gpu", "tpu"):
        errs.append(f"device: unexpected {payload.get('device')!r}")
    if not isinstance(payload.get("repeats"), int) or payload.get("repeats", 0) < 1:
        errs.append("repeats: missing or < 1")
    art = payload.get("artifact")
    if not isinstance(art, dict) or not all(
        isinstance(art.get(k), int)
        for k in ("num_users", "num_movies", "K", "num_mean_samples", "num_kept_samples")
    ):
        errs.append("artifact: needs int num_users/num_movies/K/"
                    "num_mean_samples/num_kept_samples")
    batches = payload.get("batches")
    if not isinstance(batches, dict) or not batches:
        errs.append("batches: missing or empty")
        return errs
    lat_keys = ("p50_ms", "p99_ms", "mean_ms", "qps")
    for name, e in batches.items():
        where = f"batches[{name}]"
        if not name.isdigit() or int(name) < 1:
            errs.append(f"{where}: key must be a positive batch size")
        if not isinstance(e, dict) or any(
            not isinstance(e.get(k), (int, float)) or e.get(k, 0) <= 0 for k in lat_keys
        ):
            errs.append(f"{where}: needs positive numeric {lat_keys}")
        elif e["p50_ms"] > e["p99_ms"] + 1e-9:
            errs.append(f"{where}: p50_ms > p99_ms")
    tk = payload.get("top_k")
    if not isinstance(tk, dict) or not isinstance(tk.get("k"), int) or any(
        not isinstance(tk.get(k), (int, float)) or tk.get(k, 0) <= 0 for k in lat_keys
    ):
        errs.append(f"top_k: needs int k and positive numeric {lat_keys}")
    return errs


def check_sweep_throughput(payload: dict) -> list[str]:
    """Schema of sweep_throughput.json (blocked run-loop host traffic)."""
    errs: list[str] = []
    if not isinstance(payload.get("devices"), int) or payload.get("devices", 0) < 1:
        errs.append("devices: missing or < 1")
    gather = payload.get("factor_gather_bytes")
    if not isinstance(gather, (int, float)) or gather <= 0:
        errs.append("factor_gather_bytes: missing or non-positive")
    for k in ("parity_ok", "block_transfer_drop_ok"):
        if not isinstance(payload.get(k), bool):
            errs.append(f"{k}: missing or non-bool")
        elif not payload[k]:
            errs.append(f"{k}: False — blocked loop regressed")
    backends = payload.get("backends")
    if not isinstance(backends, dict) or not backends:
        errs.append("backends: missing or empty")
        return errs
    needed = ("seconds", "sweeps_per_sec", "host_bytes_per_sweep", "rmse")
    for name, entries in backends.items():
        if not isinstance(entries, dict) or "legacy_emulated" not in entries:
            errs.append(f"backends[{name}]: needs block_* and legacy_emulated entries")
            continue
        blocks = [k for k in entries if k.startswith("block_")]
        if not any(k != "block_1" for k in blocks):
            errs.append(f"backends[{name}]: needs at least one block_>1 entry")
        for label, e in entries.items():
            where = f"backends[{name}].{label}"
            for k in needed:
                if not isinstance(e.get(k), (int, float)) or e.get(k, 0) <= 0:
                    errs.append(f"{where}.{k}: missing or non-positive")
        legacy = entries["legacy_emulated"]
        if not isinstance(
            legacy.get("host_bytes_per_post_burn_in_sweep"), (int, float)
        ):
            errs.append(
                f"backends[{name}].legacy_emulated."
                "host_bytes_per_post_burn_in_sweep: missing or non-numeric"
            )
        # overlap columns (DESIGN.md §13): both depths present, each with
        # the host-blocked accounting the pipeline exists to shrink
        for label in ("overlap_off", "overlap_on"):
            e = entries.get(label)
            where = f"backends[{name}].{label}"
            if not isinstance(e, dict):
                errs.append(f"{where}: missing overlap entry")
                continue
            if not isinstance(e.get("pipeline_blocks"), int) or e["pipeline_blocks"] < 1:
                errs.append(f"{where}.pipeline_blocks: missing or < 1")
            hb = e.get("host_blocked_s_per_block")
            if not isinstance(hb, (int, float)) or hb < 0:
                errs.append(f"{where}.host_blocked_s_per_block: missing or negative")
    if not isinstance(payload.get("overlap_speedup_ok"), bool):
        errs.append("overlap_speedup_ok: missing or non-bool")
    elif not payload["overlap_speedup_ok"]:
        # warn, never fail: on CPU host meshes pipelined blocks contend for
        # the same cores, so overlap-on beating overlap-off is not a given
        print("sweep_throughput: warning — overlap_speedup_ok is False "
              "(overlap-on slower than overlap-off; expected on CPU meshes, "
              "where the numbers order mechanisms only)")
    lat = payload.get("save_return_latency")
    if not isinstance(lat, dict) or not all(
        isinstance(lat.get(k), (int, float)) and lat.get(k, 0) > 0
        for k in ("async_s", "sync_s")
    ):
        errs.append("save_return_latency: needs positive numeric async_s and sync_s")
    elif not isinstance(lat.get("async_returns_faster"), bool):
        errs.append("save_return_latency.async_returns_faster: missing or non-bool")
    return errs


def check_fig_merge_comm(payload: dict) -> list[str]:
    """Schema of fig_merge_comm.json (RMSE vs communication trade-off)."""
    errs: list[str] = []
    if not isinstance(payload.get("devices"), int) or payload.get("devices", 0) < 1:
        errs.append("devices: missing or < 1")
    if not isinstance(payload.get("baseline_rmse"), (int, float)):
        errs.append("baseline_rmse: missing or non-numeric")
    band = payload.get("merge_band")
    if (
        not isinstance(band, list) or len(band) != 2
        or not all(isinstance(b, (int, float)) for b in band)
        or band[0] >= band[1]
    ):
        errs.append("merge_band: needs [lo, hi] with lo < hi")
    smoke = bool(payload.get("smoke", False))
    for k in ("beats_baseline", "within_band", "zero_comm_ok"):
        if not isinstance(payload.get(k), bool):
            errs.append(f"{k}: missing or non-bool")
        elif not payload[k] and not smoke:
            errs.append(f"{k}: False — merge quality/communication bar missed")
    backends = payload.get("backends")
    if not isinstance(backends, dict) or not backends:
        errs.append("backends: missing or empty")
        return errs
    merge_names = [n for n in backends if n.startswith("posterior_merge_p")]
    for required in ("sequential", "ring"):
        if required not in backends:
            errs.append(f"backends: missing {required!r} entry")
    if not merge_names:
        errs.append("backends: needs at least one posterior_merge_p<N> entry")
    for name, e in backends.items():
        where = f"backends[{name}]"
        for k in ("rmse", "rmse_artifact", "seconds"):
            if not isinstance(e.get(k), (int, float)) or e.get(k, 0) <= 0:
                errs.append(f"{where}.{k}: missing or non-positive")
        for k in ("bytes_per_sweep", "collective_ops"):
            if not isinstance(e.get(k), int) or e.get(k, -1) < 0:
                errs.append(f"{where}.{k}: missing or negative")
        # the headline claim: independent chains never talk during sampling
        if name.startswith("posterior_merge") and e.get("collective_ops", 1) != 0:
            errs.append(f"{where}.collective_ops: {e.get('collective_ops')!r} "
                        "(must be 0 — merge chains compiled a collective)")
        if name in ("ring", "ring_async", "allgather") and e.get("bytes_per_sweep", 0) <= 0:
            errs.append(f"{where}.bytes_per_sweep: ring-family entry must be positive")
    return errs


def check_fig4_scaling(payload: dict) -> list[str]:
    """Schema of fig4_scaling.json (width sweep + process-count sweep)."""
    errs: list[str] = []
    widths = payload.get("widths")
    if not isinstance(widths, list) or not widths or any(
        not isinstance(w, int) or w < 1 for w in widths
    ):
        errs.append("widths: needs a list of positive ints")
    modes = payload.get("modes")
    if not isinstance(modes, dict) or not {"ring", "allgather"} <= set(modes):
        errs.append("modes: needs ring and allgather entries")
    else:
        for mode, rows in modes.items():
            if not isinstance(rows, list) or not rows:
                errs.append(f"modes[{mode}]: missing or empty")
                continue
            for i, r in enumerate(rows):
                for k in ("devices", "seconds", "updates_per_s", "speedup"):
                    if not isinstance(r.get(k), (int, float)) or r.get(k, 0) <= 0:
                        errs.append(f"modes[{mode}][{i}].{k}: missing or non-positive")
    ps = payload.get("process_sweep")
    if not isinstance(ps, dict):
        errs.append("process_sweep: missing")
        return errs
    S = ps.get("global_devices")
    if not isinstance(S, int) or S < 1:
        errs.append("process_sweep.global_devices: missing or < 1")
    rb = ps.get("ring_bytes_per_sweep")
    if not isinstance(rb, dict) or any(
        not isinstance(rb.get(k), int) or rb.get(k, 0) <= 0
        for k in ("modelled", "measured", "cap_u", "cap_v")
    ):
        errs.append("process_sweep.ring_bytes_per_sweep: needs positive int "
                    "modelled/measured/cap_u/cap_v")
    elif rb.get("model_matches") is not True:
        errs.append(
            "process_sweep.ring_bytes_per_sweep.model_matches: False — "
            f"modelled {rb['modelled']} != traced {rb['measured']}"
        )
    layouts = ps.get("layouts")
    if not isinstance(layouts, list) or not layouts:
        errs.append("process_sweep.layouts: missing or empty")
        return errs
    seen_multi = False
    for i, r in enumerate(layouts):
        where = f"process_sweep.layouts[{i}]"
        for k in ("processes", "devices_per_process"):
            if not isinstance(r.get(k), int) or r.get(k, 0) < 1:
                errs.append(f"{where}.{k}: missing or < 1")
        if (
            isinstance(S, int)
            and isinstance(r.get("processes"), int)
            and isinstance(r.get("devices_per_process"), int)
            and r["processes"] * r["devices_per_process"] != S
        ):
            errs.append(f"{where}: processes x devices_per_process != "
                        f"global_devices ({S})")
        for k in ("seconds", "sweeps_per_s"):
            if not isinstance(r.get(k), (int, float)) or r.get(k, 0) <= 0:
                errs.append(f"{where}.{k}: missing or non-positive")
        cross = r.get("cross_process_bytes_per_sweep")
        if not isinstance(cross, int) or cross < 0:
            errs.append(f"{where}.cross_process_bytes_per_sweep: missing or negative")
        elif r.get("processes") == 1 and cross != 0:
            errs.append(f"{where}: single-process layout must report 0 "
                        "cross-process bytes")
        elif isinstance(r.get("processes"), int) and r["processes"] > 1:
            seen_multi = True
            if cross == 0:
                errs.append(f"{where}: multi-process layout reports 0 "
                            "cross-process bytes")
    if not seen_multi:
        errs.append("process_sweep.layouts: needs at least one multi-process layout")
    return errs


def check_serve_load(payload: dict) -> list[str]:
    """Schema of serve_load.json (closed-loop server load benchmark)."""
    errs: list[str] = []
    if payload.get("device") not in ("cpu", "gpu", "tpu"):
        errs.append(f"device: unexpected {payload.get('device')!r}")
    lat_keys = ("p50_ms", "p99_ms", "mean_ms")
    tk = payload.get("top_k")
    if not isinstance(tk, dict):
        errs.append("top_k: missing")
    else:
        for mode in ("replicated", "sharded"):
            e = tk.get(mode)
            if not isinstance(e, dict) or any(
                not isinstance(e.get(k), (int, float)) or e.get(k, 0) <= 0
                for k in lat_keys
            ):
                errs.append(f"top_k.{mode}: needs positive numeric {lat_keys}")
        # acceptance bar: when the committed full-catalog probe is present,
        # the item-sharded path must beat its recorded p99
        if "recorded_full_catalog_p99_ms" in tk and tk.get("sharded_beats_recorded") is not True:
            errs.append(
                "top_k.sharded_beats_recorded: False — sharded top-k p99 "
                f"({tk.get('sharded', {}).get('p99_ms')}) does not beat the "
                f"recorded full-catalog p99 "
                f"({tk.get('recorded_full_catalog_p99_ms')})"
            )
    load = payload.get("load")
    if not isinstance(load, dict) or not load:
        errs.append("load: missing or empty")
        return errs
    for name, e in load.items():
        where = f"load[{name}]"
        if not name.isdigit() or int(name) < 1:
            errs.append(f"{where}: key must be a positive client count")
            continue
        for k in ("requests", "offered_qps", "batcher_occupancy", *lat_keys):
            if not isinstance(e.get(k), (int, float)) or e.get(k, 0) <= 0:
                errs.append(f"{where}.{k}: missing or non-positive")
        # the hard serving contract: no request errors, none dropped
        for k in ("errors", "dropped"):
            if e.get(k) != 0:
                errs.append(f"{where}.{k}: {e.get(k)!r} (must be 0)")
    return errs


CHECKERS = {
    "fig2_item_update": check_fig2_item_update,
    "fig4_scaling": check_fig4_scaling,
    "fig5_overlap": check_fig5_overlap,
    "fig_merge_comm": check_fig_merge_comm,
    "serve_latency": check_serve_latency,
    "serve_load": check_serve_load,
    "sweep_throughput": check_sweep_throughput,
}


def check_smoke_flag(name: str, payload: dict) -> list[str]:
    """Committed-artifact regression: smoke output must never land here."""
    errs: list[str] = []
    if name in SMOKE_STAMPED and "smoke" not in payload:
        errs.append('smoke: key missing (benchmark stamps it; stale artifact?)')
    if payload.get("smoke", False):
        errs.append('smoke: true — a smoke run overwrote the committed JSON')
    return errs


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser(
        description="validate experiments/bench JSON artifacts",
    )
    ap.add_argument("names", nargs="+", metavar="name",
                    help=f"artifact basename; known: {sorted(CHECKERS)}")
    ap.add_argument("--path", default=None,
                    help="read the payload from this file instead of the "
                         "committed experiments/bench/<name>.json (one name "
                         "only; skips the committed smoke-flag regression)")
    args = ap.parse_args(argv)
    if args.path and len(args.names) != 1:
        ap.error("--path takes exactly one artifact name")
    rc = 0
    for name in args.names:
        if name not in CHECKERS:
            print(f"{name}: no schema checker (known: {sorted(CHECKERS)})")
            rc = 1
            continue
        path = args.path or os.path.normpath(os.path.join(BENCH_DIR, f"{name}.json"))
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError) as e:
            print(f"{name}: cannot load {path}: {e}")
            rc = 1
            continue
        errs = CHECKERS[name](payload)
        if not args.path:
            errs += check_smoke_flag(name, payload)
        if errs:
            print(f"{name}: schema FAILED ({len(errs)} violation(s))")
            for e in errs:
                print(f"  - {e}")
            rc = 1
        else:
            print(f"{name}: schema OK ({path})")
    return rc


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
