#!/usr/bin/env bash
# Tier-1 test entry point.
#
# Forces 8 host (CPU) devices so the distributed/ring code paths exercise a
# real multi-device mesh, and puts src/ on PYTHONPATH. Subprocess-based
# multidevice tests override the device count themselves
# (tests/conftest.py strips and re-appends the flag).
#
#   scripts/test.sh               # full tier-1 suite
#   scripts/test.sh tests/test_engine.py -k parity
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${XLA_FLAGS:-}" != *xla_force_host_platform_device_count* ]]; then
  export XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8"
fi
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

exec python -m pytest -x -q "$@"
