#!/usr/bin/env bash
# Tier-1 test entry point.
#
# Forces 8 host (CPU) devices so the distributed/ring code paths exercise a
# real multi-device mesh, and puts src/ on PYTHONPATH. Subprocess-based
# multidevice tests override the device count themselves
# (tests/conftest.py strips and re-appends the flag).
#
#   scripts/test.sh                     # full tier-1 suite
#   scripts/test.sh tests/test_engine.py -k parity
#   scripts/test.sh --bench-smoke       # + 2-sweep ring_async CLI smoke run
#
# Always runs the public-API docstring-coverage gate
# (scripts/check_docstrings.py) before pytest.
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${XLA_FLAGS:-}" != *xla_force_host_platform_device_count* ]]; then
  export XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8"
fi
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

BENCH_SMOKE=0
ARGS=()
for a in "$@"; do
  if [[ "$a" == "--bench-smoke" ]]; then
    BENCH_SMOKE=1
  else
    ARGS+=("$a")
  fi
done

python scripts/check_docstrings.py

if [[ "$BENCH_SMOKE" == 1 ]]; then
  echo "== bench smoke: 2-sweep ring_async on synthetic =="
  python -m repro.launch.bpmf --backend ring_async --dataset synthetic \
    --pipeline-depth 2 --sweeps 2 --burn-in 1 --K 4 \
    --users 80 --movies 40 --nnz 800
fi

exec python -m pytest -x -q ${ARGS[@]+"${ARGS[@]}"}
