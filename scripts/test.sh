#!/usr/bin/env bash
# Tier-1 test entry point.
#
# Forces 8 host (CPU) devices so the distributed/ring code paths exercise a
# real multi-device mesh, and puts src/ on PYTHONPATH. Subprocess-based
# multidevice tests override the device count themselves
# (tests/conftest.py strips and re-appends the flag).
#
#   scripts/test.sh                     # full tier-1 suite
#   scripts/test.sh tests/test_engine.py -k parity
#   scripts/test.sh -m "not slow"       # skip the subprocess/multidevice tests
#   scripts/test.sh --bench-smoke       # + 2-sweep ring_async CLI smoke run
#   scripts/test.sh --autotune-smoke    # + fig2 autotune driver (2 shapes,
#                                       #   tiny budget) + JSON schema check
#                                       #   + use_pallas shim warns-once check
#   scripts/test.sh --serve-smoke       # + train 2 sweeps -> export artifact
#                                       #   -> serve one-shot + JSONL queries
#                                       #   -> serve_latency --smoke + schema
#   scripts/test.sh --block-smoke       # + 2-block ring run (8 sweeps,
#                                       #   sweeps_per_block=4) -> export ->
#                                       #   serve one-shot; sweep_throughput
#                                       #   --smoke + JSON schema check
#   scripts/test.sh --server-smoke      # + train -> export -> persistent
#                                       #   serve_server -> concurrent client
#                                       #   burst -> hot-swap re-export ->
#                                       #   clean shutdown; serve_load --smoke
#                                       #   + JSON schema check
#   scripts/test.sh --merge-smoke       # + 2-partition posterior_merge CLI
#                                       #   run -> export -> serve one-shot;
#                                       #   fig_merge_comm --smoke + JSON
#                                       #   schema check
#   scripts/test.sh --overlap-smoke     # + depth-2 pipelined CLI run with a
#                                       #   mid-run checkpoint -> resume ->
#                                       #   export; sweep_throughput --smoke
#                                       #   (overlap + save-latency columns)
#                                       #   + JSON schema check
#   scripts/test.sh --multiproc-smoke   # + 2-process gang (DESIGN.md §14):
#                                       #   ring run -> checkpoint -> restart
#                                       #   at 1 process -> export -> serve
#                                       #   one-shot; fig4_scaling --smoke
#                                       #   + JSON schema check
#
# Benchmark smoke runs write to temp --out paths (never the committed
# experiments/bench JSONs); each stanza schema-checks its temp output via
# --path AND re-checks the committed artifact, which must carry
# "smoke": false (scripts/check_bench_schema.py).
#
# Always runs the public-API docstring-coverage gate
# (scripts/check_docstrings.py) before pytest.
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${XLA_FLAGS:-}" != *xla_force_host_platform_device_count* ]]; then
  export XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8"
fi
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

BENCH_SMOKE=0
AUTOTUNE_SMOKE=0
SERVE_SMOKE=0
BLOCK_SMOKE=0
SERVER_SMOKE=0
MERGE_SMOKE=0
OVERLAP_SMOKE=0
MULTIPROC_SMOKE=0
ARGS=()
for a in "$@"; do
  if [[ "$a" == "--bench-smoke" ]]; then
    BENCH_SMOKE=1
  elif [[ "$a" == "--autotune-smoke" ]]; then
    AUTOTUNE_SMOKE=1
  elif [[ "$a" == "--serve-smoke" ]]; then
    SERVE_SMOKE=1
  elif [[ "$a" == "--block-smoke" ]]; then
    BLOCK_SMOKE=1
  elif [[ "$a" == "--server-smoke" ]]; then
    SERVER_SMOKE=1
  elif [[ "$a" == "--merge-smoke" ]]; then
    MERGE_SMOKE=1
  elif [[ "$a" == "--overlap-smoke" ]]; then
    OVERLAP_SMOKE=1
  elif [[ "$a" == "--multiproc-smoke" ]]; then
    MULTIPROC_SMOKE=1
  else
    ARGS+=("$a")
  fi
done

python scripts/check_docstrings.py

if [[ "$BENCH_SMOKE" == 1 ]]; then
  echo "== bench smoke: 2-sweep ring_async on synthetic =="
  python -m repro.launch.bpmf --backend ring_async --dataset synthetic \
    --pipeline-depth 2 --sweeps 2 --burn-in 1 --K 4 \
    --users 80 --movies 40 --nnz 800
fi

if [[ "$AUTOTUNE_SMOKE" == 1 ]]; then
  echo "== autotune smoke: fig2 driver, 2 shapes, tiny budget =="
  FIG2_TMP="$(mktemp -d)"
  python -m benchmarks.fig2_item_update --smoke --out "$FIG2_TMP/fig2_item_update.json"
  python scripts/check_bench_schema.py fig2_item_update --path "$FIG2_TMP/fig2_item_update.json"
  python scripts/check_bench_schema.py fig2_item_update
  rm -rf "$FIG2_TMP"
  echo "== use_pallas deprecation shim: must warn exactly once =="
  # intentionally a fresh process (unlike the pytest variant, which has to
  # monkeypatch the warn-once flag): checks the real once-per-process gate
  python - <<'PY'
import warnings
from repro.bpmf.config import BackendConfig
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    a = BackendConfig(use_pallas=True)
    b = BackendConfig(use_pallas=False)
dep = [x for x in w if issubclass(x.category, DeprecationWarning)
       and "use_pallas" in str(x.message)]
assert len(dep) == 1, f"expected exactly 1 use_pallas warning, got {len(dep)}"
assert a.gram_impl == "pallas" and b.gram_impl == "xla", (a.gram_impl, b.gram_impl)
print("use_pallas shim OK: warned once, mapped to gram_impl")
PY
fi

if [[ "$SERVE_SMOKE" == 1 ]]; then
  echo "== serve smoke: 2-sweep train -> export -> serve queries =="
  SERVE_TMP="$(mktemp -d)"
  ART="$SERVE_TMP/artifact"
  python -m repro.launch.bpmf --backend sequential --dataset synthetic \
    --sweeps 2 --burn-in 1 --K 4 --users 80 --movies 40 --nnz 800 \
    --export-artifact "$ART"
  python -m repro.launch.serve --artifact "$ART" --rows 0,1,2 --cols 0,1,2 --std
  python -m repro.launch.serve --artifact "$ART" --user 0 --top-k 5
  printf '{"rows": [3, 4], "cols": [5, 6]}\n{"user": 1, "k": 3}\n' | \
    python -m repro.launch.serve --artifact "$ART" --jsonl
  echo "== serve latency smoke + schema check =="
  python -m benchmarks.serve_latency --smoke --artifact "$ART" \
    --out "$SERVE_TMP/serve_latency.json"
  python scripts/check_bench_schema.py serve_latency --path "$SERVE_TMP/serve_latency.json"
  python scripts/check_bench_schema.py serve_latency
  rm -rf "$SERVE_TMP"
fi

if [[ "$BLOCK_SMOKE" == 1 ]]; then
  echo "== block smoke: 2-block ring run -> export -> serve one-shot =="
  BLOCK_TMP="$(mktemp -d)"
  BART="$BLOCK_TMP/artifact"
  python -m repro.launch.bpmf --backend ring --dataset synthetic \
    --sweeps 8 --sweeps-per-block 4 --burn-in 2 --K 4 \
    --users 80 --movies 40 --nnz 800 \
    --export-artifact "$BART"
  python -m repro.launch.serve --artifact "$BART" --rows 0,1,2 --cols 0,1,2 --std
  echo "== sweep_throughput smoke + schema check =="
  python -m benchmarks.sweep_throughput --smoke --out "$BLOCK_TMP/sweep_throughput.json"
  python scripts/check_bench_schema.py sweep_throughput --path "$BLOCK_TMP/sweep_throughput.json"
  python scripts/check_bench_schema.py sweep_throughput
  rm -rf "$BLOCK_TMP"
fi

if [[ "$SERVER_SMOKE" == 1 ]]; then
  echo "== server smoke: train -> export -> persistent server =="
  SRV_TMP="$(mktemp -d)"
  SART="$SRV_TMP/artifact"
  python -m repro.launch.bpmf --backend sequential --dataset synthetic \
    --sweeps 2 --burn-in 1 --K 4 --users 80 --movies 40 --nnz 800 \
    --export-artifact "$SART"
  python -m repro.launch.serve_server --artifact "$SART" --port 0 \
    --poll-interval 0.2 >"$SRV_TMP/server.log" 2>&1 &
  SRV_PID=$!
  trap 'kill "$SRV_PID" 2>/dev/null || true' EXIT
  ADDR=""
  for _ in $(seq 150); do
    ADDR="$(sed -n 's,.*http://\([0-9.]*:[0-9]*\).*,\1,p' "$SRV_TMP/server.log" | head -1)"
    [[ -n "$ADDR" ]] && break
    kill -0 "$SRV_PID" 2>/dev/null || break
    sleep 0.2
  done
  if [[ -z "$ADDR" ]]; then
    echo "server did not start:"; cat "$SRV_TMP/server.log"; exit 1
  fi
  echo "== concurrent client burst against $ADDR =="
  python - "$ADDR" <<'PY'
import sys, threading
import numpy as np
from repro.serve import ServeClient

addr = sys.argv[1]
errors = []

def worker(i):
    c = ServeClient(addr)
    rng = np.random.default_rng(i)
    for _ in range(25):
        r = c.request({"rows": rng.integers(0, 80, 3).tolist(),
                       "cols": rng.integers(0, 40, 3).tolist()})
        if "error" in r or len(r.get("predictions", [])) != 3:
            errors.append(r)
        r = c.request({"user": int(rng.integers(0, 80)), "k": 5})
        if "error" in r or len(r.get("items", [])) != 5:
            errors.append(r)
    c.close()

threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
for t in threads: t.start()
for t in threads: t.join()
assert not errors, errors[:3]
st = ServeClient(addr).stats()["batcher"]
print(f"burst OK: {st['requests']} requests in {st['cycles']} cycles "
      f"(occupancy {st['occupancy']:.2f})")
PY
  python -m repro.launch.serve --server "$ADDR" --user 0 --top-k 5
  echo "== hot-swap: re-export into the live artifact dir =="
  python -m repro.launch.bpmf --backend sequential --dataset synthetic \
    --sweeps 4 --burn-in 1 --K 4 --users 80 --movies 40 --nnz 800 \
    --export-artifact "$SART"
  python - "$ADDR" <<'PY'
import sys, threading, time
import numpy as np
from repro.serve import ServeClient

addr = sys.argv[1]
stop = threading.Event()
errors = []

def hammer(i):
    c = ServeClient(addr)
    rng = np.random.default_rng(i)
    while not stop.is_set():
        r = c.request({"user": int(rng.integers(0, 80)), "k": 5})
        if "error" in r:
            errors.append(r)
    c.close()

threads = [threading.Thread(target=hammer, args=(i,)) for i in range(4)]
for t in threads: t.start()
probe = ServeClient(addr)
deadline = time.time() + 60
h = probe.health()
while h["generation"] < 1 and time.time() < deadline:
    time.sleep(0.2)
    h = probe.health()
stop.set()
for t in threads: t.join()
assert h["generation"] >= 1, f"no hot-swap observed: {h}"
assert h["swap_failures"] == 0, h
assert not errors, errors[:3]
print(f"hot-swap OK: generation {h['generation']}, "
      "zero request errors under concurrent load")
PY
  kill -TERM "$SRV_PID"
  wait "$SRV_PID"
  trap - EXIT
  grep -q "server stopped cleanly" "$SRV_TMP/server.log"
  echo "clean shutdown OK"
  echo "== serve_load smoke + schema check =="
  python -m benchmarks.serve_latency --smoke --load --out "$SRV_TMP/serve_load.json"
  python scripts/check_bench_schema.py serve_load --path "$SRV_TMP/serve_load.json"
  rm -rf "$SRV_TMP"
fi

if [[ "$MERGE_SMOKE" == 1 ]]; then
  echo "== merge smoke: 2-partition posterior_merge run -> export -> serve =="
  MERGE_TMP="$(mktemp -d)"
  MART="$MERGE_TMP/artifact"
  python -m repro.launch.bpmf --backend posterior_merge --num-partitions 2 \
    --dataset synthetic --sweeps 6 --sweeps-per-block 3 --burn-in 2 --K 4 \
    --users 80 --movies 40 --nnz 800 \
    --export-artifact "$MART"
  python -m repro.launch.serve --artifact "$MART" --rows 0,1,2 --cols 0,1,2 --std
  echo "== fig_merge_comm smoke + schema check =="
  python -m benchmarks.fig_merge_comm --smoke --out "$MERGE_TMP/fig_merge_comm.json"
  python scripts/check_bench_schema.py fig_merge_comm --path "$MERGE_TMP/fig_merge_comm.json"
  python scripts/check_bench_schema.py fig_merge_comm
  rm -rf "$MERGE_TMP"
fi

if [[ "$OVERLAP_SMOKE" == 1 ]]; then
  echo "== overlap smoke: depth-2 pipelined run -> checkpoint -> resume -> export =="
  OV_TMP="$(mktemp -d)"
  OART="$OV_TMP/artifact"
  python -m repro.launch.bpmf --backend ring --dataset synthetic \
    --sweeps 8 --sweeps-per-block 2 --pipeline-blocks 2 --burn-in 2 --K 4 \
    --users 80 --movies 40 --nnz 800 \
    --checkpoint-dir "$OV_TMP/ckpt" --checkpoint-every 3
  # a mid-run checkpoint exists (sweep 6: auto-save cadence held under the
  # pipeline); resume it with the overlapped loop, finish, export
  test -d "$OV_TMP/ckpt/step_00000006"
  python -m repro.launch.bpmf --backend ring --dataset synthetic \
    --sweeps 8 --sweeps-per-block 2 --pipeline-blocks 2 --burn-in 2 --K 4 \
    --users 80 --movies 40 --nnz 800 \
    --checkpoint-dir "$OV_TMP/ckpt" --resume \
    --export-artifact "$OART"
  python -m repro.launch.serve --artifact "$OART" --rows 0,1,2 --cols 0,1,2
  # donation fallback path stays runnable
  python -m repro.launch.bpmf --backend sequential --dataset synthetic \
    --sweeps 2 --burn-in 1 --K 4 --users 80 --movies 40 --nnz 800 \
    --pipeline-blocks 2 --donate-blocks off --sync-checkpoint-writes
  echo "== sweep_throughput smoke (overlap + save-latency columns) + schema check =="
  python -m benchmarks.sweep_throughput --smoke --out "$OV_TMP/sweep_throughput.json"
  python scripts/check_bench_schema.py sweep_throughput --path "$OV_TMP/sweep_throughput.json"
  python scripts/check_bench_schema.py sweep_throughput
  rm -rf "$OV_TMP"
fi

if [[ "$MULTIPROC_SMOKE" == 1 ]]; then
  echo "== multiproc smoke: 2-process ring gang -> ckpt -> 1-process restart -> serve =="
  MP_TMP="$(mktemp -d)"
  MPART="$MP_TMP/artifact"
  python scripts/launch_multiproc.py \
    --num-processes 2 --devices-per-process 4 --timeout 600 -- \
    --backend ring --dataset synthetic --sweeps 4 --sweeps-per-block 2 \
    --burn-in 2 --K 4 --users 80 --movies 40 --nnz 800 \
    --checkpoint-dir "$MP_TMP/ckpt" --checkpoint-every 2
  test -d "$MP_TMP/ckpt/step_00000004"
  # restart the same checkpoint at a different process count (same global
  # device total) and continue to the end, then export and serve
  python scripts/launch_multiproc.py \
    --num-processes 1 --devices-per-process 8 --timeout 600 -- \
    --backend ring --dataset synthetic --sweeps 8 --sweeps-per-block 2 \
    --burn-in 2 --K 4 --users 80 --movies 40 --nnz 800 \
    --checkpoint-dir "$MP_TMP/ckpt" --resume \
    --export-artifact "$MPART"
  python -m repro.launch.serve --artifact "$MPART" --rows 0,1,2 --cols 0,1,2
  echo "== fig4_scaling smoke + schema check =="
  python -m benchmarks.fig4_scaling --smoke --out "$MP_TMP/fig4_scaling.json"
  python scripts/check_bench_schema.py fig4_scaling --path "$MP_TMP/fig4_scaling.json"
  python scripts/check_bench_schema.py fig4_scaling
  rm -rf "$MP_TMP"
fi

exec python -m pytest -x -q ${ARGS[@]+"${ARGS[@]}"}
