#!/usr/bin/env python
"""Docstring-coverage gate for the ``repro.bpmf`` + ``repro.serve`` surface.

Walks every public module of the engine API (engine, backends, config,
datasets) and the serving subsystem (artifact, predictor) and fails if any
public symbol — module, class, function, method or property defined under
a covered package — lacks a docstring. Inherited docstrings count
(``inspect.getdoc`` follows the MRO), dunders and underscore-prefixed
names are exempt.

Run directly or via ``scripts/test.sh`` (which always includes it):

    PYTHONPATH=src python scripts/check_docstrings.py
"""
from __future__ import annotations

import inspect
import sys

MODULES = (
    "repro.bpmf",
    "repro.bpmf.engine",
    "repro.bpmf.backends",
    "repro.bpmf.config",
    "repro.bpmf.datasets",
    "repro.serve",
    "repro.serve.artifact",
    "repro.serve.predictor",
    "repro.serve.schema",
    "repro.serve.batcher",
    "repro.serve.sharded_topk",
    "repro.serve.server",
    "repro.serve.client",
    "repro.core.subset_merge",
)

# symbols defined under these packages are held to the coverage bar;
# re-exports from elsewhere (numpy, jax, repro.core) are not
PREFIXES = ("repro.bpmf", "repro.serve", "repro.core.subset_merge")


def _public_members(obj) -> list[tuple[str, object]]:
    return [
        (name, member)
        for name, member in vars(obj).items()
        if not name.startswith("_")
    ]


def _missing_in_class(cls, prefix: str) -> list[str]:
    missing = []
    for name, member in _public_members(cls):
        raw = inspect.unwrap(member) if callable(member) else member
        if isinstance(member, property):
            if not inspect.getdoc(member):
                missing.append(f"{prefix}.{name} (property)")
        elif inspect.isfunction(raw) or isinstance(member, (classmethod, staticmethod)):
            if not inspect.getdoc(getattr(cls, name)):
                missing.append(f"{prefix}.{name}()")
    return missing


def check(module_names=MODULES) -> list[str]:
    """Return a list of fully-qualified public symbols missing docstrings."""
    import importlib

    missing: list[str] = []
    for mod_name in module_names:
        mod = importlib.import_module(mod_name)
        if not inspect.getdoc(mod):
            missing.append(mod_name + " (module)")
        for name, member in _public_members(mod):
            qual = f"{mod_name}.{name}"
            if inspect.isclass(member) and member.__module__.startswith(PREFIXES):
                if not inspect.getdoc(member):
                    missing.append(qual + " (class)")
                missing.extend(_missing_in_class(member, qual))
            elif inspect.isfunction(member) and member.__module__.startswith(PREFIXES):
                if not inspect.getdoc(member):
                    missing.append(qual + "()")
    return sorted(set(missing))


def main() -> int:
    missing = check()
    if missing:
        print(f"docstring coverage FAILED: {len(missing)} public symbol(s) undocumented")
        for sym in missing:
            print(f"  - {sym}")
        return 1
    print("docstring coverage OK: all public repro.bpmf/repro.serve symbols documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
