#!/usr/bin/env python
"""N-local-process launcher for multi-process BPMF runs (DESIGN.md §14).

Spawns N copies of ``python -m repro.launch.bpmf`` on this host, wires them
into one jax.distributed job via the ``REPRO_*`` environment (coordinator on
a freshly-bound localhost port, process ids 0..N-1), and gives each child
``--devices M`` host CPU devices so the global ring mesh spans N*M devices.
Everything after ``--`` is forwarded to every child verbatim::

    PYTHONPATH=src python scripts/launch_multiproc.py \
        --num-processes 2 --devices-per-process 4 -- \
        --backend ring --sweeps 8 --checkpoint-dir /tmp/ck --checkpoint-every 2

With ``--elastic``, a dying child triggers the restart policy
(repro.runtime.elastic.RestartPolicy): the survivors are killed, and the
job respawns with ``--resume`` at the largest smaller process count that
still divides the same global device total — S is preserved, so the
checkpointed ring carries reshard onto the new process-spanning mesh and
the samples continue bitwise-identically. ``--num-processes 1`` runs the
child directly with no coordinator (plain single-process path).
"""
from __future__ import annotations

import argparse
import os
import signal
import socket
import subprocess
import sys
import threading
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python scripts/launch_multiproc.py",
        description="Run repro.launch.bpmf as N local jax processes "
                    "(args after -- are forwarded to every process).",
    )
    p.add_argument("--num-processes", type=int, default=2)
    p.add_argument("--devices-per-process", type=int, default=4,
                   help="host (CPU) devices per process; the ring mesh "
                        "spans num-processes * devices-per-process")
    p.add_argument("--elastic", action="store_true",
                   help="on a child failure, respawn at a smaller process "
                        "count (same global device total) with --resume; "
                        "requires --checkpoint-dir in the forwarded args")
    p.add_argument("--max-restarts", type=int, default=2,
                   help="elastic restart budget before giving up")
    p.add_argument("--timeout", type=float, default=600.0,
                   help="seconds before the whole job is killed")
    return p


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _pump(proc: subprocess.Popen, tag: str) -> None:
    """Forward one child's output line-by-line under a [pI] prefix."""
    for line in proc.stdout:  # type: ignore[union-attr]
        sys.stdout.write(f"[{tag}] {line}")
        sys.stdout.flush()


def run_once(num_processes: int, devices: int, forward: list[str],
             timeout: float) -> int:
    """One launch at a fixed layout; returns the first nonzero child rc (or 0).

    A child dying does not tear down its peers by itself — they block in the
    next gloo collective — so any nonzero exit kills the rest of the gang
    immediately (the cluster-manager behavior the restart policy assumes).
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO_ROOT, "src"), env.get("PYTHONPATH", "")]
    ).rstrip(os.pathsep)
    if num_processes > 1:
        env["REPRO_COORDINATOR"] = f"127.0.0.1:{_free_port()}"
        env["REPRO_NUM_PROCESSES"] = str(num_processes)
    else:
        for k in ("REPRO_COORDINATOR", "REPRO_NUM_PROCESSES", "REPRO_PROCESS_ID"):
            env.pop(k, None)

    procs: list[subprocess.Popen] = []
    pumps: list[threading.Thread] = []
    for i in range(num_processes):
        child_env = dict(env)
        if num_processes > 1:
            child_env["REPRO_PROCESS_ID"] = str(i)
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.bpmf",
             "--devices", str(devices), *forward],
            env=child_env, stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT, text=True,
        )
        procs.append(proc)
        t = threading.Thread(target=_pump, args=(proc, f"p{i}"), daemon=True)
        t.start()
        pumps.append(t)

    rc = 0
    try:
        remaining = {i: p for i, p in enumerate(procs)}
        t0 = time.time()
        while remaining:
            for i, p in list(remaining.items()):
                child_rc = p.poll()
                if child_rc is None:
                    continue
                del remaining[i]
                if child_rc != 0 and rc == 0:
                    rc = child_rc
                    print(f"[launcher] process {i} exited rc={child_rc}; "
                          "killing peers", flush=True)
            if rc != 0:
                break
            if time.time() - t0 > timeout:
                print("[launcher] timeout; killing job", flush=True)
                rc = 124
                break
            time.sleep(0.05)
    finally:
        for p in procs:
            if p.poll() is None:
                p.send_signal(signal.SIGKILL)
        for p in procs:
            p.wait()
        for t in pumps:
            t.join(timeout=5)
    return rc


def main(argv: list[str] | None = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--" in argv:
        split = argv.index("--")
        own, forward = argv[:split], argv[split + 1:]
    else:
        own, forward = argv, []
    args = build_parser().parse_args(own)

    if args.elastic and "--checkpoint-dir" not in forward:
        print("--elastic needs --checkpoint-dir (and --checkpoint-every) in "
              "the forwarded args so the respawn has something to resume",
              file=sys.stderr)
        return 2

    from repro.runtime.elastic import RestartPolicy  # light import, no jax

    num_processes = args.num_processes
    devices = args.devices_per_process
    policy = RestartPolicy(
        total_devices=num_processes * devices, max_restarts=args.max_restarts
    )

    rc = run_once(num_processes, devices, forward, args.timeout)
    while rc != 0 and args.elastic:
        layout = policy.next_layout(num_processes)
        if layout is None:
            print("[launcher] restart policy exhausted", flush=True)
            return rc
        num_processes, devices = layout
        print(f"[launcher] elastic restart: {num_processes} processes x "
              f"{devices} devices, resuming", flush=True)
        resumed = forward if "--resume" in forward else [*forward, "--resume"]
        rc = run_once(num_processes, devices, resumed, args.timeout)
    return rc


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(REPO_ROOT, "src"))
    sys.exit(main())
