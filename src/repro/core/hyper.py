"""Normal-Wishart conditional sampling for the BPMF hyper-parameters.

Given the current latent matrix X ([n, K] rows = items of one side), the
conditional posterior of (mu, Lambda) is Normal-Wishart with updated
parameters (Salakhutdinov & Mnih 2008, eq. 14):

    beta* = beta0 + n              nu* = nu0 + n
    mu*   = (beta0 mu0 + n xbar) / (beta0 + n)
    W*^-1 = W0^-1 + n S + (beta0 n / (beta0 + n)) (mu0 - xbar)(mu0 - xbar)^T

with xbar the sample mean and S the (biased) sample covariance. We sample
Lambda ~ Wishart(W*, nu*) with the Bartlett decomposition and then
mu ~ N(mu*, (beta* Lambda)^-1).

The sampler is written over *sufficient statistics* (n, sum x, sum x x^T) so
the distributed version can psum the statistics across shards and then run
the identical math with the identical key — giving bitwise-comparable
hyper-samples between the single-device and distributed samplers (up to
reduction order in the psum).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

from repro.core.types import HyperParams, NormalWishartPrior


def _sample_wishart(key: jax.Array, scale_chol: jax.Array, df: jax.Array) -> jax.Array:
    """Sample from Wishart(scale, df) given chol(scale) via Bartlett.

    Lambda = L A A^T L^T with L = chol(scale), A lower triangular,
    A_ii ~ sqrt(chi2(df - i)), A_ij ~ N(0, 1) for i > j.
    """
    K = scale_chol.shape[-1]
    kn, kc = jax.random.split(key)
    # chi2(k) = 2 * Gamma(k/2). df - arange(K) stays > 0 because df >= nu0 + n >= K.
    dfs = df - jnp.arange(K, dtype=scale_chol.dtype)
    chi2 = 2.0 * jax.random.gamma(kc, dfs / 2.0, dtype=scale_chol.dtype)
    diag = jnp.sqrt(chi2)
    normals = jax.random.normal(kn, (K, K), dtype=scale_chol.dtype)
    A = jnp.tril(normals, -1) + jnp.diag(diag)
    LA = scale_chol @ A
    return LA @ LA.T


def hyper_sufficient_stats(
    X: jax.Array, weights: jax.Array | None = None
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(n, sum_x, sum_xxT) — the distributable sufficient statistics.

    ``weights`` optionally masks rows (1 = real item, 0 = padding) so a
    sharded caller can include padded rows without biasing the posterior.
    """
    dtype = X.dtype
    if weights is None:
        n = jnp.asarray(X.shape[0], dtype)
        sx = jnp.sum(X, axis=0)
        sxx = X.T @ X
    else:
        w = weights.astype(dtype)
        n = jnp.sum(w)
        Xw = X * w[:, None]
        sx = jnp.sum(Xw, axis=0)
        sxx = Xw.T @ X
    return n, sx, sxx


def sample_hyper_from_stats(
    key: jax.Array,
    n: jax.Array,
    sum_x: jax.Array,
    sum_xxT: jax.Array,
    prior: NormalWishartPrior,
) -> HyperParams:
    """Sample (mu, Lambda) from the NW conditional given sufficient stats."""
    dtype = sum_x.dtype
    K = sum_x.shape[-1]
    xbar = sum_x / n
    S = sum_xxT / n - jnp.outer(xbar, xbar)
    S = 0.5 * (S + S.T)

    beta_star = prior.beta0 + n
    nu_star = prior.nu0 + n
    mu_star = (prior.beta0 * prior.mu0 + n * xbar) / beta_star
    dm = prior.mu0 - xbar
    W0_inv = jnp.linalg.inv(prior.W0)
    Wstar_inv = W0_inv + n * S + (prior.beta0 * n / beta_star) * jnp.outer(dm, dm)
    Wstar_inv = 0.5 * (Wstar_inv + Wstar_inv.T)
    Wstar = jnp.linalg.inv(Wstar_inv)
    Wstar = 0.5 * (Wstar + Wstar.T)
    scale_chol = jnp.linalg.cholesky(Wstar + 1e-10 * jnp.eye(K, dtype=dtype))

    k_lam, k_mu = jax.random.split(key)
    Lam = _sample_wishart(k_lam, scale_chol, nu_star)
    Lam = 0.5 * (Lam + Lam.T)

    # mu ~ N(mu*, (beta* Lam)^-1): x = mu* + chol(Lam)^-T z / sqrt(beta*)
    L = jnp.linalg.cholesky(Lam + 1e-10 * jnp.eye(K, dtype=dtype))
    z = jax.random.normal(k_mu, (K,), dtype=dtype)
    mu = mu_star + solve_triangular(L.T, z, lower=False) / jnp.sqrt(beta_star)
    return HyperParams(mu=mu, Lam=Lam)


def sample_hyper(
    key: jax.Array,
    X: jax.Array,
    prior: NormalWishartPrior,
    weights: jax.Array | None = None,
) -> HyperParams:
    """Sample (mu, Lambda) from the NW conditional given latent rows X."""
    n, sx, sxx = hyper_sufficient_stats(X, weights)
    return sample_hyper_from_stats(key, n, sx, sxx, prior)
