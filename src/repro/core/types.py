"""Core pytree containers for BPMF state, priors and bucketed rating data.

The rating matrix ``R`` (M users x N movies, sparse) is factorized as
``R ~ U @ V.T`` with ``U: [M, K]`` and ``V: [N, K]``. Conditional
independence of items given the opposite factor matrix is the source of all
parallelism in the paper; the containers here encode the bucketed layout that
makes that parallelism dense enough for the MXU.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import pytree_dataclass, static_field


@pytree_dataclass
class NormalWishartPrior:
    """Fixed hyperprior p(mu, Lambda) = N(mu|mu0, (b0 Lam)^-1) W(Lam|W0, nu0)."""

    mu0: jax.Array  # [K]
    beta0: jax.Array  # scalar
    W0: jax.Array  # [K, K]
    nu0: jax.Array  # scalar

    @staticmethod
    def default(K: int, dtype: Any = jnp.float32) -> "NormalWishartPrior":
        return NormalWishartPrior(
            mu0=jnp.zeros((K,), dtype),
            beta0=jnp.asarray(2.0, dtype),
            W0=jnp.eye(K, dtype=dtype),
            nu0=jnp.asarray(float(K), dtype),
        )


@pytree_dataclass
class HyperParams:
    """Sampled (mu, Lambda) for one side (users or movies)."""

    mu: jax.Array  # [K]
    Lam: jax.Array  # [K, K] precision

    @staticmethod
    def init(K: int, dtype: Any = jnp.float32) -> "HyperParams":
        return HyperParams(mu=jnp.zeros((K,), dtype), Lam=jnp.eye(K, dtype=dtype))


@pytree_dataclass
class BPMFState:
    """Full Gibbs state."""

    U: jax.Array  # [M, K] user latents
    V: jax.Array  # [N, K] movie latents
    hyper_U: HyperParams
    hyper_V: HyperParams
    sweep: jax.Array  # scalar int32, number of completed sweeps

    @property
    def K(self) -> int:
        return self.U.shape[-1]


@pytree_dataclass
class PosteriorAccum:
    """Device-resident posterior summary folded into the sweep loop carry.

    Replaces the engine's old host-side accumulator (which gathered the full
    (U, V) factors to the host after every post-burn-in sweep): the running
    posterior-mean sums and a rotating window of the ``keep`` most recent
    post-burn-in samples live next to the factors — sharded the same way on
    the distributed backends — and are updated inside the jitted block scan
    with an on-device burn-in predicate, so nothing crosses the host
    boundary until export/checkpoint time.

    Layout notes:
      * ``U_sum`` / ``V_sum`` accumulate float32 casts of the samples, so a
        resumed run folds bitwise the same values the old host path did.
      * ``U_window[count % keep]`` holds the sample drawn at post-burn-in
        index ``count`` (a rotating buffer); chronological order is
        reconstructed on the host from ``count`` when exporting.
      * ``count`` is the number of post-burn-in samples folded so far;
        ``filled`` is the number of *materialized* window entries
        (``min(count, keep)`` in an uninterrupted run, possibly fewer after
        restoring a checkpoint that retained fewer samples — e.g. one
        written with a smaller ``keep`` — so zero-filled slots are never
        reported as samples).
    """

    U_sum: jax.Array  # [M, K] f32 running sum of post-burn-in U samples
    V_sum: jax.Array  # [N, K] f32 running sum of post-burn-in V samples
    count: jax.Array  # scalar int32, post-burn-in samples folded
    filled: jax.Array  # scalar int32, valid window entries (<= keep)
    U_window: jax.Array  # [keep, M, K] f32 rotating recent-sample buffer
    V_window: jax.Array  # [keep, N, K] f32

    @property
    def keep(self) -> int:
        return self.U_window.shape[0]

    @staticmethod
    def init(num_users: int, num_movies: int, K: int, keep: int) -> "PosteriorAccum":
        return PosteriorAccum(
            U_sum=jnp.zeros((num_users, K), jnp.float32),
            V_sum=jnp.zeros((num_movies, K), jnp.float32),
            count=jnp.zeros((), jnp.int32),
            filled=jnp.zeros((), jnp.int32),
            U_window=jnp.zeros((keep, num_users, K), jnp.float32),
            V_window=jnp.zeros((keep, num_movies, K), jnp.float32),
        )


@pytree_dataclass
class Bucket:
    """A dense, padded group of items with similar rating counts.

    All arrays are device arrays; ``item_ids`` indexes the side being updated,
    ``nbr`` indexes the opposite side. Padded neighbor slots have index 0 and
    ``nnz`` masks them out.
    """

    item_ids: jax.Array  # [B] int32
    nbr: jax.Array  # [B, P] int32, padded neighbor (opposite-side) indices
    val: jax.Array  # [B, P] f32, centered ratings, 0 in padding
    nnz: jax.Array  # [B] int32, true rating count per item

    @property
    def B(self) -> int:
        return self.item_ids.shape[0]

    @property
    def P(self) -> int:
        return self.nbr.shape[1]

    def mask(self) -> jax.Array:
        return (jnp.arange(self.P, dtype=jnp.int32)[None, :] < self.nnz[:, None]).astype(self.val.dtype)


@pytree_dataclass
class BucketedSide:
    """All buckets for one side (the per-user or per-movie CSR, padded).

    ``buckets`` is a tuple so the container stays a valid pytree with static
    structure; bucket shapes differ, which is fine — the per-bucket update is
    traced once per shape.
    """

    buckets: tuple[Bucket, ...]
    num_items: int = static_field(default=0)

    def total_ratings(self) -> int:
        return int(sum(np.sum(np.asarray(b.nnz)) for b in self.buckets))


@pytree_dataclass
class TestSet:
    """Held-out ratings for RMSE tracking."""

    rows: jax.Array  # [T] int32 user ids
    cols: jax.Array  # [T] int32 movie ids
    vals: jax.Array  # [T] f32 raw (uncentered) ratings


@pytree_dataclass
class BPMFData:
    """Everything the Gibbs sweep needs besides the state.

    users / movies are each the bucketed neighbor lists for updating that
    side. ``mean_rating`` recenters ratings; predictions add it back.
    """

    users: BucketedSide  # update U: neighbors are movies
    movies: BucketedSide  # update V: neighbors are users
    test: TestSet
    mean_rating: jax.Array  # scalar f32
    num_users: int = static_field(default=0)
    num_movies: int = static_field(default=0)
    min_rating: float = static_field(default=-np.inf)
    max_rating: float = static_field(default=np.inf)


@dataclasses.dataclass(frozen=True)
class BPMFConfig:
    """Static configuration of the sampler (python-side, hashable)."""

    K: int = 32
    alpha: float = 2.0  # rating noise precision
    num_sweeps: int = 50
    burn_in: int = 8
    beta0: float = 2.0
    # bucketing: pad sizes tried in order; items with nnz > last go to chunked path
    bucket_pads: Sequence[int] = (8, 32, 128, 512, 2048)
    # distributed
    # "ring" (paper async, 1 step in flight) | "allgather" (sync baseline)
    # | "ring_async" (pipelined ring, `pipeline_depth` steps in flight)
    comm_mode: str = "ring"
    pipeline_depth: int = 1  # ring_async only: ppermutes in flight (d >= 1)
    sample_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32  # contraction dtype (bf16 on TPU)
    # Gram dispatch: "auto" (autotune cache -> heuristic), "pallas_fused"
    # (one fused kernel per ring step), "pallas" (per-bucket kernel), "xla"
    gram_impl: str = "auto"

    def prior(self) -> NormalWishartPrior:
        p = NormalWishartPrior.default(self.K, self.sample_dtype)
        return dataclasses.replace(p, beta0=jnp.asarray(self.beta0, self.sample_dtype))  # type: ignore[arg-type]
