"""Single-program BPMF Gibbs sweep (paper Algorithm 1), jit-compiled.

Order per sweep (exactly Algorithm 1):
  1. sample movie hyper-parameters from V
  2. resample every movie from (U, R)
  3. sample user hyper-parameters from U
  4. resample every user from (new V, R)
  5. predict test points, update RMSE

The distributed sampler in ``core/distributed.py`` reuses the same
sub-routines under ``shard_map``; this module is the sequential oracle.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import posterior
from repro.core.hyper import sample_hyper
from repro.core.prediction import (
    PredictionState,
    update_posterior_accum,
    update_predictions,
)
from repro.core.types import (
    BPMFConfig,
    BPMFData,
    BPMFState,
    HyperParams,
    PosteriorAccum,
)


class SweepMetrics(NamedTuple):
    rmse_sample: jax.Array
    rmse_avg: jax.Array
    sweep: jax.Array


def init_rows(key: jax.Array, ids: jax.Array, K: int, dtype=jnp.float32) -> jax.Array:
    """Per-item prior-predictive rows, keyed by item id.

    fold_in per id makes the init independent of array layout, so the
    distributed sampler (which stores relabeled, padded shards) starts from
    bitwise-identical factors — a precondition for the cross-version parity
    tests.
    """

    def one(i: jax.Array) -> jax.Array:
        return 0.1 * jax.random.normal(jax.random.fold_in(key, i), (K,), dtype)

    return jax.vmap(one)(ids)


def init_state(key: jax.Array, num_users: int, num_movies: int, cfg: BPMFConfig) -> BPMFState:
    """Draw U, V from the prior predictive (standard normal scaled)."""
    ku, kv = jax.random.split(key)
    dt = cfg.sample_dtype
    return BPMFState(
        U=init_rows(ku, jnp.arange(num_users, dtype=jnp.int32), cfg.K, dt),
        V=init_rows(kv, jnp.arange(num_movies, dtype=jnp.int32), cfg.K, dt),
        hyper_U=HyperParams.init(cfg.K, dt),
        hyper_V=HyperParams.init(cfg.K, dt),
        sweep=jnp.zeros((), jnp.int32),
    )


def sweep_keys(key: jax.Array, sweep: jax.Array) -> tuple[jax.Array, ...]:
    """Deterministic per-sweep keys: (hyper_V, movies, hyper_U, users).

    Keys depend only on (base key, sweep index) so any layout of the sampler
    draws identical randomness.
    """
    k = jax.random.fold_in(key, sweep)
    return tuple(jax.random.fold_in(k, i) for i in range(4))


def _sweep_body(
    key: jax.Array,
    state: BPMFState,
    pred_state: PredictionState,
    data: BPMFData,
    cfg: BPMFConfig,
) -> tuple[BPMFState, PredictionState, SweepMetrics]:
    """One Gibbs sweep (Algorithm 1), traceable — shared by the per-sweep
    jit entry point and the blocked ``lax.scan`` loop."""
    prior = cfg.prior()
    k_hv, k_v, k_hu, k_u = sweep_keys(key, state.sweep)

    # movies given users
    hyper_V = sample_hyper(k_hv, state.V, prior)
    V = posterior.update_side(
        k_v, state.V, state.U, data.movies, hyper_V, cfg.alpha,
        cfg.compute_dtype, cfg.gram_impl,
    )
    # users given (updated) movies
    hyper_U = sample_hyper(k_hu, state.U, prior)
    U = posterior.update_side(
        k_u, state.U, V, data.users, hyper_U, cfg.alpha,
        cfg.compute_dtype, cfg.gram_impl,
    )

    sweep = state.sweep + 1
    new_state = BPMFState(U=U, V=V, hyper_U=hyper_U, hyper_V=hyper_V, sweep=sweep)
    pred_state, r_sample, r_avg = update_predictions(
        pred_state, U, V, data, burned_in=sweep > cfg.burn_in
    )
    return new_state, pred_state, SweepMetrics(r_sample, r_avg, sweep)


@partial(jax.jit, static_argnames=("cfg",))
def gibbs_sweep(
    key: jax.Array,
    state: BPMFState,
    pred_state: PredictionState,
    data: BPMFData,
    cfg: BPMFConfig,
) -> tuple[BPMFState, PredictionState, SweepMetrics]:
    return _sweep_body(key, state, pred_state, data, cfg)


def _gibbs_sweep_block(
    key: jax.Array,
    state: BPMFState,
    pred_state: PredictionState,
    accum: PosteriorAccum,
    data: BPMFData,
    cfg: BPMFConfig,
    block_size: int,
) -> tuple[BPMFState, PredictionState, PosteriorAccum, jax.Array]:
    """``block_size`` Gibbs sweeps in one jitted ``lax.scan`` — no host sync.

    The posterior accumulator (running mean sums + rotating recent-sample
    window) and the prediction accumulator travel in the scan carry; the
    burn-in gate is the on-device ``sweep > burn_in`` predicate, so blocks
    may straddle burn-in. Per-sweep randomness is keyed by ``state.sweep``
    exactly as in :func:`gibbs_sweep`, so any partition of a run into blocks
    draws identical samples.

    Returns:
        ``(state, pred_state, accum, metrics)`` with ``metrics`` a
        ``[block_size, 3]`` float32 device array of per-sweep
        ``(rmse_sample, rmse_avg, sweep)`` rows — one host transfer fetches
        the whole block's metrics.
    """

    def body(carry, _):
        st, pr, ac = carry
        st, pr, m = _sweep_body(key, st, pr, data, cfg)
        ac = update_posterior_accum(ac, st.U, st.V, st.sweep > cfg.burn_in)
        row = jnp.stack(
            [m.rmse_sample, m.rmse_avg, m.sweep.astype(jnp.float32)]
        )
        return (st, pr, ac), row

    (state, pred_state, accum), metrics = jax.lax.scan(
        body, (state, pred_state, accum), None, length=block_size
    )
    return state, pred_state, accum, metrics


gibbs_sweep_block = jax.jit(_gibbs_sweep_block, static_argnames=("cfg", "block_size"))

#: Carry-donating variant of :func:`gibbs_sweep_block` (same traced body,
#: same samples): the state, prediction and posterior-accumulator inputs are
#: donated so XLA writes each block's carry into the previous block's
#: buffers instead of allocating a second factor-sized set (DESIGN.md §13).
#: The donated inputs are *consumed* — callers that re-read a block's inputs
#: after the call (or hold external references to them) must use the
#: non-donating entry point (``BackendConfig.donate_blocks="off"``).
gibbs_sweep_block_donated = jax.jit(
    _gibbs_sweep_block, static_argnames=("cfg", "block_size"), donate_argnums=(1, 2, 3)
)


def run(
    key: jax.Array,
    data: BPMFData,
    cfg: BPMFConfig,
    callback=None,
) -> tuple[BPMFState, PredictionState, list[SweepMetrics]]:
    """Deprecated entry point — prefer ``repro.bpmf.BPMFEngine``.

    Thin wrapper over the sequential backend's run loop
    (:func:`repro.bpmf.backends.run_sequential_prepared`); kept so existing
    imports keep working. New run-loop features (checkpointing, streaming
    metrics, backend selection) live only on the engine facade.
    """
    from repro.bpmf.backends import run_sequential_prepared

    return run_sequential_prepared(key, data, cfg, callback)
