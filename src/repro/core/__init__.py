"""BPMF core: the paper's contribution (Gibbs sampler + distribution)."""
from repro.core.gibbs import gibbs_sweep, init_state, run
from repro.core.types import BPMFConfig, BPMFData, BPMFState, Bucket, BucketedSide

__all__ = [
    "BPMFConfig",
    "BPMFData",
    "BPMFState",
    "Bucket",
    "BucketedSide",
    "gibbs_sweep",
    "init_state",
    "run",
]
