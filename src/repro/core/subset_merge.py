"""Subset-posterior partitioning and merge for the ``posterior_merge`` backend.

The limited-communication regime of "Distributed Bayesian Matrix
Factorization with Limited Communication" (arXiv:1703.00734) and its HPC
implementation (arXiv:2004.02561): instead of the paper's per-sweep ring
traffic, partition the ratings by user block, run an embarrassingly-parallel
Gibbs chain per partition (zero inter-chain traffic during sampling), and
combine the subset posteriors once at export time.

Partition scheme (DESIGN.md §12):

  * One global train/test split + centering first, shared with every other
    backend, so "posterior_merge vs sequential" compares inference, not
    data.
  * Users are assigned to partitions by the same nnz cost model the ring
    uses for shards (:func:`repro.core.balance.partition_items`); each
    chain sees *all* movies but only its users' ratings.

Merge math (the papers' aggregation step): subset posteriors are treated as
Gaussians with diagonal covariance estimated from each chain's retained
sample window. For the movie factors — the only ones sampled by more than
one chain — the merged posterior is the precision-weighted product of the
subset Gaussians::

    lambda_c = 1 / var_c          # per-(movie, k) precision, chain c
    w_c      = lambda_c / sum_c' lambda_c'
    mean     = sum_c w_c * mean_c
    sample_j = sum_c w_c * sample_{c,j}   # consensus Monte Carlo draw

User factors live in exactly one chain each, so their merge is a plain
scatter. ``merge_method="pool"`` (and the documented fallback whenever a
chain holds fewer than two window samples, where no variance estimate
exists) replaces the estimated precisions with uniform weights ``1/C`` —
equally-weighted pooling of the subset posteriors.

Rotation alignment: the BPMF likelihood is invariant under a joint
orthogonal rotation of ``(U, V)``, so independent chains drift to
different orientations of the latent space and averaging their ``V``'s
naively blurs the factors (measured on the reference task: ~0.96 merged
RMSE vs ~0.81 aligned at 2 partitions). Before combining, each chain is
rotated onto the first chain's posterior-mean ``V`` by orthogonal
Procrustes — prediction-invariant per chain (``(U R)(V R)^T = U V^T``),
standard practice for embarrassingly-parallel MCMC over
rotation-symmetric models.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import balance
from repro.core.types import PosteriorAccum
from repro.data.sparse import RatingsCOO
from repro.utils import pytree_dataclass

MERGE_METHODS = ("precision", "pool")

# variance regularizer: keeps 1/var finite for factors the window happens to
# hold (numerically) constant, without visibly biasing real spread estimates
MERGE_EPS = 1e-6

#: Recorded references for the synthetic reference task
#: (150 users x 80 movies, nnz=4000, noise_std=0.3, data seed 7; K=8,
#: 10 sweeps, burn_in=3, keep_factor_samples=4, run seed 0) — shared by
#: tests/test_posterior_quality.py and benchmarks/fig_merge_comm.py so the
#: statistical gate and the committed bench JSON enforce the same bands.
#: Bands are generous around measured values: with Procrustes alignment,
#: merged-artifact RMSE over sampler seeds 0..2 measured 0.810-0.877 at
#: P=2 and 0.884-0.938 at P=4 (sequential artifact 0.758-0.835, column-
#: mean baseline 1.015).
MERGE_RMSE_BAND = {2: (0.70, 0.95), 4: (0.72, 0.97)}
#: Max allowed (merged artifact RMSE - sequential artifact RMSE) on the
#: reference task: partitioned chains see less data per factor, so some
#: degradation is expected — but it must stay bounded. Measured at run
#: seed 0: +0.042 (P=2), +0.116 (P=4).
MERGE_DEGRADATION_MAX = {2: 0.10, 4: 0.18}


@pytree_dataclass
class MergeAccum:
    """Per-chain posterior accumulators for the ``posterior_merge`` backend.

    A thin pytree wrapper so the engine's device-resident accumulator slot
    (one object threaded through ``sweep_block`` and checkpointed as the
    ``"posterior"`` subtree) can hold C independent chain accumulators.
    Chains advance in lock-step — one sweep per chain per engine sweep — so
    ``chains[0].count`` is *the* post-burn-in sample count.
    """

    chains: tuple[PosteriorAccum, ...]

    @property
    def count(self) -> jax.Array:
        """Post-burn-in samples folded per chain (chains are in lock-step)."""
        return self.chains[0].count

    @property
    def num_chains(self) -> int:
        """Number of independent partition chains."""
        return len(self.chains)


def partition_users(
    coo: RatingsCOO, num_partitions: int, strategy: str = "lpt"
) -> list[np.ndarray]:
    """Assign users to ``num_partitions`` chains by rating-count cost.

    Reuses the ring's cost-model partitioner (paper §IV-B, ``"lpt"`` /
    ``"block"`` / ``"naive"``) over per-user nnz, so chain workloads are
    balanced the same way ring shards are.

    Args:
        coo: Full ratings matrix (the partition is computed pre-split so it
            is independent of ``test_fraction`` / split seed).
        num_partitions: Number of chains C, ``1 <= C <= num_users``.
        strategy: ``balance.partition_items`` strategy name.

    Returns:
        C ascending int64 arrays of original user ids — disjoint, jointly
        covering ``range(num_users)``.
    """
    if not 1 <= num_partitions <= coo.num_users:
        raise ValueError(
            f"num_partitions must be in [1, num_users={coo.num_users}], "
            f"got {num_partitions}"
        )
    nnz = np.bincount(coo.rows, minlength=coo.num_users)
    part = balance.partition_items(nnz, num_partitions, strategy=strategy)
    return [np.sort(np.asarray(s, np.int64)) for s in part.shards]


def split_by_users(
    coo: RatingsCOO, user_sets: list[np.ndarray]
) -> list[RatingsCOO]:
    """Split ratings into per-chain subsets; every rating goes to exactly
    one chain (its user's partition).

    Ids stay *original* — see :func:`localize_users` for the relabeled view
    a chain actually samples over. This is the round-trip the property test
    pins: concatenating the returned subsets is a permutation of ``coo``.

    Args:
        coo: Ratings to split.
        user_sets: Disjoint user-id arrays covering every user
            (:func:`partition_users` output).

    Returns:
        One :class:`RatingsCOO` per chain, global shape unchanged.
    """
    owner = np.full(coo.num_users, -1, np.int64)
    for c, uids in enumerate(user_sets):
        owner[uids] = c
    if np.any(owner < 0):
        missing = np.nonzero(owner < 0)[0]
        raise ValueError(f"user_sets do not cover users {missing[:5].tolist()}...")
    rating_owner = owner[coo.rows]
    out = []
    for c in range(len(user_sets)):
        sel = rating_owner == c
        out.append(
            RatingsCOO(
                coo.rows[sel], coo.cols[sel], coo.vals[sel],
                coo.num_users, coo.num_movies,
            )
        )
    return out


def localize_users(sub: RatingsCOO, user_ids: np.ndarray) -> RatingsCOO:
    """Relabel a chain's subset to local user ids ``0..len(user_ids)-1``.

    Local id ``i`` is ``user_ids[i]`` — the position in the (ascending)
    partition array — so chain-local factor row ``i`` scatters back to
    global row ``user_ids[i]`` at merge time. Movie ids stay global: every
    chain samples the full movie side.

    Args:
        sub: One chain's ratings with original user ids.
        user_ids: The chain's user partition (all of ``sub.rows`` must be
            members).

    Returns:
        The relabeled :class:`RatingsCOO` with ``num_users=len(user_ids)``.
    """
    lut = np.full(sub.num_users, -1, np.int64)
    lut[user_ids] = np.arange(len(user_ids))
    local = lut[sub.rows]
    if np.any(local < 0):
        raise ValueError("sub contains ratings for users outside user_ids")
    return RatingsCOO(
        local.astype(np.int32), sub.cols, sub.vals, len(user_ids), sub.num_movies
    )


def chain_key(key: jax.Array, chain: int) -> jax.Array:
    """The RNG key of partition chain ``chain``: ``fold_in(key, chain)``.

    Folding the chain index into the engine's run key gives every chain a
    stream disjoint from the others *and* from the sequential backend's
    (which uses ``key`` itself) — deterministic per ``(seed, chain)``,
    independent of device placement or chain count.
    """
    return jax.random.fold_in(key, chain)


def merge_weights(
    windows: np.ndarray, method: str = "precision", eps: float = MERGE_EPS
) -> np.ndarray:
    """Per-chain combination weights from the chains' sample windows.

    ``method="precision"``: diagonal precisions ``1/(var + eps)`` estimated
    from each chain's window (ddof=1), normalized across chains per
    ``(item, k)``. Falls back to uniform pooling when fewer than two window
    samples exist — a single draw carries no spread information.
    ``method="pool"``: uniform ``1/C`` always.

    Args:
        windows: ``[C, S, N, K]`` chronological per-chain sample stacks
            (``S`` may be 0).
        method: One of :data:`MERGE_METHODS`.
        eps: Variance regularizer.

    Returns:
        ``[C, N, K]`` float32 weights summing to 1 across the chain axis.
    """
    if method not in MERGE_METHODS:
        raise ValueError(f"merge_method must be one of {MERGE_METHODS}, got {method!r}")
    C, S = windows.shape[0], windows.shape[1]
    if method == "precision" and S >= 2:
        lam = 1.0 / (windows.astype(np.float64).var(axis=1, ddof=1) + eps)
        return (lam / lam.sum(axis=0)).astype(np.float32)
    return np.full((C,) + windows.shape[2:], 1.0 / C, np.float32)


def precision_merge(
    means: np.ndarray, variances: np.ndarray, eps: float = MERGE_EPS
) -> tuple[np.ndarray, np.ndarray]:
    """Closed-form product of C diagonal Gaussians (the papers' aggregation).

    ``N(m, v) ~ prod_c N(m_c, v_c)`` with ``1/v = sum_c 1/v_c`` and
    ``m = v * sum_c m_c / v_c`` — the reference the unit tests check
    :func:`merge_weights`-based merging against.

    Args:
        means: ``[C, ...]`` subset-posterior means.
        variances: ``[C, ...]`` subset-posterior variances (same shape).
        eps: Variance regularizer added before inverting.

    Returns:
        ``(mean, var)`` float32 arrays of the merged Gaussian, shape
        ``means.shape[1:]``.
    """
    lam = 1.0 / (np.asarray(variances, np.float64) + eps)
    lam_sum = lam.sum(axis=0)
    mean = (lam * np.asarray(means, np.float64)).sum(axis=0) / lam_sum
    return mean.astype(np.float32), (1.0 / lam_sum).astype(np.float32)


def procrustes_rotation(A: np.ndarray, ref: np.ndarray) -> np.ndarray:
    """Orthogonal ``[K, K]`` rotation minimizing ``||A @ R - ref||_F``.

    The classic closed form: ``R = W @ Z^T`` from the SVD
    ``A^T @ ref = W S Z^T``. Used to align a chain's latent space to the
    reference chain's before merging (see the module docstring).

    Args:
        A: ``[N, K]`` source factor matrix.
        ref: ``[N, K]`` target factor matrix.

    Returns:
        ``[K, K]`` float32 orthogonal matrix.
    """
    W, _, Zt = np.linalg.svd(A.astype(np.float64).T @ ref.astype(np.float64))
    return (W @ Zt).astype(np.float32)


def align_chain_trees(trees: list[dict]) -> list[dict]:
    """Rotate every chain's factors onto chain 0's latent orientation.

    Per chain, one orthogonal ``R_c`` (Procrustes of the chain's
    posterior-mean ``V`` onto chain 0's) right-multiplies the chain's
    ``U_sum`` / ``V_sum`` and every retained sample — a joint rotation of
    ``(U, V)``, so each chain's own predictions ``U V^T`` are unchanged
    while the chains' latent axes become comparable for averaging.
    No-op on empty accumulators (``count == 0``) and for chain 0 itself
    (``R_0 = I`` up to float round-off; it is rotated too so every chain
    goes through identical arithmetic).

    Args:
        trees: Per-chain checkpoint-schema dicts.

    Returns:
        New tree dicts with rotated factor leaves (inputs unmodified).
    """
    if int(np.asarray(trees[0]["count"])) == 0:
        return trees
    ref = np.asarray(trees[0]["V_sum"], np.float32)
    out = []
    for t in trees:
        R = procrustes_rotation(np.asarray(t["V_sum"], np.float32), ref)
        out.append({
            "U_sum": np.asarray(t["U_sum"], np.float32) @ R,
            "V_sum": np.asarray(t["V_sum"], np.float32) @ R,
            "count": t["count"],
            "U_samples": np.asarray(t["U_samples"], np.float32) @ R,
            "V_samples": np.asarray(t["V_samples"], np.float32) @ R,
        })
    return out


def merge_chain_trees(
    trees: list[dict],
    user_sets: list[np.ndarray],
    num_users: int,
    method: str = "precision",
    eps: float = MERGE_EPS,
    align: bool = True,
) -> dict:
    """Combine per-chain :func:`~repro.bpmf.backends.accum_host_tree` views
    into one global posterior summary.

    The single communication event of the ``posterior_merge`` backend: C
    host gathers in, one artifact-shaped tree out. Movie factors are merged
    per :func:`merge_weights` (the same weights combine the mean and each
    retained draw, per consensus Monte Carlo); user factors scatter from
    their owning chain.

    Args:
        trees: Per-chain checkpoint-schema dicts (equal ``count``; chains
            run in lock-step).
        user_sets: The chains' user partitions (ascending original ids).
        num_users: Global user count.
        method: One of :data:`MERGE_METHODS`.
        eps: Variance regularizer for ``"precision"``.
        align: Procrustes-align chains to chain 0 first (see
            :func:`align_chain_trees`); disable only to measure the
            rotation drift the alignment removes.

    Returns:
        ``{"count", "U_samples", "V_samples"}`` plus ``"U_mean"`` /
        ``"V_mean"`` when ``count > 0`` — the
        :meth:`repro.bpmf.backends.Backend.posterior_export` schema.
    """
    counts = {int(np.asarray(t["count"])) for t in trees}
    if len(counts) != 1:
        raise ValueError(f"chains out of lock-step: counts {sorted(counts)}")
    count = counts.pop()
    if align and count:
        trees = align_chain_trees(trees)
    S = min(t["V_samples"].shape[0] for t in trees)
    out: dict = {"count": count}
    if count == 0:
        out["U_samples"] = np.zeros((0, 0, 0), np.float32)
        out["V_samples"] = np.zeros((0, 0, 0), np.float32)
        return out

    n = np.float32(count)
    V_means = np.stack([np.asarray(t["V_sum"], np.float32) / n for t in trees])
    if S > 0:
        V_windows = np.stack(
            [np.asarray(t["V_samples"], np.float32)[-S:] for t in trees]
        )
    else:
        V_windows = np.zeros((len(trees), 0) + V_means.shape[1:], np.float32)
    w = merge_weights(V_windows, method, eps)
    out["V_mean"] = (w * V_means).sum(axis=0).astype(np.float32)
    out["V_samples"] = np.einsum("cnk,csnk->snk", w, V_windows).astype(np.float32)

    K = V_means.shape[-1]
    U_mean = np.zeros((num_users, K), np.float32)
    U_samples = np.zeros((S, num_users, K), np.float32)
    for t, uids in zip(trees, user_sets):
        U_mean[uids] = np.asarray(t["U_sum"], np.float32) / n
        if S > 0:
            U_samples[:, uids] = np.asarray(t["U_samples"], np.float32)[-S:]
    out["U_mean"] = U_mean
    out["U_samples"] = U_samples
    return out


def column_mean_rmse(
    coo: RatingsCOO, test_fraction: float, seed: int
) -> float:
    """Per-movie-mean baseline RMSE on the engine's own train/test split.

    The naive predictor every backend must beat (the statistical harness's
    gate and ``fig_merge_comm``'s ``baseline_rmse``): predict each test
    rating with its movie's training mean, falling back to the global
    training mean for unseen movies.

    Args:
        coo: Full ratings; split here with the same
            :func:`~repro.data.sparse.train_test_split` the engine uses.
        test_fraction: Held-out fraction (``RunConfig.test_fraction``).
        seed: Split seed (``RunConfig.seed``).

    Returns:
        The baseline's RMSE over the held-out ratings.
    """
    from repro.data.sparse import train_test_split

    train, test = train_test_split(coo, test_fraction, seed)
    gmean = float(train.vals.mean()) if train.nnz else 0.0
    sums = np.bincount(train.cols, weights=train.vals, minlength=coo.num_movies)
    cnts = np.bincount(train.cols, minlength=coo.num_movies)
    col_mean = np.where(cnts > 0, sums / np.maximum(cnts, 1), gmean)
    preds = col_mean[test.cols]
    return float(np.sqrt(np.mean((preds - test.vals) ** 2)))
