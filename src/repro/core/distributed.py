"""Distributed BPMF Gibbs sampling (paper §IV) via ``shard_map``.

The paper distributes U and V across MPI ranks, balances work with a
cost-model-driven reorder of R, and overlaps communication with computation
using buffered MPI_Isend/Irecv. The TPU-native mapping (DESIGN.md §2, §4):

  * ranks            -> devices along one flattened mesh axis ("ring")
  * R reordering     -> `balance.partition_items` relabeling; shard s owns the
                        contiguous relabeled id range [s*cap, (s+1)*cap)
  * Isend/Irecv +    -> `comm_mode="ring"`: `lax.ppermute` rotates the
    send buffers        opposite-side factor shard around the ring while the
                        current shard's Gram contribution computes (the
                        permute for step t+1 is issued before step t's
                        compute so XLA's scheduler overlaps ICI and MXU)
  * deep pipelining  -> `comm_mode="ring_async"`: same rotation, but
    (1705.10633)        `pipeline_depth` permutes kept in flight through a
                        rotating buffer queue (prologue / steady-state /
                        drain), hiding d link latencies per step
  * synchronous      -> `comm_mode="allgather"`: one all-gather of the full
    baseline            opposite factor, then local updates (GraphLab-like)

Correctness contract: for identical (key, data), every comm_mode and every
shard count draws the *same* posterior samples as the sequential
``core.gibbs`` sampler, up to float reduction order — per-item noise is keyed
by original item id (`posterior.item_noise`) and hyper-parameter sampling
consumes cross-shard sufficient statistics reduced in a fixed order
(:func:`_psum_ordered`). This turns the paper's "all versions reach the same
RMSE" claim (§V-B) into an exact test, and makes the draws independent of
*how* the ring mesh is realized: a 2-process × 4-device mesh runs the same
per-shard program and the same reduction tree as 1 process × 8 devices, so
multi-process runs are bitwise-identical to single-process ones
(tests/test_multiproc.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence

import inspect

import jax
import jax.numpy as jnp
import numpy as np

try:  # jax >= 0.5: public top-level API
    from jax import shard_map as _shard_map
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

# the "skip replication check" kwarg was renamed check_rep -> check_vma
_SHARD_MAP_CHECK_KW = (
    "check_vma" if "check_vma" in inspect.signature(_shard_map).parameters else "check_rep"
)


def shard_map(f, mesh, in_specs, out_specs, **kwargs):
    kwargs.setdefault(_SHARD_MAP_CHECK_KW, False)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import posterior
from repro.core.balance import CostModel, Partition, partition_items
from repro.core.gibbs import SweepMetrics, sweep_keys
from repro.core.hyper import hyper_sufficient_stats, sample_hyper_from_stats
from repro.core.prediction import PredictionState, rmse, update_posterior_accum
from repro.core.types import BPMFConfig, Bucket, HyperParams, PosteriorAccum
from repro.data.sparse import (
    ChunkedRatings, RatingsCOO, StableMeanAccumulator, csr_from_coo, stable_mean,
    train_test_split,
)
from repro.utils import pytree_dataclass, static_field

RING_AXIS = "ring"


# --------------------------------------------------------------------------
# Distributed data containers
# --------------------------------------------------------------------------


@pytree_dataclass
class RingSide:
    """Neighbor lists for updating one side, laid out for the ring schedule.

    ``steps[t]`` holds the buckets for ring step t: the contributions to each
    local item's Gram terms from opposite-side items owned by shard
    ``(d - t) mod S`` (which is exactly the shard resident in device d's
    buffer at step t). Every bucket array has a flat leading axis ``S * B``
    sharded along the ring; neighbor indices are *local to the source shard*.

    ``Bucket.item_ids`` here are LOCAL row ids into the [cap, K] shard
    (pad = -1); original item ids (for layout-independent noise) live in
    ``orig_ids``.
    """

    steps: tuple[tuple[Bucket, ...], ...]
    orig_ids: jax.Array  # [S * cap] int32 original item id per slot, -1 = pad
    cap: int = static_field(default=0)
    num_items: int = static_field(default=0)

    @property
    def num_steps(self) -> int:
        return len(self.steps)


@pytree_dataclass
class DistTestSet:
    """Held-out triples in *relabeled* coordinates, replicated."""

    rows: jax.Array  # [T] int32 relabeled user slot (shard*cap_u + row)
    cols: jax.Array  # [T] int32 relabeled movie slot
    vals: jax.Array  # [T] f32


@pytree_dataclass
class DistBPMFData:
    """Everything the distributed sweep needs besides the factor shards."""

    users: RingSide  # for updating U (neighbors: movies)
    movies: RingSide  # for updating V (neighbors: users)
    test: DistTestSet
    mean_rating: jax.Array
    num_shards: int = static_field(default=1)
    min_rating: float = static_field(default=-np.inf)
    max_rating: float = static_field(default=np.inf)


@pytree_dataclass
class DistState:
    """Sharded Gibbs state. U: [S*cap_u, K], V: [S*cap_v, K] (ring-sharded)."""

    U: jax.Array
    V: jax.Array
    hyper_U: HyperParams
    hyper_V: HyperParams
    sweep: jax.Array


@dataclasses.dataclass(frozen=True)
class DistPlan:
    """Host-side record of how the problem was partitioned (static).

    ``local_shards`` / ``local_nnz`` / ``total_nnz`` are populated by the
    per-host builder (:func:`build_distributed_data_per_host`): which ring
    shards this process materialized and how many training ratings it kept
    versus the global count — the allocation guard tests assert
    ``local_nnz < total_nnz`` on every process of a multi-process run.
    """

    part_users: Partition
    part_movies: Partition
    num_shards: int
    strategy: str
    local_shards: tuple[int, ...] | None = None
    local_nnz: int = 0
    total_nnz: int = 0


@dataclasses.dataclass(frozen=True)
class LocalShardedArray:
    """Host stand-in for a ring-sharded array of which only one row block exists.

    The per-host data builder materializes bucket arrays only for this
    process's shards; placement turns the block into a global ``jax.Array``
    via ``make_array_from_callback`` without any process ever holding the
    full array. ``shape``/``dtype`` describe the *global* array; ``block``
    holds rows ``[row_offset, row_offset + block.shape[0])``.
    """

    block: np.ndarray
    global_rows: int
    row_offset: int

    @property
    def shape(self) -> tuple[int, ...]:
        return (self.global_rows,) + self.block.shape[1:]

    @property
    def dtype(self):
        return self.block.dtype

    def place(self, sharding: NamedSharding) -> jax.Array:
        def cb(idx):
            rows = idx[0]
            start = 0 if rows.start is None else rows.start
            stop = self.global_rows if rows.stop is None else rows.stop
            if start < self.row_offset or stop > self.row_offset + self.block.shape[0]:
                raise ValueError(
                    f"device shard rows [{start}, {stop}) are outside this "
                    f"process's materialized block "
                    f"[{self.row_offset}, {self.row_offset + self.block.shape[0]}) "
                    "— local_shards does not match the mesh's addressable devices"
                )
            sl = slice(start - self.row_offset, stop - self.row_offset)
            return self.block[(sl,) + tuple(idx[1:])]

        return jax.make_array_from_callback(self.shape, sharding, cb)


# --------------------------------------------------------------------------
# Host-side data distribution (paper §IV-B)
# --------------------------------------------------------------------------


def _neighbor_shard_counts(
    indptr: np.ndarray, indices: np.ndarray, part_opp: Partition, num_shards: int
) -> np.ndarray:
    """``[num_items, S]`` count of each item's neighbors per owning opposite shard."""
    nnz_all = (indptr[1:] - indptr[:-1]).astype(np.int64)
    row_of = np.repeat(np.arange(len(nnz_all), dtype=np.int64), nnz_all)
    src = part_opp.perm[indices] // part_opp.cap
    flat = np.bincount(row_of * num_shards + src, minlength=len(nnz_all) * num_shards)
    return flat.reshape(len(nnz_all), num_shards).astype(np.int32)


def _pad_class_of(counts: np.ndarray, pads_sorted: Sequence[int]) -> np.ndarray:
    """Vectorized pad class: smallest configured pad >= n, else next power of two."""
    pads_arr = np.asarray(pads_sorted, dtype=np.int64)
    idx = np.searchsorted(pads_arr, counts, side="left")
    out = pads_arr[np.minimum(idx, len(pads_arr) - 1)].copy()
    for i in np.nonzero(idx >= len(pads_arr))[0]:
        p = int(pads_arr[-1])
        while p < counts[i]:
            p *= 2
        out[i] = p
    return out


def _ring_side_buckets(
    indptr: np.ndarray,
    indices: np.ndarray,  # already relabeled opposite-side ids
    values: np.ndarray,
    part_self: Partition,
    part_opp: Partition,
    num_shards: int,
    pads: Sequence[int],
    bucket_multiple: int = 8,
    *,
    shard_counts: np.ndarray | None = None,
    local_shards: Sequence[int] | None = None,
) -> RingSide:
    """Build the per-step bucketed neighbor lists for one side.

    For item i (owned by shard d at local row r) and ring step t, collect the
    neighbors j with shard(j) == (d - t) mod S, store their *local* opposite
    indices. Bucket shapes are agreed globally (max over devices per step &
    pad class) so the SPMD program is identical on every device.

    Per-host mode: with ``local_shards`` a contiguous subset of shards, the
    bucket *shapes* are still computed globally — from ``shard_counts``, the
    ``[num_items, S]`` per-source-shard neighbor counts, which every process
    derives from the same deterministic partition — but the bucket *arrays*
    are materialized only for the local shards and wrapped in
    :class:`LocalShardedArray`. The CSR inputs then only need rows for
    locally-owned items (remote rows may be empty); slot order (ascending
    original id within each shard) and neighbor order (CSR order, i.e.
    sorted by original opposite id) are layout-invariant, so the local block
    is bitwise-identical to the corresponding rows of a full build.
    """
    S = num_shards
    cap = part_self.cap
    cap_opp = part_opp.cap
    num_items = len(indptr) - 1

    full = local_shards is None
    local = tuple(range(S)) if full else tuple(int(d) for d in local_shards)
    if list(local) != list(range(local[0], local[-1] + 1)):
        raise ValueError(f"local_shards must be contiguous ascending, got {local}")
    L = len(local)

    if shard_counts is None:
        shard_counts = _neighbor_shard_counts(indptr, indices, part_opp, S)

    pads_sorted = sorted(pads)
    d_of = (part_self.perm // cap).astype(np.int64)  # owning shard per item
    item_ids_all = np.arange(num_items, dtype=np.int64)

    steps: list[tuple[Bucket, ...]] = []
    for t in range(S):
        src_t = (d_of - t) % S
        cnt_t = shard_counts[item_ids_all, src_t].astype(np.int64)
        present = (cnt_t > 0) | (t == 0)  # t == 0 rows always present
        pc_t = _pad_class_of(cnt_t, pads_sorted)
        # global bucket plan: per pad class, B = max over ALL devices
        buckets_t: list[Bucket] = []
        for pc in sorted(int(p) for p in np.unique(pc_t[present])):
            in_class = present & (pc_t == pc)
            per_dev = np.bincount(d_of[in_class], minlength=S)
            B = -(-int(per_dev.max()) // bucket_multiple) * bucket_multiple
            item_ids = np.full((L, B), -1, dtype=np.int32)
            nbr = np.zeros((L, B, pc), dtype=np.int32)
            val = np.zeros((L, B, pc), dtype=np.float32)
            nnz = np.zeros((L, B), dtype=np.int32)
            for li, d in enumerate(local):
                # ascending original id == insertion order of the full build
                for slot, old_id in enumerate(np.nonzero(in_class & (d_of == d))[0]):
                    r = int(part_self.perm[old_id]) % cap
                    lo, hi = indptr[old_id], indptr[old_id + 1]
                    nbr_new = part_opp.perm[indices[lo:hi]]
                    sel = (nbr_new // cap_opp) == ((d - t) % S)
                    nb = (nbr_new % cap_opp)[sel]
                    item_ids[li, slot] = r
                    nnz[li, slot] = len(nb)
                    nbr[li, slot, : len(nb)] = nb
                    val[li, slot, : len(nb)] = values[lo:hi][sel]
            if full:
                buckets_t.append(
                    Bucket(
                        item_ids=jnp.asarray(item_ids.reshape(S * B)),
                        nbr=jnp.asarray(nbr.reshape(S * B, pc)),
                        val=jnp.asarray(val.reshape(S * B, pc)),
                        nnz=jnp.asarray(nnz.reshape(S * B)),
                    )
                )
            else:
                off = local[0] * B

                def wrap(a: np.ndarray) -> LocalShardedArray:
                    return LocalShardedArray(
                        block=a.reshape((L * B,) + a.shape[2:]),
                        global_rows=S * B,
                        row_offset=off,
                    )

                buckets_t.append(
                    Bucket(item_ids=wrap(item_ids), nbr=wrap(nbr), val=wrap(val), nnz=wrap(nnz))
                )
        steps.append(tuple(buckets_t))

    orig = np.asarray(part_self.inv_perm, dtype=np.int32)  # [S*cap], -1 pads
    return RingSide(
        steps=tuple(steps),
        orig_ids=jnp.asarray(orig),
        cap=cap,
        num_items=num_items,
    )


def build_distributed_data(
    coo: RatingsCOO,
    num_shards: int,
    pads: Sequence[int] = (8, 32, 128, 512, 2048),
    test_fraction: float = 0.1,
    seed: int = 0,
    strategy: str = "lpt",
    cost_model: CostModel | None = None,
    min_rating: float | None = None,
    max_rating: float | None = None,
) -> tuple[DistBPMFData, DistPlan]:
    """Full host-side distribution pipeline (paper §IV-B).

    Splits train/test, computes the cost-balanced partition of both sides,
    relabels R accordingly and builds the per-ring-step neighbor lists.
    The centering mean uses the chunking-invariant accumulator so a
    per-host build of the same ratings (:func:`build_distributed_data_per_host`)
    centers bitwise-identically.
    """
    train, test = train_test_split(coo, test_fraction, seed)
    mean = stable_mean(train.vals) if train.nnz else 0.0
    centered = train.vals - np.float32(mean)

    u_indptr, u_idx, u_val = csr_from_coo(train.rows, train.cols, centered, coo.num_users)
    m_indptr, m_idx, m_val = csr_from_coo(train.cols, train.rows, centered, coo.num_movies)

    cm = cost_model or CostModel()
    part_u = partition_items(
        (u_indptr[1:] - u_indptr[:-1]).astype(np.int64), num_shards, cm, strategy
    )
    part_m = partition_items(
        (m_indptr[1:] - m_indptr[:-1]).astype(np.int64), num_shards, cm, strategy
    )

    users = _ring_side_buckets(u_indptr, u_idx, u_val, part_u, part_m, num_shards, pads)
    movies = _ring_side_buckets(m_indptr, m_idx, m_val, part_m, part_u, num_shards, pads)

    lo = float(coo.vals.min()) if min_rating is None else min_rating
    hi = float(coo.vals.max()) if max_rating is None else max_rating
    data = DistBPMFData(
        users=users,
        movies=movies,
        test=DistTestSet(
            rows=jnp.asarray(part_u.perm[test.rows], jnp.int32),
            cols=jnp.asarray(part_m.perm[test.cols], jnp.int32),
            vals=jnp.asarray(test.vals, jnp.float32),
        ),
        mean_rating=jnp.asarray(mean, jnp.float32),
        num_shards=num_shards,
        min_rating=lo,
        max_rating=hi,
    )
    return data, DistPlan(part_u, part_m, num_shards, strategy)


def local_shard_range(num_shards: int, process_index: int, num_processes: int) -> range:
    """The contiguous ring shards owned by one process.

    Global device order is process-major, so process p's addressable devices
    are exactly shards ``[p*S/P, (p+1)*S/P)`` of a ring mesh over all global
    devices.
    """
    if num_shards % num_processes:
        raise ValueError(
            f"num_shards={num_shards} must be divisible by num_processes={num_processes}"
        )
    per = num_shards // num_processes
    return range(process_index * per, (process_index + 1) * per)


def build_distributed_data_per_host(
    ratings: ChunkedRatings,
    num_shards: int,
    local_shards: Sequence[int],
    pads: Sequence[int] = (8, 32, 128, 512, 2048),
    test_fraction: float = 0.1,
    seed: int = 0,
    strategy: str = "lpt",
    cost_model: CostModel | None = None,
    min_rating: float | None = None,
    max_rating: float | None = None,
) -> tuple[DistBPMFData, DistPlan]:
    """Per-host distribution pipeline: global plan, local materialization.

    Every process streams the same rating chunks twice and computes the same
    deterministic global state — train/test split (the seeded RNG stream is
    consumed in chunk order, which equals the one-shot draw for PCG64),
    per-item rating counts, the cost-balanced partitions, the centering mean
    (chunking-invariant accumulator) and the global bucket shape plan — but
    only *retains* training ratings that touch one of its ``local_shards``
    and only materializes those shards' bucket arrays (as
    :class:`LocalShardedArray` blocks). No process ever holds the full
    training rating array; the guard below raises if the retention filter
    degenerates. The held-out test triples stay replicated (they are
    device-replicated at runtime anyway).

    With ``local_shards`` covering every shard this is bitwise-identical to
    :func:`build_distributed_data` on the materialized stream — asserted in
    tests/test_multiproc.py.
    """
    S = num_shards
    local = tuple(int(d) for d in local_shards)
    U, M = ratings.num_users, ratings.num_movies

    # -- pass 1: split + per-item train counts + mean + test triples ------
    rng = np.random.default_rng(seed)
    u_nnz = np.zeros(U, dtype=np.int64)
    m_nnz = np.zeros(M, dtype=np.int64)
    mean_acc = StableMeanAccumulator()
    test_rows, test_cols, test_vals = [], [], []
    vmin, vmax = np.inf, -np.inf
    total_train = 0
    for chunk in ratings.chunks():
        if chunk.nnz > ratings.chunk_rows:
            raise ValueError(
                f"chunk of {chunk.nnz} ratings exceeds chunk_rows={ratings.chunk_rows}"
            )
        t = rng.random(chunk.nnz) < test_fraction
        tr = ~t
        u_nnz += np.bincount(chunk.rows[tr], minlength=U)
        m_nnz += np.bincount(chunk.cols[tr], minlength=M)
        mean_acc.add(chunk.vals[tr])
        test_rows.append(chunk.rows[t])
        test_cols.append(chunk.cols[t])
        test_vals.append(chunk.vals[t])
        if chunk.nnz:
            vmin = min(vmin, float(chunk.vals.min()))
            vmax = max(vmax, float(chunk.vals.max()))
        total_train += int(tr.sum())
    mean = mean_acc.mean()

    cm = cost_model or CostModel()
    part_u = partition_items(u_nnz, S, cm, strategy)
    part_m = partition_items(m_nnz, S, cm, strategy)
    shard_of_u = (part_u.perm // part_u.cap).astype(np.int64)
    shard_of_m = (part_m.perm // part_m.cap).astype(np.int64)
    local_u = np.isin(shard_of_u, local)
    local_m = np.isin(shard_of_m, local)

    # -- pass 2: neighbor shard counts (global) + local rating retention --
    rng2 = np.random.default_rng(seed)
    cnt_u = np.zeros(U * S, dtype=np.int64)
    cnt_m = np.zeros(M * S, dtype=np.int64)
    keep_r, keep_c, keep_v = [], [], []
    for chunk in ratings.chunks():
        t = rng2.random(chunk.nnz) < test_fraction
        tr = ~t
        r, c, v = chunk.rows[tr], chunk.cols[tr], chunk.vals[tr]
        cnt_u += np.bincount(r.astype(np.int64) * S + shard_of_m[c], minlength=U * S)
        cnt_m += np.bincount(c.astype(np.int64) * S + shard_of_u[r], minlength=M * S)
        keep = local_u[r] | local_m[c]
        keep_r.append(r[keep])
        keep_c.append(c[keep])
        keep_v.append(v[keep])
    cnt_u = cnt_u.reshape(U, S).astype(np.int32)
    cnt_m = cnt_m.reshape(M, S).astype(np.int32)

    r = np.concatenate(keep_r) if keep_r else np.zeros(0, np.int32)
    c = np.concatenate(keep_c) if keep_c else np.zeros(0, np.int32)
    v = np.concatenate(keep_v) if keep_v else np.zeros(0, np.float32)
    local_nnz = int(r.shape[0])
    if len(local) < S and total_train and local_nnz >= total_train:
        raise RuntimeError(
            f"per-host retention kept all {total_train} training ratings on a "
            f"process owning only shards {local} of {S} — the locality filter "
            "is not reducing the resident rating array"
        )
    cv = v - np.float32(mean)

    own_u = local_u[r]  # ratings whose user is locally owned
    own_m = local_m[c]
    u_indptr, u_idx, u_val = csr_from_coo(r[own_u], c[own_u], cv[own_u], U)
    m_indptr, m_idx, m_val = csr_from_coo(c[own_m], r[own_m], cv[own_m], M)

    users = _ring_side_buckets(
        u_indptr, u_idx, u_val, part_u, part_m, S, pads,
        shard_counts=cnt_u, local_shards=local,
    )
    movies = _ring_side_buckets(
        m_indptr, m_idx, m_val, part_m, part_u, S, pads,
        shard_counts=cnt_m, local_shards=local,
    )

    trows = np.concatenate(test_rows) if test_rows else np.zeros(0, np.int32)
    tcols = np.concatenate(test_cols) if test_cols else np.zeros(0, np.int32)
    tvals = np.concatenate(test_vals) if test_vals else np.zeros(0, np.float32)
    lo = (vmin if np.isfinite(vmin) else -np.inf) if min_rating is None else min_rating
    hi = (vmax if np.isfinite(vmax) else np.inf) if max_rating is None else max_rating
    data = DistBPMFData(
        users=users,
        movies=movies,
        test=DistTestSet(
            rows=jnp.asarray(part_u.perm[trows], jnp.int32),
            cols=jnp.asarray(part_m.perm[tcols], jnp.int32),
            vals=jnp.asarray(tvals, jnp.float32),
        ),
        mean_rating=jnp.asarray(mean, jnp.float32),
        num_shards=S,
        min_rating=lo,
        max_rating=hi,
    )
    plan = DistPlan(
        part_u, part_m, S, strategy,
        local_shards=local, local_nnz=local_nnz, total_nnz=total_train,
    )
    return data, plan


# --------------------------------------------------------------------------
# Device-side sweep (inside shard_map; everything here sees LOCAL shards)
# --------------------------------------------------------------------------


def _accumulate_buckets(
    G: jax.Array,
    g: jax.Array,
    X_src: jax.Array,
    buckets: tuple[Bucket, ...],
    alpha: float,
    compute_dtype,
    gram_impl: str,
) -> tuple[jax.Array, jax.Array]:
    """Add one ring step's Gram contributions into the per-local-item (G, g).

    Dispatch is resolved at trace time by ``kernels.ops.bpmf_gram_step``:
    with a fused decision (autotune cache / heuristic / explicit
    ``gram_impl="pallas_fused"``) the whole step is one ``pallas_call``
    scatter-accumulating in-kernel; otherwise it is the per-bucket loop
    with ``at[].add`` scatters.
    """
    from repro.kernels import ops as kops

    return kops.bpmf_gram_step(
        G, g, X_src, buckets, alpha=alpha, compute_dtype=compute_dtype, gram_impl=gram_impl
    )


def _half_sweep_ring(
    key: jax.Array,
    X_opp_loc: jax.Array,  # [cap_opp, K] this device's opposite-side shard
    side: RingSide,  # LOCAL slices (leading S axis already split)
    hyper: HyperParams,
    cfg: BPMFConfig,
    num_shards: int,
) -> jax.Array:
    """Paper §IV-C: rotate opposite shards around the ring, overlap compute.

    The ppermute for step t+1 is issued *before* step t's Gram accumulation,
    so the ICI transfer proceeds while the MXU contracts — the paper's
    Isend/Irecv-with-buffering, with the whole shard as the maximal buffer.
    """
    cap = side.cap
    K = X_opp_loc.shape[-1]
    G = jnp.zeros((cap, K, K), jnp.float32)
    g = jnp.zeros((cap, K), jnp.float32)

    perm = [(i, (i + 1) % num_shards) for i in range(num_shards)]
    buf = X_opp_loc
    for t in range(num_shards):
        if t + 1 < num_shards:
            nxt = jax.lax.ppermute(buf, RING_AXIS, perm)  # in flight during gram
        G, g = _accumulate_buckets(
            G, g, buf, side.steps[t], cfg.alpha, cfg.compute_dtype, cfg.gram_impl
        )
        if t + 1 < num_shards:
            buf = nxt

    return posterior.sample_from_terms(key, side.orig_ids, G, g, hyper)


def _half_sweep_ring_async(
    key: jax.Array,
    X_opp_loc: jax.Array,  # [cap_opp, K] this device's opposite-side shard
    side: RingSide,  # LOCAL slices (leading S axis already split)
    hyper: HyperParams,
    cfg: BPMFConfig,
    num_shards: int,
) -> jax.Array:
    """Depth-d pipelined ring (Vander Aa et al. 1705.10633, DESIGN.md §7).

    Generalizes :func:`_half_sweep_ring` from one in-flight ``ppermute`` to a
    rotating queue of ``d = cfg.pipeline_depth`` buffers:

      * prologue — issue the rotations for steps 1..d-1 before the first
        Gram accumulation, so d shard buffers are live up front;
      * steady state — at step t, issue the rotation producing the buffer
        for step t+d, then accumulate step t from the queue head. Compute
        at step t therefore only waits on a transfer issued d steps
        earlier, hiding up to d link latencies instead of one;
      * drain — the last d steps issue nothing and consume the queue.

    Exactly ``num_shards - 1`` rotations are issued in total (same bytes as
    the synchronous ring), and the buffer consumed at step t holds shard
    ``(d_axis - t) mod S`` regardless of depth — rotations only reorder
    *when* transfers are issued, never the values — so the posterior draw
    is bit-identical to ``comm_mode="ring"`` for every depth. Memory cost:
    d opposite-shard buffers (d × cap_opp × K × itemsize bytes) live at
    once.
    """
    cap = side.cap
    K = X_opp_loc.shape[-1]
    G = jnp.zeros((cap, K, K), jnp.float32)
    g = jnp.zeros((cap, K), jnp.float32)

    if cfg.pipeline_depth < 1:
        raise ValueError(f"pipeline_depth must be >= 1, got {cfg.pipeline_depth}")
    depth = min(cfg.pipeline_depth, num_shards)  # > S-1 rotations can't exist

    perm = [(i, (i + 1) % num_shards) for i in range(num_shards)]
    queue = [X_opp_loc]  # queue[i] holds the buffer for step t + i
    for _ in range(depth - 1):  # prologue: pre-issue d-1 rotations
        queue.append(jax.lax.ppermute(queue[-1], RING_AXIS, perm))
    for t in range(num_shards):
        if t + depth < num_shards:  # issue step t+d while accumulating step t
            queue.append(jax.lax.ppermute(queue[-1], RING_AXIS, perm))
        buf = queue.pop(0)
        G, g = _accumulate_buckets(
            G, g, buf, side.steps[t], cfg.alpha, cfg.compute_dtype, cfg.gram_impl
        )

    return posterior.sample_from_terms(key, side.orig_ids, G, g, hyper)


def _half_sweep_allgather(
    key: jax.Array,
    X_opp_loc: jax.Array,
    side: RingSide,
    hyper: HyperParams,
    cfg: BPMFConfig,
    num_shards: int,
) -> jax.Array:
    """Synchronous baseline: one blocking all-gather, then local updates.

    Reuses the ring neighbor lists — at step t the slice of the gathered
    matrix standing in for the ring buffer is shard (d - t) mod S.
    """
    cap = side.cap
    K = X_opp_loc.shape[-1]
    cap_opp = X_opp_loc.shape[0]
    X_full = jax.lax.all_gather(X_opp_loc, RING_AXIS, tiled=True)  # [S*cap_opp, K]
    d = jax.lax.axis_index(RING_AXIS)

    G = jnp.zeros((cap, K, K), jnp.float32)
    g = jnp.zeros((cap, K), jnp.float32)
    for t in range(num_shards):
        o = (d - t) % num_shards
        shard = jax.lax.dynamic_slice(X_full, (o * cap_opp, 0), (cap_opp, K))
        G, g = _accumulate_buckets(
            G, g, shard, side.steps[t], cfg.alpha, cfg.compute_dtype, cfg.gram_impl
        )
    return posterior.sample_from_terms(key, side.orig_ids, G, g, hyper)


def _psum_ordered(x: jax.Array) -> jax.Array:
    """Ring-axis sum with a reduction order fixed by the program, not the fabric.

    ``lax.psum`` leaves the reduction tree to the collective backend, so a
    cross-process all-reduce (e.g. gloo's ring) and XLA's single-process
    all-reduce sum in different orders and differ in the last float bit —
    enough to break the multi-process == single-process bitwise contract.
    An ``all_gather`` moves bytes exactly; the axis-0 sum then runs inside
    the (identical) per-shard program, so every mesh realization reduces in
    the same order. Only worth the extra bytes for small operands — here the
    [K]/[K,K] hyper sufficient statistics.
    """
    return jnp.sum(jax.lax.all_gather(x, RING_AXIS), axis=0)


def _sample_hyper_dist(
    key: jax.Array, X_loc: jax.Array, orig_ids: jax.Array, prior
) -> HyperParams:
    """NW conditional from globally-reduced sufficient statistics.

    Identical on all devices; uses the order-deterministic reduction so the
    draw does not depend on the process layout of the ring mesh.
    """
    weights = (orig_ids >= 0).astype(X_loc.dtype)
    n, sx, sxx = hyper_sufficient_stats(X_loc, weights)
    n = _psum_ordered(n)
    sx = _psum_ordered(sx)
    sxx = _psum_ordered(sxx)
    return sample_hyper_from_stats(key, n, sx, sxx, prior)


def _predict_dist(
    U_loc: jax.Array,
    V_loc: jax.Array,
    test: DistTestSet,
    mean_rating: jax.Array,
    min_rating: float,
    max_rating: float,
    num_shards: int,
) -> jax.Array:
    """Test predictions with factor rows scattered across the ring.

    Each test row/col lives on exactly one shard; a masked local gather
    followed by a psum reconstructs the [T, K] rows on every device — two
    small collectives per sweep, negligible next to the factor rotation.
    """
    d = jax.lax.axis_index(RING_AXIS)
    cap_u, K = U_loc.shape
    cap_v = V_loc.shape[0]

    def fetch(X_loc: jax.Array, ids: jax.Array, cap: int) -> jax.Array:
        shard = ids // cap
        local = ids % cap
        mine = (shard == d).astype(X_loc.dtype)
        rows = jnp.take(X_loc, local, axis=0, mode="clip") * mine[:, None]
        return jax.lax.psum(rows, RING_AXIS)

    u_rows = fetch(U_loc, test.rows, cap_u)
    v_rows = fetch(V_loc, test.cols, cap_v)
    preds = jnp.sum(u_rows * v_rows, axis=-1) + mean_rating
    return jnp.clip(preds, min_rating, max_rating)


def _sweep_step_device(
    key: jax.Array,
    U_loc: jax.Array,
    V_loc: jax.Array,
    sweep: jax.Array,
    pred_sum: jax.Array,
    pred_n: jax.Array,
    data: DistBPMFData,  # local slices of the sharded leaves
    cfg: BPMFConfig,
) -> tuple[jax.Array, jax.Array, HyperParams, HyperParams, jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """One full Gibbs sweep on one device (Algorithm 1, distributed).

    Traceable body shared by the per-sweep ``shard_map`` entry point and the
    blocked scan loop; returns scalar ``(r_sample, r_avg)`` separately so
    callers stack metrics however they batch sweeps.
    """
    S = data.num_shards
    prior = cfg.prior()
    k_hv, k_v, k_hu, k_u = sweep_keys(key, sweep)
    halves = {
        "ring": _half_sweep_ring,
        "ring_async": _half_sweep_ring_async,
        "allgather": _half_sweep_allgather,
    }
    if cfg.comm_mode not in halves:
        raise ValueError(
            f"unknown comm_mode {cfg.comm_mode!r}; one of {sorted(halves)}"
        )
    half = halves[cfg.comm_mode]

    # movies given users
    hyper_V = _sample_hyper_dist(k_hv, V_loc, data.movies.orig_ids, prior)
    V_new = half(k_v, U_loc, data.movies, hyper_V, cfg, S)
    # users given updated movies
    hyper_U = _sample_hyper_dist(k_hu, U_loc, data.users.orig_ids, prior)
    U_new = half(k_u, V_new, data.users, hyper_U, cfg, S)

    preds = _predict_dist(
        U_new, V_new, data.test, data.mean_rating, data.min_rating, data.max_rating, S
    )
    new_sweep = sweep + 1
    burned = (new_sweep > cfg.burn_in).astype(jnp.int32)
    pred_sum = pred_sum + preds * burned
    pred_n = pred_n + burned
    r_sample = rmse(preds, data.test.vals)
    avg = pred_sum / jnp.maximum(pred_n, 1).astype(jnp.float32)
    r_avg = jnp.where(pred_n > 0, rmse(avg, data.test.vals), r_sample)
    return U_new, V_new, hyper_U, hyper_V, new_sweep, pred_sum, pred_n, r_sample, r_avg


def _sweep_device_fn(
    key: jax.Array,
    U_loc: jax.Array,
    V_loc: jax.Array,
    sweep: jax.Array,
    pred_sum: jax.Array,
    pred_n: jax.Array,
    data: DistBPMFData,
    cfg: BPMFConfig,
) -> tuple[jax.Array, jax.Array, HyperParams, HyperParams, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Per-sweep ``shard_map`` body (legacy entry point)."""
    U, V, hU, hV, sweep, pred_sum, pred_n, r_sample, r_avg = _sweep_step_device(
        key, U_loc, V_loc, sweep, pred_sum, pred_n, data, cfg
    )
    return U, V, hU, hV, sweep, pred_sum, pred_n, jnp.stack([r_sample, r_avg])


def _sweep_block_device_fn(
    key: jax.Array,
    U_loc: jax.Array,
    V_loc: jax.Array,
    hyper_U: HyperParams,
    hyper_V: HyperParams,
    sweep: jax.Array,
    pred_sum: jax.Array,
    pred_n: jax.Array,
    accum: PosteriorAccum,  # local shard slices (windows sliced on axis 1)
    data: DistBPMFData,
    cfg: BPMFConfig,
    block_size: int,
) -> tuple[jax.Array, jax.Array, HyperParams, HyperParams, jax.Array, jax.Array, jax.Array, PosteriorAccum, jax.Array]:
    """``block_size`` sweeps in one on-device ``lax.scan`` (DESIGN.md §10).

    The posterior accumulator shards travel in the scan carry next to the
    factor shards they summarize: each device folds only its local rows, so
    accumulation adds zero communication and zero host traffic. The burn-in
    gate is the traced ``sweep > burn_in`` predicate — blocks may straddle
    burn-in. Per-sweep ``[3]`` metric rows stack into the ``[block_size, 3]``
    ys output, the block's single host transfer.
    """

    def body(carry, _):
        U, V, hU, hV, sw, ps, pn, ac = carry
        U, V, hU, hV, sw, ps, pn, r_sample, r_avg = _sweep_step_device(
            key, U, V, sw, ps, pn, data, cfg
        )
        ac = update_posterior_accum(ac, U, V, sw > cfg.burn_in)
        row = jnp.stack([r_sample, r_avg, sw.astype(jnp.float32)])
        return (U, V, hU, hV, sw, ps, pn, ac), row

    init = (U_loc, V_loc, hyper_U, hyper_V, sweep, pred_sum, pred_n, accum)
    (U, V, hU, hV, sw, ps, pn, ac), metrics = jax.lax.scan(
        body, init, None, length=block_size
    )
    return U, V, hU, hV, sw, ps, pn, ac, metrics


# --------------------------------------------------------------------------
# Public API
# --------------------------------------------------------------------------


def make_ring_mesh(devices: Sequence[jax.Device] | None = None) -> Mesh:
    """1-D ring mesh over all (or the given) devices.

    ``jax.devices()`` is the *global*, process-major device list, so after
    ``jax.distributed.initialize`` this mesh spans every process — shard d
    is addressable by process ``d // local_device_count``. The logical mesh
    (and therefore the compiled per-shard program) is identical however the
    devices are split across processes.
    """
    devices = list(devices) if devices is not None else jax.devices()
    return Mesh(np.asarray(devices), (RING_AXIS,))


def init_dist_state(
    key: jax.Array, data: DistBPMFData, cfg: BPMFConfig, mesh: Mesh
) -> DistState:
    """Prior-predictive init, bitwise-identical per original item id to
    `gibbs.init_state` (both key rows by original id via fold_in)."""
    from repro.core.gibbs import init_rows

    ku, kv = jax.random.split(key)
    dt = cfg.sample_dtype
    sharding = NamedSharding(mesh, P(RING_AXIS))
    init = jax.jit(functools.partial(init_rows, K=cfg.K, dtype=dt), out_shardings=sharding)
    U = init(ku, data.users.orig_ids)
    V = init(kv, data.movies.orig_ids)
    return DistState(
        U=U,
        V=V,
        hyper_U=HyperParams.init(cfg.K, dt),
        hyper_V=HyperParams.init(cfg.K, dt),
        sweep=jnp.zeros((), jnp.int32),
    )


def _bucket_specs(side: RingSide) -> RingSide:
    """PartitionSpec tree matching RingSide: all flat leading axes ring-sharded."""
    ring = P(RING_AXIS)
    steps = tuple(
        tuple(Bucket(item_ids=ring, nbr=ring, val=ring, nnz=ring) for _ in bs)
        for bs in side.steps
    )
    return RingSide(steps=steps, orig_ids=ring, cap=side.cap, num_items=side.num_items)


def data_specs(data: DistBPMFData) -> DistBPMFData:
    rep = P()
    return DistBPMFData(
        users=_bucket_specs(data.users),
        movies=_bucket_specs(data.movies),
        test=DistTestSet(rows=rep, cols=rep, vals=rep),
        mean_rating=rep,
        num_shards=data.num_shards,
        min_rating=data.min_rating,
        max_rating=data.max_rating,
    )


def place_global(x, sharding: NamedSharding) -> jax.Array:
    """Place one host leaf under ``sharding``, multi-process aware.

    ``device_put`` of a host array requires every device to be addressable;
    in a multi-process mesh each process instead supplies only its local
    shards via ``make_array_from_callback``. :class:`LocalShardedArray`
    leaves (per-host builds) can *only* go through the callback path — the
    callback is invoked per addressable shard, which is exactly the row
    range the process materialized.
    """
    if isinstance(x, LocalShardedArray):
        return x.place(sharding)
    if jax.process_count() > 1:
        arr = np.asarray(x)
        return jax.make_array_from_callback(arr.shape, sharding, lambda idx: arr[idx])
    return jax.device_put(x, sharding)


def shard_data(data: DistBPMFData, mesh: Mesh) -> DistBPMFData:
    """Place the host-built data with its ring sharding."""
    specs = data_specs(data)
    return jax.tree_util.tree_map(
        lambda x, s: place_global(x, NamedSharding(mesh, s)),
        data,
        specs,
        is_leaf=lambda x: isinstance(x, (jax.Array, jnp.ndarray)) or hasattr(x, "shape"),
    )


@functools.partial(jax.jit, static_argnames=("cfg", "mesh"))
def dist_gibbs_sweep(
    key: jax.Array,
    state: DistState,
    pred_state: PredictionState,
    data: DistBPMFData,
    cfg: BPMFConfig,
    mesh: Mesh,
) -> tuple[DistState, PredictionState, SweepMetrics]:
    """jit entry point: one distributed sweep over the ring mesh."""
    ring = P(RING_AXIS)
    rep = P()
    hyper_spec = HyperParams(mu=rep, Lam=rep)

    fn = shard_map(
        functools.partial(_sweep_device_fn, cfg=cfg),
        mesh=mesh,
        in_specs=(
            rep,  # key
            ring,  # U
            ring,  # V
            rep,  # sweep
            rep,  # pred_sum (replicated test preds)
            rep,  # pred_n
            data_specs(data),
        ),
        out_specs=(ring, ring, hyper_spec, hyper_spec, rep, rep, rep, rep),
    )
    U, V, hU, hV, sweep, psum_, pn, r = fn(
        key, state.U, state.V, state.sweep, pred_state.sum_pred, pred_state.num_samples, data
    )
    new_state = DistState(U=U, V=V, hyper_U=hU, hyper_V=hV, sweep=sweep)
    new_pred = PredictionState(sum_pred=psum_, num_samples=pn)
    return new_state, new_pred, SweepMetrics(r[0], r[1], sweep)


def accum_specs() -> PosteriorAccum:
    """PartitionSpec tree for the sharded posterior accumulator.

    Sums are ring-sharded like the factor shards they summarize; the
    rotating windows shard their *item* axis (axis 1) the same way, with the
    window axis replicated; ``count`` is replicated.
    """
    ring = P(RING_AXIS)
    return PosteriorAccum(
        U_sum=ring, V_sum=ring, count=P(), filled=P(),
        U_window=P(None, RING_AXIS), V_window=P(None, RING_AXIS),
    )


def init_dist_accum(
    data: DistBPMFData, cfg: BPMFConfig, mesh: Mesh, keep: int
) -> PosteriorAccum:
    """Zeroed posterior accumulator in the relabeled sharded layout.

    Sums/windows cover every slot of the ``[S*cap, K]`` shards (pad slots
    accumulate garbage that the host view never reads — ``gather_factors``'
    permutation only touches real items).
    """
    num_u = data.users.orig_ids.shape[0]
    num_v = data.movies.orig_ids.shape[0]
    accum = PosteriorAccum.init(num_u, num_v, cfg.K, keep)
    specs = accum_specs()
    return jax.tree_util.tree_map(
        lambda x, s: place_global(x, NamedSharding(mesh, s)), accum, specs
    )


def _dist_gibbs_sweep_block(
    key: jax.Array,
    state: DistState,
    pred_state: PredictionState,
    accum: PosteriorAccum,
    data: DistBPMFData,
    cfg: BPMFConfig,
    mesh: Mesh,
    block_size: int,
) -> tuple[DistState, PredictionState, PosteriorAccum, jax.Array]:
    """jit entry point: ``block_size`` distributed sweeps, one host sync.

    One ``shard_map`` enter/exit per block wraps the on-device scan of
    :func:`_sweep_block_device_fn`; factors, prediction sums and the
    posterior accumulator stay sharded on-device for the whole block.
    Returns per-sweep metrics as a replicated ``[block_size, 3]`` f32 array
    of ``(rmse_sample, rmse_avg, sweep)`` rows.
    """
    ring = P(RING_AXIS)
    rep = P()
    hyper_spec = HyperParams(mu=rep, Lam=rep)

    fn = shard_map(
        functools.partial(_sweep_block_device_fn, cfg=cfg, block_size=block_size),
        mesh=mesh,
        in_specs=(
            rep,  # key
            ring,  # U
            ring,  # V
            hyper_spec,
            hyper_spec,
            rep,  # sweep
            rep,  # pred_sum (replicated test preds)
            rep,  # pred_n
            accum_specs(),
            data_specs(data),
        ),
        out_specs=(ring, ring, hyper_spec, hyper_spec, rep, rep, rep, accum_specs(), rep),
    )
    U, V, hU, hV, sweep, psum_, pn, accum, metrics = fn(
        key, state.U, state.V, state.hyper_U, state.hyper_V, state.sweep,
        pred_state.sum_pred, pred_state.num_samples, accum, data,
    )
    new_state = DistState(U=U, V=V, hyper_U=hU, hyper_V=hV, sweep=sweep)
    new_pred = PredictionState(sum_pred=psum_, num_samples=pn)
    return new_state, new_pred, accum, metrics


dist_gibbs_sweep_block = jax.jit(
    _dist_gibbs_sweep_block, static_argnames=("cfg", "mesh", "block_size")
)

#: Carry-donating variant of :func:`dist_gibbs_sweep_block` (same traced
#: body, same samples): donates the sharded state / prediction / posterior
#: accumulator inputs so each block's carry reuses the previous block's
#: shard buffers instead of doubling peak factor memory per device
#: (DESIGN.md §13). Donated inputs are consumed — callers that re-read a
#: block's inputs must use the non-donating entry point
#: (``BackendConfig.donate_blocks="off"``).
dist_gibbs_sweep_block_donated = jax.jit(
    _dist_gibbs_sweep_block,
    static_argnames=("cfg", "mesh", "block_size"),
    donate_argnums=(1, 2, 3),
)


def run_distributed(
    key: jax.Array,
    data: DistBPMFData,
    cfg: BPMFConfig,
    mesh: Mesh | None = None,
    callback=None,
) -> tuple[DistState, PredictionState, list[SweepMetrics]]:
    """Driver: init, shard, sweep ``cfg.num_sweeps`` times."""
    mesh = mesh or make_ring_mesh()
    k_init, k_run = jax.random.split(key)
    data = shard_data(data, mesh)
    state = init_dist_state(k_init, data, cfg, mesh)
    pred_state = PredictionState.init(data.test.rows.shape[0])
    history: list[SweepMetrics] = []
    for _ in range(cfg.num_sweeps):
        state, pred_state, metrics = dist_gibbs_sweep(k_run, state, pred_state, data, cfg, mesh)
        history.append(jax.tree_util.tree_map(float, metrics))
        if callback is not None:
            callback(state, metrics)
    return state, pred_state, history


def fetch_global(x) -> np.ndarray:
    """Host copy of a (possibly multi-process) jax array.

    ``np.asarray`` works for fully-addressable arrays; arrays sharded across
    processes go through ``process_allgather`` — a collective, so every
    process of the job must call this together.
    """
    if isinstance(x, jax.Array) and not x.is_fully_addressable:
        from jax.experimental import multihost_utils

        return np.asarray(multihost_utils.process_allgather(x, tiled=True))
    return np.asarray(x)


def gather_factors(
    state: DistState, plan: DistPlan
) -> tuple[np.ndarray, np.ndarray]:
    """Undo the relabeling: return (U, V) in original item order (host numpy)."""
    U = fetch_global(state.U)
    V = fetch_global(state.V)
    return U[plan.part_users.perm], V[plan.part_movies.perm]
