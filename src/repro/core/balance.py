"""Load balancing and data distribution (paper §IV-B).

The paper balances work across nodes with a workload model — "fixed cost
plus a cost per rating" — and reorders rows/columns of R so each node owns a
contiguous, equally-costly region. On an SPMD TPU mesh the same two ideas
become:

  * cost model  c(item) = a + b * nnz(item)   (coefficients fit from the
    fig2 microbenchmark, mirroring the paper's Figure 2 methodology);
  * a partition of items into S shards minimizing the max shard cost —
    either `block` (contiguous ranges, maximal rating locality, the paper's
    reordering) or `lpt` (greedy longest-processing-time, tightest balance);
  * a relabeling permutation so shard s owns the contiguous id range
    [s*cap, s*cap + |shard s|) — this *is* the paper's row/column reorder.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np


@dataclasses.dataclass(frozen=True)
class CostModel:
    """c(item) = fixed + per_rating * nnz. Defaults from the fig2 fit."""

    fixed: float = 1.0
    per_rating: float = 0.02

    def cost(self, nnz: np.ndarray) -> np.ndarray:
        return self.fixed + self.per_rating * nnz.astype(np.float64)


@dataclasses.dataclass(frozen=True)
class Partition:
    """Result of partitioning one side's items across S shards."""

    shards: list[np.ndarray]  # original item ids per shard
    perm: np.ndarray  # old id -> new global id (= shard * cap + slot)
    inv_perm: np.ndarray  # new global id -> old id (pad slots = -1)
    cap: int  # padded per-shard capacity
    loads: np.ndarray  # [S] cost per shard

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    def balance_ratio(self) -> float:
        """max/mean shard cost; 1.0 = perfectly balanced."""
        return float(self.loads.max() / max(self.loads.mean(), 1e-12))


def lpt_partition(costs: np.ndarray, num_shards: int) -> list[np.ndarray]:
    """Greedy longest-processing-time: items sorted by cost desc onto min-loaded shard."""
    order = np.argsort(-costs, kind="stable")
    assign = np.zeros(len(costs), dtype=np.int64)
    # heap-based greedy is O(n log S); fine at ChEMBL scale (~500k items, <1s)
    heap = [(0.0, s) for s in range(num_shards)]
    heapq.heapify(heap)
    for i in order:
        load, s = heapq.heappop(heap)
        assign[i] = s
        heapq.heappush(heap, (load + costs[i], s))
    return [np.nonzero(assign == s)[0] for s in range(num_shards)]


def block_partition(costs: np.ndarray, num_shards: int) -> list[np.ndarray]:
    """Contiguous ranges with near-equal cumulative cost (paper's reordering)."""
    cum = np.cumsum(costs)
    total = cum[-1]
    bounds = np.searchsorted(cum, total * np.arange(1, num_shards) / num_shards)
    starts = np.concatenate([[0], bounds])
    ends = np.concatenate([bounds, [len(costs)]])
    return [np.arange(s, e) for s, e in zip(starts, ends)]


def partition_items(
    nnz: np.ndarray,
    num_shards: int,
    cost_model: CostModel | None = None,
    strategy: str = "lpt",
    cap_multiple: int = 8,
) -> Partition:
    """Partition + relabel one side's items.

    ``cap`` (slots per shard) is the max shard size rounded up so every shard
    has identical padded length — required for SPMD. Pad slots map to no
    original item (inv_perm = -1) and behave like rating-less items.
    """
    cost_model = cost_model or CostModel()
    costs = cost_model.cost(nnz)
    if strategy == "lpt":
        shards = lpt_partition(costs, num_shards)
    elif strategy == "block":
        shards = block_partition(costs, num_shards)
    elif strategy == "naive":  # uniform contiguous split, ignores cost (baseline)
        shards = [a for a in np.array_split(np.arange(len(nnz)), num_shards)]
    else:
        raise ValueError(f"unknown strategy {strategy!r}")

    cap = max(len(s) for s in shards)
    cap = ((cap + cap_multiple - 1) // cap_multiple) * cap_multiple
    perm = np.full(len(nnz), -1, dtype=np.int64)
    inv = np.full(num_shards * cap, -1, dtype=np.int64)
    loads = np.zeros(num_shards)
    for s, ids in enumerate(shards):
        perm[ids] = s * cap + np.arange(len(ids))
        inv[s * cap : s * cap + len(ids)] = ids
        loads[s] = costs[ids].sum()
    return Partition(shards=shards, perm=perm, inv_perm=inv, cap=cap, loads=loads)


def fit_cost_model(nnz_samples: np.ndarray, times: np.ndarray) -> CostModel:
    """Least-squares fit of (fixed, per_rating) from measured update times.

    Mirrors the paper's Figure 2: measure time-to-update-one-item vs nnz,
    regress a line, use it to weigh items during partitioning.
    """
    A = np.stack([np.ones_like(nnz_samples, dtype=np.float64), nnz_samples.astype(np.float64)], 1)
    coef, *_ = np.linalg.lstsq(A, times.astype(np.float64), rcond=None)
    return CostModel(fixed=max(float(coef[0]), 1e-9), per_rating=max(float(coef[1]), 1e-12))
