"""Test-point prediction and RMSE tracking (paper Algorithm 1, last loop)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import BPMFData, TestSet
from repro.utils import pytree_dataclass


@pytree_dataclass
class PredictionState:
    """Running posterior-mean predictions over post-burn-in samples."""

    sum_pred: jax.Array  # [T] accumulated clipped predictions
    num_samples: jax.Array  # scalar int32

    @staticmethod
    def init(num_test: int) -> "PredictionState":
        return PredictionState(
            sum_pred=jnp.zeros((num_test,), jnp.float32),
            num_samples=jnp.zeros((), jnp.int32),
        )


def predict(U: jax.Array, V: jax.Array, test: TestSet, mean_rating: jax.Array,
            min_rating: float, max_rating: float) -> jax.Array:
    """Point predictions for the test triples from one posterior sample."""
    preds = jnp.sum(U[test.rows] * V[test.cols], axis=-1) + mean_rating
    return jnp.clip(preds, min_rating, max_rating)


def rmse(preds: jax.Array, vals: jax.Array) -> jax.Array:
    return jnp.sqrt(jnp.mean((preds - vals) ** 2))


def update_predictions(
    pred_state: PredictionState,
    U: jax.Array,
    V: jax.Array,
    data: BPMFData,
    burned_in: jax.Array,
) -> tuple[PredictionState, jax.Array, jax.Array]:
    """Accumulate posterior mean after burn-in; return (state, rmse_sample, rmse_avg)."""
    preds = predict(U, V, data.test, data.mean_rating, data.min_rating, data.max_rating)
    r_sample = rmse(preds, data.test.vals)
    inc = burned_in.astype(jnp.int32)
    new_state = PredictionState(
        sum_pred=pred_state.sum_pred + preds * inc,
        num_samples=pred_state.num_samples + inc,
    )
    n = jnp.maximum(new_state.num_samples, 1).astype(jnp.float32)
    avg = new_state.sum_pred / n
    # before burn-in the average is empty; report the sample RMSE instead
    r_avg = jnp.where(new_state.num_samples > 0, rmse(avg, data.test.vals), r_sample)
    return new_state, r_sample, r_avg
