"""Test-point prediction and RMSE tracking (paper Algorithm 1, last loop)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import BPMFData, PosteriorAccum, TestSet
from repro.utils import pytree_dataclass


@pytree_dataclass
class PredictionState:
    """Running posterior-mean predictions over post-burn-in samples."""

    sum_pred: jax.Array  # [T] accumulated clipped predictions
    num_samples: jax.Array  # scalar int32

    @staticmethod
    def init(num_test: int) -> "PredictionState":
        return PredictionState(
            sum_pred=jnp.zeros((num_test,), jnp.float32),
            num_samples=jnp.zeros((), jnp.int32),
        )


def predict(U: jax.Array, V: jax.Array, test: TestSet, mean_rating: jax.Array,
            min_rating: float, max_rating: float) -> jax.Array:
    """Point predictions for the test triples from one posterior sample."""
    preds = jnp.sum(U[test.rows] * V[test.cols], axis=-1) + mean_rating
    return jnp.clip(preds, min_rating, max_rating)


def rmse(preds: jax.Array, vals: jax.Array) -> jax.Array:
    return jnp.sqrt(jnp.mean((preds - vals) ** 2))


def update_predictions(
    pred_state: PredictionState,
    U: jax.Array,
    V: jax.Array,
    data: BPMFData,
    burned_in: jax.Array,
) -> tuple[PredictionState, jax.Array, jax.Array]:
    """Accumulate posterior mean after burn-in; return (state, rmse_sample, rmse_avg)."""
    preds = predict(U, V, data.test, data.mean_rating, data.min_rating, data.max_rating)
    r_sample = rmse(preds, data.test.vals)
    inc = burned_in.astype(jnp.int32)
    new_state = PredictionState(
        sum_pred=pred_state.sum_pred + preds * inc,
        num_samples=pred_state.num_samples + inc,
    )
    n = jnp.maximum(new_state.num_samples, 1).astype(jnp.float32)
    avg = new_state.sum_pred / n
    # before burn-in the average is empty; report the sample RMSE instead
    r_avg = jnp.where(new_state.num_samples > 0, rmse(avg, data.test.vals), r_sample)
    return new_state, r_sample, r_avg


def update_posterior_accum(
    accum: PosteriorAccum, U: jax.Array, V: jax.Array, burned_in: jax.Array
) -> PosteriorAccum:
    """Fold one sample into the device-resident posterior summary.

    Pure on-device (scan-body safe): ``burned_in`` is a traced predicate, so
    blocks that straddle burn-in gate per sweep without a host sync. Sums add
    ``x * 1.0f`` / ``x * 0.0f``, which is bitwise what the old host
    accumulator's conditional ``+=`` computed; the rotating window writes the
    sample at slot ``count % keep`` only when burned in (slot 0 is re-written
    with its own value otherwise, a no-op).
    """
    inc = burned_in.astype(jnp.int32)
    gate = inc.astype(jnp.float32)
    Uf = U.astype(jnp.float32)
    Vf = V.astype(jnp.float32)
    keep = accum.keep
    U_win, V_win = accum.U_window, accum.V_window
    if keep > 0:  # static: keep == 0 means no window is kept at all
        pos = jnp.where(burned_in, jnp.mod(accum.count, keep), 0)
        u_cur = jax.lax.dynamic_index_in_dim(U_win, pos, axis=0, keepdims=False)
        v_cur = jax.lax.dynamic_index_in_dim(V_win, pos, axis=0, keepdims=False)
        u_row = jnp.where(burned_in, Uf, u_cur)
        v_row = jnp.where(burned_in, Vf, v_cur)
        U_win = jax.lax.dynamic_update_index_in_dim(U_win, u_row, pos, axis=0)
        V_win = jax.lax.dynamic_update_index_in_dim(V_win, v_row, pos, axis=0)
    return PosteriorAccum(
        U_sum=accum.U_sum + Uf * gate,
        V_sum=accum.V_sum + Vf * gate,
        count=accum.count + inc,
        filled=jnp.minimum(accum.filled + inc, keep),
        U_window=U_win,
        V_window=V_win,
    )
