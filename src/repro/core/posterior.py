"""Per-item conditional posterior updates, bucketed for dense TPU compute.

For one item i of side X (say a movie) with neighbor latents {u_j} and
centered ratings {r_ij}:

    precision  P_i = Lambda + alpha * sum_j u_j u_j^T          [K, K]
    linear     l_i = Lambda mu + alpha * sum_j u_j r_ij        [K]
    sample     x_i = P_i^{-1} l_i + chol(P_i)^{-T} z,  z ~ N(0, I_K)

The paper's multi-core contribution is making the "for all items" loop fast
under skewed nnz; here each nnz-bucket is one dense [B, P, K] gather plus a
Gram contraction (Pallas kernel on TPU), and the Cholesky solve is batched.

Noise is generated per *global item id* with ``jax.random.fold_in`` so every
layout (single device, ring-distributed, re-balanced) produces the same
sample for the same item — the cross-version RMSE-parity claim of the paper
(§V-B) becomes an exact test instead of a statistical one.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

from repro.core.types import Bucket, BucketedSide, HyperParams


def item_noise(key: jax.Array, item_ids: jax.Array, K: int, dtype=jnp.float32) -> jax.Array:
    """Per-item N(0, I_K) noise, independent of batch layout."""

    def one(i: jax.Array) -> jax.Array:
        return jax.random.normal(jax.random.fold_in(key, i), (K,), dtype)

    return jax.vmap(one)(item_ids)


def _normalize_gram_impl(gram_impl) -> str:
    """Accept the legacy ``use_pallas`` boolean in ``gram_impl`` position."""
    if isinstance(gram_impl, bool):
        return "pallas" if gram_impl else "xla"
    return gram_impl


def gram_terms(
    X_opp: jax.Array,
    bucket: Bucket,
    alpha: float,
    compute_dtype=jnp.float32,
    gram_impl: str | bool = "xla",
) -> tuple[jax.Array, jax.Array]:
    """(G, g) with G = alpha * sum_j x_j x_j^T  [B,K,K], g = alpha * sum_j x_j r_j [B,K].

    ``gram_impl`` selects the gather+Gram implementation — ``"auto"``
    (autotune cache → heuristic), ``"pallas"`` or ``"xla"``; a legacy
    boolean maps to pallas/xla. Every choice dispatches through
    ``kernels.ops.bpmf_gram`` so there is exactly one implementation per
    impl: the XLA path gathers the masked ``[B, P, K]`` neighbor block
    once and contracts the augmented ``[Xn | val]`` block against itself
    (``ops._bpmf_gram_xla``), the Pallas path is the one-hot MXU kernel.
    """
    from repro.kernels import ops as kops

    gram_impl = _normalize_gram_impl(gram_impl)
    G, g = kops.bpmf_gram(
        X_opp, bucket.nbr, bucket.val, bucket.nnz,
        compute_dtype=compute_dtype,
        impl="pallas" if gram_impl == "pallas_fused" else gram_impl,
    )
    a = jnp.asarray(alpha, jnp.float32)
    return a * G, a * g


def sample_from_terms(
    key: jax.Array,
    item_ids: jax.Array,
    G: jax.Array,
    g: jax.Array,
    hyper: HyperParams,
) -> jax.Array:
    """Draw x_i ~ N(P^-1 l, P^-1) for a batch of items from accumulated terms."""
    K = g.shape[-1]
    prec = G + hyper.Lam  # [B, K, K]
    lin = g + hyper.Lam @ hyper.mu  # [B, K] (broadcast add of [K])
    L = jnp.linalg.cholesky(prec)
    # mean = P^-1 lin via two triangular solves
    y = solve_triangular(L, lin[..., None], lower=True)
    mean = solve_triangular(jnp.swapaxes(L, -1, -2), y, lower=False)[..., 0]
    z = item_noise(key, item_ids, K, dtype=g.dtype)
    noise = solve_triangular(jnp.swapaxes(L, -1, -2), z[..., None], lower=False)[..., 0]
    return mean + noise


def update_bucket(
    key: jax.Array,
    X_side: jax.Array,
    X_opp: jax.Array,
    bucket: Bucket,
    hyper: HyperParams,
    alpha: float,
    compute_dtype=jnp.float32,
    gram_impl: str | bool = "xla",
) -> jax.Array:
    """Sample all items of one bucket and scatter them into X_side.

    Bucket rows with ``item_ids == -1`` are padding and dropped by the
    scatter (mode="drop").
    """
    G, g = gram_terms(X_opp, bucket, alpha, compute_dtype, gram_impl)
    new = sample_from_terms(key, bucket.item_ids, G, g, hyper)
    return X_side.at[bucket.item_ids].set(new.astype(X_side.dtype), mode="drop")


def update_side(
    key: jax.Array,
    X_side: jax.Array,
    X_opp: jax.Array,
    side: BucketedSide,
    hyper: HyperParams,
    alpha: float,
    compute_dtype=jnp.float32,
    gram_impl: str | bool = "xla",
) -> jax.Array:
    """One half-sweep: resample every item of X_side given X_opp.

    Items are conditionally independent given (X_opp, hyper), so bucket order
    does not matter statistically; we loop buckets smallest-P first (the
    paper's cheap-items-first scheduling).
    """
    for bucket in side.buckets:
        X_side = update_bucket(
            key, X_side, X_opp, bucket, hyper, alpha, compute_dtype, gram_impl
        )
    return X_side


# --- reference (naive, un-bucketed) implementation for testing -----------------


def update_item_naive(
    key: jax.Array,
    item_id: int,
    nbr: jax.Array,
    val: jax.Array,
    X_opp: jax.Array,
    hyper: HyperParams,
    alpha: float,
) -> jax.Array:
    """Textbook single-item update (no padding, no bucketing) — test oracle."""
    Xn = X_opp[nbr]  # [n, K]
    K = Xn.shape[-1]
    prec = hyper.Lam + alpha * Xn.T @ Xn
    lin = hyper.Lam @ hyper.mu + alpha * Xn.T @ val
    L = jnp.linalg.cholesky(prec)
    y = solve_triangular(L, lin, lower=True)
    mean = solve_triangular(L.T, y, lower=False)
    z = jax.random.normal(jax.random.fold_in(key, item_id), (K,), dtype=mean.dtype)
    return mean + solve_triangular(L.T, z, lower=False)
