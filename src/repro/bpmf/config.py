"""Engine configuration: the model / run / backend split.

The legacy ``repro.core.types.BPMFConfig`` mixed three concerns into one
flat dataclass: what the model *is* (K, alpha, prior), how long to *run*
(sweeps, burn-in) and *where/how* to execute (comm_mode, gram_impl).
The engine API separates them so that switching execution backends —
sequential, ring, allgather, Pallas on or off — is a config knob with no
model or schedule implications:

  * :class:`ModelConfig`   — the statistical model (paper §III)
  * :class:`RunConfig`     — schedule, data split, checkpointing
  * :class:`BackendConfig` — execution: backend name, shard count, kernels

``BPMFConfig`` (this module's, not ``core.types``') bundles the three and
lowers to the legacy flat config via :meth:`BPMFConfig.core` for the
kernel-level code, which stays untouched.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import jax.numpy as jnp

from repro.core import types as core_types

_GRAM_IMPLS = ("auto", "pallas_fused", "pallas", "xla")
_USE_PALLAS_WARNED = False


def _warn_use_pallas_once() -> None:
    """Emit the ``use_pallas`` deprecation warning exactly once per process."""
    global _USE_PALLAS_WARNED
    if not _USE_PALLAS_WARNED:
        _USE_PALLAS_WARNED = True
        warnings.warn(
            "BackendConfig.use_pallas is deprecated; use gram_impl="
            '"auto" | "pallas" | "xla" instead (use_pallas=True -> "pallas", '
            'False -> "xla")',
            DeprecationWarning,
            stacklevel=3,
        )


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """The BPMF model itself (paper §III): rank, noise and prior.

    Attributes:
        K: Latent rank of the factorization ``R ~ U @ V.T``.
        alpha: Rating noise precision (likelihood ``N(r | u·v, 1/alpha)``).
        beta0: Normal-Wishart prior strength on the factor means.
        sample_dtype: dtype of the stored factor samples.
        compute_dtype: dtype of the Gram contraction (bf16 on TPU).
    """

    K: int = 32
    alpha: float = 2.0  # rating noise precision
    beta0: float = 2.0  # Normal-Wishart prior strength
    sample_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32  # Gram contraction dtype (bf16 on TPU)


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Schedule, data split and checkpoint policy for one fit.

    Attributes:
        num_sweeps: Total Gibbs sweeps for :meth:`BPMFEngine.fit`.
        burn_in: Sweeps discarded before the posterior-mean accumulator
            starts averaging predictions.
        seed: Seeds both the train/test split and the sampler key, so one
            integer pins the whole run.
        sweeps_per_block: Gibbs sweeps executed per jitted device block
            (DESIGN.md §10). The engine's run loop dispatches blocks of this
            many sweeps through one ``lax.scan`` with **no host sync inside
            the block** — posterior-mean sums, the recent-sample window and
            the prediction accumulator all fold on-device, and each block
            returns its per-sweep metrics in a single ``[block, 3]``
            transfer. ``1`` reproduces the historical per-sweep dispatch
            cadence; samples and artifacts are bitwise identical at every
            value. Blocks shrink automatically to land exactly on
            ``checkpoint_every`` boundaries and the final sweep.
        test_fraction: Held-out fraction for RMSE tracking.
        checkpoint_dir: Where :meth:`BPMFEngine.save` writes; ``None``
            disables checkpointing.
        checkpoint_every: Sweeps between auto-saves; 0 = explicit
            ``save()`` only.
        keep_checkpoints: Retention window (older steps are pruned).
        pipeline_blocks: Depth of the engine's block dispatch queue
            (DESIGN.md §13). With depth d > 1 the run loop launches the
            next device block on the still-on-device carry *before*
            fetching the previous block's metrics, so the host never sits
            between blocks; metric transfers complete asynchronously and
            drain d-1 blocks behind the dispatch front. The queue drains
            fully at ``checkpoint_every`` boundaries, at user ``save()`` /
            ``export()`` calls and at the end of the run, so the
            one-``SweepMetrics``-per-sweep iterator contract, history
            ordering and checkpoint cadence are bitwise identical at every
            depth. ``1`` reproduces the synchronous PR-5 loop.
        async_checkpoint_writes: Write checkpoints on the manager's
            background thread (DESIGN.md §13): ``save()`` snapshots host
            arrays and returns without waiting for the filesystem commit,
            keeping checkpoints off the dispatch critical path. The commit
            itself stays atomic (tmp-dir rename + ``LATEST`` replace);
            ``export()`` / ``restore()`` / process exit drain pending
            writes. ``False`` restores fully synchronous saves.
        keep_factor_samples: Most recent post-burn-in ``(U, V)`` samples
            retained for the serving artifact's predictive-std output
            (DESIGN.md §9); 0 keeps only the running posterior mean and
            disables ``return_std`` on the exported predictor.
    """

    num_sweeps: int = 50
    burn_in: int = 8
    seed: int = 0  # seeds both the train/test split and the sampler key
    sweeps_per_block: int = 8  # sweeps per jitted device block (1 = per-sweep)
    pipeline_blocks: int = 1  # block dispatch queue depth (1 = synchronous)
    async_checkpoint_writes: bool = True  # background checkpoint commit
    test_fraction: float = 0.1
    checkpoint_dir: str | None = None
    checkpoint_every: int = 0  # sweeps between auto-saves; 0 = explicit save() only
    keep_checkpoints: int = 3
    keep_factor_samples: int = 8  # recent post-burn-in samples for predictive std

    def __post_init__(self) -> None:
        if self.keep_factor_samples < 0:
            raise ValueError(
                f"RunConfig.keep_factor_samples must be >= 0, "
                f"got {self.keep_factor_samples}"
            )
        if self.sweeps_per_block < 1:
            raise ValueError(
                f"RunConfig.sweeps_per_block must be >= 1, "
                f"got {self.sweeps_per_block}"
            )
        if self.pipeline_blocks < 1:
            raise ValueError(
                f"RunConfig.pipeline_blocks must be >= 1, "
                f"got {self.pipeline_blocks}"
            )


@dataclasses.dataclass(frozen=True)
class BackendConfig:
    """Execution backend selection — the knob the paper's §V compares.

    ``name`` picks an entry from the backend registry
    (:mod:`repro.bpmf.backends`): ``"sequential"`` (single-program oracle),
    ``"ring"`` (paper §IV-C overlap schedule), ``"ring_async"`` (depth-d
    pipelined ring, arXiv:1705.10633 / DESIGN.md §7), ``"allgather"``
    (synchronous baseline) or ``"posterior_merge"`` (embarrassingly-parallel
    partition chains + subset-posterior merge, arXiv:1703.00734 /
    DESIGN.md §12).

    Attributes:
        name: Backend registry key; see
            :func:`repro.bpmf.available_backends`.
        num_shards: Ring length for the distributed backends; 0 means one
            shard per visible device. Ignored by ``"sequential"``.
        pipeline_depth: ``ring_async`` only — number of shard rotations
            kept in flight (d >= 1). d=1 reproduces the ``"ring"``
            schedule; larger d hides more link latency at the cost of d
            resident opposite-shard buffers per device. Clamped to the
            ring length; samples are bit-identical for every d.
        gram_impl: Gram hot-path dispatch (DESIGN.md §8): ``"auto"``
            (default — per-shape autotune cache, deterministic heuristic
            fallback: Pallas where it wins on TPU, XLA on CPU),
            ``"pallas"`` (force the per-bucket kernel), ``"xla"`` (force
            the gather+einsum path). ``"pallas_fused"`` forces the fused
            one-kernel-per-ring-step path (mainly tests/benchmarks —
            ``"auto"`` selects it when profitable).
        use_pallas: **Deprecated** boolean forerunner of ``gram_impl``;
            passing it warns once and maps ``True -> "pallas"``,
            ``False -> "xla"``.
        bucket_pads: Neighbor-count pad classes for the dense bucketed
            layout (``data/sparse.py``); items bucket into the smallest
            pad >= their rating count.
        partition_strategy: Cost-model load balancing of items onto
            shards (paper §IV-B): ``"lpt"`` (longest-processing-time) or
            ``"block"`` (contiguous). ``posterior_merge`` reuses it to
            balance users across chains.
        num_partitions: ``posterior_merge`` only — number of independent
            partition chains; 0 means one chain per visible device.
            Ignored by every other backend.
        merge_method: ``posterior_merge`` only — subset-posterior
            combination: ``"precision"`` (precision-weighted Gaussian
            product estimated from the chains' sample windows,
            arXiv:1703.00734; falls back to pooling when fewer than two
            window samples exist) or ``"pool"`` (uniform-weight pooling).
        donate_blocks: Whether the engine's block programs donate their
            carry buffers (``donate_argnums`` on state / prediction /
            posterior accumulators, DESIGN.md §13) so XLA writes each
            block's outputs into the previous block's buffers instead of
            doubling peak factor memory: ``"auto"`` (default — donate;
            samples are unaffected, only buffer reuse changes),
            ``"on"``, or ``"off"`` (the fallback path: every block
            allocates fresh outputs, inputs stay readable — use when
            wrapping ``sweep_block`` with code that re-reads its inputs).
    """

    name: str = "sequential"
    num_shards: int = 0  # 0 = one shard per visible device (distributed only)
    pipeline_depth: int = 1  # ring_async: rotations in flight (d >= 1)
    gram_impl: str = "auto"  # Gram dispatch: auto | pallas_fused | pallas | xla
    use_pallas: bool | None = None  # deprecated: use gram_impl
    bucket_pads: tuple[int, ...] = (8, 32, 128, 512, 2048)
    partition_strategy: str = "lpt"  # cost-model balancing (paper §IV-B)
    num_partitions: int = 0  # posterior_merge: chains (0 = one per device)
    merge_method: str = "precision"  # posterior_merge: precision | pool
    donate_blocks: str = "auto"  # block carry donation: auto | on | off

    def __post_init__(self) -> None:
        if self.donate_blocks not in ("auto", "on", "off"):
            raise ValueError(
                f'BackendConfig.donate_blocks must be "auto", "on" or "off", '
                f"got {self.donate_blocks!r}"
            )
        if self.pipeline_depth < 1:
            raise ValueError(
                f"BackendConfig.pipeline_depth must be >= 1, got {self.pipeline_depth}"
            )
        if self.num_partitions < 0:
            raise ValueError(
                f"BackendConfig.num_partitions must be >= 0, got {self.num_partitions}"
            )
        if self.merge_method not in ("precision", "pool"):
            raise ValueError(
                f'BackendConfig.merge_method must be "precision" or "pool", '
                f"got {self.merge_method!r}"
            )
        if self.use_pallas is not None:
            if self.gram_impl != "auto":
                raise ValueError(
                    f"BackendConfig: both gram_impl={self.gram_impl!r} and the "
                    f"deprecated use_pallas={self.use_pallas} were given — drop "
                    "use_pallas"
                )
            _warn_use_pallas_once()
            object.__setattr__(self, "gram_impl", "pallas" if self.use_pallas else "xla")
            # consume the legacy flag so later replace(gram_impl=...) calls
            # are not silently clobbered by the retained boolean (and
            # use_pallas=True == gram_impl="pallas" configs hash equal)
            object.__setattr__(self, "use_pallas", None)
        if self.gram_impl not in _GRAM_IMPLS:
            raise ValueError(
                f"BackendConfig.gram_impl must be one of {_GRAM_IMPLS}, "
                f"got {self.gram_impl!r}"
            )


@dataclasses.dataclass(frozen=True)
class BPMFConfig:
    """Everything :class:`repro.bpmf.BPMFEngine` needs, in one object."""

    model: ModelConfig = ModelConfig()
    run: RunConfig = RunConfig()
    backend: BackendConfig = BackendConfig()

    def core(self) -> core_types.BPMFConfig:
        """Lower to the legacy flat (hashable) config used by the kernels.

        Returns:
            A :class:`repro.core.types.BPMFConfig` suitable as a jit
            static argument. Backend names that are also core comm modes
            (``ring`` / ``ring_async`` / ``allgather``) pass through as
            ``comm_mode``; anything else (e.g. ``sequential``) lowers to
            ``"ring"``, which the sequential sampler ignores.
        """
        comm_modes = ("ring", "ring_async", "allgather")
        comm_mode = self.backend.name if self.backend.name in comm_modes else "ring"
        return core_types.BPMFConfig(
            K=self.model.K,
            alpha=self.model.alpha,
            num_sweeps=self.run.num_sweeps,
            burn_in=self.run.burn_in,
            beta0=self.model.beta0,
            bucket_pads=tuple(self.backend.bucket_pads),
            comm_mode=comm_mode,
            pipeline_depth=self.backend.pipeline_depth,
            sample_dtype=self.model.sample_dtype,
            compute_dtype=self.model.compute_dtype,
            gram_impl=self.backend.gram_impl,
        )

    def replace(self, **kw: Any) -> "BPMFConfig":
        """`dataclasses.replace` that also reaches one level down.

        Keys matching a sub-config field are routed there, so
        ``cfg.replace(name="ring_async", pipeline_depth=2, num_sweeps=10)``
        works without spelling out the nesting.

        Args:
            **kw: Field overrides; each key must name a ``BPMFConfig``
                field or a field of exactly one sub-config.

        Returns:
            A new :class:`BPMFConfig` with the overrides applied.

        Raises:
            TypeError: If a key matches no field anywhere.
        """
        subs = {"model": self.model, "run": self.run, "backend": self.backend}
        updates: dict[str, dict[str, Any]] = {k: {} for k in subs}
        top: dict[str, Any] = {}
        for key, val in kw.items():
            if key in subs:
                top[key] = val
                continue
            for sub_name, sub in subs.items():
                if any(f.name == key for f in dataclasses.fields(sub)):
                    updates[sub_name][key] = val
                    break
            else:
                raise TypeError(f"unknown BPMFConfig field: {key!r}")
        for sub_name, up in updates.items():
            if up:
                top[sub_name] = dataclasses.replace(subs[sub_name], **up)
        return dataclasses.replace(self, **top)
