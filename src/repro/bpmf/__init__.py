"""``repro.bpmf`` — the unified BPMF engine API.

One facade (:class:`BPMFEngine`) over the sequential, ring, ring_async
(depth-d pipelined) and allgather samplers; backend choice is a
:class:`BackendConfig` knob, not an import decision. See README.md for a
quickstart, DESIGN.md for the architecture (facade -> backend registry ->
``repro.core``) and ``python -m repro.launch.bpmf --help`` for the CLI.
"""
from repro.bpmf.backends import (
    Backend,
    DistributedBackend,
    available_backends,
    get_backend,
    register_backend,
)
from repro.bpmf.config import BackendConfig, BPMFConfig, ModelConfig, RunConfig
from repro.bpmf.datasets import available_datasets, load_dataset, register_dataset
from repro.bpmf.engine import BPMFEngine

__all__ = [
    "Backend",
    "BackendConfig",
    "DistributedBackend",
    "BPMFConfig",
    "BPMFEngine",
    "ModelConfig",
    "RunConfig",
    "available_backends",
    "available_datasets",
    "get_backend",
    "load_dataset",
    "register_backend",
    "register_dataset",
]
