"""``BPMFEngine`` — the single front door to every BPMF sampler.

One facade over the sequential oracle and the distributed
ring/ring_async/allgather samplers (paper §V-B: they are the same
sampler), with the run loop,
sweep-level checkpointing and metric streaming factored out of the
backends::

    from repro.bpmf import BPMFConfig, BPMFEngine, load_dataset

    coo = load_dataset("synthetic", num_users=400, num_movies=300, nnz=12_000)
    cfg = BPMFConfig().replace(name="ring", K=16, num_sweeps=25)
    engine = BPMFEngine(cfg).fit(coo)
    print(engine.rmse)

Backend choice is config-only: the same ``(seed, data)`` run through
``"sequential"``, ``"ring"``, ``"ring_async"`` (any depth) and
``"allgather"`` yields the same posterior samples up to float reduction
order (tests/test_engine.py asserts this).

Determinism note: the sampler key is derived from ``RunConfig.seed`` and
per-sweep keys from ``(key, state.sweep)``, so a run restored from a
checkpoint continues with *identical* randomness to an uninterrupted one.
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import numpy as np

from repro.bpmf.backends import Backend, get_backend
from repro.bpmf.config import BPMFConfig
from repro.checkpoint import CheckpointManager
from repro.core.gibbs import SweepMetrics
from repro.data.sparse import RatingsCOO


class BPMFEngine:
    """Fit / sample / predict / save / restore over a pluggable backend."""

    def __init__(self, cfg: BPMFConfig | None = None):
        """Build an engine (and its backend) from a config.

        Args:
            cfg: Full engine config; ``None`` means all defaults
                (sequential backend, synthetic-friendly schedule).
        """
        self.cfg = cfg or BPMFConfig()
        self.backend: Backend = get_backend(self.cfg)
        self.history: list[SweepMetrics] = []
        self._state = None
        self._pred = None
        self._sweeps_done = 0
        self._data_fingerprint: tuple[int, int, int] | None = None
        self._ckpt: Optional[CheckpointManager] = None
        key = jax.random.key(self.cfg.run.seed)
        self._k_init, self._k_run = jax.random.split(key)

    # ------------------------------------------------------------------
    # data / state plumbing
    # ------------------------------------------------------------------
    def prepare(self, data: RatingsCOO) -> "BPMFEngine":
        """Host-side layout (split, center, bucket, shard). Idempotent.

        Re-passing the same dataset is a no-op; passing a *different* one
        (detected by shape/nnz) raises — an engine is bound to one dataset
        for its lifetime, so metrics and checkpoints stay coherent.

        Args:
            data: Raw ratings; the backend owns split/center/bucket/shard.

        Returns:
            ``self``, prepared.
        """
        fingerprint = (data.num_users, data.num_movies, data.nnz)
        if self.backend.prepared:
            if fingerprint != self._data_fingerprint:
                raise ValueError(
                    f"engine already prepared for R {self._data_fingerprint}; "
                    f"got different data {fingerprint} — build a new BPMFEngine"
                )
            return self
        self.backend.prepare(data)
        self._data_fingerprint = fingerprint
        return self

    def _ensure_state(self) -> None:
        if not self.backend.prepared:
            raise RuntimeError("no data: call fit(data) / sample(data) / prepare(data) first")
        if self._state is None:
            self._state = self.backend.init_state(self._k_init)
            self._pred = self.backend.init_pred()
            self._sweeps_done = 0

    def _manager(self) -> CheckpointManager:
        if self._ckpt is None:
            if not self.cfg.run.checkpoint_dir:
                raise ValueError("RunConfig.checkpoint_dir is not set")
            self._ckpt = CheckpointManager(
                self.cfg.run.checkpoint_dir,
                keep=self.cfg.run.keep_checkpoints,
                async_writes=False,
            )
        return self._ckpt

    # ------------------------------------------------------------------
    # the run loop
    # ------------------------------------------------------------------
    def sample(self, data: RatingsCOO | None = None) -> Iterator[SweepMetrics]:
        """Stream per-sweep metrics from the current sweep to ``num_sweeps``.

        Resumable: after ``restore()`` the iterator continues where the
        checkpoint left off, drawing the same randomness an uninterrupted
        run would have.

        Args:
            data: Ratings to ``prepare()`` first, if not already prepared.

        Yields:
            One :class:`SweepMetrics` (sample / posterior-mean RMSE,
            sweep index) per completed sweep, as host floats.
        """
        if data is not None:
            self.prepare(data)
        self._ensure_state()
        every = self.cfg.run.checkpoint_every
        while self._sweeps_done < self.cfg.run.num_sweeps:
            self._state, self._pred, metrics = self.backend.sweep(
                self._k_run, self._state, self._pred
            )
            self._sweeps_done += 1
            metrics = jax.tree_util.tree_map(float, metrics)
            self.history.append(metrics)
            if every and self._sweeps_done % every == 0:
                self.save()
            yield metrics

    def fit(self, data: RatingsCOO | None = None, resume: bool = False) -> "BPMFEngine":
        """Run (or finish) all sweeps.

        Args:
            data: Ratings to ``prepare()`` first, if not already prepared.
            resume: Restore the latest checkpoint from
                ``RunConfig.checkpoint_dir`` (if any) before continuing.

        Returns:
            ``self``, with ``history`` / ``rmse`` / ``factors()`` populated.
        """
        if data is not None:
            self.prepare(data)
        if resume and self.cfg.run.checkpoint_dir and self._manager().latest() is not None:
            self.restore()
        for _ in self.sample():
            pass
        return self

    # ------------------------------------------------------------------
    # results
    # ------------------------------------------------------------------
    @property
    def rmse(self) -> float:
        """Posterior-mean test RMSE after the last completed sweep."""
        if not self.history:
            raise RuntimeError("no sweeps run yet")
        return float(self.history[-1].rmse_avg)

    @property
    def num_sweeps_done(self) -> int:
        """Completed sweeps (``restore()`` positions this at the checkpoint step)."""
        return self._sweeps_done

    @property
    def state(self):
        """Backend-specific Gibbs state pytree (``None`` before the first sweep)."""
        return self._state

    def factors(self) -> tuple[np.ndarray, np.ndarray]:
        """(U, V) of the current posterior sample, original item order."""
        self._ensure_state()
        return self.backend.factors(self._state)

    def predict(self, rows: np.ndarray, cols: np.ndarray) -> np.ndarray:
        """Point predictions for arbitrary (user, movie) pairs.

        Uses the current posterior sample's factors; for posterior-mean
        test-set predictions use the streamed ``rmse_avg`` metrics.

        Args:
            rows: ``[N]`` user ids (original numbering).
            cols: ``[N]`` movie ids (original numbering).

        Returns:
            ``[N]`` predicted ratings, clipped to the training range.
        """
        U, V = self.factors()
        lo, hi = self.backend.rating_range
        preds = np.einsum("nk,nk->n", U[np.asarray(rows)], V[np.asarray(cols)])
        return np.clip(preds + self.backend.mean_rating, lo, hi)

    # ------------------------------------------------------------------
    # checkpointing (sweep-level save / resume)
    # ------------------------------------------------------------------
    def save(self, step: int | None = None) -> int:
        """Checkpoint state, prediction accumulator and metric history.

        Args:
            step: Sweep count to label the checkpoint with (default: the
                current sweep).

        Returns:
            The step the checkpoint was written at.
        """
        self._ensure_state()
        step = self._sweeps_done if step is None else step
        hist = np.asarray(
            [[m.rmse_sample, m.rmse_avg, m.sweep] for m in self.history[:step]],
            np.float32,
        ).reshape(-1, 3)
        self._manager().save(
            step, {"state": self._state, "pred": self._pred, "history": hist}
        )
        return step

    def restore(self, data: RatingsCOO | None = None, step: int | None = None) -> int:
        """Load a checkpoint and position the run loop at its sweep count.

        The backend must be prepared (pass ``data`` here or call
        ``prepare`` first) so the restore target has the right shapes.
        Metric history up to the checkpointed sweep is restored too, so
        ``rmse`` and ``history`` are complete even in a fresh process.

        Args:
            data: Ratings to ``prepare()`` first, if not already prepared.
            step: Checkpoint step to load (default: latest).

        Returns:
            The restored sweep count.

        Raises:
            FileNotFoundError: If no checkpoint exists at ``step``.
        """
        if data is not None:
            self.prepare(data)
        self._ensure_state()
        mgr = self._manager()
        step = mgr.latest() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.cfg.run.checkpoint_dir}")
        tree = mgr.restore(
            {
                "state": self._state,
                "pred": self._pred,
                "history": np.zeros((0, 3), np.float32),
            },
            step=step,
        )
        self._state, self._pred = tree["state"], tree["pred"]
        self._sweeps_done = step
        self.history = [
            SweepMetrics(float(r[0]), float(r[1]), float(r[2]))
            for r in np.asarray(tree["history"])
        ]
        return step
