"""``BPMFEngine`` — the single front door to every BPMF sampler.

One facade over the sequential oracle and the distributed
ring/ring_async/allgather samplers (paper §V-B: they are the same
sampler), with the run loop,
sweep-level checkpointing and metric streaming factored out of the
backends::

    from repro.bpmf import BPMFConfig, BPMFEngine, load_dataset

    coo = load_dataset("synthetic", num_users=400, num_movies=300, nnz=12_000)
    cfg = BPMFConfig().replace(name="ring", K=16, num_sweeps=25)
    engine = BPMFEngine(cfg).fit(coo)
    print(engine.rmse)

Backend choice is config-only: the same ``(seed, data)`` run through
``"sequential"``, ``"ring"``, ``"ring_async"`` (any depth) and
``"allgather"`` yields the same posterior samples up to float reduction
order (tests/test_engine.py asserts this).

Determinism note: the sampler key is derived from ``RunConfig.seed`` and
per-sweep keys from ``(key, state.sweep)``, so a run restored from a
checkpoint continues with *identical* randomness to an uninterrupted one.

Run-loop note (DESIGN.md §10, §13): sweeps execute in jitted device blocks
of ``RunConfig.sweeps_per_block`` with one host sync per block —
posterior-mean sums, the recent-sample window and the prediction accumulator
fold on-device in the block's scan carry, and per-sweep metrics arrive as
one stacked transfer. With ``RunConfig.pipeline_blocks > 1`` the loop is
additionally *pipelined*: the next block dispatches on the still-on-device
carry before the previous block's metrics are fetched, the metric transfer
completes asynchronously, and checkpoint writes commit on a background
thread. Samples, metrics, checkpoints and exported artifacts are bitwise
identical at every block size and every pipeline depth.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Iterator, Optional

import jax
import numpy as np

from repro.bpmf.backends import Backend, get_backend
from repro.bpmf.config import BPMFConfig
from repro.checkpoint import CheckpointManager, CheckpointSchemaError
from repro.core.gibbs import SweepMetrics
from repro.data.sparse import RatingsCOO
from repro.serve import ArtifactMeta, PosteriorPredictor, save_artifact


class _PosteriorAccumulator:
    """Thin host *view* over the device-resident posterior accumulator.

    The accumulation itself happens on-device inside the blocked sweep loop
    (:class:`repro.core.types.PosteriorAccum`, DESIGN.md §10) — running
    float32 posterior-mean sums plus a rotating window of the
    ``keep_factor_samples`` most recent post-burn-in ``(U, V)`` draws,
    sharded like the factors on the distributed backends. This view only
    materializes host arrays at export/checkpoint time, in original item
    order and the same schema (chronological sample stacks) the old
    host-side accumulator used, so checkpoints and artifacts stay bitwise
    compatible across the refactor.
    """

    def __init__(self, engine: "BPMFEngine"):
        self._engine = engine

    @property
    def count(self) -> int:
        """Post-burn-in samples folded so far (0 before the first block)."""
        accum = self._engine._accum
        return int(accum.count) if accum is not None else 0

    def tree(self) -> dict:
        """Checkpointable host tree (fixed key set, shapes vary with count)."""
        return self._engine.backend.accum_host(self._engine._accum)

    def load_tree(self, tree: dict) -> None:
        """Restore the device accumulator from :meth:`tree` output (trims
        to this run's ``keep_factor_samples``)."""
        self._engine._accum = self._engine.backend.accum_from_host(tree)


class BPMFEngine:
    """Fit / sample / predict / save / restore / export over a pluggable backend."""

    def __init__(self, cfg: BPMFConfig | None = None):
        """Build an engine (and its backend) from a config.

        Args:
            cfg: Full engine config; ``None`` means all defaults
                (sequential backend, synthetic-friendly schedule).
        """
        self.cfg = cfg or BPMFConfig()
        self.backend: Backend = get_backend(self.cfg)
        self.history: list[SweepMetrics] = []
        self._state = None
        self._pred = None
        self._accum = None  # device-resident PosteriorAccum (DESIGN.md §10)
        self._sweeps_done = 0
        self._data_fingerprint: tuple[int, int, int] | None = None
        self._ckpt: Optional[CheckpointManager] = None
        self._posterior = _PosteriorAccumulator(self)
        self._predictor: Optional[PosteriorPredictor] = None
        self._predictor_sweep = -1
        # bytes fetched from device for metrics, summed over the run — what
        # benchmarks/sweep_throughput.py reports as host traffic per sweep
        self.host_metric_bytes = 0
        # seconds the host spent blocked on metric fetches, summed over the
        # run (the wait the pipelined dispatch queue exists to hide)
        self.host_blocked_s = 0.0
        # dispatched-but-not-yet-fetched blocks: (block_len, metrics rows)
        self._inflight: deque[tuple[int, object]] = deque()
        key = jax.random.key(self.cfg.run.seed)
        self._k_init, self._k_run = jax.random.split(key)

    # ------------------------------------------------------------------
    # data / state plumbing
    # ------------------------------------------------------------------
    def prepare(self, data: RatingsCOO) -> "BPMFEngine":
        """Host-side layout (split, center, bucket, shard). Idempotent.

        Re-passing the same dataset is a no-op; passing a *different* one
        (detected by shape/nnz) raises — an engine is bound to one dataset
        for its lifetime, so metrics and checkpoints stay coherent.

        Args:
            data: Raw ratings; the backend owns split/center/bucket/shard.

        Returns:
            ``self``, prepared.
        """
        fingerprint = (data.num_users, data.num_movies, data.nnz)
        if self.backend.prepared:
            if fingerprint != self._data_fingerprint:
                raise ValueError(
                    f"engine already prepared for R {self._data_fingerprint}; "
                    f"got different data {fingerprint} — build a new BPMFEngine"
                )
            return self
        self.backend.prepare(data)
        self._data_fingerprint = fingerprint
        return self

    def _ensure_state(self) -> None:
        if not self.backend.prepared:
            raise RuntimeError("no data: call fit(data) / sample(data) / prepare(data) first")
        if self._state is None:
            self._state = self.backend.init_state(self._k_init)
            self._pred = self.backend.init_pred()
            self._accum = self.backend.init_accum()
            self._sweeps_done = 0

    def _manager(self) -> CheckpointManager:
        if self._ckpt is None:
            if not self.cfg.run.checkpoint_dir:
                raise ValueError("RunConfig.checkpoint_dir is not set")
            self._ckpt = CheckpointManager(
                self.cfg.run.checkpoint_dir,
                keep=self.cfg.run.keep_checkpoints,
                async_writes=self.cfg.run.async_checkpoint_writes,
            )
        return self._ckpt

    # ------------------------------------------------------------------
    # the run loop
    # ------------------------------------------------------------------
    def _next_block_len(self) -> int:
        """Sweeps in the next device block: ``sweeps_per_block``, shrunk so
        blocks land exactly on ``checkpoint_every`` boundaries and the final
        sweep (the partition never changes the samples — only how many
        sweeps run per host round-trip)."""
        run = self.cfg.run
        n = min(run.sweeps_per_block, run.num_sweeps - self._sweeps_done)
        if run.checkpoint_every:
            n = min(n, run.checkpoint_every - self._sweeps_done % run.checkpoint_every)
        return max(n, 1)

    def _drain_one(self) -> None:
        """Fetch the oldest in-flight block's metrics into ``history``.

        The single host materialization per block: ``np.asarray`` completes
        the transfer that ``copy_to_host_async`` started at dispatch time
        (a no-op view for backends that already returned host rows), and the
        byte counter sees that one buffer.
        """
        n, rows = self._inflight.popleft()
        t0 = time.perf_counter()
        rows = np.asarray(rows)
        self.host_blocked_s += time.perf_counter() - t0
        self.host_metric_bytes += int(rows.nbytes)
        self.history.extend(
            SweepMetrics(float(r[0]), float(r[1]), float(r[2])) for r in rows
        )

    def _drain_inflight(self) -> None:
        """Drain every dispatched block's metrics into ``history`` — the
        pipeline barrier ``save()`` / ``export()`` / checkpoint boundaries
        and the iterator end run through."""
        while self._inflight:
            self._drain_one()

    def sample(self, data: RatingsCOO | None = None) -> Iterator[SweepMetrics]:
        """Stream per-sweep metrics from the current sweep to ``num_sweeps``.

        Resumable: after ``restore()`` the iterator continues where the
        checkpoint left off, drawing the same randomness an uninterrupted
        run would have.

        Execution is *blocked* (DESIGN.md §10): sweeps run on-device in
        jitted blocks of ``RunConfig.sweeps_per_block`` with a single host
        sync per block, and the block's metrics are then yielded one per
        sweep. With ``RunConfig.pipeline_blocks = d > 1`` the loop is also
        *pipelined* (DESIGN.md §13): up to ``d`` blocks are dispatched ahead
        of the metrics drain, each block's metric transfer completes
        asynchronously while later blocks compute, and the queue drains
        fully at ``checkpoint_every`` boundaries and the end of the run.
        The public iterator contract is unchanged at every block size and
        depth — one :class:`SweepMetrics` per sweep, in sweep order, with
        identical history ordering and checkpoint cadence — but metrics for
        sweeps of the same block become available together, and abandoning
        the iterator mid-run leaves the engine advanced to the end of the
        last *dispatched* block (a later ``save()`` / ``export()`` /
        ``sample()`` call drains the remaining in-flight metrics).

        Args:
            data: Ratings to ``prepare()`` first, if not already prepared.

        Yields:
            One :class:`SweepMetrics` (sample / posterior-mean RMSE,
            sweep index) per completed sweep, as host floats.
        """
        if data is not None:
            self.prepare(data)
        self._ensure_state()
        run = self.cfg.run
        every = run.checkpoint_every
        depth = run.pipeline_blocks
        yielded = len(self.history)
        while self._sweeps_done < run.num_sweeps or self._inflight:
            # dispatch up to `depth` blocks ahead of the drain, stopping at
            # checkpoint boundaries so the boundary carry is still the
            # engine's current state when save() snapshots it
            while self._sweeps_done < run.num_sweeps and len(self._inflight) < depth:
                n = self._next_block_len()
                self._state, self._pred, self._accum, rows = self.backend.sweep_block(
                    self._k_run, self._state, self._pred, self._accum, n
                )
                try:
                    rows.copy_to_host_async()  # start the metrics transfer now
                except AttributeError:  # backend already returned host rows
                    pass
                self._inflight.append((n, rows))
                self._sweeps_done += n
                if every and self._sweeps_done % every == 0:
                    break
            at_ckpt = every and self._sweeps_done % every == 0
            final = self._sweeps_done >= run.num_sweeps
            keep = 0 if (at_ckpt or final) else depth - 1
            while len(self._inflight) > keep:
                self._drain_one()
            if at_ckpt:
                self.save()
            block = self.history[yielded:]
            yielded = len(self.history)
            yield from block

    def fit(self, data: RatingsCOO | None = None, resume: bool = False) -> "BPMFEngine":
        """Run (or finish) all sweeps.

        Args:
            data: Ratings to ``prepare()`` first, if not already prepared.
            resume: Restore the latest checkpoint from
                ``RunConfig.checkpoint_dir`` (if any) before continuing.

        Returns:
            ``self``, with ``history`` / ``rmse`` / ``factors()`` populated.
        """
        if data is not None:
            self.prepare(data)
        if resume and self.cfg.run.checkpoint_dir and self._manager().latest() is not None:
            self.restore()
        for _ in self.sample():
            pass
        return self

    # ------------------------------------------------------------------
    # results
    # ------------------------------------------------------------------
    @property
    def rmse(self) -> float:
        """Posterior-mean test RMSE after the last completed sweep."""
        if not self.history:
            raise RuntimeError("no sweeps run yet")
        return float(self.history[-1].rmse_avg)

    @property
    def num_sweeps_done(self) -> int:
        """Sweeps dispatched to the device so far (``restore()`` positions
        this at the checkpoint step). At ``pipeline_blocks > 1`` the last
        ``d - 1`` blocks' metrics may still be in flight; ``save()`` /
        ``export()`` / finishing the iterator drain them."""
        return self._sweeps_done

    @property
    def state(self):
        """Backend-specific Gibbs state pytree (``None`` before the first sweep)."""
        return self._state

    def factors(self) -> tuple[np.ndarray, np.ndarray]:
        """(U, V) of the current posterior sample, original item order."""
        self._ensure_state()
        return self.backend.factors(self._state)

    def predict(
        self, rows: np.ndarray, cols: np.ndarray, return_std: bool = False
    ) -> np.ndarray | tuple[np.ndarray, np.ndarray]:
        """Posterior-mean predictions for arbitrary (user, movie) pairs.

        Delegates to the same jitted :class:`repro.serve.PosteriorPredictor`
        program a ``BPMFEngine.export()`` artifact serves, so in-process and
        served predictions agree bitwise. Uses the posterior-mean factors
        once post-burn-in samples exist; before that, the current sample's.

        Args:
            rows: ``[N]`` user ids (original numbering).
            cols: ``[N]`` movie ids (original numbering).
            return_std: Also return the predictive std over the retained
                factor samples (``RunConfig.keep_factor_samples``).

        Returns:
            ``[N]`` predicted ratings, clipped to the training range — or
            ``(preds, std)`` when ``return_std``.
        """
        return self.predictor().predict(rows, cols, return_std=return_std)

    def predictor(self) -> PosteriorPredictor:
        """In-process serving predictor over the current posterior summary.

        Cached per completed sweep; rebuilt lazily after the state advances.

        Returns:
            A :class:`repro.serve.PosteriorPredictor` — also the gateway to
            ``top_k`` recommendations without an export round-trip.
        """
        self._ensure_state()
        if self._predictor is None or self._predictor_sweep != self._sweeps_done:
            self._predictor = PosteriorPredictor.from_engine(self)
            self._predictor_sweep = self._sweeps_done
        return self._predictor

    # ------------------------------------------------------------------
    # serving export
    # ------------------------------------------------------------------
    def _artifact_payload(self) -> tuple[ArtifactMeta, dict[str, np.ndarray]]:
        """(meta, arrays) of the serving artifact for the current posterior.

        Posterior-mean factors when post-burn-in samples have been
        accumulated, else the current raw sample (``num_mean_samples=0``).
        The backend's ``posterior_export`` hook supplies the global summary
        (one host gather per device accumulator; the ``posterior_merge``
        backend additionally runs its subset-posterior merge here — its
        only communication event).
        """
        self._ensure_state()
        summary = self.backend.posterior_export(self._accum)
        count = int(summary["count"])
        if count:
            U_mean = np.asarray(summary["U_mean"], np.float32)
            V_mean = np.asarray(summary["V_mean"], np.float32)
        else:
            U, V = self.factors()
            U_mean = np.asarray(U, np.float32)
            V_mean = np.asarray(V, np.float32)
        Us = np.asarray(summary["U_samples"], np.float32)
        Vs = np.asarray(summary["V_samples"], np.float32)
        S = Us.shape[0]
        if S == 0:  # canonical empty shapes for the artifact schema
            Us = np.zeros((0,) + U_mean.shape, np.float32)
            Vs = np.zeros((0,) + V_mean.shape, np.float32)
        lo, hi = self.backend.rating_range
        meta = ArtifactMeta(
            num_users=int(U_mean.shape[0]),
            num_movies=int(V_mean.shape[0]),
            K=int(U_mean.shape[1]),
            mean_rating=float(self.backend.mean_rating),
            min_rating=float(lo),
            max_rating=float(hi),
            num_mean_samples=count,
            num_kept_samples=S,
            backend=self.cfg.backend.name,
            num_sweeps_done=self._sweeps_done,
            seed=self.cfg.run.seed,
        )
        arrays = {"U_mean": U_mean, "V_mean": V_mean, "U_samples": Us, "V_samples": Vs}
        return meta, arrays

    def export(self, directory: str) -> str:
        """Write the versioned serving artifact for the current posterior.

        The export hook of the serving path (DESIGN.md §9): persists the
        posterior-mean factors, the retained per-sweep samples, the global
        mean/clip range and dataset metadata via the checkpoint layer, for
        :class:`repro.serve.PosteriorPredictor` / ``python -m
        repro.launch.serve`` to load without re-running MCMC.

        A pipeline barrier: in-flight metric blocks drain first, and any
        checkpoint writes still pending on the async writer commit before
        the artifact is written.

        Args:
            directory: Artifact directory (replaced if it already holds
                an artifact).

        Returns:
            The artifact directory.
        """
        self._drain_inflight()
        if self._ckpt is not None:
            self._ckpt.wait()
        meta, arrays = self._artifact_payload()
        if jax.process_count() > 1:
            # the payload gathers are collective (every process runs them);
            # the filesystem write is process 0's alone, and the barrier
            # keeps peers from racing ahead to read a half-written artifact
            from jax.experimental import multihost_utils

            if jax.process_index() == 0:
                save_artifact(directory, meta, arrays)
            multihost_utils.sync_global_devices(f"artifact-export-{directory}")
            return directory
        return save_artifact(directory, meta, arrays)

    # ------------------------------------------------------------------
    # checkpointing (sweep-level save / resume)
    # ------------------------------------------------------------------
    def save(self, step: int | None = None) -> int:
        """Checkpoint state, prediction accumulator and metric history.

        Drains in-flight pipeline blocks first, then snapshots host arrays;
        with ``RunConfig.async_checkpoint_writes`` (the default) the
        filesystem commit happens on the manager's background thread and
        this returns as soon as the snapshot is taken — the commit itself
        is atomic (tmp-dir rename, then ``LATEST`` replace), so a crash
        mid-write never leaves a torn checkpoint visible.

        Args:
            step: Sweep count to label the checkpoint with (default: the
                current sweep).

        Returns:
            The step the checkpoint was written at.
        """
        self._ensure_state()
        self._drain_inflight()
        step = self._sweeps_done if step is None else step
        hist = np.asarray(
            [[m.rmse_sample, m.rmse_avg, m.sweep] for m in self.history[:step]],
            np.float32,
        ).reshape(-1, 3)
        self._manager().save(
            step,
            {
                "state": self._state,
                "pred": self._pred,
                "history": hist,
                "posterior": self._posterior.tree(),
            },
        )
        return step

    def restore(self, data: RatingsCOO | None = None, step: int | None = None) -> int:
        """Load a checkpoint and position the run loop at its sweep count.

        The backend must be prepared (pass ``data`` here or call
        ``prepare`` first) so the restore target has the right shapes.
        Metric history up to the checkpointed sweep is restored too, so
        ``rmse`` and ``history`` are complete even in a fresh process.
        Checkpoints written before the serving subsystem (no ``posterior``
        subtree) still restore; the posterior accumulator just restarts
        empty, so a subsequent ``export()`` only reflects sweeps run after
        the resume.

        Args:
            data: Ratings to ``prepare()`` first, if not already prepared.
            step: Checkpoint step to load (default: latest).

        Returns:
            The restored sweep count.

        Raises:
            FileNotFoundError: If no checkpoint exists at ``step``.
        """
        if data is not None:
            self.prepare(data)
        self._ensure_state()
        # metrics still in flight belong to sweeps the restore rewinds past
        self._inflight.clear()
        mgr = self._manager()
        step = mgr.latest() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.cfg.run.checkpoint_dir}")
        # posterior template: leaf names only (restore loads whatever shapes
        # the checkpoint holds) — cheaper than gathering the zeroed device
        # accumulator just to name its leaves. The backend owns the subtree
        # shape (posterior_merge checkpoints per-chain subtrees).
        posterior_target = self.backend.posterior_template()
        target = {
            "state": self._state,
            "pred": self._pred,
            "history": np.zeros((0, 3), np.float32),
            "posterior": posterior_target,
        }
        try:
            tree = mgr.restore(target, step=step)
            self._posterior.load_tree(tree["posterior"])
        except CheckpointSchemaError:
            # checkpoint written before the serving subsystem: no posterior
            # subtree. Restore everything else and start the accumulator
            # empty — export() degrades to the raw current sample until new
            # post-burn-in sweeps accumulate. (A genuinely damaged
            # checkpoint re-raises from the second restore.)
            tree = mgr.restore(
                {k: v for k, v in target.items() if k != "posterior"}, step=step
            )
            self._accum = self.backend.init_accum()
        self._state, self._pred = tree["state"], tree["pred"]
        self._predictor, self._predictor_sweep = None, -1
        self._sweeps_done = step
        self.history = [
            SweepMetrics(float(r[0]), float(r[1]), float(r[2]))
            for r in np.asarray(tree["history"])
        ]
        return step
