"""Dataset registry behind ``repro.bpmf.load_dataset(name, **kw)``.

Loaders return a :class:`repro.data.sparse.RatingsCOO`; the engine owns the
train/test split and layout so every backend sees the identical split.
New workloads register here instead of adding another ad-hoc script::

    @register_dataset("my-data")
    def _load(path=None):
        return RatingsCOO(...)
"""
from __future__ import annotations

from typing import Callable

from repro.data.movielens import load_chembl, load_movielens
from repro.data.sparse import RatingsCOO
from repro.data.synthetic import SyntheticSpec, synthetic_ratings

DATASETS: dict[str, Callable[..., RatingsCOO]] = {}


def register_dataset(name: str) -> Callable[[Callable[..., RatingsCOO]], Callable[..., RatingsCOO]]:
    """Function decorator adding a loader under ``name`` (last wins).

    Args:
        name: Registry key used by :func:`load_dataset` and the CLI's
            ``--dataset`` flag.

    Returns:
        The decorator; it registers the loader and returns it unchanged.
    """

    def deco(fn: Callable[..., RatingsCOO]) -> Callable[..., RatingsCOO]:
        DATASETS[name] = fn
        return fn

    return deco


def load_dataset(name: str, **kw) -> RatingsCOO:
    """Load a registered dataset by name.

    Args:
        name: Registry key (see :func:`available_datasets`).
        **kw: Forwarded to the loader (e.g. ``path=``, or the synthetic
            generator's ``num_users`` / ``num_movies`` / ``nnz``).

    Returns:
        The raw ratings; the engine owns the train/test split.

    Raises:
        ValueError: If ``name`` is not registered.
    """
    if name not in DATASETS:
        raise ValueError(f"unknown dataset {name!r}; available: {sorted(DATASETS)}")
    return DATASETS[name](**kw)


def available_datasets() -> list[str]:
    """Sorted registry names (``["chembl", "movielens", "synthetic", ...]``)."""
    return sorted(DATASETS)


@register_dataset("synthetic")
def _synthetic(
    num_users: int = 400,
    num_movies: int = 300,
    nnz: int = 12_000,
    true_rank: int = 8,
    noise_std: float = 0.5,
    discretize: bool = False,
    seed: int = 0,
) -> RatingsCOO:
    """Low-rank + noise ratings with MovieLens-shaped degree skew."""
    spec = SyntheticSpec(
        num_users=num_users,
        num_movies=num_movies,
        nnz=nnz,
        true_rank=true_rank,
        noise_std=noise_std,
        discretize=discretize,
        seed=seed,
    )
    coo, _ = synthetic_ratings(spec)
    return coo


@register_dataset("movielens")
def _movielens(path: str | None = None, variant: str = "ml-100k") -> RatingsCOO:
    """Real ml-20m/ml-100k files when ``path`` exists, else synthetic stand-in."""
    return load_movielens(path, variant)


@register_dataset("chembl")
def _chembl(path: str | None = None) -> RatingsCOO:
    """ChEMBL IC50 compound x target subset (paper §V workload)."""
    return load_chembl(path)
