"""Backend registry: one sampler, several execution strategies.

The paper's §V-B claim — sequential, shared-memory and distributed BPMF are
the *same sampler* — is encoded here as a small protocol: every backend
prepares its own data layout from the same :class:`RatingsCOO`, but draws
identical posterior samples for identical ``(key, data)`` (up to float
reduction order). ``BPMFEngine`` dispatches to a registry entry by
``BackendConfig.name``; later scaling PRs add entries instead of new entry
points.

Registered backends:

  * ``"sequential"`` — wraps :mod:`repro.core.gibbs` (single program)
  * ``"ring"``       — wraps :mod:`repro.core.distributed`, §IV-C overlap
  * ``"ring_async"`` — same, with ``BackendConfig.pipeline_depth`` ring
    rotations kept in flight (arXiv:1705.10633; DESIGN.md §7)
  * ``"allgather"``  — same, synchronous all-gather baseline
"""
from __future__ import annotations

import abc
from typing import Callable

import jax
import numpy as np

from repro.bpmf.config import BPMFConfig
from repro.core import distributed as dist
from repro.core import gibbs
from repro.core.gibbs import SweepMetrics
from repro.core.prediction import PredictionState
from repro.data.sparse import RatingsCOO, build_bpmf_data

BACKENDS: dict[str, type["Backend"]] = {}


def register_backend(name: str) -> Callable[[type["Backend"]], type["Backend"]]:
    """Class decorator adding a backend under ``name`` (last wins).

    This is the extension point the ROADMAP's scaling PRs use instead of
    new entry points: subclass :class:`Backend` (or, for shard_map-based
    strategies, :class:`DistributedBackend`), register it, and it becomes
    reachable from the engine, CLI and tests purely through
    ``BackendConfig.name``::

        from repro.bpmf import DistributedBackend, register_backend

        @register_backend("ring_traced")
        class TracedRingBackend(DistributedBackend):
            def sweep(self, key, state, pred):
                out = super().sweep(key, state, pred)
                print("sweep done")
                return out

        BPMFEngine(BPMFConfig().replace(name="ring_traced")).fit(coo)

    Args:
        name: Registry key; re-registering an existing name replaces it.

    Returns:
        The class decorator; it sets ``cls.name`` and returns the class
        unchanged.
    """

    def deco(cls: type["Backend"]) -> type["Backend"]:
        cls.name = name
        BACKENDS[name] = cls
        return cls

    return deco


def get_backend(cfg: BPMFConfig) -> "Backend":
    """Instantiate the backend named by ``cfg.backend.name``.

    Args:
        cfg: Full engine config; the new backend keeps a reference.

    Returns:
        An unprepared :class:`Backend` instance.

    Raises:
        ValueError: If the name is not in the registry.
    """
    name = cfg.backend.name
    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; available: {sorted(BACKENDS)}")
    return BACKENDS[name](cfg)


def available_backends() -> list[str]:
    """Sorted registry names (``["allgather", "ring", "ring_async", ...]``)."""
    return sorted(BACKENDS)


class Backend(abc.ABC):
    """Execution strategy for the BPMF Gibbs sampler.

    Lifecycle: ``prepare(coo)`` once (host-side layout), then
    ``init_state(key)`` / ``sweep(key, state, pred)`` repeatedly.
    State pytrees are backend-specific (dense vs ring-sharded) but
    checkpointable as-is; ``factors(state)`` recovers (U, V) in original
    item order for prediction and cross-backend comparison.
    """

    name: str = "?"

    def __init__(self, cfg: BPMFConfig):
        self.cfg = cfg
        self.core_cfg = cfg.core()
        self._prepared = False

    # ------------------------------------------------------------------
    @abc.abstractmethod
    def prepare(self, coo: RatingsCOO) -> None:
        """Build the backend's data layout (split, center, bucket, shard)."""

    @abc.abstractmethod
    def init_state(self, key: jax.Array):
        """Prior-predictive state; layout-independent per original item id."""

    @abc.abstractmethod
    def sweep(self, key: jax.Array, state, pred: PredictionState):
        """One Gibbs sweep -> (state, pred, SweepMetrics)."""

    @abc.abstractmethod
    def factors(self, state) -> tuple[np.ndarray, np.ndarray]:
        """(U, V) as host arrays in *original* item order."""

    # ------------------------------------------------------------------
    @property
    def prepared(self) -> bool:
        """Whether ``prepare()`` has built this backend's data layout."""
        return self._prepared

    def init_pred(self) -> PredictionState:
        """Zeroed posterior-mean prediction accumulator for the test set."""
        return PredictionState.init(self.num_test)

    @property
    @abc.abstractmethod
    def num_test(self) -> int:
        """Number of held-out ratings."""

    @property
    @abc.abstractmethod
    def test_vals(self) -> jax.Array:
        """Held-out rating values, ``[num_test]`` f32 (uncentered)."""

    @property
    @abc.abstractmethod
    def mean_rating(self) -> float:
        """Training-set mean subtracted before sampling, re-added at predict."""

    @property
    @abc.abstractmethod
    def rating_range(self) -> tuple[float, float]:
        """(lo, hi) clip range for predictions."""


# --------------------------------------------------------------------------
# Sequential (the single-program oracle)
# --------------------------------------------------------------------------


@register_backend("sequential")
class SequentialBackend(Backend):
    """Single-program Algorithm 1 via :mod:`repro.core.gibbs`."""

    def prepare(self, coo: RatingsCOO) -> None:
        self.data = build_bpmf_data(
            coo,
            pads=self.cfg.backend.bucket_pads,
            test_fraction=self.cfg.run.test_fraction,
            seed=self.cfg.run.seed,
        )
        self._prepared = True

    def init_state(self, key: jax.Array):
        return gibbs.init_state(key, self.data.num_users, self.data.num_movies, self.core_cfg)

    def sweep(self, key: jax.Array, state, pred: PredictionState):
        return gibbs.gibbs_sweep(key, state, pred, self.data, self.core_cfg)

    def factors(self, state) -> tuple[np.ndarray, np.ndarray]:
        return np.asarray(state.U), np.asarray(state.V)

    @property
    def num_test(self) -> int:
        return int(self.data.test.rows.shape[0])

    @property
    def test_vals(self) -> jax.Array:
        return self.data.test.vals

    @property
    def mean_rating(self) -> float:
        return float(self.data.mean_rating)

    @property
    def rating_range(self) -> tuple[float, float]:
        return self.data.min_rating, self.data.max_rating


# --------------------------------------------------------------------------
# Distributed (ring / allgather over a device mesh)
# --------------------------------------------------------------------------


class DistributedBackend(Backend):
    """Shared machinery for the shard_map backends (paper §IV).

    Subclass this (and :func:`register_backend` the subclass) to add new
    distributed execution strategies: it owns the mesh construction,
    host-side data distribution, sharded init/sweep dispatch and factor
    gathering; subclasses typically only pick a ``comm_mode`` via
    ``BackendConfig.name`` or override :meth:`sweep`.
    """

    def prepare(self, coo: RatingsCOO) -> None:
        devices = jax.devices()
        S = self.cfg.backend.num_shards or len(devices)
        if S > len(devices):
            raise ValueError(
                f"BackendConfig.num_shards={S} exceeds the {len(devices)} visible "
                f"device(s); lower it or force more host devices "
                f"(XLA_FLAGS=--xla_force_host_platform_device_count=N)"
            )
        self.mesh = dist.make_ring_mesh(devices[:S])
        data, self.plan = dist.build_distributed_data(
            coo,
            num_shards=S,
            pads=self.cfg.backend.bucket_pads,
            test_fraction=self.cfg.run.test_fraction,
            seed=self.cfg.run.seed,
            strategy=self.cfg.backend.partition_strategy,
        )
        self.data = dist.shard_data(data, self.mesh)
        self.num_shards = S
        self._prepared = True

    def init_state(self, key: jax.Array):
        return dist.init_dist_state(key, self.data, self.core_cfg, self.mesh)

    def sweep(self, key: jax.Array, state, pred: PredictionState):
        return dist.dist_gibbs_sweep(key, state, pred, self.data, self.core_cfg, self.mesh)

    def factors(self, state) -> tuple[np.ndarray, np.ndarray]:
        return dist.gather_factors(state, self.plan)

    @property
    def num_test(self) -> int:
        return int(self.data.test.rows.shape[0])

    @property
    def test_vals(self) -> jax.Array:
        return self.data.test.vals

    @property
    def mean_rating(self) -> float:
        return float(self.data.mean_rating)

    @property
    def rating_range(self) -> tuple[float, float]:
        return self.data.min_rating, self.data.max_rating


@register_backend("ring")
class RingBackend(DistributedBackend):
    """Paper §IV-C: ppermute rotation with compute/comm overlap."""


@register_backend("ring_async")
class AsyncRingBackend(DistributedBackend):
    """Depth-d pipelined ring (arXiv:1705.10633; DESIGN.md §7).

    Keeps ``BackendConfig.pipeline_depth`` shard rotations in flight in a
    rotating buffer queue instead of the synchronous ring's one, hiding up
    to d link latencies per Gram step at a memory cost of d resident
    opposite-shard buffers. Bit-identical samples to ``"ring"`` for every
    depth (the rotation schedule changes *when* transfers are issued,
    never the values each Gram step consumes).
    """


@register_backend("allgather")
class AllGatherBackend(DistributedBackend):
    """Synchronous baseline: blocking all-gather then local updates."""


# --------------------------------------------------------------------------
# Legacy driver (kept for repro.core.gibbs.run)
# --------------------------------------------------------------------------


def run_sequential_prepared(
    key: jax.Array,
    data,
    core_cfg,
    callback=None,
) -> tuple[object, PredictionState, list[SweepMetrics]]:
    """The pre-facade ``core.gibbs.run`` loop, over already-built BPMFData.

    Kept here so ``core.gibbs.run`` can stay a thin deprecation-safe wrapper
    while the engine owns all new run-loop features (checkpointing,
    streaming metrics).
    """
    k_init, k_run = jax.random.split(key)
    state = gibbs.init_state(k_init, data.num_users, data.num_movies, core_cfg)
    pred_state = PredictionState.init(data.test.rows.shape[0])
    history: list[SweepMetrics] = []
    for _ in range(core_cfg.num_sweeps):
        state, pred_state, metrics = gibbs.gibbs_sweep(k_run, state, pred_state, data, core_cfg)
        history.append(jax.tree_util.tree_map(float, metrics))
        if callback is not None:
            callback(state, metrics)
    return state, pred_state, history
