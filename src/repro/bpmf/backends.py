"""Backend registry: one sampler, several execution strategies.

The paper's §V-B claim — sequential, shared-memory and distributed BPMF are
the *same sampler* — is encoded here as a small protocol: every backend
prepares its own data layout from the same :class:`RatingsCOO`, but draws
identical posterior samples for identical ``(key, data)`` (up to float
reduction order). ``BPMFEngine`` dispatches to a registry entry by
``BackendConfig.name``; later scaling PRs add entries instead of new entry
points.

Registered backends:

  * ``"sequential"`` — wraps :mod:`repro.core.gibbs` (single program)
  * ``"ring"``       — wraps :mod:`repro.core.distributed`, §IV-C overlap
  * ``"ring_async"`` — same, with ``BackendConfig.pipeline_depth`` ring
    rotations kept in flight (arXiv:1705.10633; DESIGN.md §7)
  * ``"allgather"``  — same, synchronous all-gather baseline
  * ``"posterior_merge"`` — embarrassingly-parallel partition chains with a
    subset-posterior merge at export (arXiv:1703.00734 / 2004.02561;
    DESIGN.md §12) — zero inter-chain traffic during sampling
"""
from __future__ import annotations

import abc
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.bpmf.config import BPMFConfig
from repro.checkpoint import ShardedHostLeaf
from repro.core import distributed as dist
from repro.core import gibbs
from repro.core import subset_merge
from repro.core.gibbs import SweepMetrics
from repro.core.prediction import PredictionState
from repro.core.subset_merge import MergeAccum
from repro.core.types import BPMFState, HyperParams, PosteriorAccum
from repro.data.sparse import (
    ChunkedRatings,
    RatingsCOO,
    build_bpmf_data,
    build_bpmf_data_presplit,
    train_test_split,
)

BACKENDS: dict[str, type["Backend"]] = {}

_EMPTY_SUM = np.zeros((0, 0), np.float32)
_EMPTY_STACK = np.zeros((0, 0, 0), np.float32)


def _window_slots(count: int, keep: int, available: int) -> np.ndarray:
    """Rotating-buffer slots of the most recent samples, oldest first.

    Post-burn-in sample ``i`` lives at slot ``i % keep``; the ``S`` most
    recent retained samples are global indices ``count - S .. count - 1``.
    ``available`` caps ``S`` (a restored checkpoint may carry fewer samples
    than the window holds).
    """
    S = min(count, keep, available)
    return np.arange(count - S, count, dtype=np.int64) % max(keep, 1)


def accum_host_tree(
    accum: PosteriorAccum,
    u_order: np.ndarray | None = None,
    v_order: np.ndarray | None = None,
) -> dict:
    """Host view of a device accumulator in the PR-4 checkpoint schema.

    Returns the fixed-key ``{"U_sum", "V_sum", "count", "U_samples",
    "V_samples"}`` dict the ``"posterior"`` checkpoint subtree has always
    used: sums are ``(0, 0)``-shaped until the first post-burn-in sample,
    and the sample stacks are chronological (oldest kept draw first) —
    bitwise what the old host-side accumulator checkpointed, so pre-block
    checkpoints restore and new checkpoints match old readers.

    Args:
        accum: Device accumulator (any sharding; gathered here).
        u_order / v_order: Optional relabeled->original permutations
            (``plan.part_*.perm``) applied to the item axis, for the
            distributed backends. Pass both or neither.
    """
    if (u_order is None) != (v_order is None):
        raise ValueError("accum_host_tree: pass both u_order and v_order, or neither")
    count = int(accum.count)
    keep = accum.keep
    if count == 0:
        U_sum, V_sum = _EMPTY_SUM, _EMPTY_SUM
    else:
        # fetch_global: a collective host gather when the accumulator is
        # sharded across processes (every process calls accum_host together)
        U_sum = dist.fetch_global(accum.U_sum)
        V_sum = dist.fetch_global(accum.V_sum)
        if u_order is not None:
            U_sum, V_sum = U_sum[u_order], V_sum[v_order]
    slots = _window_slots(count, keep, int(accum.filled))
    if slots.size:
        Us = dist.fetch_global(accum.U_window)[slots]
        Vs = dist.fetch_global(accum.V_window)[slots]
        if u_order is not None:
            Us, Vs = Us[:, u_order], Vs[:, v_order]
    else:
        Us, Vs = _EMPTY_STACK, _EMPTY_STACK
    return {
        "U_sum": U_sum,
        "V_sum": V_sum,
        "count": np.asarray(count, np.int32),
        "U_samples": Us,
        "V_samples": Vs,
    }


def accum_from_host_tree(
    tree: dict,
    template: PosteriorAccum,
    u_scatter: np.ndarray | None = None,
    v_scatter: np.ndarray | None = None,
) -> PosteriorAccum:
    """Rebuild a device accumulator from :func:`accum_host_tree` output.

    Inverse of the host view: chronological sample stacks go back to their
    rotating-buffer slots (``(count - S + j) % keep``), so a restore at any
    sweep reproduces bitwise the window an uninterrupted device run holds.
    Checkpoints written with a different ``keep`` restore the most recent
    ``min(S, keep)`` samples.

    Args:
        tree: Host arrays (np or device) in the checkpoint schema.
        template: Zeroed accumulator in the backend's internal layout
            (shapes/sharding to restore into).
        u_scatter / v_scatter: Optional original->relabeled permutations
            (``plan.part_*.perm``) mapping host rows into shard slots.
            Pass both or neither.
    """
    if (u_scatter is None) != (v_scatter is None):
        raise ValueError(
            "accum_from_host_tree: pass both u_scatter and v_scatter, or neither"
        )
    count = int(np.asarray(tree["count"]))
    keep = template.keep
    shape_u = template.U_sum.shape  # internal layout [M or S*cap, K]
    shape_v = template.V_sum.shape

    def to_internal(host: np.ndarray, shape, scatter) -> np.ndarray:
        out = np.zeros(shape, np.float32)
        host = np.asarray(host, np.float32)
        if scatter is None:
            out[: host.shape[0]] = host
        else:
            out[scatter] = host
        return out

    U_sum = np.zeros(shape_u, np.float32)
    V_sum = np.zeros(shape_v, np.float32)
    if count:
        U_sum = to_internal(tree["U_sum"], shape_u, u_scatter)
        V_sum = to_internal(tree["V_sum"], shape_v, v_scatter)
    Us = np.asarray(tree["U_samples"], np.float32)
    Vs = np.asarray(tree["V_samples"], np.float32)
    U_win = np.zeros((keep,) + shape_u, np.float32)
    V_win = np.zeros((keep,) + shape_v, np.float32)
    S = min(Us.shape[0], keep, count)
    slots = _window_slots(count, keep, S)
    for j, slot in enumerate(slots):
        # the stacks hold the last Us.shape[0] draws; take their tail
        src = Us.shape[0] - S + j
        U_win[slot] = to_internal(Us[src], shape_u, u_scatter)
        V_win[slot] = to_internal(Vs[src], shape_v, v_scatter)
    return PosteriorAccum(
        U_sum=U_sum,
        V_sum=V_sum,
        count=np.asarray(count, np.int32),
        # only the S slots actually placed are valid: a checkpoint that
        # retained fewer samples than min(count, keep) (e.g. written with a
        # smaller keep) must not report zero-filled slots as samples
        filled=np.asarray(S, np.int32),
        U_window=U_win,
        V_window=V_win,
    )


def register_backend(name: str) -> Callable[[type["Backend"]], type["Backend"]]:
    """Class decorator adding a backend under ``name`` (last wins).

    This is the extension point the ROADMAP's scaling PRs use instead of
    new entry points: subclass :class:`Backend` (or, for shard_map-based
    strategies, :class:`DistributedBackend`), register it, and it becomes
    reachable from the engine, CLI and tests purely through
    ``BackendConfig.name``::

        from repro.bpmf import DistributedBackend, register_backend

        @register_backend("ring_traced")
        class TracedRingBackend(DistributedBackend):
            def sweep_block(self, key, state, pred, accum, block_size):
                out = super().sweep_block(key, state, pred, accum, block_size)
                print(f"block of {block_size} sweeps done")
                return out

        BPMFEngine(BPMFConfig().replace(name="ring_traced")).fit(coo)

    Args:
        name: Registry key; re-registering an existing name replaces it.

    Returns:
        The class decorator; it sets ``cls.name`` and returns the class
        unchanged.
    """

    def deco(cls: type["Backend"]) -> type["Backend"]:
        cls.name = name
        BACKENDS[name] = cls
        return cls

    return deco


def get_backend(cfg: BPMFConfig) -> "Backend":
    """Instantiate the backend named by ``cfg.backend.name``.

    Args:
        cfg: Full engine config; the new backend keeps a reference.

    Returns:
        An unprepared :class:`Backend` instance.

    Raises:
        ValueError: If the name is not in the registry.
    """
    name = cfg.backend.name
    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; available: {sorted(BACKENDS)}")
    return BACKENDS[name](cfg)


def available_backends() -> list[str]:
    """Sorted registry names (``["allgather", "ring", "ring_async", ...]``)."""
    return sorted(BACKENDS)


class Backend(abc.ABC):
    """Execution strategy for the BPMF Gibbs sampler.

    Lifecycle: ``prepare(coo)`` once (host-side layout), then
    ``init_state(key)`` / ``sweep(key, state, pred)`` repeatedly.
    State pytrees are backend-specific (dense vs ring-sharded) but
    checkpointable as-is; ``factors(state)`` recovers (U, V) in original
    item order for prediction and cross-backend comparison.
    """

    name: str = "?"
    #: Whether the backend draws the exact same posterior samples as
    #: ``sequential`` for the same ``(seed, data)`` (the paper's §V-B
    #: parity claim, enforced by the cross-backend parity tests).
    #: Approximate-inference backends (``posterior_merge``) set it False
    #: and are gated by the statistical harness instead.
    exact_parity: bool = True

    def __init__(self, cfg: BPMFConfig):
        self.cfg = cfg
        self.core_cfg = cfg.core()
        self._prepared = False
        # "auto" donates: XLA reuses the block carry's buffers on every
        # platform we run on, and samples are unaffected. "off" is the
        # fallback path for callers that re-read a block's inputs.
        self.donate_blocks = cfg.backend.donate_blocks in ("auto", "on")

    # ------------------------------------------------------------------
    @abc.abstractmethod
    def prepare(self, coo: RatingsCOO) -> None:
        """Build the backend's data layout (split, center, bucket, shard)."""

    @abc.abstractmethod
    def init_state(self, key: jax.Array):
        """Prior-predictive state; layout-independent per original item id."""

    @abc.abstractmethod
    def sweep(self, key: jax.Array, state, pred: PredictionState):
        """One Gibbs sweep -> (state, pred, SweepMetrics). Legacy per-sweep
        dispatch; the engine run loop goes through :meth:`sweep_block`."""

    @abc.abstractmethod
    def sweep_block(
        self, key: jax.Array, state, pred: PredictionState,
        accum: PosteriorAccum, block_size: int,
    ):
        """``block_size`` sweeps in one jitted call, no host sync inside.

        The engine's run loop primitive (DESIGN.md §10): posterior and
        prediction accumulation happen on-device in the block's scan carry.

        Returns:
            ``(state, pred, accum, metrics)`` — ``metrics`` a
            ``[block_size, 3]`` f32 device array of per-sweep
            ``(rmse_sample, rmse_avg, sweep)`` rows.
        """

    @abc.abstractmethod
    def factors(self, state) -> tuple[np.ndarray, np.ndarray]:
        """(U, V) as host arrays in *original* item order."""

    # ------------------------------------------------------------------
    @abc.abstractmethod
    def init_accum(self) -> PosteriorAccum:
        """Zeroed device posterior accumulator in this backend's layout
        (window depth = ``RunConfig.keep_factor_samples``)."""

    @abc.abstractmethod
    def accum_host(self, accum: PosteriorAccum) -> dict:
        """Host view of the accumulator in original item order — the
        ``"posterior"`` checkpoint subtree schema (see
        :func:`accum_host_tree`)."""

    @abc.abstractmethod
    def accum_from_host(self, tree: dict) -> PosteriorAccum:
        """Rebuild the device accumulator from an :meth:`accum_host` tree
        (checkpoint restore path)."""

    def posterior_template(self) -> dict:
        """Empty-leaf restore target naming the ``"posterior"`` checkpoint
        subtree's leaves (:meth:`accum_host`'s schema — the restore loads
        whatever shapes the checkpoint holds, so only leaf *names* matter).
        Backends with a different subtree shape (``posterior_merge``'s
        per-chain dicts) override this."""
        return {
            "U_sum": np.zeros((0, 0), np.float32),
            "V_sum": np.zeros((0, 0), np.float32),
            "count": np.zeros((), np.int32),
            "U_samples": np.zeros((0, 0, 0), np.float32),
            "V_samples": np.zeros((0, 0, 0), np.float32),
        }

    def posterior_export(self, accum) -> dict:
        """Global posterior summary feeding the serving artifact.

        Returns ``{"count", "U_samples", "V_samples"}`` plus ``"U_mean"`` /
        ``"V_mean"`` when ``count > 0`` — host float32 arrays in original
        item order, chronological sample stacks. The default derives it from
        the single :meth:`accum_host` tree (bitwise the arithmetic the
        engine has always exported); ``posterior_merge`` overrides it with
        the subset-posterior combination.
        """
        tree = self.accum_host(accum)
        count = int(np.asarray(tree["count"]))
        out: dict = {
            "count": count,
            "U_samples": np.asarray(tree["U_samples"], np.float32),
            "V_samples": np.asarray(tree["V_samples"], np.float32),
        }
        if count:
            n = np.float32(count)
            out["U_mean"] = np.asarray(tree["U_sum"] / n, np.float32)
            out["V_mean"] = np.asarray(tree["V_sum"] / n, np.float32)
        return out

    # ------------------------------------------------------------------
    @property
    def prepared(self) -> bool:
        """Whether ``prepare()`` has built this backend's data layout."""
        return self._prepared

    def init_pred(self) -> PredictionState:
        """Zeroed posterior-mean prediction accumulator for the test set."""
        return PredictionState.init(self.num_test)

    @property
    @abc.abstractmethod
    def num_test(self) -> int:
        """Number of held-out ratings."""

    @property
    @abc.abstractmethod
    def test_vals(self) -> jax.Array:
        """Held-out rating values, ``[num_test]`` f32 (uncentered)."""

    @property
    @abc.abstractmethod
    def mean_rating(self) -> float:
        """Training-set mean subtracted before sampling, re-added at predict."""

    @property
    @abc.abstractmethod
    def rating_range(self) -> tuple[float, float]:
        """(lo, hi) clip range for predictions."""


# --------------------------------------------------------------------------
# Sequential (the single-program oracle)
# --------------------------------------------------------------------------


@register_backend("sequential")
class SequentialBackend(Backend):
    """Single-program Algorithm 1 via :mod:`repro.core.gibbs`."""

    def prepare(self, coo: RatingsCOO | ChunkedRatings) -> None:
        if isinstance(coo, ChunkedRatings):  # no per-host path: concatenate
            coo = coo.materialize()
        self.data = build_bpmf_data(
            coo,
            pads=self.cfg.backend.bucket_pads,
            test_fraction=self.cfg.run.test_fraction,
            seed=self.cfg.run.seed,
        )
        self._prepared = True

    def init_state(self, key: jax.Array):
        return gibbs.init_state(key, self.data.num_users, self.data.num_movies, self.core_cfg)

    def sweep(self, key: jax.Array, state, pred: PredictionState):
        return gibbs.gibbs_sweep(key, state, pred, self.data, self.core_cfg)

    def sweep_block(
        self, key: jax.Array, state, pred: PredictionState,
        accum: PosteriorAccum, block_size: int,
    ):
        fn = (
            gibbs.gibbs_sweep_block_donated
            if self.donate_blocks
            else gibbs.gibbs_sweep_block
        )
        return fn(key, state, pred, accum, self.data, self.core_cfg, block_size)

    def factors(self, state) -> tuple[np.ndarray, np.ndarray]:
        return np.asarray(state.U), np.asarray(state.V)

    def init_accum(self) -> PosteriorAccum:
        return PosteriorAccum.init(
            self.data.num_users, self.data.num_movies,
            self.core_cfg.K, self.cfg.run.keep_factor_samples,
        )

    def accum_host(self, accum: PosteriorAccum) -> dict:
        return accum_host_tree(accum)

    def accum_from_host(self, tree: dict) -> PosteriorAccum:
        host = accum_from_host_tree(tree, self.init_accum())
        return jax.tree_util.tree_map(jax.numpy.asarray, host)

    @property
    def num_test(self) -> int:
        return int(self.data.test.rows.shape[0])

    @property
    def test_vals(self) -> jax.Array:
        return self.data.test.vals

    @property
    def mean_rating(self) -> float:
        return float(self.data.mean_rating)

    @property
    def rating_range(self) -> tuple[float, float]:
        return self.data.min_rating, self.data.max_rating


# --------------------------------------------------------------------------
# Distributed (ring / allgather over a device mesh)
# --------------------------------------------------------------------------


class DistributedBackend(Backend):
    """Shared machinery for the shard_map backends (paper §IV).

    Subclass this (and :func:`register_backend` the subclass) to add new
    distributed execution strategies: it owns the mesh construction,
    host-side data distribution, sharded init/sweep dispatch and factor
    gathering; subclasses typically only pick a ``comm_mode`` via
    ``BackendConfig.name`` or override :meth:`sweep`.
    """

    def prepare(self, coo: RatingsCOO | ChunkedRatings) -> None:
        devices = jax.devices()
        procs = jax.process_count()
        S = self.cfg.backend.num_shards or len(devices)
        if S > len(devices):
            raise ValueError(
                f"BackendConfig.num_shards={S} exceeds the {len(devices)} visible "
                f"device(s); lower it or force more host devices "
                f"(XLA_FLAGS=--xla_force_host_platform_device_count=N)"
            )
        if procs > 1 and S != len(devices):
            raise ValueError(
                f"multi-process runs must ring all {len(devices)} global "
                f"devices (got num_shards={S}); vary --devices per process "
                f"instead"
            )
        self.mesh = dist.make_ring_mesh(devices[:S])
        if procs > 1 or isinstance(coo, ChunkedRatings):
            # per-host loading (DESIGN.md §14): every process computes the
            # same global plan from the shared chunk stream but materializes
            # only its own shards' buckets/rating rows
            chunked = coo if isinstance(coo, ChunkedRatings) else coo.chunked()
            local = dist.local_shard_range(S, jax.process_index(), procs)
            data, self.plan = dist.build_distributed_data_per_host(
                chunked,
                num_shards=S,
                local_shards=local,
                pads=self.cfg.backend.bucket_pads,
                test_fraction=self.cfg.run.test_fraction,
                seed=self.cfg.run.seed,
                strategy=self.cfg.backend.partition_strategy,
            )
        else:
            data, self.plan = dist.build_distributed_data(
                coo,
                num_shards=S,
                pads=self.cfg.backend.bucket_pads,
                test_fraction=self.cfg.run.test_fraction,
                seed=self.cfg.run.seed,
                strategy=self.cfg.backend.partition_strategy,
            )
        self.data = dist.shard_data(data, self.mesh)
        self.num_shards = S
        self._prepared = True

    def init_state(self, key: jax.Array):
        return dist.init_dist_state(key, self.data, self.core_cfg, self.mesh)

    def sweep(self, key: jax.Array, state, pred: PredictionState):
        return dist.dist_gibbs_sweep(key, state, pred, self.data, self.core_cfg, self.mesh)

    def sweep_block(
        self, key: jax.Array, state, pred: PredictionState,
        accum: PosteriorAccum, block_size: int,
    ):
        fn = (
            dist.dist_gibbs_sweep_block_donated
            if self.donate_blocks
            else dist.dist_gibbs_sweep_block
        )
        return fn(
            key, state, pred, accum, self.data, self.core_cfg, self.mesh, block_size
        )

    def factors(self, state) -> tuple[np.ndarray, np.ndarray]:
        return dist.gather_factors(state, self.plan)

    def init_accum(self) -> PosteriorAccum:
        return dist.init_dist_accum(
            self.data, self.core_cfg, self.mesh, self.cfg.run.keep_factor_samples
        )

    def accum_host(self, accum: PosteriorAccum) -> dict:
        return accum_host_tree(
            accum,
            u_order=self.plan.part_users.perm,
            v_order=self.plan.part_movies.perm,
        )

    def accum_from_host(self, tree: dict) -> PosteriorAccum:
        host = accum_from_host_tree(
            tree,
            self.init_accum(),
            u_scatter=self.plan.part_users.perm,
            v_scatter=self.plan.part_movies.perm,
        )
        specs = dist.accum_specs()
        return jax.tree_util.tree_map(
            lambda x, s: dist.place_global(x, NamedSharding(self.mesh, s)), host, specs
        )

    @property
    def num_test(self) -> int:
        return int(self.data.test.rows.shape[0])

    @property
    def test_vals(self) -> jax.Array:
        return self.data.test.vals

    @property
    def mean_rating(self) -> float:
        return float(self.data.mean_rating)

    @property
    def rating_range(self) -> tuple[float, float]:
        return self.data.min_rating, self.data.max_rating


@register_backend("ring")
class RingBackend(DistributedBackend):
    """Paper §IV-C: ppermute rotation with compute/comm overlap."""


@register_backend("ring_async")
class AsyncRingBackend(DistributedBackend):
    """Depth-d pipelined ring (arXiv:1705.10633; DESIGN.md §7).

    Keeps ``BackendConfig.pipeline_depth`` shard rotations in flight in a
    rotating buffer queue instead of the synchronous ring's one, hiding up
    to d link latencies per Gram step at a memory cost of d resident
    opposite-shard buffers. Bit-identical samples to ``"ring"`` for every
    depth (the rotation schedule changes *when* transfers are issued,
    never the values each Gram step consumes).
    """


@register_backend("allgather")
class AllGatherBackend(DistributedBackend):
    """Synchronous baseline: blocking all-gather then local updates."""


# --------------------------------------------------------------------------
# Posterior merge (limited-communication subset posteriors)
# --------------------------------------------------------------------------


@register_backend("posterior_merge")
class PosteriorMergeBackend(Backend):
    """Embarrassingly-parallel partition chains + subset-posterior merge.

    The limited-communication regime of arXiv:1703.00734 / 2004.02561
    (DESIGN.md §12): one global train/test split, users partitioned into
    ``BackendConfig.num_partitions`` chains by the ring's nnz cost model,
    and one fully independent Gibbs chain per partition — each running the
    same device-resident blocked sweep loop the sequential backend uses,
    placed round-robin across the visible devices. Chains exchange **zero
    bytes per sweep** (no collectives at all — ``fig_merge_comm`` measures
    this on the compiled HLO); the subset posteriors meet only at
    export/serve time, combined per ``BackendConfig.merge_method``
    (:func:`repro.core.subset_merge.merge_chain_trees`).

    State / pred / accum are tuples of per-chain pytrees (checkpointed as
    ``chain_000``-keyed subtrees), chain c draws from the disjoint RNG
    stream ``fold_in(run_key, c)``, and user-factor rows are initialized by
    *original* user id, so the per-chain init matches the sequential
    backend's rows for the same seed.

    Multi-process (DESIGN.md §14): chains are placed round-robin over the
    *global* device list, so the first multi-host tenant costs only
    placement. Each process builds device data and runs the sweep loop for
    its own chains alone; a chain owned by another process travels through
    this process's pytrees as zero-shard :class:`ShardedHostLeaf`
    placeholders — structurally identical trees on every process, so the
    checkpoint commit protocol sees one global leaf set with each chain's
    bytes written by its owner. Per-sweep metrics and the export-time merge
    gather chain summaries with a zero-filled host allgather (each chain's
    slot filled only by its owner), and the merged artifact is written by
    process 0.
    """

    # approximate inference: merged posterior != sequential samples; gated
    # by the statistical harness (tests/test_posterior_quality.py)
    exact_parity = False

    def prepare(self, coo: RatingsCOO | ChunkedRatings) -> None:
        if isinstance(coo, ChunkedRatings):  # chains split users, not shards
            coo = coo.materialize()
        bk = self.cfg.backend
        devices = jax.devices()  # global, process-major
        P = bk.num_partitions or min(len(devices), coo.num_users)
        self.user_sets = subset_merge.partition_users(
            coo, P, strategy=bk.partition_strategy
        )
        # one GLOBAL split + centering, identical to the sequential
        # backend's, so cross-backend RMSE compares inference not data
        train, test = train_test_split(
            coo, self.cfg.run.test_fraction, self.cfg.run.seed
        )
        self._mean = float(train.vals.mean()) if train.nnz else 0.0
        self._range = (float(coo.vals.min()), float(coo.vals.max()))
        train_subs = subset_merge.split_by_users(train, self.user_sets)
        test_subs = subset_merge.split_by_users(test, self.user_sets)
        self.devices = [devices[c % len(devices)] for c in range(P)]
        self._owner = [int(d.process_index) for d in self.devices]
        self._test_counts = [int(t.nnz) for t in test_subs]
        self._test_vals = (
            np.concatenate([np.asarray(t.vals, np.float32) for t in test_subs])
            if test_subs
            else np.zeros(0, np.float32)
        )
        pid = jax.process_index()
        self._local_chains = [c for c in range(P) if self._owner[c] == pid]
        # per-host loading: only this process's chains get bucketed device
        # data; foreign chains stay host-side split metadata
        self.chain_data = {}
        for c in self._local_chains:
            data = build_bpmf_data_presplit(
                subset_merge.localize_users(train_subs[c], self.user_sets[c]),
                subset_merge.localize_users(test_subs[c], self.user_sets[c]),
                pads=bk.bucket_pads,
                mean_rating=self._mean,
                min_rating=self._range[0],
                max_rating=self._range[1],
            )
            self.chain_data[c] = jax.device_put(data, self.devices[c])
        self.num_partitions = P
        self._num_users = coo.num_users
        self._num_movies = coo.num_movies
        self._prepared = True

    @staticmethod
    def _chain_name(c: int) -> str:
        """Checkpoint subtree key of chain ``c`` (zero-padded, stable order)."""
        return f"chain_{c:03d}"

    # ------------------------------------------------------------------
    # cross-process plumbing (no-ops on a single process)
    # ------------------------------------------------------------------
    def _to_chain_device(self, tree, c: int):
        """Commit a host pytree to chain ``c``'s device.

        Local chains ``device_put`` as always; a chain owned by another
        process becomes a pytree of zero-shard :class:`ShardedHostLeaf`
        placeholders (global shape/dtype, no data) — never computed on
        here, but keeping every process's trees structurally identical for
        the checkpoint layer.
        """
        if self._owner[c] == jax.process_index():
            return jax.device_put(tree, self.devices[c])
        return jax.tree_util.tree_map(
            lambda a: ShardedHostLeaf(
                global_shape=tuple(int(d) for d in np.shape(a)),
                dtype=str(np.result_type(a)),
                shards=(),
            ),
            tree,
        )

    def _fetch(self, x, c: int) -> np.ndarray:
        """Host copy of chain ``c``'s array, on every process.

        A collective in multi-process jobs (all processes must call it in
        the same order): every process contributes a zero-filled slot
        except the owner, the slots are allgathered, and the owner's is
        selected — bitwise the owner's bytes, everywhere.
        """
        if jax.process_count() == 1:
            return np.asarray(jax.device_get(x))
        from jax.experimental import multihost_utils

        if isinstance(x, ShardedHostLeaf):
            local = np.zeros(x.global_shape, np.dtype(x.dtype))
        else:
            local = np.asarray(jax.device_get(x))
        gathered = multihost_utils.process_allgather(local)
        return np.asarray(gathered[self._owner[c]], local.dtype)

    def _host_accum(self, c: int, a) -> PosteriorAccum:
        """Chain ``c``'s accumulator as host numpy (collective, see
        :meth:`_fetch`)."""
        return PosteriorAccum(
            U_sum=self._fetch(a.U_sum, c),
            V_sum=self._fetch(a.V_sum, c),
            count=self._fetch(a.count, c),
            filled=self._fetch(a.filled, c),
            U_window=self._fetch(a.U_window, c),
            V_window=self._fetch(a.V_window, c),
        )

    def _global_rows(self, per_chain: np.ndarray) -> np.ndarray:
        """Sum each chain's metric rows over processes (owner contributes
        the values, everyone else zeros)."""
        if jax.process_count() == 1:
            return per_chain
        from jax.experimental import multihost_utils

        return np.asarray(multihost_utils.process_allgather(per_chain)).sum(axis=0)

    # ------------------------------------------------------------------
    def init_state(self, key: jax.Array):
        """Per-chain prior-predictive states; U rows keyed by *original*
        user id (bitwise the sequential init's rows), V identical across
        chains."""
        dt = self.core_cfg.sample_dtype
        K = self.core_cfg.K
        ku, kv = jax.random.split(key)
        states = []
        for c, uids in enumerate(self.user_sets):
            st = BPMFState(
                U=gibbs.init_rows(ku, jnp.asarray(uids, jnp.int32), K, dt),
                V=gibbs.init_rows(
                    kv, jnp.arange(self._num_movies, dtype=jnp.int32), K, dt
                ),
                hyper_U=HyperParams.init(K, dt),
                hyper_V=HyperParams.init(K, dt),
                sweep=jnp.zeros((), jnp.int32),
            )
            states.append(self._to_chain_device(st, c))
        return tuple(states)

    def _combine_metric_rows(self, per_chain: np.ndarray) -> np.ndarray:
        """``[C, B, 3]`` per-chain metric rows -> ``[B, 3]`` global rows.

        Each chain's RMSE covers its own (disjoint) test subset, so the
        pooled global RMSE is the nnz-weighted quadratic mean
        ``sqrt(sum_c T_c * rmse_c^2 / T)``; chains with an empty test
        subset report NaN and are zero-weighted. The sweep column is shared
        (chains run in lock-step).
        """
        T = np.asarray(self._test_counts, np.float64)
        total = max(T.sum(), 1.0)
        sq = np.square(np.nan_to_num(per_chain[:, :, :2].astype(np.float64)))
        comb = np.sqrt((T[:, None, None] * sq).sum(axis=0) / total)
        rows = np.concatenate([comb, per_chain[0, :, 2:3].astype(np.float64)], axis=1)
        return rows.astype(np.float32)

    def sweep(self, key: jax.Array, state, pred):
        outs = {
            c: gibbs.gibbs_sweep(
                subset_merge.chain_key(key, c), state[c], pred[c],
                self.chain_data[c], self.core_cfg,
            )
            for c in self._local_chains
        }
        per_chain = np.zeros((self.num_partitions, 1, 3), np.float32)
        for c, (_, _, m) in outs.items():
            per_chain[c, 0] = np.asarray(
                jax.device_get(
                    jnp.stack([m.rmse_sample, m.rmse_avg, m.sweep.astype(jnp.float32)])
                )
            )
        row = self._combine_metric_rows(self._global_rows(per_chain))[0]
        metrics = SweepMetrics(float(row[0]), float(row[1]), float(row[2]))
        C = self.num_partitions
        return (
            tuple(outs[c][0] if c in outs else state[c] for c in range(C)),
            tuple(outs[c][1] if c in outs else pred[c] for c in range(C)),
            metrics,
        )

    def sweep_block(
        self, key: jax.Array, state, pred, accum: MergeAccum, block_size: int
    ):
        fn = (
            gibbs.gibbs_sweep_block_donated
            if self.donate_blocks
            else gibbs.gibbs_sweep_block
        )
        outs = {}
        for c in self._local_chains:
            outs[c] = fn(
                subset_merge.chain_key(key, c), state[c], pred[c],
                accum.chains[c], self.chain_data[c], self.core_cfg, block_size,
            )
        # all local chain blocks are dispatched (async) before the first
        # fetch; foreign chains' rows arrive through the allgather below
        per_chain = np.zeros((self.num_partitions, block_size, 3), np.float32)
        for c, o in outs.items():
            per_chain[c] = np.asarray(jax.device_get(o[3]))
        metrics = self._combine_metric_rows(self._global_rows(per_chain))
        C = self.num_partitions
        return (
            tuple(outs[c][0] if c in outs else state[c] for c in range(C)),
            tuple(outs[c][1] if c in outs else pred[c] for c in range(C)),
            MergeAccum(
                chains=tuple(
                    outs[c][2] if c in outs else accum.chains[c] for c in range(C)
                )
            ),
            metrics,
        )

    def factors(self, state) -> tuple[np.ndarray, np.ndarray]:
        """(U, V) of the current per-chain samples: U rows scatter from
        their owning chain; V (sampled by every chain) is the uniform mean
        of the chains' current draws."""
        K = self.core_cfg.K
        U = np.zeros((self._num_users, K), np.float32)
        Vs = []
        for c, uids in enumerate(self.user_sets):
            U[uids] = np.asarray(self._fetch(state[c].U, c), np.float32)
            Vs.append(np.asarray(self._fetch(state[c].V, c), np.float32))
        V = np.mean(np.stack(Vs), axis=0).astype(np.float32)
        return U, V

    def init_accum(self) -> MergeAccum:
        keep = self.cfg.run.keep_factor_samples
        K = self.core_cfg.K
        chains = []
        for c, uids in enumerate(self.user_sets):
            a = PosteriorAccum.init(len(uids), self._num_movies, K, keep)
            chains.append(self._to_chain_device(a, c))
        return MergeAccum(chains=tuple(chains))

    def init_pred(self):
        """Per-chain prediction accumulators, one per chain test subset."""
        return tuple(
            self._to_chain_device(PredictionState.init(self._test_counts[c]), c)
            for c in range(self.num_partitions)
        )

    def accum_host(self, accum: MergeAccum) -> dict:
        return {
            self._chain_name(c): accum_host_tree(self._host_accum(c, a))
            for c, a in enumerate(accum.chains)
        }

    def accum_from_host(self, tree: dict) -> MergeAccum:
        keep = self.cfg.run.keep_factor_samples
        K = self.core_cfg.K
        chains = []
        for c, uids in enumerate(self.user_sets):
            template = PosteriorAccum.init(len(uids), self._num_movies, K, keep)
            host = accum_from_host_tree(tree[self._chain_name(c)], template)
            chains.append(self._to_chain_device(host, c))
        return MergeAccum(chains=tuple(chains))

    def posterior_template(self) -> dict:
        return {
            self._chain_name(c): super(PosteriorMergeBackend, self).posterior_template()
            for c in range(self.num_partitions)
        }

    def posterior_export(self, accum: MergeAccum) -> dict:
        """The backend's single communication event: gather each chain's
        accumulator (collective across processes) and merge the subset
        posteriors (:func:`repro.core.subset_merge.merge_chain_trees`)."""
        trees = [
            accum_host_tree(self._host_accum(c, a))
            for c, a in enumerate(accum.chains)
        ]
        return subset_merge.merge_chain_trees(
            trees,
            self.user_sets,
            self._num_users,
            method=self.cfg.backend.merge_method,
        )

    @property
    def num_test(self) -> int:
        return sum(self._test_counts)

    @property
    def test_vals(self) -> jax.Array:
        return jnp.asarray(self._test_vals)

    @property
    def mean_rating(self) -> float:
        return self._mean

    @property
    def rating_range(self) -> tuple[float, float]:
        return self._range


# --------------------------------------------------------------------------
# Legacy driver (kept for repro.core.gibbs.run)
# --------------------------------------------------------------------------


def run_sequential_prepared(
    key: jax.Array,
    data,
    core_cfg,
    callback=None,
) -> tuple[object, PredictionState, list[SweepMetrics]]:
    """The pre-facade ``core.gibbs.run`` loop, over already-built BPMFData.

    Kept here so ``core.gibbs.run`` can stay a thin deprecation-safe wrapper
    while the engine owns all new run-loop features (checkpointing,
    streaming metrics). Dispatches per sweep through the same blocked scan
    the engine uses (block size 1), so legacy-loop samples stay bitwise
    identical to engine runs at any ``sweeps_per_block``.
    """
    k_init, k_run = jax.random.split(key)
    state = gibbs.init_state(k_init, data.num_users, data.num_movies, core_cfg)
    pred_state = PredictionState.init(data.test.rows.shape[0])
    accum = PosteriorAccum.init(data.num_users, data.num_movies, core_cfg.K, keep=0)
    history: list[SweepMetrics] = []
    for _ in range(core_cfg.num_sweeps):
        # non-donating on purpose: the callback may retain the state it is
        # handed, which the next iteration would otherwise consume
        state, pred_state, accum, rows = gibbs.gibbs_sweep_block(
            k_run, state, pred_state, accum, data, core_cfg, 1
        )
        metrics = SweepMetrics(*(float(v) for v in np.asarray(rows)[0]))
        history.append(metrics)
        if callback is not None:
            callback(state, metrics)
    return state, pred_state, history
