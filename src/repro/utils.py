"""Small shared utilities: pytree dataclasses, logging, timing, dtypes."""
from __future__ import annotations

import dataclasses
import functools
import logging
import time
from typing import Any, Callable, Iterator, TypeVar

import jax
import jax.numpy as jnp
import numpy as np

T = TypeVar("T")

logger = logging.getLogger("repro")
if not logger.handlers:
    _h = logging.StreamHandler()
    _h.setFormatter(logging.Formatter("[%(asctime)s %(levelname)s] %(message)s", "%H:%M:%S"))
    logger.addHandler(_h)
    logger.setLevel(logging.INFO)


def pytree_dataclass(cls: type[T]) -> type[T]:
    """A frozen dataclass registered as a JAX pytree.

    Fields annotated with ``static=True`` metadata are treated as aux data
    (hashable, not traced).
    """
    cls = dataclasses.dataclass(frozen=True)(cls)
    data_fields = [f.name for f in dataclasses.fields(cls) if not f.metadata.get("static", False)]
    meta_fields = [f.name for f in dataclasses.fields(cls) if f.metadata.get("static", False)]
    return jax.tree_util.register_dataclass(cls, data_fields=data_fields, meta_fields=meta_fields)


def static_field(**kwargs: Any) -> Any:
    """Dataclass field treated as static (aux) data in the pytree."""
    return dataclasses.field(metadata={"static": True}, **kwargs)


def tree_size_bytes(tree: Any) -> int:
    """Total size in bytes of all array leaves."""
    return sum(
        np.prod(x.shape) * np.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
        if hasattr(x, "shape")
    )


def tree_num_params(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree) if hasattr(x, "shape"))


def block_until_ready(tree: Any) -> Any:
    return jax.block_until_ready(tree)


class Timer:
    """Context-manager wall timer."""

    def __init__(self, name: str = "", log: bool = False):
        self.name, self.log = name, log
        self.elapsed = 0.0

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.elapsed = time.perf_counter() - self._t0
        if self.log:
            logger.info("%s: %.4fs", self.name, self.elapsed)


def timeit(fn: Callable[..., Any], *args: Any, iters: int = 10, warmup: int = 2, **kw: Any) -> float:
    """Median seconds per call of ``fn`` (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def next_power_of_two(n: int) -> int:
    return 1 if n <= 1 else 1 << (int(n - 1).bit_length())


def round_up(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple


def chunked(seq: list, n: int) -> Iterator[list]:
    for i in range(0, len(seq), n):
        yield seq[i : i + n]


@functools.lru_cache(maxsize=None)
def cpu_count() -> int:
    import os

    return os.cpu_count() or 1


def cast_floating(tree: Any, dtype: Any) -> Any:
    """Cast floating-point leaves of a pytree to ``dtype``."""

    def _cast(x: Any) -> Any:
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map(_cast, tree)
