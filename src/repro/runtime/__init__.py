from repro.runtime.elastic import FailureInjector, NodeFailure, RestartPolicy, StepTimer

__all__ = ["FailureInjector", "NodeFailure", "RestartPolicy", "StepTimer"]
