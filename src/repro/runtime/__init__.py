from repro.runtime.elastic import ElasticRunner, FailureInjector

__all__ = ["ElasticRunner", "FailureInjector"]
