"""Elastic training runtime: failure injection, restart policy, watchdog.

At 1000+ node scale the failure model is "some pod is always down". The
pieces here are what the launch layer composes into a preemption-safe run
(DESIGN.md §14, exercised by ``scripts/launch_multiproc.py`` and
tests/test_multiproc.py):

* :class:`FailureInjector` raises :class:`NodeFailure` at configured sweeps
  (standing in for the cluster health-checker). ``repro.launch.bpmf``
  exposes it as ``--inject-failure`` so a test launcher can kill one
  process of a live multi-process job deterministically.
* :class:`RestartPolicy` decides how the job comes back up after a process
  dies: one fewer process, same **global** device count. The checkpointed
  ring carries are sharded over S global devices, and S is what the
  compiled sweep blocks were specialized to — so a restart must re-split
  the same S across the survivors and let the checkpoint layer reshard
  the saved carry onto the new process-spanning mesh (checkpoint.py's
  ``make_array_from_callback`` read path).
* :class:`StepTimer` is the straggler watchdog: SPMD has no per-device
  work queues, so the paper's work-stealing maps to (a) static cost-model
  balancing (core/balance.py, applied per-shard before compile) and
  (b) flagging slow sweeps so the orchestration layer can evict a slow
  host between checkpoints — the standard TPU-fleet remediation.
  ``repro.launch.bpmf`` records every sweep through one.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.utils import logger


class NodeFailure(RuntimeError):
    """Simulated loss of one or more devices/hosts."""

    def __init__(self, lost_devices: int):
        super().__init__(f"lost {lost_devices} devices")
        self.lost_devices = lost_devices


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule: {step: devices_lost}."""

    schedule: dict[int, int]

    def check(self, step: int) -> None:
        if step in self.schedule:
            lost = self.schedule.pop(step)
            raise NodeFailure(lost)


@dataclasses.dataclass
class RestartPolicy:
    """How a preempted multi-process job restarts at a smaller size.

    The invariant is the global device count ``total_devices`` (= the ring
    shard count S): compiled sweep blocks, checkpointed carries and the
    data partition are all specialized to S, so a restart keeps S fixed
    and re-splits it over fewer processes. ``scripts/launch_multiproc.py``
    consults this after a child dies and respawns the survivors with
    ``--resume`` from the last committed checkpoint.
    """

    total_devices: int
    min_processes: int = 1
    max_restarts: int = 2
    restarts_done: int = 0

    def next_layout(self, num_processes: int) -> tuple[int, int] | None:
        """Layout after losing a process: ``(processes, devices_per_process)``.

        Picks the largest process count below ``num_processes`` that still
        divides ``total_devices`` evenly (S preserved exactly). Returns
        None when the restart budget is spent or no such count exists —
        the job then fails for real.
        """
        if self.restarts_done >= self.max_restarts:
            return None
        for procs in range(num_processes - 1, self.min_processes - 1, -1):
            if procs >= 1 and self.total_devices % procs == 0:
                self.restarts_done += 1
                logger.warning(
                    "elastic restart %d/%d: %d -> %d processes x %d devices",
                    self.restarts_done, self.max_restarts,
                    num_processes, procs, self.total_devices // procs,
                )
                return procs, self.total_devices // procs
        return None


class StepTimer:
    """Rolling step-time stats; flags stragglers (> threshold x median)."""

    def __init__(self, window: int = 50, threshold: float = 2.0):
        self.times: list[float] = []
        self.window = window
        self.threshold = threshold
        self.straggler_steps: list[int] = []

    def record(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        self.times = self.times[-self.window :]
        med = float(np.median(self.times))
        slow = len(self.times) >= 5 and seconds > self.threshold * med
        if slow:
            self.straggler_steps.append(step)
            logger.warning("step %d straggled: %.3fs vs median %.3fs", step, seconds, med)
        return slow
