"""Elastic training runtime: failure injection, mesh shrink/grow, restore.

At 1000+ node scale the failure model is "some pod is always down". The
runtime mechanism demonstrated here (and exercised in
tests/test_elastic.py on CPU host devices):

  1. a ``FailureInjector`` raises :class:`NodeFailure` at configured steps
     (standing in for the cluster health-checker);
  2. the :class:`ElasticRunner` catches it, rebuilds the mesh over the
     surviving device set (any count — sharding specs are resolved against
     the *new* mesh, with non-divisible dims falling back per module.py),
  3. restores the last committed checkpoint directly onto the new mesh
     (checkpoint.py's elastic read path), and
  4. re-jits the step function and continues from the restored step.

Straggler mitigation: SPMD has no per-device work queues, so the paper's
work-stealing maps to (a) static cost-model balancing (core/balance.py,
applied per-shard before compile) and (b) the ``StepTimer`` watchdog that
flags slow steps so the orchestration layer can evict a slow host between
checkpoints — the standard TPU-fleet remediation.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint.manager import CheckpointManager
from repro.utils import logger

Tree = Any


class NodeFailure(RuntimeError):
    """Simulated loss of one or more devices/hosts."""

    def __init__(self, lost_devices: int):
        super().__init__(f"lost {lost_devices} devices")
        self.lost_devices = lost_devices


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule: {step: devices_lost}."""

    schedule: dict[int, int]

    def check(self, step: int) -> None:
        if step in self.schedule:
            lost = self.schedule.pop(step)
            raise NodeFailure(lost)


class StepTimer:
    """Rolling step-time stats; flags stragglers (> threshold x median)."""

    def __init__(self, window: int = 50, threshold: float = 2.0):
        self.times: list[float] = []
        self.window = window
        self.threshold = threshold
        self.straggler_steps: list[int] = []

    def record(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        self.times = self.times[-self.window :]
        med = float(np.median(self.times))
        slow = len(self.times) >= 5 and seconds > self.threshold * med
        if slow:
            self.straggler_steps.append(step)
            logger.warning("step %d straggled: %.3fs vs median %.3fs", step, seconds, med)
        return slow


@dataclasses.dataclass
class ElasticRunner:
    """Drives a train loop that survives device loss.

    ``make_mesh(devices)``      — build a mesh over the surviving devices.
    ``make_step(mesh)``         — (re)build the jitted step for a mesh.
    ``make_state(mesh, target)``— init or restore state on a mesh; receives
                                  the abstract target (ShapeDtypeStructs).
    ``make_batch(step, mesh)``  — produce the (host) batch for a step.
    """

    make_mesh: Callable[[Sequence[jax.Device]], Mesh]
    make_step: Callable[[Mesh], Callable]
    abstract_state: Tree
    shardings_for: Callable[[Mesh], Tree]
    make_batch: Callable[[int, Mesh], Any]
    init_state: Callable[[Mesh], Tree]
    manager: CheckpointManager
    checkpoint_every: int = 10
    injector: Optional[FailureInjector] = None
    timer: StepTimer = dataclasses.field(default_factory=StepTimer)

    def run(self, num_steps: int, devices: Optional[list] = None) -> tuple[Tree, dict]:
        devices = list(devices if devices is not None else jax.devices())
        mesh = self.make_mesh(devices)
        step_fn = self.make_step(mesh)

        start = self.manager.latest()
        if start is None:
            state = self.init_state(mesh)
            start = 0
        else:
            state = self.manager.restore(
                self.abstract_state, mesh=mesh, shardings=self.shardings_for(mesh)
            )
        events: list[str] = []

        step = start
        while step < num_steps:
            try:
                if self.injector is not None:
                    self.injector.check(step)
                t0 = time.perf_counter()
                state, metrics = step_fn(state, self.make_batch(step, mesh))
                jax.block_until_ready(metrics)
                self.timer.record(step, time.perf_counter() - t0)
                step += 1
                if step % self.checkpoint_every == 0:
                    self.manager.save(step, state)
            except NodeFailure as e:
                events.append(f"step {step}: {e}")
                logger.warning("failure at step %d: %s — shrinking mesh", step, e)
                devices = devices[: max(1, len(devices) - e.lost_devices)]
                mesh = self.make_mesh(devices)
                step_fn = self.make_step(mesh)
                restored = self.manager.latest()
                if restored is None:
                    state = self.init_state(mesh)
                    step = 0
                else:
                    state = self.manager.restore(
                        self.abstract_state, mesh=mesh, shardings=self.shardings_for(mesh)
                    )
                    step = restored
                logger.info("resumed at step %d on %d devices", step, len(devices))

        self.manager.save(num_steps, state)
        self.manager.wait()
        return state, {"events": events, "straggler_steps": self.timer.straggler_steps}
