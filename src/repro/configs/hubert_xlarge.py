"""hubert-xlarge [audio] — encoder-only, w2v2 arch [arXiv:2106.07447].

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (masked-prediction
codebook). The conv waveform frontend is a STUB per the assignment:
``input_specs`` feeds precomputed 512-dim frame embeddings; the backbone
projects them to d_model. Bidirectional attention, no decode shapes.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    num_layers=48,
    d_model=1280,
    d_ff=5120,
    vocab_size=504,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    attention="gqa",
    causal=False,
    is_encoder=True,
    mlp="gelu",
    norm="layernorm",
    input_mode="frames",
    frame_dim=512,
)
