from repro.configs.registry import ARCHS, SHAPES, get_config, list_archs, runnable_cells

__all__ = ["ARCHS", "SHAPES", "get_config", "list_archs", "runnable_cells"]
