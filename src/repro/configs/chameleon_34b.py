"""chameleon-34b [vlm] — early-fusion, VQ image tokens [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536. The VQ-VAE image
tokenizer is a STUB per the assignment: images arrive as token ids inside the
shared 65536-entry vocabulary (early fusion = the backbone is a plain decoder
over the fused token stream). Chameleon applies qk-norm for stability.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    d_ff=22016,
    vocab_size=65536,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    attention="gqa",
    qk_norm=True,
    mlp="swiglu",
    norm="rmsnorm",
    param_dtype="bfloat16",
    remat_group=8,  # 48 x [1, 4096, 8192] carries: group to fit 16 GB HBM
)
