"""gemma-2b [dense] — GeGLU, head_dim=256, MQA [arXiv:2403.08295].

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000. Tied embeddings,
sqrt(d_model) embedding scaling, GeGLU MLP.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    d_ff=16384,
    vocab_size=256000,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    attention="gqa",
    mlp="geglu",
    norm="rmsnorm",
    tie_embeddings=True,
    scale_embeddings=True,
)
