"""Architecture + shape registry: the assigned (arch x shape) grid.

``runnable_cells()`` applies the DESIGN.md §5 skip rules:
  * ``long_500k`` needs sub-quadratic attention — runs only for ssm/hybrid
    archs and SWA archs (mixtral's rolling window); skipped for pure
    full-attention archs.
  * encoder-only archs (hubert) have no decode step — decode shapes skipped.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "chameleon-34b": "repro.configs.chameleon_34b",
    "nemotron-4-340b": "repro.configs.nemotron_4_340b",
    "yi-6b": "repro.configs.yi_6b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "gemma-2b": "repro.configs.gemma_2b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
}

ARCHS = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {', '.join(ARCHS)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def list_archs() -> tuple[str, ...]:
    return ARCHS


def cell_runnable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable?, reason-if-not). The skip rules of DESIGN.md §5."""
    if shape.kind == "decode" and cfg.is_encoder:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k":
        if cfg.is_encoder:
            return False, "encoder-only arch has no decode step"
        if not cfg.sub_quadratic:
            return False, "quadratic attention / unbounded KV at 524k is not deployable"
    return True, ""


def runnable_cells() -> list[tuple[str, str]]:
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, _ = cell_runnable(cfg, shape)
            if ok:
                out.append((arch, shape.name))
    return out
