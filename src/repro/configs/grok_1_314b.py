"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8e top-2.
GELU expert MLPs, logit softcap 30 (grok caps attention+output logits; we
apply the output cap).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    d_ff=32768,
    vocab_size=131072,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    attention="gqa",
    mlp="gelu",
    norm="rmsnorm",
    num_experts=8,
    num_experts_per_tok=2,
    logits_softcap=30.0,
    param_dtype="bfloat16",
    remat_group=8,  # §Perf H1 policy (see mixtral)
)
