"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP [arXiv:2402.16819].

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000. At 340B params the
bf16-param + bf16-moment optimizer path (optimizer.py) is what fits the
16 GB/chip v5e budget on a 256-chip pod — see EXPERIMENTS.md §Dry-run.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    d_ff=73728,
    vocab_size=256000,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    attention="gqa",
    mlp="relu2",
    norm="layernorm",
    param_dtype="bfloat16",
    remat_group=8,  # 96 x [1, 4096, 18432] residual carries alone are 14.5 GB
)
