"""mixtral-8x22b [moe] — 8 experts top-2, SWA [arXiv:2401.04088].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8e top-2,
sliding window 4096 (per the assignment). The rolling KV cache bounds
decode memory to the window, which is what makes its ``long_500k`` cell
runnable (DESIGN.md §5).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    d_ff=16384,
    vocab_size=32768,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    attention="gqa",
    sliding_window=4096,
    mlp="swiglu",
    norm="rmsnorm",
    num_experts=8,
    num_experts_per_tok=2,
    param_dtype="bfloat16",
    remat_group=7,  # §Perf H1: with microbatch=4, collective -32% (75.8->51.5s)
)
