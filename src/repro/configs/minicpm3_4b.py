"""minicpm3-4b [dense] — MLA latent attention [hf:openbmb/MiniCPM3-4B].

62L d_model=2560 40H d_ff=6400 vocab=73448. Multi-head Latent Attention:
q_lora_rank=768, kv_lora_rank=256, qk_nope=64, qk_rope=32, v_head_dim=64.
The decode cache stores only [ckv|k_pe] = 288 floats/token — 13x smaller
than the equivalent GQA cache, which is why its decode shapes are the
memory-lightest of the dense archs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    d_ff=6400,
    vocab_size=73448,
    num_heads=40,
    num_kv_heads=40,
    head_dim=96,  # qk_nope + qk_rope
    attention="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    mlp="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
)
