"""mamba2-130m [ssm] — SSD state-space duality [arXiv:2405.21060].

24L d_model=768, attention-free, vocab=50280, ssm_state=128. d_inner=1536,
head_dim=64 -> 24 SSD heads, 1 B/C group. O(1)-per-token decode state makes
every long-context shape runnable.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    d_ff=0,
    vocab_size=50280,
    attention="none",
    norm="rmsnorm",
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    ssm_chunk=256,
    tie_embeddings=True,
)
