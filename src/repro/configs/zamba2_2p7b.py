"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attn blocks [arXiv:2411.15242].

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000, ssm_state=64. One
shared transformer block applied every 6 mamba layers (9 applications, each
with its own KV cache). d_inner=5120, ssm head_dim=64 -> 80 SSD heads.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    d_ff=10240,
    vocab_size=32000,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    attention="gqa",
    mlp="geglu",
    norm="rmsnorm",
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    ssm_chunk=256,
    shared_attn_every=6,
    tie_embeddings=True,
)
