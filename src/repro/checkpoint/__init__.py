from repro.checkpoint.checkpoint import (
    CheckpointCorruptError,
    CheckpointError,
    CheckpointSchemaError,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.checkpoint.manager import CheckpointManager

__all__ = [
    "CheckpointCorruptError",
    "CheckpointError",
    "CheckpointSchemaError",
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "CheckpointManager",
]
