from repro.checkpoint.checkpoint import (
    CheckpointCorruptError,
    CheckpointError,
    CheckpointSchemaError,
    ShardedHostLeaf,
    host_snapshot_leaf,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.checkpoint.manager import CheckpointManager

__all__ = [
    "CheckpointCorruptError",
    "CheckpointError",
    "CheckpointSchemaError",
    "ShardedHostLeaf",
    "host_snapshot_leaf",
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "CheckpointManager",
]
