"""Checkpoint manager: retention, async background writes, restore policy.

The async writer runs ``save_checkpoint`` on a single worker thread after
``jax.device_get`` has snapshotted the arrays (device_get happens on the
caller thread so the training step can donate/overwrite buffers immediately
— the classic overlap-checkpoint-IO-with-compute trick). ``wait()`` joins
outstanding writes; retention prunes beyond ``keep``; every live manager is
drained at interpreter exit (an ``atexit`` hook over a weak set), so a
process that finishes right after an async ``save()`` still commits it.
Commits are atomic either way — ``save_checkpoint`` renames a complete
tmp dir into place and swaps ``LATEST`` via ``os.replace`` — so a crash
mid-write (even ``os._exit``) never exposes a torn checkpoint.
"""
from __future__ import annotations

import atexit
import os
import shutil
import weakref
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import jax
import numpy as np

from repro.checkpoint.checkpoint import (
    host_snapshot_leaf,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
    _step_dir,
)
from repro.utils import logger

Tree = Any

# live managers with a worker pool, drained by the atexit hook below; weak
# references so a dropped manager (and its pool) can still be collected
_LIVE_MANAGERS: "weakref.WeakSet[CheckpointManager]" = weakref.WeakSet()


@atexit.register
def _drain_managers_at_exit() -> None:
    """Join every live manager's pending writes at interpreter exit."""
    for mgr in list(_LIVE_MANAGERS):
        try:
            mgr.wait()
        except Exception:  # pragma: no cover - exit path must not raise
            logger.exception("checkpoint drain at exit failed for %s", mgr.directory)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_writes: bool = True):
        self.directory = directory
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1) if async_writes else None
        self._pending: list[Future] = []
        if self._pool is not None:
            _LIVE_MANAGERS.add(self)
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Tree) -> None:
        """Snapshot now; write in background (if async).

        Multi-process jobs always commit synchronously on the caller thread:
        the commit protocol runs cross-process barriers, which must
        interleave with the main thread's other collectives in program
        order — a background writer would deadlock against them.
        """
        host_tree = jax.tree.map(host_snapshot_leaf, tree)
        if self._pool is None or jax.process_count() > 1:
            save_checkpoint(self.directory, step, host_tree)
            self._retain()
        else:
            self._pending = [f for f in self._pending if not f.done()]
            fut = self._pool.submit(self._write, step, host_tree)
            self._pending.append(fut)

    def _write(self, step: int, host_tree: Tree) -> None:
        try:
            save_checkpoint(self.directory, step, host_tree)
            self._retain()
        except Exception:  # pragma: no cover - logged, not raised into the pool
            logger.exception("async checkpoint write for step %d failed", step)

    def wait(self) -> None:
        for f in self._pending:
            f.result()
        self._pending.clear()

    # ------------------------------------------------------------------
    def restore(self, target: Tree, step: Optional[int] = None, mesh=None, shardings=None) -> Tree:
        self.wait()
        return restore_checkpoint(self.directory, target, step, mesh, shardings)

    def latest(self) -> Optional[int]:
        self.wait()
        return latest_step(self.directory)

    def all_steps(self) -> list[int]:
        self.wait()  # read-your-writes, like latest()/restore()
        return self._list_steps()

    def _list_steps(self) -> list[int]:
        """Committed steps on disk right now — no writer join, so this is
        safe to call from the writer thread itself (``_retain``)."""
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and ".tmp" not in name:
                steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def _retain(self) -> None:
        if jax.process_index() != 0:
            return  # one pruner; peers may still be reading these dirs
        steps = self._list_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(_step_dir(self.directory, s), ignore_errors=True)

    def close(self) -> None:
        self.wait()
        if self._pool is not None:
            self._pool.shutdown()
