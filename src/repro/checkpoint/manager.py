"""Checkpoint manager: retention, async background writes, restore policy.

The async writer runs ``save_checkpoint`` on a single worker thread after
``jax.device_get`` has snapshotted the arrays (device_get happens on the
caller thread so the training step can donate/overwrite buffers immediately
— the classic overlap-checkpoint-IO-with-compute trick). ``wait()`` joins
outstanding writes; retention prunes beyond ``keep``.
"""
from __future__ import annotations

import os
import shutil
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import jax
import numpy as np

from repro.checkpoint.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
    _step_dir,
)
from repro.utils import logger

Tree = Any


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_writes: bool = True):
        self.directory = directory
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1) if async_writes else None
        self._pending: list[Future] = []
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Tree) -> None:
        """Snapshot now; write in background (if async)."""
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self._pool is None:
            save_checkpoint(self.directory, step, host_tree)
            self._retain()
        else:
            self._pending = [f for f in self._pending if not f.done()]
            fut = self._pool.submit(self._write, step, host_tree)
            self._pending.append(fut)

    def _write(self, step: int, host_tree: Tree) -> None:
        try:
            save_checkpoint(self.directory, step, host_tree)
            self._retain()
        except Exception:  # pragma: no cover - logged, not raised into the pool
            logger.exception("async checkpoint write for step %d failed", step)

    def wait(self) -> None:
        for f in self._pending:
            f.result()
        self._pending.clear()

    # ------------------------------------------------------------------
    def restore(self, target: Tree, step: Optional[int] = None, mesh=None, shardings=None) -> Tree:
        self.wait()
        return restore_checkpoint(self.directory, target, step, mesh, shardings)

    def latest(self) -> Optional[int]:
        self.wait()
        return latest_step(self.directory)

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and ".tmp" not in name:
                steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def _retain(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(_step_dir(self.directory, s), ignore_errors=True)

    def close(self) -> None:
        self.wait()
        if self._pool is not None:
            self._pool.shutdown()
