"""Sharded checkpointing with atomic commit and elastic restore.

Layout (one directory per step)::

    <dir>/step_000100.tmp-<nonce>/     # written here first
        manifest.json                  # treedef, shapes, dtypes, mesh shape
        <leaf-path>.npy                # one file per pytree leaf
    <dir>/step_000100/                 # atomic rename on commit
    <dir>/LATEST                      # text file: committed step number

Multi-process posture (DESIGN.md §14): a leaf that is sharded across
processes is written as per-shard files — each process saves only the
shards it can address, with the shard's global index range encoded in the
file name (``<leaf>.shard-<start>_<stop>[-...].npy``) and ``"sharded":
true`` recorded in the manifest. All processes stage into one
*deterministic* tmp directory on the shared checkpoint filesystem
(``step_N.tmp-mp`` — the single-process nonce would scatter the shards
across directories), a global device barrier confirms every shard file is
on disk, and then **process 0 alone** writes the manifest, renames the tmp
directory into place and swaps ``LATEST`` — the commit protocol. The read
path reassembles the global array from the shard files and places it under
the *target's* sharding, so a checkpoint written at one process count
restores at any other (resharding), including back to a single process.
On a single process all of this degenerates to exactly the old one-file-
per-leaf format, so existing checkpoints interoperate both ways.

Atomicity: the tmp directory is renamed to its final name only after every
leaf + manifest hit disk, and ``LATEST`` is updated after the rename, so a
killed process never leaves a half-readable "latest" checkpoint.
"""
from __future__ import annotations

import dataclasses
import json
import os
import secrets
import shutil
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

Tree = Any

_MANIFEST = "manifest.json"


class CheckpointError(Exception):
    """Base class for typed checkpoint/artifact read failures.

    Raised instead of letting raw ``json``/``numpy`` tracebacks escape, so
    callers (the engine, the serving loader, ops tooling) can distinguish
    "this directory is not a checkpoint" (``FileNotFoundError``) from "this
    checkpoint is damaged" (:class:`CheckpointCorruptError`) from "this
    checkpoint has a different schema" (:class:`CheckpointSchemaError`).
    """


class CheckpointCorruptError(CheckpointError):
    """A committed checkpoint is unreadable: truncated leaf file, garbage
    manifest, or an unparsable ``LATEST`` pointer."""


class CheckpointSchemaError(CheckpointError, ValueError):
    """The checkpoint is readable but its leaf set does not match the
    restore target (schema drift: missing or renamed leaves).

    Subclasses ``ValueError`` for backward compatibility with callers that
    matched the old untyped ``missing leaves`` error.
    """


@dataclasses.dataclass(frozen=True)
class ShardedHostLeaf:
    """Host snapshot of one process's view of a cross-process jax array.

    Holds only the shards this process can address (as numpy blocks keyed by
    their global ``(start, stop)`` index ranges) plus the global shape/dtype
    — what :func:`save_checkpoint` needs to write this process's shard files
    and what process 0 needs for the manifest entry. Produced by
    :func:`host_snapshot_leaf`; opaque to ``jax.tree`` (no pytree
    registration), so it travels through checkpoint trees as a leaf.
    """

    global_shape: tuple[int, ...]
    dtype: str
    #: ``(((start, stop), ...per dim), block)`` per distinct addressable shard
    shards: tuple[tuple[tuple[tuple[int, int], ...], np.ndarray], ...]


def _shard_ranges(shape: tuple[int, ...], index) -> tuple[tuple[int, int], ...]:
    """Resolve a shard's ``.index`` (slices) into per-dim (start, stop)."""
    out = []
    for dim, sl in zip(shape, index):
        start, stop, step = sl.indices(dim)
        if step != 1:  # pragma: no cover - jax shardings are contiguous
            raise ValueError(f"non-contiguous shard slice {sl}")
        out.append((int(start), int(stop)))
    return tuple(out)


def host_snapshot_leaf(x: Any) -> Any:
    """Snapshot one checkpoint leaf to host form, multi-process aware.

    Single process: plain ``device_get`` numpy arrays, exactly as before.
    Multi-process: *every* jax array becomes a :class:`ShardedHostLeaf` of
    this process's addressable shards — the only part it can snapshot
    locally. The rule is uniform on purpose: a ring-sharded factor yields
    one row-range shard per process; a replicated array yields identical
    full-range shards from every process (the writers race to the same
    bytes); a single-device array (a ``posterior_merge`` chain) yields one
    full-range shard from its owner and nothing elsewhere — its peers hold
    non-addressable placeholders and stay silent. Plain host (numpy) leaves
    pass through and are written by process 0 alone.
    """
    if isinstance(x, ShardedHostLeaf):
        return x
    if isinstance(x, jax.Array) and jax.process_count() > 1:
        seen: dict[tuple, np.ndarray] = {}
        for sh in x.addressable_shards:
            rng = _shard_ranges(x.shape, sh.index)
            if rng not in seen:  # replicas within the process: one copy
                seen[rng] = np.asarray(sh.data)
        return ShardedHostLeaf(
            global_shape=tuple(int(d) for d in x.shape),
            dtype=str(x.dtype),
            shards=tuple(sorted(seen.items(), key=lambda kv: kv[0])),
        )
    return np.asarray(jax.device_get(x))


def _shard_filename(name: str, ranges: tuple[tuple[int, int], ...]) -> str:
    body = "-".join(f"{a}_{b}" for a, b in ranges) or "scalar"
    return f"{name}.shard-{body}.npy"


def _parse_shard_ranges(fname: str, name: str) -> tuple[tuple[int, int], ...]:
    body = fname[len(name) + len(".shard-") : -len(".npy")]
    if body == "scalar":
        return ()
    return tuple(
        (int(a), int(b)) for a, b in (part.split("_") for part in body.split("-"))
    )


def _barrier(tag: str) -> None:
    """Block until every process of the job reaches this point."""
    from jax.experimental import multihost_utils

    multihost_utils.sync_global_devices(tag)


def _leaf_paths(tree: Tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]

    def name(path) -> str:
        parts = []
        for p in path:
            parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
        return "__".join(parts) or "leaf"

    return [(name(p), leaf) for p, leaf in flat]


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}")


def save_checkpoint(
    directory: str, step: int, tree: Tree, *, collective: bool = True
) -> str:
    """Write ``tree`` for ``step``; atomic commit; returns the final path.

    Single process: the original one-file-per-leaf format, byte identical.
    Multi-process (``jax.process_count() > 1``): a *collective* — every
    process must call it with the same ``step``. All processes stage their
    shard files into one deterministic tmp directory, barrier, and process 0
    alone writes the manifest, renames and updates ``LATEST`` (the commit);
    a final barrier keeps no process running ahead of an uncommitted
    checkpoint.

    ``collective=False`` forces the single-writer path even in a
    multi-process job: no barriers, this process writes every (host) leaf —
    for process-0-only writes of already-gathered trees (the artifact
    export), which must not entangle with the job's collective order.
    """
    os.makedirs(directory, exist_ok=True)
    final = _step_dir(directory, step)
    procs = jax.process_count() if collective else 1
    pid = jax.process_index() if collective else 0
    if procs == 1:
        tmp = f"{final}.tmp-{secrets.token_hex(4)}"
        os.makedirs(tmp, exist_ok=True)
    else:
        # deterministic name: every process must stage into the *same*
        # directory of the shared checkpoint filesystem
        tmp = f"{final}.tmp-mp"
        if pid == 0:
            if os.path.exists(tmp):  # stale tmp from a killed earlier job
                shutil.rmtree(tmp)
            os.makedirs(tmp, exist_ok=True)
        _barrier(f"ckpt-begin-{step}")

    leaves = _leaf_paths(tree)
    manifest = {"step": step, "leaves": []}
    for name, leaf in leaves:
        leaf = host_snapshot_leaf(leaf)
        if isinstance(leaf, ShardedHostLeaf):
            for ranges, block in leaf.shards:
                path = os.path.join(tmp, _shard_filename(name, ranges))
                # partially-replicated shards can be held by several
                # processes: each stages under its own name and the replace
                # races to identical content, never a torn file
                stage = f"{path}.p{pid}"
                with open(stage, "wb") as f:
                    np.save(f, block)
                os.replace(stage, path)
            manifest["leaves"].append(
                {
                    "name": name,
                    "shape": list(leaf.global_shape),
                    "dtype": leaf.dtype,
                    "sharded": True,
                }
            )
        else:
            arr = np.asarray(leaf)
            if pid == 0:  # replicated leaf: one writer suffices
                np.save(os.path.join(tmp, f"{name}.npy"), arr)
            manifest["leaves"].append(
                {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )

    if procs > 1:
        _barrier(f"ckpt-written-{step}")
    if pid == 0:
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):  # re-save of same step: replace
            shutil.rmtree(final)
        os.rename(tmp, final)
        latest_tmp = os.path.join(directory, f".LATEST-{secrets.token_hex(4)}")
        with open(latest_tmp, "w") as f:
            f.write(str(step))
        os.replace(latest_tmp, os.path.join(directory, "LATEST"))
    if procs > 1:
        _barrier(f"ckpt-committed-{step}")
    return final


def latest_step(directory: str) -> Optional[int]:
    path = os.path.join(directory, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        raw = f.read().strip()
    try:
        return int(raw)
    except ValueError as e:
        raise CheckpointCorruptError(
            f"unparsable LATEST pointer {path!r}: {raw[:40]!r}"
        ) from e


def _assemble_sharded_leaf(final: str, entry: dict) -> np.ndarray:
    """Reassemble a ``"sharded": true`` leaf from its shard files."""
    name = entry["name"]
    shape = tuple(int(d) for d in entry["shape"])
    dtype = np.dtype(entry["dtype"])
    prefix = f"{name}.shard-"
    files = [
        f
        for f in os.listdir(final)
        if f.startswith(prefix) and f.endswith(".npy")
    ]
    if not files:
        raise CheckpointCorruptError(
            f"sharded checkpoint leaf {name!r} has no shard files under {final}"
        )
    out = np.zeros(shape, dtype)
    covered = np.zeros(shape, bool)
    for fname in files:
        try:
            ranges = _parse_shard_ranges(fname, name)
            block = np.load(os.path.join(final, fname))
        except (OSError, ValueError, EOFError) as e:
            raise CheckpointCorruptError(
                f"unreadable checkpoint shard {os.path.join(final, fname)}: {e}"
            ) from e
        sl = tuple(slice(a, b) for a, b in ranges)
        out[sl] = block
        covered[sl] = True
    if not covered.all():
        raise CheckpointCorruptError(
            f"sharded checkpoint leaf {name!r} under {final} has gaps: "
            f"{int(covered.size - covered.sum())} of {covered.size} elements "
            f"missing (a writer process died before the commit barrier?)"
        )
    return out


def _place_restored(arr: np.ndarray, sharding) -> jax.Array:
    """Place a restored host array under any target sharding — including one
    spanning processes this host cannot address (the elastic/resharding
    path: each process supplies only the slices it owns)."""
    if getattr(sharding, "is_fully_addressable", True):
        return jax.device_put(arr, sharding)
    return jax.make_array_from_callback(arr.shape, sharding, lambda idx: arr[idx])


def restore_checkpoint(
    directory: str,
    target: Tree,
    step: Optional[int] = None,
    mesh: Optional[Mesh] = None,
    shardings: Optional[Tree] = None,
) -> Tree:
    """Restore into the structure of ``target`` (arrays or ShapeDtypeStructs).

    ``shardings``: optional NamedSharding tree (same structure) — this is the
    elastic path: the saved arrays are placed directly onto the *new* mesh,
    whatever its device count, without requiring the saving mesh. Without an
    explicit tree, a target leaf that is itself a sharded jax array of the
    restored shape lends its sharding (so restoring device state round-trips
    placement, across any process count).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {directory}")
    final = _step_dir(directory, step)
    if not os.path.isdir(final):
        raise FileNotFoundError(f"no checkpoint directory {final}")
    manifest_path = os.path.join(final, _MANIFEST)
    try:
        with open(manifest_path) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        raise CheckpointCorruptError(
            f"unreadable checkpoint manifest {manifest_path}: {e}"
        ) from e
    if not isinstance(manifest, dict) or not isinstance(manifest.get("leaves"), list):
        raise CheckpointCorruptError(
            f"checkpoint manifest {manifest_path} has no leaf table"
        )
    by_name = {e["name"]: e for e in manifest["leaves"] if isinstance(e, dict)}

    target_pairs = _leaf_paths(target)
    names = [n for n, _ in target_pairs]
    missing = [n for n in names if n not in by_name]
    if missing:
        raise CheckpointSchemaError(
            f"checkpoint {final} missing leaves: {missing[:5]}..."
        )

    shard_leaves = None
    if shardings is not None:
        shard_leaves = [s for _, s in _leaf_paths(shardings)]

    out_leaves = []
    for i, (name, target_leaf) in enumerate(target_pairs):
        leaf_path = os.path.join(final, f"{name}.npy")
        if os.path.exists(leaf_path):
            try:
                arr = np.load(leaf_path)
            except (OSError, ValueError, EOFError) as e:
                raise CheckpointCorruptError(
                    f"unreadable checkpoint leaf {leaf_path} (truncated or "
                    f"overwritten?): {e}"
                ) from e
        elif by_name[name].get("sharded"):
            arr = _assemble_sharded_leaf(final, by_name[name])
        else:
            raise CheckpointCorruptError(
                f"checkpoint leaf file {leaf_path} missing (truncated commit?)"
            )
        if isinstance(target_leaf, ShardedHostLeaf):
            # a placeholder target (e.g. posterior_merge's remote chains):
            # keep only the ranges this process claims — usually none — so
            # a later save never writes a stale local copy of a leaf whose
            # live value advances on another process
            out_leaves.append(
                dataclasses.replace(
                    target_leaf,
                    shards=tuple(
                        (rng, arr[tuple(slice(a, b) for a, b in rng)])
                        for rng, _ in target_leaf.shards
                    ),
                )
            )
        elif shard_leaves is not None:
            out_leaves.append(_place_restored(arr, shard_leaves[i]))
        elif mesh is not None:
            out_leaves.append(jax.device_put(arr, NamedSharding(mesh, P())))
        else:
            s = getattr(target_leaf, "sharding", None)
            if (
                isinstance(s, jax.sharding.Sharding)
                and tuple(getattr(target_leaf, "shape", ())) == tuple(arr.shape)
                and (not s.is_fully_addressable or len(s.device_set) > 1)
            ):
                # multi-device targets lend their sharding (cross-process
                # ones *must* — a host array cannot feed a global-mesh
                # program). Single-device targets stay uncommitted host
                # placements, as they always were: committing them would
                # pin device placement that the old path left to jit.
                out_leaves.append(_place_restored(arr, s))
            else:
                out_leaves.append(jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(target)
    return jax.tree_util.tree_unflatten(treedef, out_leaves)
