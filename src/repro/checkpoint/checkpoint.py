"""Sharded checkpointing with atomic commit and elastic restore.

Layout (one directory per step)::

    <dir>/step_000100.tmp-<nonce>/     # written here first
        manifest.json                  # treedef, shapes, dtypes, mesh shape
        <leaf-path>.npy                # one file per pytree leaf
    <dir>/step_000100/                 # atomic rename on commit
    <dir>/LATEST                      # text file: committed step number

Multi-host posture: each leaf is written via
``jax.experimental.multihost_utils``-free addressable-shard gathering — on a
real multi-host cluster each process writes only the shards it owns into
per-process files. On this single-process container that degenerates to one
file per leaf, but the read path already accepts *any* target sharding, so a
checkpoint written on one mesh restores onto a different mesh/device-count
(elastic restore — exercised by tests/test_checkpoint.py and
runtime/elastic.py).

Atomicity: the ``.tmp-<nonce>`` directory is renamed to its final name only
after every leaf + manifest hit disk, and ``LATEST`` is updated after the
rename, so a killed process never leaves a half-readable "latest" checkpoint.
"""
from __future__ import annotations

import json
import os
import secrets
import shutil
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

Tree = Any

_MANIFEST = "manifest.json"


class CheckpointError(Exception):
    """Base class for typed checkpoint/artifact read failures.

    Raised instead of letting raw ``json``/``numpy`` tracebacks escape, so
    callers (the engine, the serving loader, ops tooling) can distinguish
    "this directory is not a checkpoint" (``FileNotFoundError``) from "this
    checkpoint is damaged" (:class:`CheckpointCorruptError`) from "this
    checkpoint has a different schema" (:class:`CheckpointSchemaError`).
    """


class CheckpointCorruptError(CheckpointError):
    """A committed checkpoint is unreadable: truncated leaf file, garbage
    manifest, or an unparsable ``LATEST`` pointer."""


class CheckpointSchemaError(CheckpointError, ValueError):
    """The checkpoint is readable but its leaf set does not match the
    restore target (schema drift: missing or renamed leaves).

    Subclasses ``ValueError`` for backward compatibility with callers that
    matched the old untyped ``missing leaves`` error.
    """


def _leaf_paths(tree: Tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]

    def name(path) -> str:
        parts = []
        for p in path:
            parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
        return "__".join(parts) or "leaf"

    return [(name(p), leaf) for p, leaf in flat]


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}")


def save_checkpoint(directory: str, step: int, tree: Tree) -> str:
    """Write ``tree`` for ``step``; atomic commit; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = _step_dir(directory, step)
    tmp = f"{final}.tmp-{secrets.token_hex(4)}"
    os.makedirs(tmp, exist_ok=True)

    leaves = _leaf_paths(tree)
    manifest = {"step": step, "leaves": []}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"{name}.npy"), arr)
        manifest["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)

    if os.path.exists(final):  # re-save of same step: replace
        shutil.rmtree(final)
    os.rename(tmp, final)
    latest_tmp = os.path.join(directory, f".LATEST-{secrets.token_hex(4)}")
    with open(latest_tmp, "w") as f:
        f.write(str(step))
    os.replace(latest_tmp, os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> Optional[int]:
    path = os.path.join(directory, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        raw = f.read().strip()
    try:
        return int(raw)
    except ValueError as e:
        raise CheckpointCorruptError(
            f"unparsable LATEST pointer {path!r}: {raw[:40]!r}"
        ) from e


def restore_checkpoint(
    directory: str,
    target: Tree,
    step: Optional[int] = None,
    mesh: Optional[Mesh] = None,
    shardings: Optional[Tree] = None,
) -> Tree:
    """Restore into the structure of ``target`` (arrays or ShapeDtypeStructs).

    ``shardings``: optional NamedSharding tree (same structure) — this is the
    elastic path: the saved arrays are placed directly onto the *new* mesh,
    whatever its device count, without requiring the saving mesh.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {directory}")
    final = _step_dir(directory, step)
    if not os.path.isdir(final):
        raise FileNotFoundError(f"no checkpoint directory {final}")
    manifest_path = os.path.join(final, _MANIFEST)
    try:
        with open(manifest_path) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        raise CheckpointCorruptError(
            f"unreadable checkpoint manifest {manifest_path}: {e}"
        ) from e
    if not isinstance(manifest, dict) or not isinstance(manifest.get("leaves"), list):
        raise CheckpointCorruptError(
            f"checkpoint manifest {manifest_path} has no leaf table"
        )
    by_name = {e["name"]: e for e in manifest["leaves"] if isinstance(e, dict)}

    names = [n for n, _ in _leaf_paths(target)]
    missing = [n for n in names if n not in by_name]
    if missing:
        raise CheckpointSchemaError(
            f"checkpoint {final} missing leaves: {missing[:5]}..."
        )

    shard_leaves = None
    if shardings is not None:
        shard_leaves = [s for _, s in _leaf_paths(shardings)]

    out_leaves = []
    for i, name in enumerate(names):
        leaf_path = os.path.join(final, f"{name}.npy")
        try:
            arr = np.load(leaf_path)
        except (OSError, ValueError, EOFError) as e:
            raise CheckpointCorruptError(
                f"unreadable checkpoint leaf {leaf_path} (truncated or "
                f"overwritten?): {e}"
            ) from e
        if shard_leaves is not None:
            out_leaves.append(jax.device_put(arr, shard_leaves[i]))
        elif mesh is not None:
            out_leaves.append(jax.device_put(arr, NamedSharding(mesh, P())))
        else:
            out_leaves.append(jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(target)
    return jax.tree_util.tree_unflatten(treedef, out_leaves)
