"""BPMF serving CLI: answer rating queries from an exported artifact.

One-shot query mode (JSON on stdout)::

    python -m repro.launch.serve --artifact /tmp/bpmf-art --rows 0,1,2 --cols 5,6,7
    python -m repro.launch.serve --artifact /tmp/bpmf-art --user 7 --top-k 10

Micro-batch loop: one JSON request per stdin line, one JSON response per
stdout line (a minimal sidecar-friendly serving loop)::

    printf '{"rows": [0, 1], "cols": [5, 6]}\n{"user": 7, "k": 3}\n' | \\
        python -m repro.launch.serve --artifact /tmp/bpmf-art --jsonl

Requests: ``{"rows": [...], "cols": [...], "std": bool?}`` for point
predictions, ``{"user": id, "k": n}`` for top-k. Malformed requests yield
``{"error": ...}`` responses; the loop keeps serving. ``--devices N``
forces N host devices before jax initializes (same contract as
``repro.launch.bpmf``) so the mesh-sharded batch path is exercisable on CPU.

The LM prefill/decode driver that previously lived here moved with its
step builders to ``repro.training.lm_serve`` (dry-run tooling only).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.launch.hostdevices import force_host_device_count


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.launch.serve",
        description="Serve posterior-mean BPMF predictions from an exported artifact.",
    )
    p.add_argument("--artifact", required=True,
                   help="artifact directory written by BPMFEngine.export() / "
                        "repro.launch.bpmf --export-artifact")
    p.add_argument("--rows", default=None,
                   help="comma-separated user ids for a one-shot prediction batch")
    p.add_argument("--cols", default=None,
                   help="comma-separated movie ids (paired with --rows)")
    p.add_argument("--user", type=int, default=None,
                   help="one-shot top-k: user id to rank the catalog for")
    p.add_argument("--top-k", type=int, default=10,
                   help="number of movies returned with --user")
    p.add_argument("--std", action="store_true",
                   help="include the predictive std (needs retained samples)")
    p.add_argument("--jsonl", action="store_true",
                   help="micro-batch loop: JSONL requests on stdin, JSON "
                        "responses on stdout")
    p.add_argument("--devices", type=int, default=0,
                   help="force N host (CPU) devices before jax init")
    return p


def _parse_ids(text: str, flag: str) -> list[int]:
    try:
        return [int(x) for x in text.split(",") if x.strip() != ""]
    except ValueError as e:
        raise SystemExit(f"{flag} must be a comma-separated id list: {e}")


def _handle(predictor, req: dict) -> dict:
    """One request -> one response dict (predict or top_k)."""
    if "rows" in req or "cols" in req:
        preds = predictor.predict(
            req.get("rows", ()), req.get("cols", ()), return_std=bool(req.get("std"))
        )
        if isinstance(preds, tuple):
            preds, std = preds
            return {"predictions": preds.tolist(), "std": std.tolist()}
        return {"predictions": preds.tolist()}
    if "user" in req:
        ids, scores = predictor.top_k(int(req["user"]), int(req.get("k", 10)))
        return {"user": int(req["user"]), "items": ids.tolist(),
                "scores": scores.tolist()}
    return {"error": "request needs either rows/cols or user"}


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)

    force_host_device_count(args.devices)

    # heavy imports only after XLA_FLAGS is settled
    from repro.serve import ArtifactError, PosteriorPredictor

    try:
        predictor = PosteriorPredictor.load(args.artifact)
    except ArtifactError as e:
        print(f"cannot load artifact: {e}", file=sys.stderr)
        return 1
    meta = predictor.meta
    print(
        f"serving artifact {args.artifact}: R {meta.num_users} x "
        f"{meta.num_movies}, K={meta.K}, backend={meta.backend}, "
        f"{meta.num_mean_samples} posterior samples averaged, "
        f"{meta.num_kept_samples} kept for std",
        file=sys.stderr,
    )

    def handle_safe(req: dict) -> dict:
        # invalid queries (out-of-range ids, --std without retained samples)
        # become error responses in every mode, never tracebacks
        try:
            return _handle(predictor, req)
        except (ValueError, KeyError, TypeError) as e:
            return {"error": f"{type(e).__name__}: {e}"}

    if args.jsonl:
        for line in sys.stdin:
            line = line.strip()
            if not line:
                continue
            try:
                resp = handle_safe(json.loads(line))
            except ValueError as e:  # json.JSONDecodeError
                resp = {"error": f"{type(e).__name__}: {e}"}
            print(json.dumps(resp), flush=True)
        return 0

    if args.user is not None:
        req = {"user": args.user, "k": args.top_k}
    elif args.rows is not None and args.cols is not None:
        req = {"rows": _parse_ids(args.rows, "--rows"),
               "cols": _parse_ids(args.cols, "--cols")}
        if args.std:
            req["std"] = True
    else:
        print("one-shot mode needs --rows AND --cols (or --user, or --jsonl)",
              file=sys.stderr)
        return 2
    resp = handle_safe(req)
    if "error" in resp:
        print(json.dumps(resp), file=sys.stderr)
        return 1
    print(json.dumps(resp))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
