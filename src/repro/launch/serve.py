"""BPMF serving CLI: answer rating queries from an artifact or a server.

One-shot query mode (JSON on stdout)::

    python -m repro.launch.serve --artifact /tmp/bpmf-art --rows 0,1,2 --cols 5,6,7
    python -m repro.launch.serve --artifact /tmp/bpmf-art --user 7 --top-k 10

Micro-batch loop: one JSON request per stdin line, one JSON response per
stdout line (a minimal sidecar-friendly serving loop)::

    printf '{"rows": [0, 1], "cols": [5, 6]}\n{"user": 7, "k": 3}\n' | \\
        python -m repro.launch.serve --artifact /tmp/bpmf-art --jsonl

Client mode: ``--server host:port`` (instead of ``--artifact``) sends the
same requests to a running ``python -m repro.launch.serve_server`` — the
identical request/response schema (:mod:`repro.serve.schema`) drives either
the in-process predictor or the persistent server, so scripts can switch
transports with one flag::

    python -m repro.launch.serve --server 127.0.0.1:8642 --user 7 --top-k 10

Requests: ``{"rows": [...], "cols": [...], "std": bool?}`` for point
predictions, ``{"user": id, "k": n}`` (or ``{"users": [...], "k": n}``)
for top-k. Malformed requests yield ``{"error": ...}`` responses; the loop
keeps serving. ``--devices N`` forces N host devices before jax
initializes (same contract as ``repro.launch.bpmf``) so the mesh-sharded
batch path is exercisable on CPU.

The LM prefill/decode driver that previously lived here moved with its
step builders to ``repro.training.lm_serve`` (dry-run tooling only).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.launch.hostdevices import force_host_device_count


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.launch.serve",
        description="Serve posterior-mean BPMF predictions from an exported "
                    "artifact, or query a running serve_server.",
    )
    p.add_argument("--artifact", default=None,
                   help="artifact directory written by BPMFEngine.export() / "
                        "repro.launch.bpmf --export-artifact")
    p.add_argument("--server", default=None, metavar="HOST:PORT",
                   help="query a running repro.launch.serve_server instead "
                        "of loading an artifact in-process")
    p.add_argument("--rows", default=None,
                   help="comma-separated user ids for a one-shot prediction batch")
    p.add_argument("--cols", default=None,
                   help="comma-separated movie ids (paired with --rows)")
    p.add_argument("--user", type=int, default=None,
                   help="one-shot top-k: user id to rank the catalog for")
    p.add_argument("--top-k", type=int, default=10,
                   help="number of movies returned with --user")
    p.add_argument("--std", action="store_true",
                   help="include the predictive std (needs retained samples)")
    p.add_argument("--jsonl", action="store_true",
                   help="micro-batch loop: JSONL requests on stdin, JSON "
                        "responses on stdout")
    p.add_argument("--devices", type=int, default=0,
                   help="force N host (CPU) devices before jax init")
    return p


def _parse_ids(text: str, flag: str) -> list[int]:
    try:
        return [int(x) for x in text.split(",") if x.strip() != ""]
    except ValueError as e:
        raise SystemExit(f"{flag} must be a comma-separated id list: {e}")


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    if (args.artifact is None) == (args.server is None):
        print("exactly one of --artifact or --server is required", file=sys.stderr)
        return 2

    force_host_device_count(args.devices)

    # heavy imports only after XLA_FLAGS is settled
    from repro.serve import (
        ArtifactError,
        PosteriorPredictor,
        RequestError,
        ServeClient,
        ServeConnectionError,
        parse_request,
        run_request,
    )
    from repro.serve.schema import error_response

    if args.server is not None:
        try:
            client = ServeClient(args.server)
            health = client.health()
        except (ValueError, ServeConnectionError) as e:
            print(f"cannot reach server: {e}", file=sys.stderr)
            return 1
        art = health.get("artifact", {})
        print(
            f"querying server {args.server}: R {art.get('num_users')} x "
            f"{art.get('num_movies')}, K={art.get('K')}, "
            f"backend={art.get('backend')}, "
            f"generation={health.get('generation')}",
            file=sys.stderr,
        )

        def handle_safe(req: dict) -> dict:
            # server-side validation comes back as an {"error": ...} body;
            # transport failures become error responses too, so the JSONL
            # loop keeps serving
            try:
                return client.request(req)
            except ServeConnectionError as e:
                return {"error": f"{type(e).__name__}: {e}"}
    else:
        try:
            predictor = PosteriorPredictor.load(args.artifact)
        except ArtifactError as e:
            print(f"cannot load artifact: {e}", file=sys.stderr)
            return 1
        meta = predictor.meta
        print(
            f"serving artifact {args.artifact}: R {meta.num_users} x "
            f"{meta.num_movies}, K={meta.K}, backend={meta.backend}, "
            f"{meta.num_mean_samples} posterior samples averaged, "
            f"{meta.num_kept_samples} kept for std",
            file=sys.stderr,
        )

        def handle_safe(req: dict) -> dict:
            # invalid queries (bad shapes, out-of-range ids, --std without
            # retained samples) become error responses in every mode,
            # never tracebacks — same schema the server speaks
            try:
                return run_request(predictor, parse_request(req))
            except (RequestError, ValueError, KeyError, TypeError) as e:
                return error_response(e)

    if args.jsonl:
        for line in sys.stdin:
            line = line.strip()
            if not line:
                continue
            try:
                resp = handle_safe(json.loads(line))
            except ValueError as e:  # json.JSONDecodeError
                resp = {"error": f"{type(e).__name__}: {e}"}
            print(json.dumps(resp), flush=True)
        return 0

    if args.user is not None:
        req = {"user": args.user, "k": args.top_k}
    elif args.rows is not None and args.cols is not None:
        req = {"rows": _parse_ids(args.rows, "--rows"),
               "cols": _parse_ids(args.cols, "--cols")}
        if args.std:
            req["std"] = True
    else:
        print("one-shot mode needs --rows AND --cols (or --user, or --jsonl)",
              file=sys.stderr)
        return 2
    resp = handle_safe(req)
    if "error" in resp:
        print(json.dumps(resp), file=sys.stderr)
        return 1
    print(json.dumps(resp))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
