"""Batched serving driver: prefill a prompt batch, then decode N tokens.

    python -m repro.launch.serve --arch gemma-2b --reduced --batch 4 \
        --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model
from repro.models.module import DECODE_RULES, SERVE_RULES
from repro.training.serve import make_decode_step, make_prefill_step
from repro.utils import logger


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step")
    model = build_model(cfg)
    mesh = make_host_mesh(model=args.model_parallel)
    key = jax.random.key(args.seed)
    params = model.init(key)

    max_len = args.prompt_len + args.gen
    cache = model.init_cache(args.batch, max_len)
    prefill = jax.jit(make_prefill_step(model, SERVE_RULES, mesh), donate_argnums=(2,))
    decode = jax.jit(make_decode_step(model, DECODE_RULES, mesh, args.temperature),
                     donate_argnums=(2,))

    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    t0 = time.time()
    logits, cache = prefill(params, prompt, cache)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)

    out = [tok]
    t0 = time.time()
    for t in range(args.gen - 1):
        tok, cache = decode(params, tok, cache,
                            jnp.asarray(args.prompt_len + t, jnp.int32),
                            jax.random.fold_in(key, t))
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out, axis=1)
    logger.info("prefill: %d tokens in %.3fs (%.0f tok/s)",
                args.batch * args.prompt_len, t_prefill,
                args.batch * args.prompt_len / max(t_prefill, 1e-9))
    logger.info("decode: %d steps in %.3fs (%.1f tok/s/seq, %.1f total tok/s)",
                args.gen - 1, t_decode, (args.gen - 1) / max(t_decode, 1e-9),
                args.batch * (args.gen - 1) / max(t_decode, 1e-9))
    logger.info("sample generations (token ids): %s", gen[:2, :12].tolist())
    assert gen.shape == (args.batch, args.gen)
    assert bool(jnp.all((gen >= 0) & (gen < cfg.padded_vocab)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
