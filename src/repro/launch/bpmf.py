"""BPMF engine CLI: backend / dataset / schedule as flags, not imports.

    PYTHONPATH=src python -m repro.launch.bpmf \
        --backend ring --dataset synthetic --sweeps 50 \
        --devices 8 --checkpoint-dir /tmp/bpmf-ckpt

Prints per-sweep sample and posterior-mean RMSE. ``--resume`` continues
from the latest checkpoint in ``--checkpoint-dir`` with randomness
identical to an uninterrupted run. ``--devices N`` forces N host devices
(CPU) so the ring/allgather backends exercise a real multi-device mesh —
it must be applied before jax initializes, which is why this module parses
arguments before importing anything heavy.

Multi-process: ``--coordinator host:port --num-processes N --process-id i``
(or the ``REPRO_*`` environment set by ``scripts/launch_multiproc.py``)
joins this process into one jax job whose ring mesh spans every process's
devices; ``--devices`` then means devices *per process*. Only process 0
prints and exports — peers run the same collective program silently.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from repro.launch.hostdevices import init_multiprocess


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.launch.bpmf",
        description="Run BPMF Gibbs sampling through the repro.bpmf engine facade.",
    )
    p.add_argument("--backend", default="sequential",
                   help="sequential | ring | ring_async | allgather | "
                        "posterior_merge (registry name)")
    p.add_argument("--dataset", default="synthetic",
                   help="synthetic | movielens | chembl (registry name)")
    p.add_argument("--dataset-path", default=None, help="file for movielens/chembl loaders")
    p.add_argument("--users", type=int, default=400, help="synthetic: number of users")
    p.add_argument("--movies", type=int, default=300, help="synthetic: number of movies")
    p.add_argument("--nnz", type=int, default=12_000, help="synthetic: number of ratings")
    p.add_argument("--K", type=int, default=16, help="latent rank")
    p.add_argument("--alpha", type=float, default=2.0, help="rating noise precision")
    p.add_argument("--sweeps", type=int, default=50)
    p.add_argument("--sweeps-per-block", type=int, default=8,
                   help="Gibbs sweeps per jitted device block (one host sync "
                        "per block; 1 = per-sweep dispatch, same samples)")
    p.add_argument("--pipeline-blocks", type=int, default=1,
                   help="block dispatch queue depth: launch the next device "
                        "block before fetching the previous block's metrics "
                        "(1 = synchronous; same samples at every depth)")
    p.add_argument("--donate-blocks", default="auto",
                   choices=["auto", "on", "off"],
                   help="donate the block carry buffers to XLA so blocks "
                        "reuse factor/accumulator memory (off = fallback "
                        "path, fresh outputs every block)")
    p.add_argument("--sync-checkpoint-writes", action="store_true",
                   help="commit checkpoints synchronously instead of on the "
                        "background writer thread")
    p.add_argument("--burn-in", type=int, default=8)
    p.add_argument("--seed", type=int, default=0, help="split + sampler seed")
    p.add_argument("--num-shards", type=int, default=0,
                   help="distributed shard count (0 = all visible devices)")
    p.add_argument("--pipeline-depth", type=int, default=1,
                   help="ring_async: ring rotations kept in flight (d >= 1)")
    p.add_argument("--num-partitions", type=int, default=0,
                   help="posterior_merge: independent partition chains "
                        "(0 = one per visible device)")
    p.add_argument("--merge-method", default="precision",
                   choices=["precision", "pool"],
                   help="posterior_merge: subset-posterior combination "
                        "(precision-weighted Gaussian product or uniform "
                        "pooling)")
    p.add_argument("--devices", type=int, default=0,
                   help="force N host (CPU) devices before jax init "
                        "(per process in a multi-process job)")
    p.add_argument("--coordinator", default=None,
                   help="host:port of process 0 — joins a multi-process jax "
                        "job (env fallback: REPRO_COORDINATOR)")
    p.add_argument("--num-processes", type=int, default=None,
                   help="process count of the multi-process job "
                        "(env fallback: REPRO_NUM_PROCESSES)")
    p.add_argument("--process-id", type=int, default=None,
                   help="this process's rank in [0, num-processes) "
                        "(env fallback: REPRO_PROCESS_ID)")
    p.add_argument("--inject-failure", type=int, default=None, metavar="SWEEP",
                   help="testing: raise a simulated NodeFailure on process 0 "
                        "after SWEEP completes (skipped under --resume so an "
                        "elastic restart does not re-fire it)")
    p.add_argument("--gram-impl", default="auto",
                   choices=["auto", "pallas_fused", "pallas", "xla"],
                   help="Gram hot-path dispatch: auto (autotune cache + "
                        "heuristic), pallas_fused, pallas, or xla")
    p.add_argument("--use-pallas", action="store_true",
                   help="deprecated alias for --gram-impl pallas (warns once)")
    p.add_argument("--export-artifact", default=None,
                   help="after the run, write the posterior serving artifact "
                        "here (consumed by python -m repro.launch.serve)")
    p.add_argument("--checkpoint-dir", default=None)
    p.add_argument("--checkpoint-every", type=int, default=0,
                   help="sweeps between auto-saves (0 = none)")
    p.add_argument("--resume", action="store_true",
                   help="continue from the latest checkpoint in --checkpoint-dir")
    p.add_argument("--log-every", type=int, default=1, help="print every Nth sweep")
    return p


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)

    # joins the multi-process job when configured (flags or REPRO_* env);
    # otherwise just forces the host device count. Either way XLA_FLAGS is
    # settled before the heavy imports below.
    init_multiprocess(
        args.coordinator, args.num_processes, args.process_id,
        local_devices=args.devices,
    )

    import jax

    from repro.bpmf import BPMFConfig, BPMFEngine, load_dataset
    from repro.runtime.elastic import FailureInjector, StepTimer

    main_proc = jax.process_index() == 0
    say = print if main_proc else (lambda *a, **kw: None)

    dataset_kw = {}
    if args.dataset == "synthetic":
        dataset_kw = dict(num_users=args.users, num_movies=args.movies, nnz=args.nnz)
    elif args.dataset_path:
        dataset_kw = dict(path=args.dataset_path)
    coo = load_dataset(args.dataset, **dataset_kw)

    # pass both through: BackendConfig warns on the deprecated flag alone
    # and raises if it conflicts with an explicit --gram-impl
    gram_kw = {"gram_impl": args.gram_impl}
    if args.use_pallas:
        gram_kw["use_pallas"] = True
    cfg = BPMFConfig().replace(
        name=args.backend,
        num_shards=args.num_shards,
        pipeline_depth=args.pipeline_depth,
        num_partitions=args.num_partitions,
        merge_method=args.merge_method,
        **gram_kw,
        K=args.K,
        alpha=args.alpha,
        num_sweeps=args.sweeps,
        sweeps_per_block=args.sweeps_per_block,
        pipeline_blocks=args.pipeline_blocks,
        donate_blocks=args.donate_blocks,
        async_checkpoint_writes=not args.sync_checkpoint_writes,
        burn_in=args.burn_in,
        seed=args.seed,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
    )
    engine = BPMFEngine(cfg)
    engine.prepare(coo)
    resumed_at = 0
    if args.resume:
        resumed_at = engine.restore()
        say(f"resumed from checkpoint at sweep {resumed_at}")

    # elastic-runtime hooks: the straggler watchdog times every sweep, and
    # the injector simulates a preemption so the launcher's restart policy
    # can be exercised end to end (never re-fires on a resumed run)
    timer = StepTimer()
    injector = None
    if args.inject_failure is not None and main_proc and not args.resume:
        injector = FailureInjector({args.inject_failure: 1})

    say(
        f"backend={args.backend} devices={len(jax.devices())} "
        f"processes={jax.process_count()} "
        f"dataset={args.dataset} R: {coo.num_users} x {coo.num_movies}, "
        f"{coo.nnz} ratings; K={cfg.model.K} sweeps={cfg.run.num_sweeps}"
    )
    t0 = time.time()
    t_prev = t0
    for m in engine.sample():
        sweep = int(m.sweep)
        t_now = time.time()
        timer.record(sweep, t_now - t_prev)
        t_prev = t_now
        if args.log_every and (sweep % args.log_every == 0 or sweep == cfg.run.num_sweeps):
            say(
                f"  sweep {sweep:4d}  rmse(sample)={m.rmse_sample:.4f}  "
                f"rmse(avg)={m.rmse_avg:.4f}"
            )
        if injector is not None:
            try:
                injector.check(sweep)
            except Exception as e:
                # die like a preempted pod: hard exit, no jax.distributed
                # shutdown handshake, no atexit drains — only committed
                # checkpoints survive, which is exactly what the launcher's
                # restart policy resumes from
                print(f"injected failure at sweep {sweep}: {e}", flush=True)
                os._exit(1)
    dt = time.time() - t0
    swept = engine.num_sweeps_done - resumed_at  # only what this process ran
    updates = (coo.num_users + coo.num_movies) * swept
    say(
        f"final rmse(avg)={engine.rmse:.4f} after {engine.num_sweeps_done} sweeps "
        f"({swept} this run) in {dt:.2f}s ({updates / max(dt, 1e-9):,.0f} item updates/s)"
    )
    if args.export_artifact:
        # collective in a multi-process job (peers hit the export barrier);
        # only process 0 writes and reports
        path = engine.export(args.export_artifact)
        say(f"exported serving artifact to {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
