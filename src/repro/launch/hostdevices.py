"""Force a host (CPU) device count before jax initializes.

jax locks the device count at first backend init, so every CLI that offers
``--devices N`` must rewrite ``XLA_FLAGS`` *before* any jax import — which
is why this helper imports nothing heavy and why the CLIs parse arguments
first. Shared by ``repro.launch.bpmf`` and ``repro.launch.serve``
(tests/conftest.py keeps its own copy because it edits a subprocess env
dict, not this process).
"""
from __future__ import annotations

import os
import re


def force_host_device_count(n: int) -> None:
    """Rewrite ``XLA_FLAGS`` so jax sees ``n`` host devices.

    Strips any inherited ``--xla_force_host_platform_device_count`` flag so
    the requested count always wins. Must run before jax initializes; a
    no-op for ``n <= 0``.

    Args:
        n: Host device count to force.
    """
    if n <= 0:
        return
    flags = re.sub(
        r"--xla_force_host_platform_device_count=\d+",
        "",
        os.environ.get("XLA_FLAGS", ""),
    ).strip()
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}".strip()
    )
