"""Force a host (CPU) device count / join a multi-process job before jax init.

jax locks the device count at first backend init, so every CLI that offers
``--devices N`` must rewrite ``XLA_FLAGS`` *before* any jax import — which
is why this helper imports nothing heavy at module scope and why the CLIs
parse arguments first. Shared by ``repro.launch.bpmf`` and
``repro.launch.serve`` (tests/conftest.py keeps its own copy because it
edits a subprocess env dict, not this process).

Multi-process path (DESIGN.md §14): :func:`init_multiprocess` wires this
process into a ``jax.distributed`` job — coordinator address plus process
count/id from CLI flags or the ``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCESSES``
/ ``REPRO_PROCESS_ID`` environment (the env route is what
``scripts/launch_multiproc.py`` uses). Call order matters: the host device
count must be forced first, then the distributed service initialized, and
only then may any jax backend spin up.
"""
from __future__ import annotations

import os
import re
import sys


def multiprocess_active() -> bool:
    """True once ``jax.distributed.initialize`` has run in this process."""
    if "jax" not in sys.modules:
        return False  # jax never imported -> distributed cannot be active
    try:
        from jax._src import distributed

        return distributed.global_state.coordinator_address is not None
    except Exception:  # pragma: no cover - internal layout moved
        return False


def force_host_device_count(n: int) -> None:
    """Rewrite ``XLA_FLAGS`` so jax sees ``n`` host devices.

    Strips any inherited ``--xla_force_host_platform_device_count`` flag so
    the requested count always wins. Must run before jax initializes; a
    no-op for ``n <= 0``. Refused outright once ``jax.distributed`` is
    active: the global device list is already agreed across processes at
    that point, and a silent per-process rewrite would fail far away from
    the cause (mismatched meshes mid-collective).

    Args:
        n: Host device count to force.
    """
    if n <= 0:
        return
    if multiprocess_active():
        raise RuntimeError(
            "cannot force the host device count after jax.distributed is "
            "initialized — pass the per-process device count to "
            "init_multiprocess(local_devices=...) (CLI: put --devices before "
            "the coordinator flags are acted on, which the repro CLIs do)"
        )
    flags = re.sub(
        r"--xla_force_host_platform_device_count=\d+",
        "",
        os.environ.get("XLA_FLAGS", ""),
    ).strip()
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}".strip()
    )


def init_multiprocess(
    coordinator: str | None = None,
    num_processes: int | None = None,
    process_id: int | None = None,
    local_devices: int = 0,
) -> bool:
    """Join a multi-process jax job if one is configured; else no-op.

    Flag values win over the ``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCESSES``
    / ``REPRO_PROCESS_ID`` environment. Returns True when the distributed
    service was initialized (after which ``jax.devices()`` is the global,
    process-major device list), False for a plain single-process run.

    ``local_devices`` forces the per-process host (CPU) device count and is
    applied *before* the backend initializes — the only ordering jax
    accepts. CPU cross-process collectives are routed through gloo, which
    must also be configured pre-backend.
    """
    coordinator = coordinator or os.environ.get("REPRO_COORDINATOR") or None
    if num_processes is None and os.environ.get("REPRO_NUM_PROCESSES"):
        num_processes = int(os.environ["REPRO_NUM_PROCESSES"])
    if process_id is None and os.environ.get("REPRO_PROCESS_ID"):
        process_id = int(os.environ["REPRO_PROCESS_ID"])

    if coordinator is None:
        if num_processes not in (None, 1) or process_id not in (None, 0):
            raise ValueError(
                "got --num-processes/--process-id without a --coordinator "
                "address (or REPRO_COORDINATOR)"
            )
        force_host_device_count(local_devices)
        return False
    if num_processes is None or process_id is None:
        raise ValueError(
            "multi-process init needs all of coordinator, num_processes and "
            f"process_id (got {coordinator=}, {num_processes=}, {process_id=})"
        )

    force_host_device_count(local_devices)
    import jax

    # CPU backend: cross-process collectives need the gloo implementation,
    # selected before the backend exists. No-op for TPU/GPU backends.
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(
        coordinator_address=coordinator,
        num_processes=num_processes,
        process_id=process_id,
    )
    return True
