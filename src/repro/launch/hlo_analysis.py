"""Static cost analysis over compiled HLO text, with correct loop handling.

XLA's ``compiled.cost_analysis()`` visits every computation once — a
``while`` body's cost is NOT multiplied by its trip count, so any scanned
program (scan-over-layers, flash-attention KV scans, the BPMF ring schedule)
is undercounted by orders of magnitude. This module re-derives
flops / HBM bytes / collective bytes from ``compiled.as_text()``:

  * per-computation symbol tables give every operand's shape;
  * ``while`` ops multiply (body + condition) costs by the trip count
    recovered from the loop-condition constant (jax scans count 0..N by 1,
    so the compare constant IS the trip count);
  * ``fusion``/``call`` ops descend into their called computation for flops,
    while HBM bytes are charged at fusion boundaries only (operands read +
    results written — ops inside a fusion don't touch HBM);
  * collectives record ring-algorithm wire bytes, also loop-multiplied.

Flops counted: dot / convolution (2*K multiply-adds), plus LAPACK-style
custom-calls (cholesky K^3/3, triangular-solve K^2*nrhs). Elementwise flops
are ignored (dot-dominated programs; consistent with the MFU convention).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\](?:\{[^}]*\})?")
# type: either a tuple "(...)" (array types, /*index=N*/ comments — never
# nested parens) or one array type "dtype[dims]{layout}"
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[\w.\-]+)\s*=\s*(?P<type>\([^()]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>[\w\-]+)\((?P<operands>[^()]*)\)(?P<attrs>.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s+\((?P<params>.*)\)\s*->")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))")


def _parse_shape(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    """All (dtype, dims) buffers in a (possibly tuple) type string."""
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _numel(dims: tuple[int, ...]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _bytes_of(type_str: str) -> int:
    return sum(_numel(d) * _DTYPE_BYTES[dt] for dt, d in _parse_shape(type_str))


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    type_str: str
    operands: list[str]
    attrs: str
    raw_operands: str = ""


@dataclasses.dataclass
class Computation:
    name: str
    params: dict[str, str]  # param name -> type str
    ops: list[Op]
    types: dict[str, str]  # every %name -> type str (params + ops)
    root: str = ""  # name of the ROOT op


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_wire_bytes: float = 0.0
    coll_by_op: dict = dataclasses.field(default_factory=dict)
    flops_by_site: dict = dataclasses.field(default_factory=dict)  # op_name -> flops
    coll_by_site: dict = dataclasses.field(default_factory=dict)  # op_name -> wire bytes
    bytes_by_site: dict = dataclasses.field(default_factory=dict)  # op_name -> hbm bytes

    def __iadd__(self, o: "Cost") -> "Cost":
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_wire_bytes += o.coll_wire_bytes
        for k, v in o.coll_by_op.items():
            d = self.coll_by_op.setdefault(k, {"count": 0, "wire_bytes": 0.0})
            d["count"] += v["count"]
            d["wire_bytes"] += v["wire_bytes"]
        for k, v in o.flops_by_site.items():
            self.flops_by_site[k] = self.flops_by_site.get(k, 0.0) + v
        for k, v in o.coll_by_site.items():
            self.coll_by_site[k] = self.coll_by_site.get(k, 0.0) + v
        for k, v in o.bytes_by_site.items():
            self.bytes_by_site[k] = self.bytes_by_site.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(
            self.flops * f,
            self.bytes * f,
            self.coll_wire_bytes * f,
            {k: {"count": v["count"] * f, "wire_bytes": v["wire_bytes"] * f}
             for k, v in self.coll_by_op.items()},
            {k: v * f for k, v in self.flops_by_site.items()},
            {k: v * f for k, v in self.coll_by_site.items()},
            {k: v * f for k, v in self.bytes_by_site.items()},
        )


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line or line.lstrip().startswith("//"):
            continue
        if not line.startswith(" ") and ("->" in line) and line.endswith("{"):
            m = _COMP_RE.match(line.strip())
            if m:
                params = {k: v for k, v in _PARAM_RE.findall(m.group("params"))}
                cur = Computation(m.group("name"), params, [], dict(params))
                comps[cur.name] = cur
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        operands = [o.strip().lstrip("%") for o in m.group("operands").split(",") if o.strip().startswith("%")]
        op = Op(m.group("name"), m.group("op"), m.group("type"), operands,
                m.group("attrs"), m.group("operands"))
        cur.ops.append(op)
        cur.types[op.name] = op.type_str
        if line.lstrip().startswith("ROOT"):
            cur.root = op.name
    return comps


_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LEGACY_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
_CUSTOM_TARGET_RE = re.compile(r'custom_call_target="([^"]+)"')

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
    "all-gather-start", "all-reduce-start", "collective-permute-start",
}


class HloCostModel:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self._memo: dict[str, Cost] = {}
        self.entry = self._find_entry(text)

    def _find_entry(self, text: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        if m and m.group(1) in self.comps:
            return m.group(1)
        # fallback: computation that no one calls
        called = set()
        for c in self.comps.values():
            for op in c.ops:
                for rx in (_CALLS_RE, _COND_RE, _BODY_RE):
                    mm = rx.search(op.attrs)
                    if mm:
                        called.add(mm.group(1))
        for name in self.comps:
            if name not in called:
                return name
        return next(iter(self.comps))

    # ------------------------------------------------------------------
    def trip_count(self, cond_name: str) -> int:
        """jax scans compare an s32 induction var against a constant."""
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        consts = []
        for op in comp.ops:
            if op.kind == "constant" and op.type_str.replace(" ", "").startswith("s32[]"):
                mv = re.match(r"\s*(\d+)\s*$", op.raw_operands)
                if mv:
                    consts.append(int(mv.group(1)))
        return max(consts) if consts else 1

    def has_while(self, name: str, _seen=None) -> bool:
        """Does this computation (through fusion/call chains) contain a while?"""
        _seen = _seen or set()
        if name in _seen:
            return False
        _seen.add(name)
        comp = self.comps.get(name)
        if comp is None:
            return False
        for op in comp.ops:
            if op.kind == "while":
                return True
            if op.kind in ("fusion", "call", "conditional", "async-start"):
                m = _CALLS_RE.search(op.attrs)
                if m and self.has_while(m.group(1), _seen):
                    return True
        return False

    # ------------------------------------------------------------------
    def _op_flops(self, comp: Computation, op: Op) -> float:
        if op.kind in ("dot", "convolution"):
            out_elems = sum(_numel(d) for _, d in _parse_shape(op.type_str))
            if not op.operands:
                return 0.0
            lhs_type = comp.types.get(op.operands[0], "")
            lhs = _parse_shape(lhs_type)
            if not lhs:
                return 0.0
            lhs_dims = lhs[0][1]
            if op.kind == "dot":
                m = _CONTRACT_RE.search(op.attrs)
                contract = 1
                if m and m.group(1):
                    for i in m.group(1).split(","):
                        idx = int(i)
                        if idx < len(lhs_dims):
                            contract *= lhs_dims[idx]
                return 2.0 * out_elems * contract
            # convolution: 2 * out_elems * (kernel window * in_channels)
            rhs = _parse_shape(comp.types.get(op.operands[1], ""))
            kernel = _numel(rhs[0][1]) if rhs else 1
            out_ch = 1
            for _, d in _parse_shape(op.type_str):
                out_ch = d[-1] if d else 1
            return 2.0 * out_elems * max(kernel // max(out_ch, 1), 1)
        if op.kind == "custom-call":
            m = _CUSTOM_TARGET_RE.search(op.attrs)
            target = m.group(1) if m else ""
            shapes = _parse_shape(op.type_str)
            if "potrf" in target or "cholesky" in target.lower():
                dims = shapes[0][1] if shapes else ()
                if len(dims) >= 2:
                    k = dims[-1]
                    batch = _numel(dims[:-2])
                    return batch * k**3 / 3.0
            if "trsm" in target or "triangular" in target.lower():
                dims = shapes[0][1] if shapes else ()
                if len(dims) >= 2:
                    k = dims[-2]
                    nrhs = dims[-1]
                    batch = _numel(dims[:-2])
                    return batch * k * k * nrhs
        return 0.0

    def _collective(self, op: Op) -> Optional[tuple[str, float]]:
        kind = op.kind.replace("-start", "")
        if kind not in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute"):
            return None
        nbytes = _bytes_of(op.type_str)
        # XLA:CPU promotes bf16 reductions to f32 (`to_apply=%add..._promoted`)
        # — on TPU the same all-reduce runs bf16; halve the wire estimate.
        if "promoted" in op.attrs and "f32" in op.type_str:
            nbytes //= 2
        m = _GROUPS_RE.search(op.attrs)
        if m:
            S = int(m.group(2))
        else:
            m = _GROUPS_LEGACY_RE.search(op.attrs)
            S = len(m.group(1).split(",")) if m else 1
        if kind == "collective-permute":
            wire = float(nbytes)
        elif S <= 1:
            wire = 0.0
        elif kind == "all-reduce":
            wire = 2.0 * (S - 1) / S * nbytes
        elif kind == "all-gather":
            wire = (S - 1) / S * nbytes
        elif kind == "reduce-scatter":
            wire = float((S - 1) * nbytes)
        else:  # all-to-all
            wire = (S - 1) / S * nbytes
        return kind, wire

    # ------------------------------------------------------------------
    def comp_cost(self, name: str, mode: str = "normal") -> Cost:
        """Cost of one computation.

        mode="fusion": body of a fusion op — no HBM traffic of its own (the
        fusion boundary charge covers reads/writes); flops + collectives only.
        mode="loop": body of an INNERMOST while loop — modeled as one fused
        kernel (what the TPU Pallas lowering does): HBM traffic = sliced xs
        reads + carry-slice writes + dot tensors too big for VMEM (>32 MB);
        everything else stays on-chip.
        """
        in_fusion = mode == "fusion"
        in_loop = mode == "loop"
        memo_key = (name, mode)
        if memo_key in self._memo:
            return self._memo[memo_key]
        comp = self.comps.get(name)
        total = Cost()
        if comp is None:
            return total
        self._memo[memo_key] = total  # break cycles defensively

        def charge(op, amount):
            total.bytes += amount
            site = "B:" + _site_of(op)
            total.bytes_by_site[site] = total.bytes_by_site.get(site, 0.0) + amount

        for op in comp.ops:
            f = self._op_flops(comp, op)
            total.flops += f
            if f > 0:
                site = _site_of(op)
                total.flops_by_site[site] = total.flops_by_site.get(site, 0.0) + f
            coll = self._collective(op)
            if coll is not None:
                kind, wire = coll
                total.coll_wire_bytes += wire
                d = total.coll_by_op.setdefault(kind, {"count": 0, "wire_bytes": 0.0})
                d["count"] += 1
                d["wire_bytes"] += wire
                total.bytes += _bytes_of(op.type_str)
                site = f"{kind}:{_site_of(op)}"
                total.coll_by_site[site] = total.coll_by_site.get(site, 0.0) + wire
            if op.kind in ("fusion", "call", "async-start"):
                m = _CALLS_RE.search(op.attrs)
                if m:
                    sub_mode = "fusion" if op.kind != "call" else mode
                    sub = self.comp_cost(m.group(1), sub_mode)
                    total.flops += sub.flops
                    total.coll_wire_bytes += sub.coll_wire_bytes
                    for k, v in sub.coll_by_op.items():
                        d = total.coll_by_op.setdefault(k, {"count": 0, "wire_bytes": 0.0})
                        d["count"] += v["count"]
                        d["wire_bytes"] += v["wire_bytes"]
                    for k, v in sub.coll_by_site.items():
                        total.coll_by_site[k] = total.coll_by_site.get(k, 0.0) + v
                # fusion HBM traffic: operands read + result written. An
                # operand vastly larger than the result is almost surely
                # dynamic-sliced inside the fusion (scan reading one layer of
                # a stacked [L, ...] weight) — cap its charge, else every
                # loop iteration is billed the whole stack.
                rb = _bytes_of(op.type_str)
                # in-place accumulation fusion (root = dynamic-update-slice,
                # e.g. scan writing one layer slice of a stacked carry):
                # traffic is ~2x the update region, not the whole buffer
                root_kind, root_aux_bytes = None, 0
                if m:
                    sub_comp = self.comps.get(m.group(1))
                    if sub_comp is not None and sub_comp.root:
                        root_op = next((o for o in sub_comp.ops if o.name == sub_comp.root), None)
                        if root_op is not None:
                            root_kind = root_op.kind
                            if root_kind == "dynamic-update-slice" and len(root_op.operands) > 1:
                                root_aux_bytes = _bytes_of(sub_comp.types.get(root_op.operands[1], ""))
                if not in_fusion and not in_loop:
                    if root_kind == "dynamic-update-slice":
                        charge(op, 2 * root_aux_bytes)
                    else:
                        cap = max(4 * rb, 1 << 20)
                        charge(op, rb + sum(
                            min(_bytes_of(comp.types.get(o, "")), cap) for o in op.operands
                        ))
                elif in_loop:
                    # fused-kernel model: only slice reads / update writes
                    if root_kind == "dynamic-update-slice":
                        charge(op, 2 * root_aux_bytes)
                    elif root_kind in ("dynamic-slice", "slice", "gather"):
                        charge(op, rb)
            elif op.kind == "while":
                body = _BODY_RE.search(op.attrs)
                cond = _COND_RE.search(op.attrs)
                trips = self.trip_count(cond.group(1)) if cond else 1
                inner = Cost()
                if body:
                    body_name = body.group(1)
                    if mode == "fusion":
                        body_mode = "fusion"
                    elif not self.has_while(body_name):
                        body_mode = "loop"  # innermost: fused-kernel byte model
                    else:
                        body_mode = mode
                    inner += self.comp_cost(body_name, body_mode)
                if cond:
                    inner += self.comp_cost(cond.group(1), "fusion")
                total += inner.scaled(float(max(trips, 1)))
            elif op.kind == "conditional":
                m = _BRANCHES_RE.search(op.attrs)
                if m:
                    branches = [b.strip().lstrip("%") for b in m.group(1).split(",")]
                    costs = [self.comp_cost(b, mode) for b in branches if b in self.comps]
                    if costs:
                        total += max(costs, key=lambda c: c.flops)
            elif op.kind in ("dynamic-slice", "gather", "slice"):
                # reads ~result-sized region of the operand, writes result
                if not in_fusion:
                    charge(op, (1 if in_loop else 2) * _bytes_of(op.type_str))
            elif op.kind in ("dynamic-update-slice", "scatter"):
                # in-place update: reads + writes an ~update-sized region
                if not in_fusion:
                    upd = _bytes_of(comp.types.get(op.operands[1], "")) if len(op.operands) > 1 else 0
                    charge(op, 2 * upd)
            elif op.kind == "dot" and in_loop:
                # inside a fused loop only VMEM-exceeding tensors spill to HBM
                big = _bytes_of(op.type_str) if _bytes_of(op.type_str) > (32 << 20) else 0
                big += sum(b for b in (_bytes_of(comp.types.get(o, "")) for o in op.operands)
                           if b > (32 << 20))
                if big:
                    charge(op, big)
            elif op.kind in ("dot", "convolution", "custom-call", "reduce", "sort",
                             "broadcast", "transpose", "reshape", "copy", "concatenate",
                             "pad", "iota", "reduce-window", "select-and-scatter"):
                # top-level (unfused) materializing op: charge HBM traffic
                if not in_fusion and not in_loop:
                    charge(op, _bytes_of(op.type_str)
                           + sum(_bytes_of(comp.types.get(o, "")) for o in op.operands))
        return total

    def entry_cost(self) -> Cost:
        return self.comp_cost(self.entry, "normal")


_SITE_RE = re.compile(r'op_name="([^"]*)"')


def _site_of(op: Op) -> str:
    m = _SITE_RE.search(op.attrs)
    if not m:
        return op.kind
    name = m.group(1)
    # strip jit wrappers / uniquifying suffixes, keep the semantic tail
    parts = [p for p in name.split("/") if not p.startswith("jit(")]
    return "/".join(parts[-3:]) if parts else name


def analyze(hlo_text: str, top_sites: int = 0) -> dict:
    model = HloCostModel(hlo_text)
    c = model.entry_cost()
    out = {
        "flops": c.flops,
        "bytes": c.bytes,
        "collective_wire_bytes": c.coll_wire_bytes,
        "collectives_by_op": c.coll_by_op,
    }
    if top_sites:
        out["top_flop_sites"] = sorted(c.flops_by_site.items(), key=lambda kv: -kv[1])[:top_sites]
        out["top_coll_sites"] = sorted(c.coll_by_site.items(), key=lambda kv: -kv[1])[:top_sites]
        out["top_byte_sites"] = sorted(c.bytes_by_site.items(), key=lambda kv: -kv[1])[:top_sites]
    return out
