"""End-to-end LM training driver.

Runs any assigned architecture (reduced or full config) on whatever devices
exist, with checkpointing, restore and the elastic runtime. On this CPU
container it drives the reduced configs (examples/lm_train.py); on real
hardware the same entry point takes the production mesh.

    python -m repro.launch.train --arch yi-6b --reduced --steps 100
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model
from repro.models.module import TRAIN_RULES
from repro.training.optimizer import AdamW, warmup_cosine
from repro.training.train import (
    batch_specs,
    init_train_state,
    jit_train_step,
    state_specs,
)
from repro.utils import logger


def synthetic_lm_batch(key: jax.Array, cfg, batch: int, seq: int) -> dict:
    """Markov-ish synthetic tokens (learnable structure, not pure noise)."""
    k1, k2, k3 = jax.random.split(key, 3)
    base = jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size)
    # induce bigram structure: half the positions copy the previous token + 1
    copy = jax.random.bernoulli(k2, 0.5, (batch, seq))
    shifted = jnp.roll(base, 1, axis=1)
    tokens = jnp.where(copy, (shifted + 1) % cfg.vocab_size, base)
    if cfg.input_mode == "frames":
        inputs = jax.random.normal(k3, (batch, seq, cfg.frame_dim), jnp.bfloat16)
        labels = tokens
        mask = jax.random.bernoulli(k2, cfg.mask_prob, (batch, seq)).astype(jnp.float32)
        mask = jnp.maximum(mask, 1e-6)  # avoid all-zero masks on tiny batches
    else:
        inputs = tokens
        labels = jnp.roll(tokens, -1, axis=1)
        mask = jnp.ones((batch, seq), jnp.float32).at[:, -1].set(0.0)
    return {"inputs": inputs, "labels": labels, "mask": mask}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="smoke-size config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    mesh = make_host_mesh(model=args.model_parallel)
    opt = AdamW(learning_rate=warmup_cosine(args.lr, args.steps // 10 + 1, args.steps))

    step_fn = jit_train_step(
        model, opt, mesh, TRAIN_RULES, args.microbatches, args.batch, args.seq
    )
    key = jax.random.key(args.seed)
    state = init_train_state(key, model, opt)
    logger.info("arch=%s params=%.2fM devices=%d", cfg.name, model.num_params() / 1e6, len(jax.devices()))

    manager = None
    start = 0
    if args.checkpoint_dir:
        manager = CheckpointManager(args.checkpoint_dir)
        latest = manager.latest()
        if latest is not None:
            sspec = state_specs(model, opt, TRAIN_RULES, mesh)
            shardings = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s), sspec,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
            state = manager.restore(jax.eval_shape(lambda: state), shardings=shardings)
            start = latest
            logger.info("restored step %d from %s", start, args.checkpoint_dir)

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = synthetic_lm_batch(jax.random.fold_in(key, step), cfg, args.batch, args.seq)
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % args.log_every == 0:
            dt = (time.time() - t0) / args.log_every
            tok_s = args.batch * args.seq / dt
            logger.info("step %4d loss=%.4f acc=%.3f gnorm=%.2f %.0f tok/s",
                        step + 1, losses[-1], float(metrics["accuracy"]),
                        float(metrics["grad_norm"]), tok_s)
            t0 = time.time()
        if manager and (step + 1) % args.checkpoint_every == 0:
            manager.save(step + 1, state)
    if manager:
        manager.save(args.steps, state)
        manager.close()

    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    logger.info("loss %.4f -> %.4f (%s)", first, last, "LEARNING" if last < first else "flat")
    return 0 if last < first else 1


if __name__ == "__main__":
    raise SystemExit(main())
