"""Production mesh definitions.

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before its first jax call and only then
builds meshes.

Axes:
  * ``pod``   — across-pod axis (2 pods in the multi-pod dry-run). Only
    data parallelism crosses pods: inter-pod DCI links are an order of
    magnitude slower than intra-pod ICI, so the gradient all-reduce is the
    only collective allowed to traverse them.
  * ``data``  — within-pod data parallelism (batch) + FSDP-style weight
    sharding of the "embed" dimension.
  * ``model`` — tensor parallelism (mlp/heads/vocab) + sequence-sharded KV
    caches at decode.

The BPMF core uses its own 1-D "ring" mesh (core/distributed.py); for
multi-pod BPMF the (pod, data, model) mesh is flattened into that ring —
see launch/dryrun.py::bpmf_ring_from.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / CPU smoke runs)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))


def bpmf_ring_from(mesh: Mesh) -> Mesh:
    """Flatten a production mesh into the 1-D BPMF ring (paper §IV maps MPI
    ranks onto one logical ring; ICI neighbors stay adjacent)."""
    devices = np.asarray(mesh.devices).reshape(-1)
    return Mesh(devices, ("ring",))


def bpmf_ring(num_shards: int = 0) -> Mesh:
    """Process-spanning BPMF ring over the first ``num_shards`` global devices.

    ``jax.devices()`` is global and process-major, so after
    ``hostdevices.init_multiprocess`` this one mesh covers every process's
    devices in coordinator order and the ring sweep blocks compile
    unchanged — the logical mesh, and hence the per-shard SPMD program, is
    identical whether 8 shards live in one process or 4+4 in two.

    ``num_shards == 0`` means all global devices. A multi-process job must
    use all of them: a sub-ring would leave some processes outside the mesh,
    which ``shard_map`` cannot express.
    """
    devices = jax.devices()
    n = num_shards or len(devices)
    if n > len(devices):
        raise ValueError(f"num_shards={n} exceeds {len(devices)} global devices")
    if jax.process_count() > 1 and n != len(devices):
        raise ValueError(
            f"multi-process runs must ring all {len(devices)} global devices "
            f"(got num_shards={n}); adjust --devices per process instead"
        )
    return Mesh(np.asarray(devices[:n]), ("ring",))
