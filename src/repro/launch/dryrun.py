import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import: jax locks the device count on first init.
#
# Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
# on the production meshes and extract the roofline terms from the compiled
# artifact. This is the proof that the distribution config is coherent —
# sharding mismatches, compile-time OOM and unsupported collectives all
# surface here.
#
# Usage:
#   python -m repro.launch.dryrun --arch yi-6b --shape train_4k
#   python -m repro.launch.dryrun --all                  # single-pod 16x16
#   python -m repro.launch.dryrun --all --multi-pod      # 2x16x16
#   python -m repro.launch.dryrun --bpmf                 # the paper's own program
#
# Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json and feed
# benchmarks/roofline.py + EXPERIMENTS.md.

import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config, list_archs
from repro.configs.registry import cell_runnable
from repro.launch.mesh import bpmf_ring_from, make_production_mesh
from repro.models.config import ModelConfig
from repro.models.model import LMModel, build_model
from repro.models.module import DECODE_RULES, SERVE_RULES, TRAIN_RULES, ZERO_RULES, ShardingRules
from repro.training.optimizer import AdamW
from repro.training.lm_serve import make_decode_step, make_prefill_step
from repro.training.train import (
    abstract_batch,
    abstract_train_state,
    batch_specs,
    make_train_step,
    state_specs,
)

# TPU v5e hardware constants (per chip / per link)
V5E = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


# ---------------------------------------------------------------------------
# Optimizer / rules defaults
# ---------------------------------------------------------------------------


def default_optimizer(cfg: ModelConfig, num_params: int) -> AdamW:
    """bf16 moments above 50B params — the HBM fit for nemotron/grok
    (DESIGN.md §6, optimizer.py header)."""
    moment_dtype = jnp.bfloat16 if num_params > 50e9 else jnp.float32
    return AdamW(learning_rate=1e-4, moment_dtype=moment_dtype)


def to_shardings(tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )


# ---------------------------------------------------------------------------
# Collective-bytes extraction from the partitioned HLO
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<rtype>\(?[a-z0-9\[\],\{\} ]+?\)?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LEGACY_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _result_bytes(rtype: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(rtype):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LEGACY_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_stats(hlo_text: str) -> dict:
    """Per-device collective byte counts from the partitioned module.

    ``wire_bytes`` estimates bytes that actually cross ICI per device with
    ring-algorithm costs: all-reduce 2(S-1)/S, all-gather (S-1)/S of the
    gathered result, reduce-scatter (S-1)/S of the scattered input,
    permute/all-to-all (S-1)/S of the payload.
    """
    by_op: dict[str, dict] = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line:
            continue
        op = m.group("op")
        nbytes = _result_bytes(m.group("rtype"))
        S = _group_size(line)
        if S <= 1:
            w = 0.0
        elif op == "all-reduce":
            w = 2.0 * (S - 1) / S * nbytes
        elif op == "all-gather":
            w = (S - 1) / S * nbytes
        elif op == "reduce-scatter":
            w = (S - 1) * nbytes  # result is 1/S of the input
        else:  # all-to-all, collective-permute
            w = (S - 1) / S * nbytes if op == "all-to-all" else float(nbytes)
        d = by_op.setdefault(op, {"count": 0, "result_bytes": 0, "wire_bytes": 0.0})
        d["count"] += 1
        d["result_bytes"] += nbytes
        d["wire_bytes"] += w
        wire += w
    return {"by_op": by_op, "wire_bytes_per_device": wire}


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------


def train_plan(cfg: ModelConfig, mesh, global_batch: int) -> tuple[ShardingRules, int]:
    """(rules, microbatches) for a train cell.

    Small/medium dense + ssm/hybrid/encoder: pure-ZeRO (batch over every
    axis, weights gathered at use) — no per-layer activation all-reduces,
    and the per-layer gather is < ~1.5 GB bf16.

    MoE + >=100B dense (nemotron): the gathered per-layer weights (3-7 GB
    bf16) would dominate the 16 GB budget transiently, so weights stay
    tensor-parallel/resident.

    Microbatches are chosen so each device holds ONE sequence per
    microbatch under the batch sharding the mesh actually resolves (e.g.
    batch=256 on the 512-chip multi-pod mesh falls back to 32-way
    (pod,data) sharding -> 8 rows/device -> 8 microbatches).
    """
    from repro.models.module import resolve_spec

    model = build_model(cfg)
    per_layer_bytes = 2 * (model.num_params() - cfg.padded_vocab * cfg.d_model) / max(cfg.num_layers, 1)
    rules = TRAIN_RULES if (cfg.num_experts or per_layer_bytes > 1.5e9) else ZERO_RULES
    spec = resolve_spec((global_batch,), ("batch",), rules, mesh)
    names = spec[0] if spec else None
    names = (names,) if isinstance(names, str) else (names or ())
    ways = 1
    for n in names:
        ways *= mesh.shape[n]
    mb = max(1, global_batch // max(ways, 1))
    if cfg.num_experts:
        # §Perf H1: fewer microbatches amortize the per-microbatch expert-bank
        # re-gathers (collective -32%); grouped remat bounds the carries.
        mb = max(1, mb // 4)
    return rules, mb


def lower_cell(arch: str, shape_name: str, mesh, loss_chunk: int = 512,
               rules_train: ShardingRules | None = None,
               microbatches: int | None = None,
               rules_serve: ShardingRules = SERVE_RULES):
    """Build + lower one (arch x shape) cell on ``mesh``. Returns (lowered,
    meta) — compile happens in run_cell so failures are attributable."""
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    model = build_model(cfg)
    B, L = spec.global_batch, spec.seq_len
    n_params = model.num_params()

    if spec.kind == "train":
        plan_rules, plan_mb = train_plan(cfg, mesh, B)
        rules_train = rules_train or plan_rules
        mb = microbatches or plan_mb
        opt = default_optimizer(cfg, n_params)
        step = make_train_step(model, opt, rules_train, mesh, microbatches=mb,
                               loss_chunk=loss_chunk)
        state_abs = abstract_train_state(model, opt)
        sspec = to_shardings(state_specs(model, opt, rules_train, mesh), mesh)
        bspec = to_shardings(batch_specs(cfg, rules_train, mesh, B, L), mesh)
        lowered = jax.jit(
            step, in_shardings=(sspec, bspec), out_shardings=(sspec, None),
            donate_argnums=(0,),
        ).lower(state_abs, abstract_batch(cfg, B, L))
        tokens = B * L
        model_flops = 6.0 * model.matmul_params() * tokens

    elif spec.kind == "prefill":
        # sequence-parallel flash prefill (§Perf H2): q-block axis vmapped and
        # sharded over "model" instead of scanned
        cfg = cfg.replace(flash_q_parallel=True)
        model = build_model(cfg)
        params_abs = model.abstract()
        pspec = to_shardings(model.specs(rules_serve, mesh), mesh)
        if cfg.is_encoder:
            # encoder "prefill" = one batched forward over the 32k frames
            fwd = lambda p, x: model.forward(p, x, ctx=_ctx(mesh, rules_serve))[0]
            inp = jax.ShapeDtypeStruct((B, L, cfg.frame_dim), jnp.bfloat16)
            ispec = NamedSharding(mesh, _first_spec(rules_serve, mesh, (B, L, cfg.frame_dim)))
            lowered = jax.jit(fwd, in_shardings=(pspec, ispec)).lower(params_abs, inp)
        else:
            step = make_prefill_step(model, rules_serve, mesh)
            cache_abs = model.abstract_cache(B, L)
            cspec = to_shardings(model.cache_specs(rules_serve, mesh, B, L), mesh)
            inp = _abstract_tokens(cfg, B, L)
            ispec = NamedSharding(mesh, _first_spec(rules_serve, mesh, inp.shape))
            lowered = jax.jit(
                step, in_shardings=(pspec, ispec, cspec),
                out_shardings=(None, cspec), donate_argnums=(2,),
            ).lower(params_abs, inp, cache_abs)
        model_flops = 2.0 * model.matmul_params() * B * L

    elif spec.kind == "decode":
        rules_dec = DECODE_RULES if rules_serve is SERVE_RULES else rules_serve
        params_abs = model.abstract()
        pspec = to_shardings(model.specs(rules_dec, mesh), mesh)
        step = make_decode_step(model, rules_dec, mesh)
        cache_abs = model.abstract_cache(B, L)
        cspec = to_shardings(model.cache_specs(rules_dec, mesh, B, L), mesh)
        tok = _abstract_tokens(cfg, B, 1)
        tspec = NamedSharding(mesh, _first_spec(rules_dec, mesh, tok.shape))
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        key = jax.eval_shape(lambda: jax.random.key(0))
        lowered = jax.jit(
            step, in_shardings=(pspec, tspec, cspec, None, None),
            out_shardings=(tspec, cspec), donate_argnums=(2,),
        ).lower(params_abs, tok, cache_abs, pos, key)
        model_flops = 2.0 * model.matmul_params() * B

    else:
        raise ValueError(spec.kind)

    meta = {
        "arch": arch, "shape": shape_name, "kind": spec.kind,
        "global_batch": B, "seq_len": L,
        "num_params": n_params, "active_params": model.active_params(),
        "model_flops_global": model_flops,
    }
    return lowered, meta


def _ctx(mesh, rules):
    from repro.models.module import ShardingCtx

    return ShardingCtx(mesh=mesh, rules=rules)


def _first_spec(rules, mesh, shape):
    from repro.models.module import resolve_spec

    axes = ("batch", "seq", None)[: len(shape)]
    return resolve_spec(shape, axes, rules, mesh)


def _abstract_tokens(cfg: ModelConfig, B: int, L: int):
    if cfg.input_mode == "tokens":
        return jax.ShapeDtypeStruct((B, L), jnp.int32)
    return jax.ShapeDtypeStruct((B, L, cfg.frame_dim), jnp.bfloat16)


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------


def roofline_terms(compiled, meta: dict, num_devices: int) -> dict:
    from repro.launch.hlo_analysis import analyze

    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    text = compiled.as_text()
    # loop-aware static analysis (XLA's cost_analysis counts while bodies
    # ONCE — wrong for every scanned program; see hlo_analysis.py)
    hlo = analyze(text)
    hlo_flops_dev = float(hlo["flops"])
    hlo_bytes_dev = float(hlo["bytes"])
    coll = {
        "by_op": hlo["collectives_by_op"],
        "wire_bytes_per_device": hlo["collective_wire_bytes"],
    }

    compute_s = hlo_flops_dev / V5E["peak_flops"]
    memory_s = hlo_bytes_dev / V5E["hbm_bw"]
    collective_s = coll["wire_bytes_per_device"] / V5E["ici_bw"]
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    model_flops_dev = meta["model_flops_global"] / num_devices
    mem = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_bytes_est": ma.argument_size_in_bytes + ma.output_size_in_bytes
        + ma.temp_size_in_bytes - ma.alias_size_in_bytes,
    }
    return {
        **terms,
        "dominant": dominant,
        "hlo_flops_per_device": hlo_flops_dev,
        "hlo_bytes_per_device": hlo_bytes_dev,
        "collectives": coll,
        "model_flops_per_device": model_flops_dev,
        "useful_flops_ratio": (model_flops_dev / hlo_flops_dev) if hlo_flops_dev > 0 else None,
        "memory": mem,
        "fits_hbm": mem["peak_bytes_est"] <= 16e9,
        "roofline_fraction": (model_flops_dev / V5E["peak_flops"])
        / max(max(terms.values()), 1e-30),
        "xla_cost_analysis": {  # reference only — undercounts loop bodies
            "flops": float(ca.get("flops", -1.0)),
            "bytes_accessed": float(ca.get("bytes accessed", -1.0)),
        },
    }


# ---------------------------------------------------------------------------
# BPMF dry-run (the paper's own program on the production mesh)
# ---------------------------------------------------------------------------


def abstract_bpmf_data(num_shards: int, num_users: int, num_movies: int, nnz: int,
                       K: int, pads=(32, 128, 512), steps_with_work: int = 8):
    """ShapeDtypeStruct stand-in for DistBPMFData: bucket shapes follow the
    paper's workload model (cost = a + b*nnz) for a ChEMBL-like skew, without
    the O(items x shards) host build. Ring steps beyond ``steps_with_work``
    carry one empty-ish bucket each (most remote shards contribute few
    ratings after the locality reordering — §IV-B)."""
    from repro.core.distributed import DistBPMFData, DistTestSet, RingSide
    from repro.core.types import Bucket

    S = num_shards
    sds = jax.ShapeDtypeStruct

    def side(num_items: int, nnz_side: int) -> RingSide:
        cap = -(-num_items // S)
        per_shard_nnz = nnz_side // S
        steps = []
        for t in range(S):
            buckets = []
            if t < steps_with_work:
                for pad in pads:
                    Bk = max(8, per_shard_nnz // (steps_with_work * pad * len(pads)))
                    Bk = -(-Bk // 8) * 8
                    buckets.append(
                        Bucket(
                            item_ids=sds((S * Bk,), jnp.int32),
                            nbr=sds((S * Bk, pad), jnp.int32),
                            val=sds((S * Bk, pad), jnp.float32),
                            nnz=sds((S * Bk,), jnp.int32),
                        )
                    )
            else:
                buckets.append(
                    Bucket(
                        item_ids=sds((S * 8,), jnp.int32),
                        nbr=sds((S * 8, pads[0]), jnp.int32),
                        val=sds((S * 8, pads[0]), jnp.float32),
                        nnz=sds((S * 8,), jnp.int32),
                    )
                )
            steps.append(tuple(buckets))
        return RingSide(
            steps=tuple(steps), orig_ids=sds((S * cap,), jnp.int32),
            cap=cap, num_items=num_items,
        )

    T = 10000
    return DistBPMFData(
        users=side(num_users, nnz),
        movies=side(num_movies, nnz),
        test=DistTestSet(rows=sds((T,), jnp.int32), cols=sds((T,), jnp.int32),
                         vals=sds((T,), jnp.float32)),
        mean_rating=sds((), jnp.float32),
        num_shards=S,
        min_rating=1.0,
        max_rating=5.0,
    )


def lower_bpmf(mesh, K: int = 32, comm_mode: str = "ring",
               num_users: int = 483_500, num_movies: int = 5_775, nnz: int = 1_023_952):
    """Lower the distributed Gibbs sweep (ChEMBL-20 scale by default) on the
    production mesh flattened to the BPMF ring."""
    from repro.core.distributed import DistState, data_specs, dist_gibbs_sweep
    from repro.core.prediction import PredictionState
    from repro.core.types import BPMFConfig, HyperParams

    ring = bpmf_ring_from(mesh)
    S = ring.devices.size
    cfg = BPMFConfig(K=K, comm_mode=comm_mode, gram_impl="xla")
    data = abstract_bpmf_data(S, num_users, num_movies, nnz, K)
    sds = jax.ShapeDtypeStruct
    cap_u, cap_v = data.users.cap, data.movies.cap
    state = DistState(
        U=sds((S * cap_u, K), jnp.float32),
        V=sds((S * cap_v, K), jnp.float32),
        hyper_U=HyperParams(mu=sds((K,), jnp.float32), Lam=sds((K, K), jnp.float32)),
        hyper_V=HyperParams(mu=sds((K,), jnp.float32), Lam=sds((K, K), jnp.float32)),
        sweep=sds((), jnp.int32),
    )
    T = data.test.rows.shape[0]
    pred = PredictionState(sum_pred=sds((T,), jnp.float32), num_samples=sds((), jnp.int32))
    key = sds((2,), jnp.uint32)

    lowered = jax.jit(
        dist_gibbs_sweep, static_argnames=("cfg", "mesh")
    ).lower(jax.random.key(0), state, pred, data, cfg, ring)
    meta = {
        "arch": "bpmf", "shape": f"chembl_K{K}_{comm_mode}", "kind": "bpmf_sweep",
        "num_users": num_users, "num_movies": num_movies, "nnz": nnz, "K": K,
        # one sweep updates every user+movie: gram (2K^2 flops/rating/side)
        # + per-item Cholesky solve ~ (2/3)K^3 + 4K^2
        "model_flops_global": 2 * (2.0 * K * K * nnz) + (num_users + num_movies)
        * ((2.0 / 3.0) * K**3 + 4.0 * K * K),
    }
    return lowered, meta


# ---------------------------------------------------------------------------
# Runner / CLI
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             loss_chunk: int = 512) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    try:
        if arch == "bpmf":
            lowered, meta = lower_bpmf(mesh, comm_mode=shape_name or "ring")
        else:
            lowered, meta = lower_cell(arch, shape_name, mesh, loss_chunk=loss_chunk)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        result = {
            **meta, "mesh": mesh_name, "num_devices": n_dev, "status": "ok",
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "roofline": roofline_terms(compiled, meta, n_dev),
        }
    except Exception as e:  # noqa: BLE001 — every failure is a recorded result
        result = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name, "num_devices": n_dev,
            "status": "error", "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-2000:],
        }
    os.makedirs(os.path.join(out_dir, mesh_name), exist_ok=True)
    path = os.path.join(out_dir, mesh_name, f"{arch}__{shape_name}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1, default=str)
    return result


def _print_result(r: dict) -> None:
    if r["status"] != "ok":
        print(f"[FAIL] {r['arch']:16s} {r['shape']:12s} {r['mesh']}: {r['error']}")
        return
    rf = r["roofline"]
    print(
        f"[ok] {r['arch']:16s} {r['shape']:12s} {r['mesh']:10s} "
        f"compute={rf['compute_s']:.3e}s memory={rf['memory_s']:.3e}s "
        f"coll={rf['collective_s']:.3e}s dom={rf['dominant']:9s} "
        f"useful={rf['useful_flops_ratio'] if rf['useful_flops_ratio'] is None else round(rf['useful_flops_ratio'], 3)} "
        f"hbm={rf['memory']['peak_bytes_est'] / 1e9:.2f}GB fit={rf['fits_hbm']} "
        f"(compile {r['compile_s']}s)"
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", help="architecture id (or 'bpmf')")
    ap.add_argument("--shape", help="shape id (or comm_mode for --arch bpmf)")
    ap.add_argument("--all", action="store_true", help="run every runnable cell")
    ap.add_argument("--multi-pod", action="store_true", help="2x16x16 mesh (else 16x16)")
    ap.add_argument("--out-dir", default=os.path.normpath(OUT_DIR))
    ap.add_argument("--loss-chunk", type=int, default=512)
    args = ap.parse_args(argv)

    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in list_archs():
            cfg = get_config(arch)
            for shape in SHAPES.values():
                ok, why = cell_runnable(cfg, shape)
                if ok:
                    cells.append((arch, shape.name))
                else:
                    print(f"[skip] {arch:16s} {shape.name:12s} — {why}")
        cells.append(("bpmf", "ring"))
        cells.append(("bpmf", "allgather"))
    elif args.arch:
        cells.append((args.arch, args.shape or ("ring" if args.arch == "bpmf" else "train_4k")))
    else:
        ap.error("--arch or --all required")

    failures = 0
    for arch, shape in cells:
        r = run_cell(arch, shape, args.multi_pod, args.out_dir, args.loss_chunk)
        _print_result(r)
        failures += r["status"] != "ok"
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
