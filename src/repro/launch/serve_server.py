"""Persistent BPMF serving server CLI.

Serves concurrent ``predict``/``top_k`` queries over an exported artifact
with adaptive micro-batching, item-sharded catalog top-k and zero-downtime
artifact hot-swap (DESIGN.md §11)::

    python -m repro.launch.serve_server --artifact /tmp/bpmf-art --port 8642

    # then, from anywhere:
    python -m repro.launch.serve --server 127.0.0.1:8642 --user 7 --top-k 10
    curl -s -XPOST -d '{"rows": [0], "cols": [5]}' 127.0.0.1:8642/query
    curl -s 127.0.0.1:8642/healthz

Re-exporting into the same artifact directory (e.g. ``python -m
repro.launch.bpmf ... --export-artifact <same dir>`` after more sweeps)
hot-swaps the live posterior without dropping a request: the watcher
validates the fresh export, warms its programs, and swaps it in between
micro-batches. ``--port 0`` binds an ephemeral port (printed on stderr).
``--devices N`` forces N host devices before jax initializes (same
contract as ``repro.launch.bpmf``).
"""
from __future__ import annotations

import argparse
import signal
import sys

from repro.launch.hostdevices import force_host_device_count


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.launch.serve_server",
        description="Persistent micro-batching BPMF serving server.",
    )
    p.add_argument("--artifact", required=True,
                   help="artifact directory written by BPMFEngine.export(); "
                        "also the directory watched for hot-swap re-exports")
    p.add_argument("--host", default="127.0.0.1", help="bind address")
    p.add_argument("--port", type=int, default=8642,
                   help="bind port (0 = ephemeral, printed on stderr)")
    p.add_argument("--deadline-ms", type=float, default=2.0,
                   help="micro-batch coalescing deadline (max added latency)")
    p.add_argument("--max-batch", type=int, default=1024,
                   help="coalesced query-row cap per dispatch cycle")
    p.add_argument("--no-adaptive", action="store_true",
                   help="always wait the full deadline (default: skip the "
                        "wait while traffic is sparse)")
    p.add_argument("--topk-mode", choices=("auto", "replicated", "sharded"),
                   default="auto",
                   help="catalog top-k execution: replicated full scan, "
                        "item-sharded + merge, or auto by catalog size")
    p.add_argument("--no-watch", action="store_true",
                   help="disable the artifact hot-swap watcher")
    p.add_argument("--poll-interval", type=float, default=1.0,
                   help="hot-swap watcher poll cadence in seconds")
    p.add_argument("--devices", type=int, default=0,
                   help="force N host (CPU) devices before jax init")
    return p


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)

    force_host_device_count(args.devices)

    # heavy imports only after XLA_FLAGS is settled
    from repro.serve import ArtifactError, BPMFServer

    try:
        server = BPMFServer(
            args.artifact,
            host=args.host,
            port=args.port,
            deadline_ms=args.deadline_ms,
            max_batch=args.max_batch,
            adaptive=not args.no_adaptive,
            topk_mode=args.topk_mode,
            watch=not args.no_watch,
            poll_interval_s=args.poll_interval,
        )
    except ArtifactError as e:
        print(f"cannot load artifact: {e}", file=sys.stderr)
        return 1

    def _graceful(signum, frame):
        server.shutdown()

    signal.signal(signal.SIGTERM, _graceful)
    signal.signal(signal.SIGINT, _graceful)

    host, port = server.start()
    meta = server.handle.get().meta
    print(
        f"serving {args.artifact} on http://{host}:{port} "
        f"(R {meta.num_users} x {meta.num_movies}, K={meta.K}, "
        f"backend={meta.backend}, topk_mode={args.topk_mode}, "
        f"deadline={args.deadline_ms}ms, "
        f"watch={'off' if args.no_watch else 'on'})",
        file=sys.stderr, flush=True,
    )
    try:
        server.serve_forever()
    finally:
        server.shutdown()
    print("server stopped cleanly", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
