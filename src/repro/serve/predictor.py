"""``PosteriorPredictor`` — jit-compiled, mesh-sharded posterior-mean serving.

Loads an exported artifact (or an engine's in-memory posterior) and answers
rating queries without touching the sampler:

* :meth:`PosteriorPredictor.predict` — batched ``(user, movie)`` point
  predictions from the posterior-mean factors, optionally with the
  predictive std estimated over the retained per-sweep samples,
* :meth:`PosteriorPredictor.top_k` — per-user catalog scoring + top-k.

Execution layout (DESIGN.md §9): the factor matrices are small relative to
query traffic, so they are **replicated** across a 1-D ``("serve",)`` device
mesh and the **query batch is sharded** along it — every device scores its
slice of the batch against its full local factor copy, so no collectives
appear on the hot path. Query batches are padded to a power-of-two pad class
(multiple of the mesh size), the serving analogue of the trainer's
nnz-bucketing: batch sizes 1..32 share one compiled program instead of
recompiling per request size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.serve.artifact import ArtifactMeta, load_artifact
from repro.serve.sharded_topk import build_local_topk, merge_topk, shard_items
from repro.utils import next_power_of_two, round_up

_MIN_PAD = 32  # smallest query pad class: batches 1..32 share one program
_AUTO_SHARD_MIN_ITEMS = 1024  # topk_mode="auto": shard catalogs at least this big


@functools.partial(jax.jit, static_argnames=("lo", "hi"))
def _predict_pairs(U, V, rows, cols, mean, lo, hi):
    """Clipped plug-in predictions for a padded (rows, cols) batch."""
    preds = jnp.sum(U[rows] * V[cols], axis=-1) + mean
    return jnp.clip(preds, lo, hi)


@functools.partial(jax.jit, static_argnames=("lo", "hi"))
def _predict_pairs_std(Us, Vs, rows, cols, mean, lo, hi):
    """Std of the clipped per-sample predictions over the sample axis."""
    preds = jnp.einsum("sbk,sbk->sb", Us[:, rows], Vs[:, cols]) + mean
    return jnp.std(jnp.clip(preds, lo, hi), axis=0)


@functools.partial(jax.jit, static_argnames=("k", "lo", "hi"))
def _top_k(U, V, users, mean, k, lo, hi):
    """Per-user catalog scores -> (ids [B, k], scores [B, k])."""
    scores = jnp.clip(U[users] @ V.T + mean, lo, hi)
    vals, ids = jax.lax.top_k(scores, k)
    return ids.astype(jnp.int32), vals


def serve_mesh(max_devices: int = 0) -> Mesh:
    """1-D ``("serve",)`` mesh over the visible devices.

    Args:
        max_devices: Cap on the mesh size; 0 means every visible device.

    Returns:
        The mesh the predictor shards query batches over.
    """
    devices = jax.devices()
    if max_devices:
        devices = devices[:max_devices]
    return Mesh(np.asarray(devices), ("serve",))


class PosteriorPredictor:
    """Answer rating queries from an exported BPMF posterior.

    Construction paths:

    * :meth:`load` — from an on-disk artifact (the serving process),
    * :meth:`from_engine` — from a live engine's posterior summary, no
      disk round-trip (also what :meth:`repro.bpmf.BPMFEngine.predict`
      delegates to, so served and in-process predictions are computed by
      the *same* jitted program).
    """

    def __init__(
        self,
        meta: ArtifactMeta,
        arrays: dict[str, np.ndarray],
        mesh: Mesh | None = None,
        topk_mode: str = "auto",
    ):
        """Place the posterior summary on the serve mesh.

        Args:
            meta: Artifact metadata (shapes, clip range, mean rating).
            arrays: ``U_mean``/``V_mean``/``U_samples``/``V_samples`` host
                arrays in the shapes ``meta`` promises.
            mesh: Serve mesh; ``None`` builds one over all visible devices.
            topk_mode: Default ``top_k`` execution — ``"replicated"``
                (full-catalog scan on every device), ``"sharded"``
                (item-sharded ``V`` + per-shard top-k + host merge,
                DESIGN.md §11) or ``"auto"`` (sharded when the mesh has
                more than one device and the catalog is large enough for
                the shard pass to win). Per-call override via
                ``top_k(..., sharded=...)``.
        """
        if topk_mode not in ("auto", "replicated", "sharded"):
            raise ValueError(
                f"topk_mode must be auto|replicated|sharded, got {topk_mode!r}"
            )
        self.meta = meta
        self.mesh = mesh if mesh is not None else serve_mesh()
        self.topk_mode = topk_mode
        self._replicated = NamedSharding(self.mesh, P())
        self._batch_sharded = NamedSharding(self.mesh, P("serve"))
        put = functools.partial(jax.device_put, device=self._replicated)
        self._U = put(np.asarray(arrays["U_mean"], np.float32))
        self._V = put(np.asarray(arrays["V_mean"], np.float32))
        self._Us = put(np.asarray(arrays["U_samples"], np.float32))
        self._Vs = put(np.asarray(arrays["V_samples"], np.float32))
        self._mean = put(np.asarray(meta.mean_rating, np.float32))
        # item-sharded top-k state, built lazily on the first sharded call
        self._V_sharded: jax.Array | None = None
        self._local_topk = None

    # ------------------------------------------------------------------
    @classmethod
    def load(
        cls, directory: str, mesh: Mesh | None = None, topk_mode: str = "auto"
    ) -> "PosteriorPredictor":
        """Load a predictor from an artifact directory.

        Args:
            directory: Artifact written by ``BPMFEngine.export()``.
            mesh: Optional serve mesh (default: all visible devices).
            topk_mode: Default ``top_k`` execution mode (see
                :meth:`__init__`).

        Returns:
            A ready predictor.

        Raises:
            ArtifactError: Typed load failure — see
                :mod:`repro.serve.artifact`.
        """
        meta, arrays = load_artifact(directory)
        return cls(meta, arrays, mesh, topk_mode=topk_mode)

    @classmethod
    def from_engine(cls, engine, mesh: Mesh | None = None) -> "PosteriorPredictor":
        """Build a predictor from a live engine, without touching disk.

        Args:
            engine: A fitted :class:`repro.bpmf.BPMFEngine` (anything with
                an ``_artifact_payload()``).
            mesh: Optional serve mesh.

        Returns:
            A predictor over the engine's current posterior summary —
            bitwise the same predictions a save/load round-trip yields.
        """
        meta, arrays = engine._artifact_payload()
        return cls(meta, arrays, mesh)

    # ------------------------------------------------------------------
    @property
    def num_kept_samples(self) -> int:
        """Retained per-sweep factor samples (0 disables predictive std)."""
        return int(self._Us.shape[0])

    def _pad_class(self, n: int) -> int:
        size = self.mesh.devices.size
        return round_up(next_power_of_two(max(int(n), _MIN_PAD)), size)

    def _queries(self, ids: np.ndarray, limit: int, what: str) -> np.ndarray:
        ids = np.asarray(ids, np.int32).reshape(-1)
        if ids.size and (ids.min() < 0 or ids.max() >= limit):
            raise ValueError(
                f"{what} ids must be in [0, {limit}), got range "
                f"[{ids.min()}, {ids.max()}]"
            )
        return ids

    def _pad_sharded(self, ids: np.ndarray, pad: int) -> jax.Array:
        out = np.zeros((pad,), np.int32)
        out[: ids.size] = ids
        return jax.device_put(out, self._batch_sharded)

    # ------------------------------------------------------------------
    def predict(
        self, rows: np.ndarray, cols: np.ndarray, return_std: bool = False
    ) -> np.ndarray | tuple[np.ndarray, np.ndarray]:
        """Batched point predictions for ``(user, movie)`` pairs.

        Args:
            rows: ``[B]`` user ids (original numbering).
            cols: ``[B]`` movie ids (original numbering).
            return_std: Also return the predictive std over the retained
                factor samples.

        Returns:
            ``[B]`` predicted ratings clipped to the training range, or
            ``(preds, std)`` when ``return_std``.

        Raises:
            ValueError: Mismatched batch shapes, out-of-range ids, or
                ``return_std`` on an artifact with no retained samples.
        """
        rows = self._queries(rows, self.meta.num_users, "user")
        cols = self._queries(cols, self.meta.num_movies, "movie")
        if rows.shape != cols.shape:
            raise ValueError(f"rows/cols batch mismatch: {rows.shape} vs {cols.shape}")
        if return_std and self.num_kept_samples == 0:
            raise ValueError(
                "predictive std needs retained factor samples; this artifact "
                "was exported with num_kept_samples=0 "
                "(RunConfig.keep_factor_samples)"
            )
        B = rows.size
        pad = self._pad_class(B)
        r = self._pad_sharded(rows, pad)
        c = self._pad_sharded(cols, pad)
        lo, hi = self.meta.min_rating, self.meta.max_rating
        preds = np.asarray(_predict_pairs(self._U, self._V, r, c, self._mean, lo, hi))[:B]
        if not return_std:
            return preds
        std = np.asarray(
            _predict_pairs_std(self._Us, self._Vs, r, c, self._mean, lo, hi)
        )[:B]
        return preds, std

    def _use_sharded_topk(self, sharded: bool | None) -> bool:
        if sharded is not None:
            return bool(sharded)
        if self.topk_mode == "auto":
            return (
                self.mesh.devices.size > 1
                and self.meta.num_movies >= _AUTO_SHARD_MIN_ITEMS
            )
        return self.topk_mode == "sharded"

    def _top_k_sharded(
        self, users_padded: jax.Array, k: int
    ) -> tuple[np.ndarray, np.ndarray]:
        """Per-shard device top-k over the item-sharded catalog + host merge."""
        if self._V_sharded is None:
            self._V_sharded = shard_items(np.asarray(self._V), self.mesh)
            self._local_topk = build_local_topk(self.mesh, self.meta.num_movies)
        lo, hi = self.meta.min_rating, self.meta.max_rating
        cand_ids, cand_vals = self._local_topk(
            self._U, self._V_sharded, users_padded, self._mean, k, lo, hi
        )
        return merge_topk(np.asarray(cand_ids), np.asarray(cand_vals), k)

    def top_k(
        self, user: int | np.ndarray, k: int, sharded: bool | None = None
    ) -> tuple[np.ndarray, np.ndarray]:
        """Highest-scoring movies for one user (or a batch of users).

        Args:
            user: A user id, or a ``[B]`` array of user ids.
            k: Number of movies to return (clamped to the catalog size).
            sharded: Force the item-sharded (``True``) or replicated
                (``False``) program; ``None`` follows the constructor's
                ``topk_mode``. Both return the same ranking (sharded merge
                reproduces ``jax.lax.top_k`` ordering incl. tie-breaks).

        Returns:
            ``(ids, scores)`` — ``[k]`` arrays for a scalar ``user``,
            ``[B, k]`` for a batch. Scores are clipped predicted ratings.

        Raises:
            ValueError: Out-of-range user ids or ``k < 1``.
        """
        if k < 1:
            raise ValueError(f"top_k needs k >= 1, got {k}")
        k = min(int(k), self.meta.num_movies)
        scalar = np.ndim(user) == 0
        users = self._queries(np.atleast_1d(np.asarray(user)), self.meta.num_users, "user")
        pad = self._pad_class(users.size)
        if self._use_sharded_topk(sharded):
            # item-sharded path: the user batch is REPLICATED (every shard
            # scores all users against its item slab), so pad via the
            # replicated sharding instead of the batch-sharded one
            u_host = np.zeros((pad,), np.int32)
            u_host[: users.size] = users
            u = jax.device_put(u_host, self._replicated)
            ids, vals = self._top_k_sharded(u, k)
        else:
            u = self._pad_sharded(users, pad)
            lo, hi = self.meta.min_rating, self.meta.max_rating
            ids, vals = _top_k(self._U, self._V, u, self._mean, k, lo, hi)
        ids = np.asarray(ids)[: users.size]
        vals = np.asarray(vals)[: users.size]
        return (ids[0], vals[0]) if scalar else (ids, vals)


class PredictorHandle:
    """Atomically swappable reference to the live :class:`PosteriorPredictor`.

    The hot-swap primitive of the serving server (DESIGN.md §11): request
    handlers read the current predictor with :meth:`get` exactly once per
    coalesced batch, and :meth:`swap` replaces it in a single reference
    assignment (atomic under the GIL) — so every batch runs start-to-finish
    against one posterior, in-flight batches drain on the artifact they
    started with, and no request ever observes a half-loaded artifact
    (the new predictor is fully constructed *before* the swap).
    """

    def __init__(self, predictor: PosteriorPredictor):
        """Wrap the initial predictor at generation 0.

        Args:
            predictor: The predictor to serve until the first swap.
        """
        self._current: tuple[PosteriorPredictor, int] = (predictor, 0)

    @property
    def generation(self) -> int:
        """Completed swaps (0 = the artifact the server started with)."""
        return self._current[1]

    def get(self) -> PosteriorPredictor:
        """The live predictor (one atomic read — call once per batch)."""
        return self._current[0]

    def get_with_generation(self) -> tuple[PosteriorPredictor, int]:
        """Consistent ``(predictor, generation)`` pair in one atomic read."""
        return self._current

    def swap(self, predictor: PosteriorPredictor) -> int:
        """Atomically publish a new predictor.

        Args:
            predictor: Fully-constructed (validated + device-resident)
                replacement.

        Returns:
            The new generation number.
        """
        gen = self._current[1] + 1
        self._current = (predictor, gen)
        return gen
