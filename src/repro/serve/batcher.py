"""Adaptive micro-batcher: coalesce concurrent requests under a deadline.

The serving analogue of the trainer's nnz bucketing, applied across
*requests* instead of within one: concurrent ``predict``/``top_k`` requests
are coalesced into one padded device call per compatible group
(:meth:`repro.serve.schema.PredictRequest.batch_key`), so arriving
singletons ride the already-compiled pow2 pad-class programs instead of
dispatching — or worse, compiling — per request.

Policy (DESIGN.md §11): the first queued request arms a deadline of
``deadline_ms``; the dispatcher drains everything that arrives before it
fires, dispatching early when the coalesced size reaches ``max_batch``
(the largest pad class worth filling). The deadline is *adaptive*: when the
recent dispatch occupancy (EMA of requests per cycle) is ~1, traffic is
sparse and waiting only adds latency, so the batcher dispatches the moment
the queue is empty; under concurrency the EMA rises and the batcher waits
out the full deadline to fill batches. Requests are never dropped — even a
failing group run resolves every member ticket with an error response, and
``stop()`` flushes the queue before exiting.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Sequence

from repro.serve.schema import Request

_IDLE_EMA_MAX = 1.25  # EMA occupancy below this = sparse traffic, skip the wait
_EMA_ALPHA = 0.2


class Ticket:
    """One submitted request's completion handle (internal future)."""

    __slots__ = ("request", "_event", "result", "error")

    def __init__(self, request: Request):
        """Wrap a request for queueing.

        Args:
            request: The parsed request awaiting a coalesced dispatch.
        """
        self.request = request
        self._event = threading.Event()
        self.result = None
        self.error: BaseException | None = None

    def resolve(self, result=None, error: BaseException | None = None) -> None:
        """Complete the ticket and wake its waiter.

        Args:
            result: Per-request slice of the group result.
            error: Exception if the group run (or shutdown) failed.
        """
        self.result = result
        self.error = error
        self._event.set()

    def wait(self, timeout: float | None = None):
        """Block until resolved; re-raise a group error in the caller.

        Args:
            timeout: Seconds to wait (``None`` = forever).

        Returns:
            The per-request result.

        Raises:
            TimeoutError: The dispatcher did not resolve in time.
            BaseException: Whatever the group run raised, re-raised here.
        """
        if not self._event.wait(timeout):
            raise TimeoutError("micro-batch dispatch timed out")
        if self.error is not None:
            raise self.error
        return self.result


class MicroBatcher:
    """Deadline-based request coalescer in front of a predictor.

    Args:
        run_group: ``run_group(key, requests) -> [result, ...]`` — execute
            one coalesced group (all requests share ``batch_key() == key``)
            and return one result per request, in order. Called on the
            dispatcher thread; reads its predictor reference once per call,
            which is what makes artifact hot-swap batch-atomic.
        deadline_ms: Max added latency a request can pay waiting for
            co-travellers (the coalescing window).
        max_batch: Coalesced query-row cap per cycle — reaching it
            dispatches immediately (fills the largest pad class).
        adaptive: Skip the deadline wait while the occupancy EMA says
            traffic is sparse. ``False`` always waits the full deadline
            (deterministic coalescing, used by the bitwise tests).
    """

    def __init__(
        self,
        run_group: Callable[[tuple, Sequence[Request]], Sequence[object]],
        deadline_ms: float = 2.0,
        max_batch: int = 1024,
        adaptive: bool = True,
    ):
        if deadline_ms < 0:
            raise ValueError(f"deadline_ms must be >= 0, got {deadline_ms}")
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self._run_group = run_group
        self._deadline_s = deadline_ms / 1e3
        self._max_batch = max_batch
        self._adaptive = adaptive
        self._lock = threading.Condition()
        self._queue: list[Ticket] = []
        self._stopped = False
        self._ema_occupancy = 0.0
        self._stats = {
            "requests": 0, "rows": 0, "cycles": 0, "group_calls": 0,
            "coalesced_requests": 0, "max_cycle_requests": 0,
        }
        self._thread = threading.Thread(
            target=self._dispatch_loop, name="micro-batcher", daemon=True
        )
        self._thread.start()

    # ------------------------------------------------------------------
    def submit(self, request: Request) -> Ticket:
        """Queue a request for the next coalesced dispatch.

        Args:
            request: Parsed request (:mod:`repro.serve.schema`).

        Returns:
            A :class:`Ticket`; call :meth:`Ticket.wait` for the result.

        Raises:
            RuntimeError: The batcher has been stopped.
        """
        ticket = Ticket(request)
        with self._lock:
            if self._stopped:
                raise RuntimeError("micro-batcher is stopped")
            self._queue.append(ticket)
            self._lock.notify_all()
        return ticket

    def stop(self) -> None:
        """Stop the dispatcher, flushing (never dropping) queued requests."""
        with self._lock:
            self._stopped = True
            self._lock.notify_all()
        self._thread.join(timeout=30)

    def stats(self) -> dict:
        """Occupancy counters for monitoring / the load benchmark.

        Returns:
            Dict with ``requests`` (submitted), ``rows`` (query rows),
            ``cycles`` (dispatch cycles), ``group_calls`` (device program
            calls), ``coalesced_requests`` (requests that shared a cycle
            with at least one other), ``max_cycle_requests``, ``occupancy``
            (requests per cycle) and the adaptive ``ema_occupancy``.
        """
        with self._lock:
            s = dict(self._stats)
            s["ema_occupancy"] = self._ema_occupancy
        s["occupancy"] = s["requests"] / s["cycles"] if s["cycles"] else 0.0
        return s

    # ------------------------------------------------------------------
    def _take_batch(self) -> list[Ticket]:
        """Block for the first request, then coalesce until deadline/full."""
        with self._lock:
            while not self._queue and not self._stopped:
                self._lock.wait()
            if not self._queue:
                return []
            deadline = time.monotonic() + self._deadline_s
            batch: list[Ticket] = []
            rows = 0
            while True:
                while self._queue and rows < self._max_batch:
                    t = self._queue.pop(0)
                    batch.append(t)
                    rows += t.request.size
                if rows >= self._max_batch or self._stopped:
                    break
                if self._adaptive and self._ema_occupancy < _IDLE_EMA_MAX:
                    break  # sparse traffic: don't pay the deadline for nothing
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._lock.wait(timeout=remaining)
                if not self._queue:
                    # woken by timeout (or spurious): re-check the clock
                    if deadline - time.monotonic() <= 0:
                        break
            self._ema_occupancy = (
                (1 - _EMA_ALPHA) * self._ema_occupancy + _EMA_ALPHA * len(batch)
            )
            self._stats["cycles"] += 1
            self._stats["requests"] += len(batch)
            self._stats["rows"] += rows
            if len(batch) > 1:
                self._stats["coalesced_requests"] += len(batch)
            self._stats["max_cycle_requests"] = max(
                self._stats["max_cycle_requests"], len(batch)
            )
            return batch

    def _dispatch_loop(self) -> None:
        while True:
            batch = self._take_batch()
            if not batch:
                with self._lock:
                    if self._stopped and not self._queue:
                        return
                continue
            groups: dict[tuple, list[Ticket]] = {}
            for t in batch:  # insertion order preserved within each group
                groups.setdefault(t.request.batch_key(), []).append(t)
            for key, tickets in groups.items():
                with self._lock:
                    self._stats["group_calls"] += 1
                try:
                    results = self._run_group(key, [t.request for t in tickets])
                    if len(results) != len(tickets):
                        raise RuntimeError(
                            f"run_group returned {len(results)} results for "
                            f"{len(tickets)} requests"
                        )
                except BaseException as e:  # resolve EVERY ticket, never drop
                    for t in tickets:
                        t.resolve(error=e)
                else:
                    for t, r in zip(tickets, results):
                        t.resolve(result=r)
