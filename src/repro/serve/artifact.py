"""Versioned posterior serving artifact: what ``BPMFEngine.export()`` writes.

An artifact is everything a serving process needs to answer rating queries
without re-running MCMC (smurff-style deployment, arXiv:2004.02561):

* posterior-mean factors ``U_mean`` / ``V_mean`` (the plug-in predictive
  mean), averaged over every post-burn-in Gibbs sample,
* a bounded window of recent per-sweep factor samples ``U_samples`` /
  ``V_samples`` for predictive-std output,
* the global mean rating, the clip range, and dataset/model metadata.

Layout (one directory per artifact)::

    <dir>/
        artifact.json      # schema version + metadata (this module)
        step_00000000/     # array payload via the checkpoint layer
            manifest.json  # leaf names/shapes/dtypes
            U_mean.npy  V_mean.npy  U_samples.npy  V_samples.npy
        LATEST

The array payload rides on :mod:`repro.checkpoint` so it inherits the atomic
tmp-dir + rename commit, and ``artifact.json`` is written (atomically) only
*after* the arrays commit — a killed export never leaves a loadable-looking
artifact with missing arrays. Damage found at load time surfaces as the
typed :class:`ArtifactError` hierarchy instead of raw ``json``/``numpy``
tracebacks (tests/test_serve.py).
"""
from __future__ import annotations

import dataclasses
import json
import os
import secrets

import numpy as np

from repro.checkpoint import (
    CheckpointCorruptError,
    CheckpointError,
    CheckpointSchemaError,
    restore_checkpoint,
    save_checkpoint,
)

SERVE_ARTIFACT_VERSION = 1
"""Current artifact schema version; bump on any layout/metadata change."""

_ARTIFACT_JSON = "artifact.json"
_ARRAYS_STEP = 0
ARRAY_KEYS = ("U_mean", "V_mean", "U_samples", "V_samples")
"""Leaf names of the array payload, in manifest order."""


class ArtifactError(RuntimeError):
    """Base class for serving-artifact load failures (typed, never a raw
    ``json``/``numpy``/pickle traceback)."""


class ArtifactNotFoundError(ArtifactError, FileNotFoundError):
    """The directory does not contain a committed serving artifact."""


class ArtifactCorruptError(ArtifactError):
    """The artifact exists but is damaged: unparsable ``artifact.json``,
    missing/truncated array files, or a broken checkpoint payload."""


class ArtifactSchemaError(ArtifactError):
    """The artifact is readable but does not match this code's schema:
    unsupported version, missing metadata keys, or array shapes that
    contradict the metadata."""


@dataclasses.dataclass(frozen=True)
class ArtifactMeta:
    """Metadata block of a serving artifact (``artifact.json``).

    Attributes:
        num_users: Row count of the factorized rating matrix.
        num_movies: Column count of the factorized rating matrix.
        K: Latent rank of the exported factors.
        mean_rating: Global training mean re-added to every prediction.
        min_rating: Lower clip bound for served predictions.
        max_rating: Upper clip bound for served predictions.
        num_mean_samples: Post-burn-in Gibbs samples averaged into
            ``U_mean`` / ``V_mean``; 0 means the export fell back to the
            last raw sample (no burn-in completed).
        num_kept_samples: Retained per-sweep factor samples (the leading
            axis of ``U_samples`` / ``V_samples``); 0 disables
            predictive-std output.
        backend: Backend registry name that produced the posterior.
        num_sweeps_done: Completed Gibbs sweeps at export time.
        seed: ``RunConfig.seed`` of the producing run (split + sampler).
        version: Artifact schema version (``SERVE_ARTIFACT_VERSION``).
    """

    num_users: int
    num_movies: int
    K: int
    mean_rating: float
    min_rating: float
    max_rating: float
    num_mean_samples: int
    num_kept_samples: int
    backend: str
    num_sweeps_done: int
    seed: int
    version: int = SERVE_ARTIFACT_VERSION

    def to_json(self) -> dict:
        """Plain-dict form written to ``artifact.json``."""
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(payload: object) -> "ArtifactMeta":
        """Validate and parse an ``artifact.json`` payload.

        Args:
            payload: Decoded JSON value.

        Returns:
            The parsed metadata.

        Raises:
            ArtifactSchemaError: On a non-dict payload, an unsupported
                ``version``, or missing/ill-typed metadata keys.
        """
        if not isinstance(payload, dict):
            raise ArtifactSchemaError(
                f"artifact.json must hold an object, got {type(payload).__name__}"
            )
        version = payload.get("version")
        if version != SERVE_ARTIFACT_VERSION:
            raise ArtifactSchemaError(
                f"unsupported artifact version {version!r} "
                f"(this build reads version {SERVE_ARTIFACT_VERSION})"
            )
        fields = {f.name: f for f in dataclasses.fields(ArtifactMeta)}
        missing = sorted(set(fields) - set(payload))
        if missing:
            raise ArtifactSchemaError(f"artifact.json missing keys: {missing}")
        kw = {}
        for name, field in fields.items():
            val = payload[name]
            want = field.type if isinstance(field.type, type) else {
                "int": int, "float": float, "str": str
            }.get(str(field.type))
            if want is float and isinstance(val, int):
                val = float(val)
            if want is not None and not isinstance(val, want):
                raise ArtifactSchemaError(
                    f"artifact.json key {name!r}: expected {want.__name__}, "
                    f"got {type(val).__name__}"
                )
            kw[name] = val
        return ArtifactMeta(**kw)


def _expected_shapes(meta: ArtifactMeta) -> dict[str, tuple[int, ...]]:
    S = meta.num_kept_samples
    return {
        "U_mean": (meta.num_users, meta.K),
        "V_mean": (meta.num_movies, meta.K),
        "U_samples": (S, meta.num_users, meta.K),
        "V_samples": (S, meta.num_movies, meta.K),
    }


def save_artifact(directory: str, meta: ArtifactMeta, arrays: dict[str, np.ndarray]) -> str:
    """Write a serving artifact: arrays first (atomic), metadata last.

    Args:
        directory: Artifact directory (created if needed). Re-exporting
            into the same directory replaces the artifact.
        meta: Metadata block; array shapes must agree with it.
        arrays: Exactly the :data:`ARRAY_KEYS` leaves, host numpy.

    Returns:
        ``directory``.

    Raises:
        ValueError: If ``arrays`` has the wrong key set or shapes that
            contradict ``meta`` (producer-side bug, not a typed load error).
    """
    if set(arrays) != set(ARRAY_KEYS):
        raise ValueError(
            f"artifact arrays must be exactly {ARRAY_KEYS}, got {sorted(arrays)}"
        )
    for name, want in _expected_shapes(meta).items():
        got = tuple(np.asarray(arrays[name]).shape)
        if got != want:
            raise ValueError(f"artifact array {name}: shape {got} != {want} from meta")
    os.makedirs(directory, exist_ok=True)
    # non-collective: in a multi-process job only process 0 exports, from
    # already-gathered host arrays — no cross-process commit protocol
    save_checkpoint(
        directory,
        _ARRAYS_STEP,
        {k: np.asarray(arrays[k]) for k in ARRAY_KEYS},
        collective=False,
    )
    tmp = os.path.join(directory, f".{_ARTIFACT_JSON}-{secrets.token_hex(4)}")
    with open(tmp, "w") as f:
        json.dump(meta.to_json(), f, indent=1)
    os.replace(tmp, os.path.join(directory, _ARTIFACT_JSON))
    return directory


def load_artifact(directory: str) -> tuple[ArtifactMeta, dict[str, np.ndarray]]:
    """Load and validate a serving artifact.

    Args:
        directory: Directory previously written by :func:`save_artifact`
            (or :meth:`repro.bpmf.BPMFEngine.export`).

    Returns:
        ``(meta, arrays)`` with arrays as host numpy in the shapes
        promised by ``meta``.

    Raises:
        ArtifactNotFoundError: No ``artifact.json`` under ``directory``.
        ArtifactCorruptError: Unparsable metadata, or a missing/truncated
            array payload.
        ArtifactSchemaError: Version/metadata/shape drift.
    """
    meta_path = os.path.join(directory, _ARTIFACT_JSON)
    if not os.path.exists(meta_path):
        raise ArtifactNotFoundError(f"no serving artifact under {directory!r}")
    try:
        with open(meta_path) as f:
            payload = json.load(f)
    except (OSError, ValueError) as e:
        raise ArtifactCorruptError(f"unreadable {meta_path}: {e}") from e
    meta = ArtifactMeta.from_json(payload)

    target = {k: np.zeros((0,), np.float32) for k in ARRAY_KEYS}
    try:
        tree = restore_checkpoint(directory, target, step=_ARRAYS_STEP)
    except CheckpointSchemaError as e:
        raise ArtifactSchemaError(f"artifact array payload: {e}") from e
    except (CheckpointError, FileNotFoundError) as e:
        raise ArtifactCorruptError(f"artifact array payload: {e}") from e
    arrays = {k: np.asarray(v) for k, v in tree.items()}
    for name, want in _expected_shapes(meta).items():
        got = tuple(arrays[name].shape)
        if got != want:
            raise ArtifactSchemaError(
                f"artifact array {name}: shape {got} contradicts metadata {want}"
            )
    return meta, arrays
