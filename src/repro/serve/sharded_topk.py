"""Item-sharded catalog top-k: per-shard device top-k + host merge.

The replicated ``top_k`` program scores the *full* catalog on every device
(`U[users] @ V.T` over all M items) — fine for small catalogs, but the
recorded ``serve_latency.json`` shows the full scan is the serving p99 hot
spot, and a catalog too large to replicate cannot serve that way at all.
This module shards ``V`` along the **item axis** over the serve mesh:

1. each device scores its ``ceil(M/S)`` item rows against the (replicated)
   user batch and takes a *local* ``top_k'`` (``k' = min(k, M_shard)``) —
   an O(M/S) pass per device instead of O(M);
2. the ``[S, B, k']`` candidate slabs travel to the host (``S·B·k'`` floats
   — tiny next to the catalog) where a vectorized merge selects the global
   top-k with the same ordering contract as ``jax.lax.top_k``: scores
   descending, ties broken toward the lower item id.

A shard contributes at most ``k'`` candidates and can own at most ``k'`` of
the global top-k (``k' = k`` unless the shard is smaller than ``k``, in
which case it contributes everything it has), so the merge is exact. Pad
rows (``M`` rounded up to a mesh multiple) are masked to ``-inf`` before
the local top-k and can never surface: their ids lie outside ``[0, M)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.distributed import shard_map
from repro.utils import round_up


def shard_items(V: np.ndarray, mesh: Mesh) -> jax.Array:
    """Place the item-factor matrix sharded along the item axis.

    Args:
        V: ``[M, K]`` item factors (host).
        mesh: 1-D ``("serve",)`` mesh to shard over.

    Returns:
        ``[M_pad, K]`` device array, ``M_pad = ceil(M/S)·S``, sharded
        ``P("serve", None)`` — each device holds one contiguous item slab.
    """
    V = np.asarray(V, np.float32)
    S = mesh.devices.size
    M_pad = round_up(max(V.shape[0], S), S)
    if M_pad != V.shape[0]:
        V = np.concatenate(
            [V, np.zeros((M_pad - V.shape[0], V.shape[1]), np.float32)]
        )
    return jax.device_put(V, NamedSharding(mesh, P("serve", None)))


def build_local_topk(mesh: Mesh, num_items: int):
    """Build the jitted per-shard scoring + local top-k program.

    Args:
        mesh: 1-D ``("serve",)`` mesh the item shards live on.
        num_items: True catalog size ``M`` (pad rows beyond it are masked).

    Returns:
        ``fn(U, V_sharded, users, mean, k, lo, hi) -> (ids, vals)`` with
        ``ids``/``vals`` shaped ``[S, B, k']`` — per-shard global item ids
        and clipped scores, ``k' = min(k, M_pad / S)``; compiled once per
        ``(pad class, k)``.
    """

    @functools.partial(jax.jit, static_argnames=("k", "lo", "hi"))
    def local_topk(U, V_sh, users, mean, k, lo, hi):
        m = V_sh.shape[0] // mesh.devices.size  # items per shard
        kl = min(k, m)

        def shard_fn(V_loc, U, users, mean):
            idx = jax.lax.axis_index("serve")
            gid = idx * m + jnp.arange(m, dtype=jnp.int32)
            scores = jnp.clip(U[users] @ V_loc.T + mean, lo, hi)
            scores = jnp.where(gid[None, :] < num_items, scores, -jnp.inf)
            vals, ids = jax.lax.top_k(scores, kl)
            return (gid[ids])[None], vals[None]

        return shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(P("serve", None), P(), P(), P()),
            out_specs=(P("serve", None, None), P("serve", None, None)),
        )(V_sh, U, users, mean)

    return local_topk


def merge_topk(
    cand_ids: np.ndarray, cand_vals: np.ndarray, k: int
) -> tuple[np.ndarray, np.ndarray]:
    """Host-side exact merge of per-shard top-k candidates.

    Args:
        cand_ids: ``[S, B, k']`` global item ids from the shards.
        cand_vals: ``[S, B, k']`` matching scores.
        k: Global top-k size (``<=`` total candidates ``S·k'``).

    Returns:
        ``(ids [B, k], vals [B, k])`` with ``jax.lax.top_k`` ordering:
        scores descending, ties toward the lower item id.
    """
    S, B, kl = cand_ids.shape
    ids = np.ascontiguousarray(np.transpose(cand_ids, (1, 0, 2))).reshape(B, S * kl)
    vals = np.ascontiguousarray(np.transpose(cand_vals, (1, 0, 2))).reshape(B, S * kl)
    # primary key: score descending; secondary: item id ascending — the
    # tie-break jax.lax.top_k applies via positional order
    order = np.lexsort((ids, -vals), axis=1)[:, :k]
    rows = np.arange(B)[:, None]
    return ids[rows, order].astype(np.int32), vals[rows, order]
