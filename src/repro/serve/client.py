"""Thin stdlib HTTP client for the persistent serving server.

``ServeClient`` speaks the shared request/response schema
(:mod:`repro.serve.schema`) against a :class:`repro.serve.server.BPMFServer`
— used by ``python -m repro.launch.serve --server host:port`` (the same CLI
drives the in-process predictor or a remote server), the closed-loop load
benchmark, and the tests. One persistent keep-alive connection per client
instance; instances are NOT thread-safe — give each client thread its own
(the load benchmark does exactly that).
"""
from __future__ import annotations

import http.client
import json
import socket

import numpy as np


class ServeConnectionError(ConnectionError):
    """The server could not be reached or returned a non-JSON payload."""


class ServeRequestError(ValueError):
    """The server answered with an ``{"error": ...}`` response."""


def parse_address(address: str) -> tuple[str, int]:
    """Parse ``host:port`` (optionally ``http://host:port``) into a pair.

    Args:
        address: Server address string.

    Returns:
        ``(host, port)``.

    Raises:
        ValueError: No parsable ``host:port`` in ``address``.
    """
    addr = address.strip()
    if addr.startswith("http://"):
        addr = addr[len("http://"):]
    addr = addr.rstrip("/")
    host, sep, port = addr.rpartition(":")
    if not sep or not port.isdigit():
        raise ValueError(f"server address must be host:port, got {address!r}")
    return host or "127.0.0.1", int(port)


class ServeClient:
    """Client for one serving server.

    Args:
        address: ``host:port`` (or ``http://host:port``) of a running
            :class:`repro.serve.server.BPMFServer`.
        timeout: Per-request socket timeout in seconds.
    """

    def __init__(self, address: str, timeout: float = 60.0):
        self._host, self._port = parse_address(address)
        self._timeout = timeout
        self._conn: http.client.HTTPConnection | None = None

    def close(self) -> None:
        """Close the underlying connection (reopened lazily on next use)."""
        if self._conn is not None:
            self._conn.close()
            self._conn = None

    def _roundtrip(self, method: str, path: str, body: dict | None) -> dict:
        payload = None if body is None else json.dumps(body).encode()
        headers = {"Content-Type": "application/json"} if payload else {}
        for attempt in (0, 1):  # one retry on a stale keep-alive connection
            if self._conn is None:
                self._conn = http.client.HTTPConnection(
                    self._host, self._port, timeout=self._timeout
                )
                try:
                    # headers and body go out in separate writes; without
                    # TCP_NODELAY, Nagle + delayed ACK stalls the body ~40ms
                    self._conn.connect()
                    self._conn.sock.setsockopt(
                        socket.IPPROTO_TCP, socket.TCP_NODELAY, 1
                    )
                except OSError as e:
                    self.close()
                    raise ServeConnectionError(
                        f"cannot reach server at {self._host}:{self._port}: {e}"
                    ) from e
            try:
                self._conn.request(method, path, body=payload, headers=headers)
                resp = self._conn.getresponse()
                raw = resp.read()
            except (OSError, http.client.HTTPException) as e:
                self.close()
                if attempt:
                    raise ServeConnectionError(
                        f"cannot reach server at {self._host}:{self._port}: {e}"
                    ) from e
                continue
            try:
                return json.loads(raw)
            except ValueError as e:
                self.close()
                raise ServeConnectionError(
                    f"non-JSON response (HTTP {resp.status}): {raw[:200]!r}"
                ) from e
        raise AssertionError("unreachable")

    # ------------------------------------------------------------------
    def request(self, payload: dict) -> dict:
        """POST one raw schema request and return the raw response dict.

        Args:
            payload: JSON-able request (``{"rows": ..., "cols": ...}`` or
                ``{"user"/"users": ..., "k": ...}``).

        Returns:
            The response dict — may contain ``"error"`` (the transport
            succeeded; the request was rejected).

        Raises:
            ServeConnectionError: Transport-level failure.
        """
        return self._roundtrip("POST", "/query", payload)

    def _checked(self, payload: dict) -> dict:
        resp = self.request(payload)
        if "error" in resp:
            raise ServeRequestError(resp["error"])
        return resp

    def predict(
        self, rows, cols, return_std: bool = False
    ) -> np.ndarray | tuple[np.ndarray, np.ndarray]:
        """Remote ``predict`` mirroring the predictor API.

        Args:
            rows: ``[B]`` user ids.
            cols: ``[B]`` movie ids.
            return_std: Also return the predictive std.

        Returns:
            ``[B]`` float32 predictions, or ``(preds, std)``.

        Raises:
            ServeRequestError: The server rejected the request.
            ServeConnectionError: Transport-level failure.
        """
        req = {"rows": np.asarray(rows).tolist(), "cols": np.asarray(cols).tolist()}
        if return_std:
            req["std"] = True
        resp = self._checked(req)
        preds = np.asarray(resp["predictions"], np.float32)
        if return_std:
            return preds, np.asarray(resp["std"], np.float32)
        return preds

    def top_k(self, user, k: int) -> tuple[np.ndarray, np.ndarray]:
        """Remote ``top_k`` mirroring the predictor API.

        Args:
            user: A user id, or a ``[B]`` array of user ids.
            k: Movies to return per user.

        Returns:
            ``(ids, scores)`` — ``[k]`` for a scalar user, ``[B, k]`` for
            a batch.

        Raises:
            ServeRequestError: The server rejected the request.
            ServeConnectionError: Transport-level failure.
        """
        if np.ndim(user) == 0:
            resp = self._checked({"user": int(user), "k": int(k)})
        else:
            resp = self._checked({"users": np.asarray(user).tolist(), "k": int(k)})
        return (np.asarray(resp["items"], np.int32),
                np.asarray(resp["scores"], np.float32))

    def health(self) -> dict:
        """``GET /healthz`` — liveness, artifact metadata, swap generation."""
        return self._roundtrip("GET", "/healthz", None)

    def stats(self) -> dict:
        """``GET /stats`` — batcher occupancy counters + swap state."""
        return self._roundtrip("GET", "/stats", None)
