"""``repro.serve`` — posterior-mean serving over exported BPMF artifacts.

The post-training half of the ROADMAP's "serve heavy traffic" north star:
``BPMFEngine.export()`` persists the sampled posterior as a versioned
artifact (:mod:`repro.serve.artifact`), and :class:`PosteriorPredictor`
(:mod:`repro.serve.predictor`) loads it into a jit-compiled, mesh-sharded
batch predictor — ``predict(rows, cols)`` and ``top_k(user, k)`` with
optional predictive-std output, no sampler in the process. On top of the
predictor sits the persistent serving server
(:class:`repro.serve.server.BPMFServer`): adaptive micro-batching
(:mod:`repro.serve.batcher`), item-sharded catalog top-k
(:mod:`repro.serve.sharded_topk`) and zero-downtime artifact hot-swap, all
speaking one request/response schema (:mod:`repro.serve.schema`) shared
with the CLIs and :class:`repro.serve.client.ServeClient`. CLIs:
``python -m repro.launch.serve`` (one-shot / JSONL / ``--server`` client
mode) and ``python -m repro.launch.serve_server``; architecture notes in
DESIGN.md §9 and §11.
"""
from repro.serve.artifact import (
    ARRAY_KEYS,
    SERVE_ARTIFACT_VERSION,
    ArtifactCorruptError,
    ArtifactError,
    ArtifactMeta,
    ArtifactNotFoundError,
    ArtifactSchemaError,
    load_artifact,
    save_artifact,
)
from repro.serve.batcher import MicroBatcher
from repro.serve.client import (
    ServeClient,
    ServeConnectionError,
    ServeRequestError,
)
from repro.serve.predictor import PosteriorPredictor, PredictorHandle, serve_mesh
from repro.serve.schema import RequestError, parse_request, run_request
from repro.serve.server import BPMFServer

__all__ = [
    "ARRAY_KEYS",
    "SERVE_ARTIFACT_VERSION",
    "ArtifactCorruptError",
    "ArtifactError",
    "ArtifactMeta",
    "ArtifactNotFoundError",
    "ArtifactSchemaError",
    "BPMFServer",
    "MicroBatcher",
    "PosteriorPredictor",
    "PredictorHandle",
    "RequestError",
    "ServeClient",
    "ServeConnectionError",
    "ServeRequestError",
    "load_artifact",
    "parse_request",
    "run_request",
    "save_artifact",
    "serve_mesh",
]
