"""``repro.serve`` — posterior-mean serving over exported BPMF artifacts.

The post-training half of the ROADMAP's "serve heavy traffic" north star:
``BPMFEngine.export()`` persists the sampled posterior as a versioned
artifact (:mod:`repro.serve.artifact`), and :class:`PosteriorPredictor`
(:mod:`repro.serve.predictor`) loads it into a jit-compiled, mesh-sharded
batch predictor — ``predict(rows, cols)`` and ``top_k(user, k)`` with
optional predictive-std output, no sampler in the process. CLI:
``python -m repro.launch.serve``; architecture notes in DESIGN.md §9.
"""
from repro.serve.artifact import (
    ARRAY_KEYS,
    SERVE_ARTIFACT_VERSION,
    ArtifactCorruptError,
    ArtifactError,
    ArtifactMeta,
    ArtifactNotFoundError,
    ArtifactSchemaError,
    load_artifact,
    save_artifact,
)
from repro.serve.predictor import PosteriorPredictor, serve_mesh

__all__ = [
    "ARRAY_KEYS",
    "SERVE_ARTIFACT_VERSION",
    "ArtifactCorruptError",
    "ArtifactError",
    "ArtifactMeta",
    "ArtifactNotFoundError",
    "ArtifactSchemaError",
    "PosteriorPredictor",
    "load_artifact",
    "save_artifact",
    "serve_mesh",
]
