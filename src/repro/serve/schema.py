"""Shared serving request/response schema (CLI, JSONL loop, server, client).

One wire format for every serving entry point: the one-shot CLI, the stdin
JSONL loop, the persistent HTTP server (:mod:`repro.serve.server`) and its
client helpers (:mod:`repro.serve.client`) all parse requests with
:func:`parse_request` and execute them with :func:`run_request` — validation
lives here exactly once.

Request objects (JSON on the wire):

* ``{"rows": [...], "cols": [...], "std": bool?}`` — batched point
  predictions (:class:`PredictRequest`),
* ``{"user": id, "k": n}`` or ``{"users": [...], "k": n}`` — catalog top-k
  (:class:`TopKRequest`).

Responses are plain JSON objects: ``{"predictions": [...], "std"?: [...]}``
for predictions, ``{"user"/"users": ..., "items": ..., "scores": ...}`` for
top-k, ``{"error": "..."}`` on failure. Floats round-trip exactly through
JSON (f32 → f64 repr), so a response compared against an in-process
predictor call is a *bitwise* comparison.
"""
from __future__ import annotations

import dataclasses

import numpy as np


class RequestError(ValueError):
    """A structurally invalid serving request (unknown shape, bad types)."""


@dataclasses.dataclass(frozen=True)
class PredictRequest:
    """Batched ``(user, movie)`` point-prediction request.

    Attributes:
        rows: ``[B]`` int32 user ids.
        cols: ``[B]`` int32 movie ids.
        std: Also return the predictive std over retained samples.
    """

    rows: np.ndarray
    cols: np.ndarray
    std: bool = False

    @property
    def size(self) -> int:
        """Query rows this request contributes to a coalesced batch."""
        return int(self.rows.size)

    def batch_key(self) -> tuple:
        """Coalescing group key — requests with equal keys may share one
        padded device program call."""
        return ("predict", self.std)


@dataclasses.dataclass(frozen=True)
class TopKRequest:
    """Catalog top-k request for one user or a batch of users.

    Attributes:
        users: ``[B]`` int32 user ids (``B == 1`` for the scalar form).
        k: Movies to return per user.
        scalar: Request used the scalar ``{"user": id}`` form; the response
            mirrors it (``user``/flat lists instead of ``users``/nested).
    """

    users: np.ndarray
    k: int
    scalar: bool = False

    @property
    def size(self) -> int:
        """Query rows this request contributes to a coalesced batch."""
        return int(self.users.size)

    def batch_key(self) -> tuple:
        """Coalescing group key (top-k batches must share ``k``)."""
        return ("top_k", self.k)


Request = PredictRequest | TopKRequest
"""Union of the parsed request types."""


def _ids(obj: object, what: str) -> np.ndarray:
    if isinstance(obj, (int, np.integer)):
        obj = [obj]
    if not isinstance(obj, (list, tuple, np.ndarray)):
        raise RequestError(f"{what} must be an id list, got {type(obj).__name__}")
    try:
        arr = np.asarray(obj, dtype=np.int64).reshape(-1)
    except (TypeError, ValueError, OverflowError) as e:
        raise RequestError(f"{what} must hold integer ids: {e}") from None
    return arr.astype(np.int32)


def parse_request(obj: object) -> Request:
    """Validate a decoded JSON request into a typed request object.

    Structural validation only (shapes/types); id-range checks against a
    specific catalog happen inside the predictor and surface as
    ``ValueError`` at execution time.

    Args:
        obj: Decoded JSON value (one stdin JSONL line / one HTTP body).

    Returns:
        A :class:`PredictRequest` or :class:`TopKRequest`.

    Raises:
        RequestError: Not a dict, neither request shape, mismatched
            rows/cols lengths, non-integer ids, or a non-positive ``k``.
    """
    if not isinstance(obj, dict):
        raise RequestError(f"request must be a JSON object, got {type(obj).__name__}")
    if "rows" in obj or "cols" in obj:
        rows = _ids(obj.get("rows", ()), "rows")
        cols = _ids(obj.get("cols", ()), "cols")
        if rows.shape != cols.shape:
            raise RequestError(
                f"rows/cols batch mismatch: {rows.size} vs {cols.size}"
            )
        if rows.size == 0:
            raise RequestError("empty prediction batch")
        return PredictRequest(rows=rows, cols=cols, std=bool(obj.get("std", False)))
    if "user" in obj or "users" in obj:
        scalar = "user" in obj
        if scalar and "users" in obj:
            raise RequestError("request must use either 'user' or 'users', not both")
        users = _ids(obj["user"] if scalar else obj["users"], "users")
        if scalar and users.size != 1:
            raise RequestError("'user' must be a single id (use 'users' for a batch)")
        if users.size == 0:
            raise RequestError("empty users batch")
        k = obj.get("k", 10)
        if not isinstance(k, (int, np.integer)) or isinstance(k, bool) or k < 1:
            raise RequestError(f"k must be a positive integer, got {k!r}")
        return TopKRequest(users=users, k=int(k), scalar=scalar)
    raise RequestError("request needs either rows/cols or user/users")


def run_request(predictor, req: Request) -> dict:
    """Execute one parsed request in isolation against a predictor.

    The reference (non-coalesced) execution path: the one-shot CLI and the
    JSONL loop call this directly, and the server's micro-batcher is tested
    bitwise against it.

    Args:
        predictor: A :class:`repro.serve.PosteriorPredictor` (or the
            engine's in-process predictor).
        req: Parsed request.

    Returns:
        The JSON-able response dict.

    Raises:
        ValueError: Out-of-range ids / std-without-samples (predictor-side
            validation).
    """
    if isinstance(req, PredictRequest):
        out = predictor.predict(req.rows, req.cols, return_std=req.std)
        if req.std:
            preds, std = out
            return {"predictions": preds.tolist(), "std": std.tolist()}
        return {"predictions": out.tolist()}
    ids, scores = predictor.top_k(req.users, req.k)
    if req.scalar:
        return {"user": int(req.users[0]), "items": ids[0].tolist(),
                "scores": scores[0].tolist()}
    return {"users": req.users.tolist(), "items": ids.tolist(),
            "scores": scores.tolist()}


def error_response(exc: BaseException) -> dict:
    """Uniform ``{"error": ...}`` response for a failed request.

    Args:
        exc: The exception that aborted the request.

    Returns:
        A JSON-able error dict (``RequestError`` renders without the class
        name; other exceptions keep it for debuggability).
    """
    if isinstance(exc, RequestError):
        return {"error": str(exc)}
    return {"error": f"{type(exc).__name__}: {exc}"}
