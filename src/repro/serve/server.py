"""Persistent BPMF serving server: HTTP front, micro-batched device back.

The production half of the serving subsystem (DESIGN.md §11): a threaded
HTTP server that fields concurrent ``predict``/``top_k`` requests over one
:class:`repro.serve.PosteriorPredictor`, with

* **adaptive micro-batching** — concurrent requests coalesce into the
  predictor's pow2 pad-class programs under a latency deadline
  (:mod:`repro.serve.batcher`), so singleton queries ride already-compiled
  batch programs;
* **item-sharded top-k** — ``topk_mode="auto"``/``"sharded"`` routes
  catalog ranking through the per-shard top-k + host merge
  (:mod:`repro.serve.sharded_topk`);
* **zero-downtime hot-swap** — a watcher thread polls the artifact
  directory, validates any fresh export by *fully loading* it (typed
  ``ArtifactError`` failures keep the old posterior serving), warms the
  compiled programs, and atomically swaps the live predictor between
  batches (:class:`repro.serve.predictor.PredictorHandle`); in-flight
  batches drain on the posterior they started with.

Endpoints (JSON over HTTP/1.1, schema in :mod:`repro.serve.schema`):

* ``POST /query`` — one request object per call; 400 + ``{"error": ...}``
  on invalid requests, 200 + the response object otherwise.
* ``GET /healthz`` — liveness + artifact metadata + swap ``generation``.
* ``GET /stats`` — micro-batcher occupancy counters + swap state.

Start via :class:`BPMFServer` in-process or
``python -m repro.launch.serve_server`` from the CLI; query with
:class:`repro.serve.client.ServeClient` or
``python -m repro.launch.serve --server host:port``.
"""
from __future__ import annotations

import json
import os
import sys
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import numpy as np

from repro.serve import schema
from repro.serve.artifact import ArtifactError
from repro.serve.batcher import MicroBatcher
from repro.serve.predictor import PosteriorPredictor, PredictorHandle

_MAX_BODY_BYTES = 8 << 20  # refuse absurd request bodies before json.loads


def _artifact_signature(directory: str) -> tuple | None:
    """Cheap change signature of an artifact dir: mtime_ns + size of the
    metadata file (written last by an atomic export) *and* of the array
    manifest — so a re-export that has already replaced the arrays but not
    yet committed fresh metadata still changes the signature, and a load
    that raced it is rejected by the post-load signature re-check."""
    try:
        meta = os.stat(os.path.join(directory, "artifact.json"))
        man = os.stat(os.path.join(directory, "step_00000000", "manifest.json"))
        return (meta.st_mtime_ns, meta.st_size, man.st_mtime_ns, man.st_size)
    except OSError:
        return None


class BPMFServer:
    """Persistent serving server over an exported posterior artifact.

    Args:
        artifact: Artifact directory written by ``BPMFEngine.export()``.
        host: Bind address (default loopback).
        port: Bind port; 0 picks an ephemeral port (see :attr:`address`).
        deadline_ms: Micro-batch coalescing deadline — the max latency a
            request pays waiting for co-travellers.
        max_batch: Coalesced query-row cap per dispatch cycle.
        adaptive: Skip the deadline wait while traffic is sparse
            (:class:`repro.serve.batcher.MicroBatcher`).
        topk_mode: ``top_k`` execution mode passed to the predictor
            (``auto`` / ``replicated`` / ``sharded``).
        watch: Poll ``artifact`` for fresh exports and hot-swap them in.
        poll_interval_s: Watcher poll cadence.
        mesh: Serve mesh override (default: all visible devices).

    Raises:
        ArtifactError: The initial artifact fails to load.
    """

    def __init__(
        self,
        artifact: str,
        host: str = "127.0.0.1",
        port: int = 0,
        deadline_ms: float = 2.0,
        max_batch: int = 1024,
        adaptive: bool = True,
        topk_mode: str = "auto",
        watch: bool = True,
        poll_interval_s: float = 1.0,
        mesh=None,
    ):
        self._artifact_dir = artifact
        self._mesh = mesh
        self._topk_mode = topk_mode
        self._signature = _artifact_signature(artifact)
        predictor = PosteriorPredictor.load(artifact, mesh=mesh, topk_mode=topk_mode)
        self.handle = PredictorHandle(predictor)
        self._warmup(predictor)
        self.batcher = MicroBatcher(
            self._run_group, deadline_ms=deadline_ms, max_batch=max_batch,
            adaptive=adaptive,
        )
        self._watch = watch
        self._poll_interval_s = poll_interval_s
        self._stop_event = threading.Event()
        self._watcher: threading.Thread | None = None
        self._swap_failures = 0
        self._http = _make_http_server(self, host, port)
        self._http_thread: threading.Thread | None = None
        self._started = False

    # ------------------------------------------------------------------
    @property
    def address(self) -> tuple[str, int]:
        """Bound ``(host, port)`` — resolved even when ``port=0`` was asked."""
        return self._http.server_address[:2]

    @property
    def generation(self) -> int:
        """Artifact swaps completed since startup."""
        return self.handle.generation

    def start(self) -> tuple[str, int]:
        """Start the HTTP listener (and watcher) threads; non-blocking.

        Returns:
            The bound ``(host, port)``.
        """
        if self._started:
            return self.address
        self._started = True
        self._http_thread = threading.Thread(
            target=self._http.serve_forever, name="bpmf-serve-http", daemon=True
        )
        self._http_thread.start()
        if self._watch:
            self._watcher = threading.Thread(
                target=self._watch_loop, name="bpmf-serve-watch", daemon=True
            )
            self._watcher.start()
        return self.address

    def serve_forever(self) -> None:
        """Blocking variant of :meth:`start` (returns after :meth:`shutdown`)."""
        self.start()
        self._stop_event.wait()

    def shutdown(self) -> None:
        """Clean shutdown: stop accepting, drain in-flight requests, stop
        the watcher. Idempotent."""
        if self._stop_event.is_set():
            return
        self._stop_event.set()
        self._http.shutdown()  # stop accepting; running handlers finish
        if self._http_thread is not None:
            self._http_thread.join(timeout=30)
        self._http.server_close()
        self.batcher.stop()  # flushes the queue — nothing is dropped
        if self._watcher is not None:
            self._watcher.join(timeout=30)

    def __enter__(self) -> "BPMFServer":
        """Context-manager start."""
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        """Context-manager clean shutdown."""
        self.shutdown()

    # ------------------------------------------------------------------
    # request execution (dispatcher thread)
    # ------------------------------------------------------------------
    def _run_group(self, key: tuple, requests: list) -> list[dict]:
        """Execute one coalesced group; the single handle read per batch is
        what makes hot-swap batch-atomic."""
        predictor = self.handle.get()
        if key[0] == "predict":
            rows = np.concatenate([r.rows for r in requests])
            cols = np.concatenate([r.cols for r in requests])
            out = predictor.predict(rows, cols, return_std=key[1])
            preds, std = out if key[1] else (out, None)
            results, off = [], 0
            for r in requests:
                sl = slice(off, off + r.size)
                resp = {"predictions": preds[sl].tolist()}
                if std is not None:
                    resp["std"] = std[sl].tolist()
                results.append(resp)
                off += r.size
            return results
        users = np.concatenate([r.users for r in requests])
        ids, scores = predictor.top_k(users, key[1])
        results, off = [], 0
        for r in requests:
            sl = slice(off, off + r.size)
            if r.scalar:
                results.append({
                    "user": int(r.users[0]), "items": ids[off].tolist(),
                    "scores": scores[off].tolist(),
                })
            else:
                results.append({
                    "users": r.users.tolist(), "items": ids[sl].tolist(),
                    "scores": scores[sl].tolist(),
                })
            off += r.size
        return results

    def handle_request(self, payload: object, timeout: float = 60.0) -> tuple[int, dict]:
        """Parse + dispatch one decoded request body.

        Args:
            payload: Decoded JSON request.
            timeout: Seconds to wait for the coalesced dispatch.

        Returns:
            ``(http_status, response_dict)``.
        """
        try:
            req = schema.parse_request(payload)
        except schema.RequestError as e:
            return 400, schema.error_response(e)
        try:
            result = self.batcher.submit(req).wait(timeout=timeout)
            return 200, result
        except (ValueError, KeyError, TypeError) as e:
            # predictor-side validation (out-of-range ids, std w/o samples)
            return 400, schema.error_response(e)
        except Exception as e:  # never leak a traceback to the wire
            return 500, schema.error_response(e)

    # ------------------------------------------------------------------
    # hot-swap watcher
    # ------------------------------------------------------------------
    def _warmup(self, predictor: PosteriorPredictor) -> None:
        """Touch the smallest pad-class programs so the first real query
        (and the first query after a swap) never pays a compile."""
        meta = predictor.meta
        predictor.predict([0], [0])
        predictor.top_k(0, min(10, meta.num_movies))

    def _try_swap(self) -> bool:
        """Validate + swap a fresh export; on any failure keep serving the
        old posterior. Returns True when a swap happened."""
        sig = _artifact_signature(self._artifact_dir)
        if sig is None or sig == self._signature:
            return False
        try:
            fresh = PosteriorPredictor.load(
                self._artifact_dir, mesh=self._mesh, topk_mode=self._topk_mode
            )
            self._warmup(fresh)
        except ArtifactError as e:
            # half-written / torn export: keep the live posterior, retry
            # next poll (the exporter commits metadata last, so this clears)
            self._swap_failures += 1
            print(f"[bpmf-serve] swap rejected: {e}", file=sys.stderr)
            return False
        if _artifact_signature(self._artifact_dir) != sig:
            return False  # exporter still writing — pick it up next poll
        self._signature = sig
        gen = self.handle.swap(fresh)
        meta = fresh.meta
        print(
            f"[bpmf-serve] hot-swapped artifact (generation {gen}): "
            f"{meta.num_sweeps_done} sweeps, {meta.num_mean_samples} samples "
            f"averaged, backend={meta.backend}",
            file=sys.stderr,
        )
        return True

    def _watch_loop(self) -> None:
        while not self._stop_event.wait(self._poll_interval_s):
            try:
                self._try_swap()
            except Exception as e:  # watcher must never die
                self._swap_failures += 1
                print(f"[bpmf-serve] watcher error: {e}", file=sys.stderr)

    def poll_artifact_now(self) -> bool:
        """Force one watcher poll (tests / manual reload without waiting).

        Returns:
            True when a fresh artifact was validated and swapped in.
        """
        return self._try_swap()

    # ------------------------------------------------------------------
    def health(self) -> dict:
        """Liveness payload served at ``GET /healthz``."""
        predictor, gen = self.handle.get_with_generation()
        meta = predictor.meta
        return {
            "status": "ok",
            "generation": gen,
            "swap_failures": self._swap_failures,
            "artifact": {
                "num_users": meta.num_users, "num_movies": meta.num_movies,
                "K": meta.K, "backend": meta.backend,
                "num_sweeps_done": meta.num_sweeps_done,
                "num_mean_samples": meta.num_mean_samples,
            },
        }

    def stats(self) -> dict:
        """Batcher occupancy + swap counters served at ``GET /stats``."""
        return {
            "generation": self.handle.generation,
            "swap_failures": self._swap_failures,
            "topk_mode": self._topk_mode,
            "batcher": self.batcher.stats(),
        }


def _make_http_server(server: BPMFServer, host: str, port: int) -> ThreadingHTTPServer:
    """Build the threaded HTTP front bound to ``server``."""

    class Handler(BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"
        # response status/headers/body are separate writes: without
        # TCP_NODELAY, Nagle + delayed ACK adds ~40ms per response
        disable_nagle_algorithm = True

        def _send(self, status: int, payload: dict) -> None:
            body = json.dumps(payload).encode()
            self.send_response(status)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self):  # noqa: N802 (http.server API)
            if self.path in ("/healthz", "/health"):
                self._send(200, server.health())
            elif self.path == "/stats":
                self._send(200, server.stats())
            else:
                self._send(404, {"error": f"unknown path {self.path!r}"})

        def do_POST(self):  # noqa: N802 (http.server API)
            if self.path not in ("/query", "/"):
                self._send(404, {"error": f"unknown path {self.path!r}"})
                return
            try:
                length = int(self.headers.get("Content-Length", 0))
            except ValueError:
                length = -1
            if length < 0 or length > _MAX_BODY_BYTES:
                self._send(400, {"error": "missing or oversized Content-Length"})
                return
            try:
                payload = json.loads(self.rfile.read(length))
            except ValueError as e:
                self._send(400, {"error": f"{type(e).__name__}: {e}"})
                return
            status, resp = server.handle_request(payload)
            self._send(status, resp)

        def log_message(self, fmt, *args):  # quiet: one line per request is noise
            pass

    return ThreadingHTTPServer((host, port), Handler)
