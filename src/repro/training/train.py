"""train_step factory: loss + grad + optimizer under explicit sharding.

The factory returns a pure ``(state, batch, key?) -> (state, metrics)``
function plus the in/out shardings needed to jit it on a mesh — the same
artifact the launcher jits for real steps and the dry-run lowers abstractly.

Microbatch gradient accumulation runs as a ``lax.scan`` over a reshaped
batch: [B, ...] -> [n_mb, B/n_mb, ...], grads accumulated in fp32. With
``n_mb == 1`` the scan disappears (no overhead path).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.model import LMModel
from repro.models.module import TRAIN_RULES, ShardingCtx, ShardingRules, resolve_spec
from repro.training.losses import chunked_lm_loss, total_loss
from repro.training.optimizer import AdamW, OptState
from repro.utils import pytree_dataclass

Tree = Any


@pytree_dataclass
class TrainState:
    params: Tree
    opt: OptState
    step: jax.Array  # [] int32


def init_train_state(key: jax.Array, model: LMModel, optimizer: AdamW) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=optimizer.init(params), step=jnp.zeros((), jnp.int32))


def abstract_train_state(model: LMModel, optimizer: AdamW) -> TrainState:
    """ShapeDtypeStruct state for the dry-run."""
    return jax.eval_shape(lambda k: init_train_state(k, model, optimizer), jax.random.key(0))


def batch_specs(cfg: ModelConfig, rules: ShardingRules, mesh: Mesh, batch: int, seq: int) -> dict:
    """PartitionSpecs for one training batch dict."""
    if cfg.input_mode == "tokens":
        inp = resolve_spec((batch, seq), ("batch", "seq"), rules, mesh)
    else:
        inp = resolve_spec((batch, seq, cfg.frame_dim), ("batch", "seq", None), rules, mesh)
    tok = resolve_spec((batch, seq), ("batch", "seq"), rules, mesh)
    return {"inputs": inp, "labels": tok, "mask": tok}


def abstract_batch(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """ShapeDtypeStruct batch for the dry-run / compile."""
    if cfg.input_mode == "tokens":
        inputs = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    else:
        inputs = jax.ShapeDtypeStruct((batch, seq, cfg.frame_dim), jnp.bfloat16)
    ids = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    return {"inputs": inputs, "labels": ids, "mask": jax.ShapeDtypeStruct((batch, seq), jnp.float32)}


def state_specs(model: LMModel, optimizer: AdamW, rules: ShardingRules, mesh: Mesh) -> TrainState:
    p = model.specs(rules, mesh)
    return TrainState(params=p, opt=optimizer.state_specs(p), step=P())


def make_train_step(
    model: LMModel,
    optimizer: AdamW,
    rules: ShardingRules = TRAIN_RULES,
    mesh: Optional[Mesh] = None,
    microbatches: int = 1,
    z_weight: float = 1e-4,
    loss_chunk: int = 512,
):
    """Returns ``train_step(state, batch) -> (state, metrics)`` (pure)."""
    cfg = model.cfg
    ctx = ShardingCtx(mesh=mesh, rules=rules) if mesh is not None else ShardingCtx()

    def loss_fn(params: Tree, batch: dict) -> tuple[jax.Array, dict]:
        hidden, moe_metrics = model.hidden(params, batch["inputs"], ctx=ctx)
        loss, metrics = chunked_lm_loss(
            lambda h: model.logits(
                params, ctx.constrain(h, ("loss_batch", "seq", "act_embed")), ctx
            ),
            hidden,
            batch["labels"],
            batch["mask"],
            chunk=loss_chunk,
            z_weight=z_weight,
        )
        if cfg.num_experts:
            loss = loss + cfg.router_aux_weight * moe_metrics["aux_loss"]
            loss = loss + 1e-3 * moe_metrics["router_z"]
            metrics = {**metrics, **moe_metrics}
        metrics["loss"] = loss
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def accum_grads(params: Tree, batch: dict) -> tuple[Tree, dict]:
        if microbatches == 1:
            (_, metrics), grads = grad_fn(params, batch)
            return grads, metrics
        mb = jax.tree.map(
            lambda x: x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:]), batch
        )
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, one):
            (_, metrics), grads = grad_fn(params, one)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return acc, metrics

        grads, metrics = jax.lax.scan(body, zero, mb)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        metrics = jax.tree.map(jnp.mean, metrics)
        return grads, metrics

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        grads, metrics = accum_grads(state.params, batch)
        params, opt, opt_metrics = optimizer.update(grads, state.opt, state.params)
        new_state = TrainState(params=params, opt=opt, step=state.step + 1)
        return new_state, {**metrics, **opt_metrics}

    return train_step


def jit_train_step(
    model: LMModel,
    optimizer: AdamW,
    mesh: Mesh,
    rules: ShardingRules = TRAIN_RULES,
    microbatches: int = 1,
    batch: int = 8,
    seq: int = 512,
    donate: bool = True,
):
    """jit the factory output with explicit in/out shardings on ``mesh``."""
    step_fn = make_train_step(model, optimizer, rules, mesh, microbatches)
    sspec = state_specs(model, optimizer, rules, mesh)
    bspec = batch_specs(model.cfg, rules, mesh, batch, seq)
    to_sharding = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )
    return jax.jit(
        step_fn,
        in_shardings=(to_sharding(sspec), to_sharding(bspec)),
        out_shardings=(to_sharding(sspec), None),
        donate_argnums=(0,) if donate else (),
    )
