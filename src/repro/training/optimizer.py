"""AdamW with schedule, global-norm clipping and low-precision moment option.

Distributed-optimization notes (DESIGN.md §6, EXPERIMENTS.md §Perf):

  * Moments inherit each parameter's sharding (same shape -> same
    PartitionSpec), so optimizer memory scales down with the 2D weight
    sharding for free — no separate ZeRO machinery is needed under GSPMD.
  * ``moment_dtype=bfloat16`` halves optimizer HBM for the >100B archs
    (nemotron-340b, grok-314b); the update math still runs in fp32
    (moments are upcast, the new moments rounded back).
  * The update is fully elementwise + one global-norm psum, so XLA fuses it
    into the backward pass tail; no blocking host work.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import pytree_dataclass

Tree = Any


@pytree_dataclass
class OptState:
    mu: Tree
    nu: Tree
    count: jax.Array  # [] int32


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.1) -> Callable:
    """Linear warmup then cosine decay to ``floor * peak``."""

    def schedule(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = peak * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return schedule


def global_norm(tree: Tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: float | Callable = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: Any = jnp.float32
    # leaves with >= this many elements update under lax.map chunks so the
    # fp32 upcasts never materialize for the whole stacked [L, ...] weight
    # at once (XLA:CPU does not fuse the elementwise chain; ~10 live fp32
    # temporaries of a 340B param stack = tens of GB)
    scan_update_elems: int = 32 * 1024 * 1024
    scan_chunks: int = 8

    def init(self, params: Tree) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, self.moment_dtype)
        return OptState(
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
            count=jnp.zeros((), jnp.int32),
        )

    def lr(self, step: jax.Array) -> jax.Array:
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(
        self, grads: Tree, state: OptState, params: Tree
    ) -> tuple[Tree, OptState, dict]:
        """Returns (new_params, new_state, metrics). All math fp32."""
        count = state.count + 1
        gnorm = global_norm(grads)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12))
        else:
            scale = jnp.ones((), jnp.float32)
        lr = self.lr(count)
        c1 = 1.0 - self.b1 ** count.astype(jnp.float32)
        c2 = 1.0 - self.b2 ** count.astype(jnp.float32)

        def upd_elem(g, m, v, p, decay):
            g = g.astype(jnp.float32) * scale
            m32 = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g
            v32 = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * jnp.square(g)
            step_ = lr * (m32 / c1) / (jnp.sqrt(v32 / c2) + self.eps)
            if decay:
                step_ = step_ + lr * self.weight_decay * p.astype(jnp.float32)
            return (
                (p.astype(jnp.float32) - step_).astype(p.dtype),
                m32.astype(self.moment_dtype),
                v32.astype(self.moment_dtype),
            )

        def upd(g, m, v, p):
            decay = bool(self.weight_decay) and p.ndim >= 2  # none on norms/biases
            n = int(np.prod(p.shape))
            lead = p.shape[0] if p.ndim else 0
            if n >= self.scan_update_elems and lead and lead % self.scan_chunks == 0:
                # chunk the leading (layer-stack) dim so fp32 temporaries
                # stay one chunk big
                def chunk(args):
                    return upd_elem(*args, decay)

                r = lambda x: x.reshape(self.scan_chunks, lead // self.scan_chunks, *p.shape[1:])
                po, mo, vo = jax.lax.map(chunk, (r(g), r(m), r(v), r(p)))
                return po.reshape(p.shape), mo.reshape(p.shape), vo.reshape(p.shape)
            return upd_elem(g, m, v, p, decay)

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        new_state = OptState(mu=new_mu, nu=new_nu, count=count)
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}

    def state_specs(self, p_specs: Tree) -> OptState:
        """Optimizer-state PartitionSpecs mirroring the parameter specs."""
        from jax.sharding import PartitionSpec as P

        return OptState(mu=p_specs, nu=p_specs, count=P())
