"""Serving for the reproduced system = BPMF posterior-mean prediction.

This module used to hold LM prefill/decode scaffolding unrelated to the
paper; that code now lives in :mod:`repro.training.lm_serve` (kept for the
dry-run/roofline tooling). Serving the *actual* workload — answering rating
queries from a sampled posterior without re-running MCMC — is owned by
:mod:`repro.serve`:

* ``BPMFEngine.export(dir)`` writes the versioned posterior artifact,
* :class:`repro.serve.PosteriorPredictor` loads it into a jit-compiled,
  mesh-sharded batch predictor (``predict`` / ``top_k``),
* ``python -m repro.launch.serve`` is the query CLI.

The re-exports below keep ``repro.training.serve`` importable as the
serving entry point; new code should import :mod:`repro.serve` directly.
"""
from repro.serve import (  # noqa: F401 — compatibility re-exports
    ArtifactError,
    ArtifactMeta,
    PosteriorPredictor,
    load_artifact,
    save_artifact,
)

__all__ = [
    "ArtifactError",
    "ArtifactMeta",
    "PosteriorPredictor",
    "load_artifact",
    "save_artifact",
]
