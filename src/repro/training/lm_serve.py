"""LM serving steps: prefill (prompt -> cache) and decode (one token / step).

Moved here from ``repro.training.serve`` when that module was repurposed for
BPMF posterior-mean serving (the repo's actual workload — see
:mod:`repro.serve`); these builders remain only for the LM dry-run/roofline
tooling (``repro.launch.dryrun``).

``decode_step`` is what the ``decode_32k`` / ``long_500k`` dry-run shapes
lower: one new token against a seq_len-deep cache. Sampling is greedy or
temperature-categorical; the sampled token is returned so a serving loop is
just ``lax.fori_loop`` / host loop over this pure function.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.model import LMModel
from repro.models.module import SERVE_RULES, ShardingCtx, ShardingRules, resolve_spec

Tree = Any


def make_prefill_step(model: LMModel, rules: ShardingRules = SERVE_RULES, mesh: Optional[Mesh] = None):
    ctx = ShardingCtx(mesh=mesh, rules=rules) if mesh is not None else ShardingCtx()

    def prefill_step(params: Tree, inputs: jax.Array, cache: Tree) -> tuple[jax.Array, Tree]:
        """(params, prompt [B,L], zero cache) -> (last logits [B,1,V], cache')."""
        return model.prefill(params, inputs, cache, ctx=ctx)

    return prefill_step


def make_decode_step(
    model: LMModel,
    rules: ShardingRules = SERVE_RULES,
    mesh: Optional[Mesh] = None,
    temperature: float = 0.0,
):
    ctx = ShardingCtx(mesh=mesh, rules=rules) if mesh is not None else ShardingCtx()

    def decode_step(
        params: Tree,
        tokens: jax.Array,  # [B, 1] int32 — last sampled tokens
        cache: Tree,
        pos: jax.Array,  # [] int32 — absolute position of this token
        key: jax.Array,
    ) -> tuple[jax.Array, Tree]:
        """Returns (next_tokens [B, 1], cache')."""
        logits, cache = model.decode(params, tokens, cache, pos[None], ctx=ctx)
        last = logits[:, -1, :]
        if temperature > 0:
            nxt = jax.random.categorical(key, last / temperature, axis=-1)
        else:
            nxt = jnp.argmax(last, axis=-1)
        return nxt[:, None].astype(jnp.int32), cache

    return decode_step


def greedy_generate(
    model: LMModel,
    params: Tree,
    prompt: jax.Array,  # [B, L] int32
    steps: int,
    max_len: int,
    rules: ShardingRules = SERVE_RULES,
    mesh: Optional[Mesh] = None,
) -> jax.Array:
    """Convenience loop for the examples: prefill then greedy decode."""
    B, L = prompt.shape
    cache = model.init_cache(B, max_len)
    prefill = jax.jit(make_prefill_step(model, rules, mesh))
    decode = jax.jit(make_decode_step(model, rules, mesh))
    logits, cache = prefill(params, prompt, cache)
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    key = jax.random.key(0)
    for t in range(steps - 1):
        tok, cache = decode(params, tok, cache, jnp.asarray(L + t, jnp.int32), key)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def serve_input_specs(model: LMModel, rules: ShardingRules, mesh: Mesh, batch: int):
    """PartitionSpecs for the decode-step token inputs."""
    tok = resolve_spec((batch, 1), ("batch", None), rules, mesh)
    return tok
