"""Losses: LM cross-entropy (+ z-loss), masked prediction, MoE auxiliaries.

Logits arrive fp32 (lm_head casts); the softmax cross-entropy is computed
with the max-subtracted logsumexp so bf16 activations upstream cannot
overflow it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def _loss_sums(logits: jax.Array, labels: jax.Array, mask: jax.Array) -> dict:
    """Masked sums (not means) so chunks combine exactly."""
    lse = jax.nn.logsumexp(logits, axis=-1)  # [B, L]
    picked = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    m = mask.astype(jnp.float32)
    return {
        "xent": jnp.sum((lse - picked) * m),
        "z": jnp.sum(jnp.square(lse) * m),
        "correct": jnp.sum((jnp.argmax(logits, -1) == labels) * m),
        "tokens": jnp.sum(m),
    }


def _finalize(sums: dict, z_weight: float) -> tuple[jax.Array, dict]:
    denom = jnp.maximum(sums["tokens"], 1.0)
    ce = sums["xent"] / denom
    z = sums["z"] / denom
    loss = ce + z_weight * z
    return loss, {"ce": ce, "z_loss": z, "accuracy": sums["correct"] / denom, "tokens": sums["tokens"]}


def lm_loss(
    logits: jax.Array,  # [B, L, V] fp32
    labels: jax.Array,  # [B, L] int32
    mask: jax.Array,  # [B, L] {0,1} — 1 = contributes to the loss
    z_weight: float = 1e-4,
) -> tuple[jax.Array, dict]:
    """Mean masked token cross-entropy + z-loss. Returns (loss, metrics)."""
    return _finalize(_loss_sums(logits, labels, mask), z_weight)


def chunked_lm_loss(
    head_fn,  # hidden [B, Lc, D] -> logits [B, Lc, V] (fp32)
    hidden: jax.Array,  # [B, L, D] final-norm'd backbone output
    labels: jax.Array,
    mask: jax.Array,
    chunk: int = 512,
    z_weight: float = 1e-4,
) -> tuple[jax.Array, dict]:
    """Cross-entropy with the vocabulary head applied per sequence chunk.

    The full [B, L, V] logits tensor is never materialized — at
    vocab=256k / 1M tokens it would be terabytes. ``lax.scan`` over L/chunk
    blocks keeps one [B, chunk, V] block live; the backward pass recomputes
    each block's logits (the head weights are reused, so this costs one
    extra head matmul — the standard memory/compute trade for big vocabs).
    """
    B, L, D = hidden.shape
    if L <= chunk or L % chunk != 0:
        return lm_loss(head_fn(hidden), labels, mask, z_weight)
    n = L // chunk
    hb = jnp.moveaxis(hidden.reshape(B, n, chunk, D), 1, 0)
    lb = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)
    mb = jnp.moveaxis(mask.reshape(B, n, chunk), 1, 0)

    @jax.checkpoint
    def body(acc, blk):
        # checkpointed: the [B, chunk, V] logits block is recomputed in the
        # backward pass instead of being saved per scan step (8 x 1.6 GB for
        # a 50k vocab — the whole point of chunking).
        h, l, m = blk
        s = _loss_sums(head_fn(h), l, m)
        return jax.tree.map(jnp.add, acc, s), None

    zero = {k: jnp.zeros((), jnp.float32) for k in ("xent", "z", "correct", "tokens")}
    sums, _ = jax.lax.scan(body, zero, (hb, lb, mb))
    return _finalize(sums, z_weight)


def total_loss(
    cfg: ModelConfig,
    logits: jax.Array,
    labels: jax.Array,
    mask: jax.Array,
    moe_metrics: dict,
    z_weight: float = 1e-4,
) -> tuple[jax.Array, dict]:
    """Task loss + MoE auxiliaries (over materialized logits — small-vocab /
    test path; the train_step uses the chunked head). The encoder (hubert)
    masked-prediction objective is the same xent restricted to corrupted
    positions — the data pipeline supplies that mask."""
    loss, metrics = lm_loss(logits, labels, mask, z_weight)
    if cfg.num_experts:
        aux = cfg.router_aux_weight * moe_metrics["aux_loss"]
        zr = 1e-3 * moe_metrics["router_z"]
        loss = loss + aux + zr
        metrics = {**metrics, **moe_metrics}
    metrics["loss"] = loss
    return loss, metrics
