from repro.training.losses import lm_loss, total_loss
from repro.training.optimizer import AdamW, OptState, warmup_cosine
from repro.training.train import TrainState, make_train_step, init_train_state
from repro.training.lm_serve import make_prefill_step, make_decode_step

__all__ = [
    "lm_loss",
    "total_loss",
    "AdamW",
    "OptState",
    "warmup_cosine",
    "TrainState",
    "make_train_step",
    "init_train_state",
    "make_prefill_step",
    "make_decode_step",
]
