"""Shared layer primitives: norms, rotary embeddings, MLP variants, embeddings.

Every layer is a pair (``desc_x(cfg) -> descriptor tree``, ``apply_x(params,
...) -> array``). Descriptors carry logical sharding axes (module.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.module import NO_SHARDING, ShardingCtx, TensorDesc, desc, fan_in_desc

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def desc_norm(cfg: ModelConfig, dim: int | None = None) -> dict:
    d = dim or cfg.d_model
    out = {"scale": desc((d,), ("act_embed",), init="ones", dtype=cfg.dtype("param"))}
    if cfg.norm == "layernorm":
        out["bias"] = desc((d,), ("act_embed",), init="zeros", dtype=cfg.dtype("param"))
    return out


def apply_norm(params: dict, x: jax.Array, cfg: ModelConfig, eps: float = 1e-6) -> jax.Array:
    """RMSNorm or LayerNorm; stats in fp32, output in input dtype."""
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies [head_dim // 2] (fp32)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate [..., seq, heads, head_dim] by per-position angles.

    ``positions``: [..., seq] int32. Split-half convention (llama).
    """
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)  # [half]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, half]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs: SwiGLU / GeGLU / squared-ReLU / GELU
# ---------------------------------------------------------------------------


def desc_mlp(cfg: ModelConfig, d_model: int | None = None, d_ff: int | None = None) -> dict:
    dm = d_model or cfg.d_model
    df = d_ff or cfg.d_ff
    pd = cfg.dtype("param")
    gated = cfg.mlp in ("swiglu", "geglu")
    out = {
        "w_up": fan_in_desc((dm, df), ("embed", "mlp"), dm, pd),
        "w_down": fan_in_desc((df, dm), ("mlp", "embed"), df, pd),
    }
    if gated:
        out["w_gate"] = fan_in_desc((dm, df), ("embed", "mlp"), dm, pd)
    return out


def apply_mlp(params: dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx = NO_SHARDING) -> jax.Array:
    """[..., d_model] -> [..., d_model]; activations in cfg.activation_dtype."""
    ad = cfg.dtype("act")
    x = x.astype(ad)
    w_up = ctx.weight(params["w_up"].astype(ad), ("embed", "mlp"))
    up = x @ w_up
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ ctx.weight(params["w_gate"].astype(ad), ("embed", "mlp"))) * up
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(x @ ctx.weight(params["w_gate"].astype(ad), ("embed", "mlp")), approximate=True) * up
    elif cfg.mlp == "relu2":  # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(up))
    elif cfg.mlp == "gelu":
        h = jax.nn.gelu(up, approximate=True)
    else:
        raise ValueError(f"unknown mlp {cfg.mlp!r}")
    h = ctx.constrain(h, ("batch", "seq", "mlp"))
    return h @ ctx.weight(params["w_down"].astype(ad), ("mlp", "embed"))


# ---------------------------------------------------------------------------
# Embeddings + output head
# ---------------------------------------------------------------------------


def desc_embed(cfg: ModelConfig) -> dict:
    pd = cfg.dtype("param")
    out: dict = {}
    if cfg.input_mode == "tokens":
        # padded so the table shards over the model axis (apply_lm_head masks
        # the padded tail out of the softmax)
        out["tok"] = desc((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), scale=1.0, dtype=pd)
    else:  # frames: a projection stub standing in for the modality frontend
        out["frame_proj"] = fan_in_desc(
            (cfg.frame_dim, cfg.d_model), ("embed_out", "embed"), cfg.frame_dim, pd
        )
    return out


def apply_embed(params: dict, inputs: jax.Array, cfg: ModelConfig,
                ctx: ShardingCtx = NO_SHARDING) -> jax.Array:
    ad = cfg.dtype("act")
    if cfg.input_mode == "tokens":
        # use-constrained table: under ZERO rules the lookup runs against a
        # [V/16, D] vocab-TP slice (masked local gather + small all-reduce);
        # gathering from the raw (vocab x embed)-2D-sharded table makes GSPMD
        # materialize batch-replicated [B, L, D/16] intermediates instead.
        tok = ctx.weight(params["tok"].astype(ad), ("vocab", "embed"))
        x = jnp.take(tok, inputs, axis=0)
        return x
    return (inputs.astype(ad) @ ctx.weight(params["frame_proj"].astype(ad), ("embed_out", "embed")))


def desc_lm_head(cfg: ModelConfig) -> dict:
    if cfg.tie_embeddings:
        return {}
    pd = cfg.dtype("param")
    # "embed_out" (data-only), not "embed": under ZERO rules the use-time
    # vocab-TP spec must be reachable from storage without a full reshard
    return {"w": fan_in_desc((cfg.d_model, cfg.padded_vocab), ("embed_out", "vocab"), cfg.d_model, pd)}


def apply_lm_head(params: dict, embed_params: dict, x: jax.Array, cfg: ModelConfig,
                  ctx: ShardingCtx = NO_SHARDING) -> jax.Array:
    """Final-norm'd hidden states -> logits [..., padded_vocab] (fp32).

    Padded vocab entries are masked to NEG_INF so they carry no softmax mass;
    callers may slice [..., :vocab_size] when handing logits to users."""
    ad = cfg.dtype("act")
    if cfg.tie_embeddings:
        w = ctx.weight(embed_params["tok"].astype(ad), ("vocab", "embed")).T
    else:
        w = ctx.weight(params["w"].astype(ad), ("embed_out", "vocab"))
    logits = (x.astype(ad) @ w).astype(jnp.float32)
    if cfg.logits_softcap > 0:
        c = cfg.logits_softcap
        logits = c * jnp.tanh(logits / c)
    if cfg.padded_vocab != cfg.vocab_size:
        neg = -0.7 * float(jnp.finfo(jnp.float32).max)
        logits = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab_size, logits, neg)
    return logits
