"""Config -> model builder: one object tying descriptors, forward passes,
caches and sharding together for every assigned architecture.

The same ``LMModel`` drives training (``forward``), inference
(``prefill`` / ``decode``) and the multi-pod dry-run (``abstract`` /
``abstract_cache`` — ShapeDtypeStructs, zero allocation).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import transformer
from repro.models.attention import KVCache, MLACache
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_embed,
    apply_lm_head,
    apply_norm,
    desc_embed,
    desc_lm_head,
    desc_norm,
)
from repro.models.mamba2 import SSMState
from repro.models.module import (
    NO_SHARDING,
    ShardingCtx,
    ShardingRules,
    abstract_params,
    init_params,
    param_shardings,
    param_specs,
    resolve_spec,
)
from repro.models.transformer import HybridCache

Tree = Any


@dataclasses.dataclass(frozen=True)
class LMModel:
    """A built architecture. Stateless: params/caches are passed explicitly."""

    cfg: ModelConfig

    # ------------------------------------------------------------------
    # Parameters
    # ------------------------------------------------------------------

    def descs(self) -> Tree:
        cfg = self.cfg
        out = {
            "embed": desc_embed(cfg),
            "stack": transformer.desc_stack(cfg),
            "ln_final": desc_norm(cfg),
            "head": desc_lm_head(cfg),
        }
        return out

    def init(self, key: jax.Array) -> Tree:
        return init_params(key, self.descs())

    def abstract(self) -> Tree:
        return abstract_params(self.descs())

    def specs(self, rules: ShardingRules, mesh: Mesh) -> Tree:
        return param_specs(self.descs(), rules, mesh)

    def shardings(self, rules: ShardingRules, mesh: Mesh) -> Tree:
        return param_shardings(self.descs(), rules, mesh)

    def num_params(self) -> int:
        return sum(
            int(math.prod(d.shape))
            for d in jax.tree.leaves(self.descs(), is_leaf=lambda x: hasattr(x, "axes"))
        )

    def active_params(self) -> int:
        """Params touched per token (MoE: top-k of experts)."""
        cfg = self.cfg
        total = self.num_params()
        if not cfg.num_experts:
            return total
        gated = 3 if cfg.mlp in ("swiglu", "geglu") else 2
        expert = gated * cfg.d_model * cfg.d_ff
        inactive = cfg.num_layers * (cfg.num_experts - cfg.num_experts_per_tok) * expert
        return total - inactive

    def matmul_params(self) -> int:
        """Active params that participate in matmuls per token — the N of the
        6·N·D MODEL_FLOPS convention. The input-embedding gather is not a
        matmul, so the table is excluded; with tied embeddings the table *is*
        the head matmul, so it stays counted once."""
        n = self.active_params()
        if self.cfg.input_mode == "tokens" and not self.cfg.tie_embeddings:
            n -= self.cfg.padded_vocab * self.cfg.d_model
        return n

    # ------------------------------------------------------------------
    # Forward passes
    # ------------------------------------------------------------------

    def _embed(self, params: Tree, inputs: jax.Array, ctx: ShardingCtx) -> jax.Array:
        cfg = self.cfg
        x = apply_embed(params["embed"], inputs, cfg, ctx)
        if cfg.scale_embeddings:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        return ctx.constrain(x, ("batch", "seq", "act_embed"))

    def _head(self, params: Tree, x: jax.Array, ctx: ShardingCtx = NO_SHARDING) -> jax.Array:
        x = apply_norm(params["ln_final"], x, self.cfg)
        return apply_lm_head(params["head"], params["embed"], x, self.cfg, ctx)

    def hidden(
        self,
        params: Tree,
        inputs: jax.Array,
        positions: Optional[jax.Array] = None,
        ctx: ShardingCtx = NO_SHARDING,
    ) -> tuple[jax.Array, dict]:
        """Backbone only: final-norm'd hidden states [B, L, D] + metrics.
        The training loss chunks the (huge-vocab) head over this output."""
        L = inputs.shape[1]
        if positions is None:
            positions = jnp.arange(L, dtype=jnp.int32)
        x = self._embed(params, inputs, ctx)
        x, _, metrics = transformer.apply_stack(params["stack"], x, positions, self.cfg, ctx)
        return apply_norm(params["ln_final"], x, self.cfg), metrics

    def logits(self, params: Tree, hidden: jax.Array, ctx: ShardingCtx = NO_SHARDING) -> jax.Array:
        """LM head over (already final-norm'd) hidden states."""
        return apply_lm_head(params["head"], params["embed"], hidden, self.cfg, ctx)

    def forward(
        self,
        params: Tree,
        inputs: jax.Array,  # tokens [B, L] int32 | frames [B, L, frame_dim]
        positions: Optional[jax.Array] = None,  # [L] int32
        ctx: ShardingCtx = NO_SHARDING,
    ) -> tuple[jax.Array, dict]:
        """Stateless training/encoder forward. Returns (logits [B,L,V], metrics)."""
        x, metrics = self.hidden(params, inputs, positions, ctx)
        return self.logits(params, x, ctx), metrics

    def prefill(
        self,
        params: Tree,
        inputs: jax.Array,
        cache: Tree,
        positions: Optional[jax.Array] = None,
        ctx: ShardingCtx = NO_SHARDING,
    ) -> tuple[jax.Array, Tree]:
        """Fill the cache with a prompt; returns (last-position logits, cache')."""
        L = inputs.shape[1]
        if positions is None:
            positions = jnp.arange(L, dtype=jnp.int32)
        x = self._embed(params, inputs, ctx)
        x, new_cache, _ = transformer.apply_stack(
            params["stack"], x, positions, self.cfg, ctx, caches=cache, return_state=True
        )
        logits = self._head(params, x[:, -1:, :], ctx)
        return logits, new_cache

    def decode(
        self,
        params: Tree,
        tokens: jax.Array,  # [B, 1] int32 (or [B, 1, frame_dim])
        cache: Tree,
        positions: jax.Array,  # [1] int32 absolute position
        ctx: ShardingCtx = NO_SHARDING,
    ) -> tuple[jax.Array, Tree]:
        """One-token decode step. Returns (logits [B,1,V], cache')."""
        x = self._embed(params, tokens, ctx)
        x, new_cache, _ = transformer.apply_stack(
            params["stack"], x, positions, self.cfg, ctx, caches=cache
        )
        return self._head(params, x, ctx), new_cache

    # ------------------------------------------------------------------
    # Caches
    # ------------------------------------------------------------------

    def init_cache(self, batch: int, max_len: int) -> Tree:
        return transformer.init_caches(self.cfg, batch, max_len)

    def abstract_cache(self, batch: int, max_len: int) -> Tree:
        return transformer.abstract_caches(self.cfg, batch, max_len)

    def cache_specs(self, rules: ShardingRules, mesh: Mesh, batch: int, max_len: int) -> Tree:
        """PartitionSpec tree matching ``init_cache``'s structure."""
        abstract = self.abstract_cache(batch, max_len)
        if abstract is None:
            return None
        axes = _cache_axes(self.cfg)
        return jax.tree.map(
            lambda leaf, ax: resolve_spec(leaf.shape, ax, rules, mesh),
            abstract,
            axes,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )

    def cache_shardings(self, rules: ShardingRules, mesh: Mesh, batch: int, max_len: int) -> Tree:
        specs = self.cache_specs(rules, mesh, batch, max_len)
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
        )


# ---------------------------------------------------------------------------
# Cache logical axes (per family) — mirrors transformer.init_caches structure
# ---------------------------------------------------------------------------


def _kv_axes(lead: tuple[str, ...], rolling: bool = False) -> KVCache:
    return KVCache(
        k=(*lead, "batch", "cache_seq", "kv_heads", "kv_head_dim"),
        v=(*lead, "batch", "cache_seq", "kv_heads", "kv_head_dim"),
        next_pos=lead,
        rolling=rolling,  # static field must match the real cache's pytree aux
    )


def _mla_axes(lead: tuple[str, ...]) -> MLACache:
    return MLACache(
        ckv=(*lead, "batch", "cache_seq", "latent"),
        kpe=(*lead, "batch", "cache_seq", None),
        next_pos=lead,
    )


def _ssm_axes(lead: tuple[str, ...]) -> SSMState:
    return SSMState(
        S=(*lead, "batch", "ssm_heads", None, "state"),
        conv=(*lead, "batch", "conv", "inner"),
        next_pos=lead,
    )


def _cache_axes(cfg: ModelConfig) -> Tree:
    rolling = cfg.sliding_window is not None
    if cfg.family == "ssm":
        return _ssm_axes(("layers",))
    if cfg.family == "hybrid":
        return HybridCache(ssm=_ssm_axes(("layers", None)), attn=_kv_axes(("layers",), rolling))
    if cfg.attention == "mla":
        return _mla_axes(("layers",))
    return _kv_axes(("layers",), rolling)


def build_model(cfg: ModelConfig) -> LMModel:
    return LMModel(cfg=cfg)
