"""Attention variants: GQA / MQA (kv=1) / MLA (latent-compressed) / SWA.

One module covers the assigned archs:

  * GQA with arbitrary q-per-kv grouping (yi, nemotron, chameleon, grok,
    mixtral, zamba2-shared-block, hubert with kv == heads)
  * MQA as GQA with num_kv_heads == 1 (gemma)
  * qk-norm (chameleon's query/key layernorm)
  * sliding-window attention with a rolling KV cache (mixtral) — the cache
    allocation is ``window`` slots regardless of logical position, which is
    what makes the 500k-token decode shape deployable
  * MLA (minicpm3): queries/keys/values reconstructed from a low-rank latent;
    the cache stores only [ckv (kv_lora) | k_pe (rope_dim)] per token.

Caches are pytrees; decode steps are pure functions (cache in, cache out).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, rms_norm
from repro.models.module import NO_SHARDING, ShardingCtx, desc, fan_in_desc
from repro.utils import pytree_dataclass, static_field

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


@pytree_dataclass
class KVCache:
    """Dense or rolling KV cache.

    ``k``/``v``: [B, W, KV, hd]. For full attention W = max_len and slot i
    holds position i. For sliding-window attention W = window and slot i
    holds the latest position p < next_pos with p % W == i.
    """

    k: jax.Array
    v: jax.Array
    next_pos: jax.Array  # [] int32 — tokens cached so far (same for the batch)
    rolling: bool = static_field(default=False)

    @property
    def window(self) -> int:
        return self.k.shape[1]


@pytree_dataclass
class MLACache:
    """Latent cache: per token only kv_lora + rope_dim floats."""

    ckv: jax.Array  # [B, S, kv_lora]
    kpe: jax.Array  # [B, S, rope_dim]
    next_pos: jax.Array


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> KVCache:
    dt = dtype or cfg.dtype("act")
    window = cfg.sliding_window if cfg.sliding_window is not None else max_len
    W = min(window, max_len)
    shape = (batch, W, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(
        k=jnp.zeros(shape, dt),
        v=jnp.zeros(shape, dt),
        next_pos=jnp.zeros((), jnp.int32),
        rolling=cfg.sliding_window is not None,
    )


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> MLACache:
    dt = dtype or cfg.dtype("act")
    return MLACache(
        ckv=jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
        kpe=jnp.zeros((batch, max_len, cfg.qk_rope_dim), dt),
        next_pos=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Descriptors
# ---------------------------------------------------------------------------


def desc_attention(cfg: ModelConfig) -> dict:
    pd = cfg.dtype("param")
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if cfg.attention == "mla":
        r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
        dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        out = {
            "w_dkv": fan_in_desc((D, r_kv), ("embed", "latent"), D, pd),
            "w_kpe": fan_in_desc((D, dr), ("embed", "head_dim"), D, pd),
            "kv_norm": desc((r_kv,), ("latent",), init="ones", dtype=pd),
            "w_uk": fan_in_desc((r_kv, H, dn), ("latent", "q_heads", "head_dim"), r_kv, pd),
            "w_uv": fan_in_desc((r_kv, H, dv), ("latent", "q_heads", "head_dim"), r_kv, pd),
            "w_o": fan_in_desc((H, dv, D), ("q_heads", "head_dim", "embed"), H * dv, pd),
        }
        if r_q > 0:
            out["w_dq"] = fan_in_desc((D, r_q), ("embed", "latent"), D, pd)
            out["q_norm"] = desc((r_q,), ("latent",), init="ones", dtype=pd)
            out["w_uq"] = fan_in_desc((r_q, H, dn + dr), ("latent", "q_heads", "head_dim"), r_q, pd)
        else:
            out["w_q"] = fan_in_desc((D, H, dn + dr), ("embed", "q_heads", "head_dim"), D, pd)
        return out

    out = {
        "w_q": fan_in_desc((D, H, hd), ("embed", "q_heads", "head_dim"), D, pd),
        "w_k": fan_in_desc((D, KV, hd), ("embed", "kv_heads", "head_dim"), D, pd),
        "w_v": fan_in_desc((D, KV, hd), ("embed", "kv_heads", "head_dim"), D, pd),
        "w_o": fan_in_desc((H, hd, D), ("q_heads", "head_dim", "embed"), H * hd, pd),
    }
    if cfg.qk_norm:
        out["q_norm"] = desc((hd,), ("head_dim",), init="ones", dtype=pd)
        out["k_norm"] = desc((hd,), ("head_dim",), init="ones", dtype=pd)
    return out


# ---------------------------------------------------------------------------
# Masking
# ---------------------------------------------------------------------------


def attention_mask(
    q_pos: jax.Array,  # [Lq] int32 absolute positions of queries
    kv_pos: jax.Array,  # [S] int32 absolute positions of keys (-1 = invalid slot)
    causal: bool,
    window: Optional[int],
) -> jax.Array:
    """Bool [Lq, S]; True = attend."""
    valid = kv_pos[None, :] >= 0
    m = valid
    if causal:
        m = m & (kv_pos[None, :] <= q_pos[:, None])
    if window is not None:
        m = m & (kv_pos[None, :] > q_pos[:, None] - window)
    return m


def rolling_slot_positions(next_pos: jax.Array, window: int) -> jax.Array:
    """Absolute position held by each rolling-cache slot (-1 if empty).

    Slot i holds the largest p < next_pos with p % W == i.
    """
    i = jnp.arange(window, dtype=jnp.int32)
    np_ = next_pos.astype(jnp.int32)
    cycles = (np_ - 1 - i) // window  # floor; negative when slot unwritten
    pos = i + cycles * window
    return jnp.where((np_ > 0) & (pos >= 0), pos, -1)


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------


def _attend_dense(
    q: jax.Array,  # [B, Lq, H, dh]
    k: jax.Array,  # [B, S, KV, dh]
    v: jax.Array,  # [B, S, KV, dv]
    mask: jax.Array,  # [Lq, S] bool
    scale: float,
    ctx: ShardingCtx = NO_SHARDING,
) -> jax.Array:
    """Grouped dot-product attention, fp32 softmax. Returns [B, Lq, H, dv].

    KV heads are EXPANDED to H before the einsums: a [B, Lq, KV, G, dh]
    factorization of tensor-sharded q heads is inexpressible for GSPMD
    (H=16-way sharding does not decompose over (KV, G) dims), which makes it
    re-shard via [B, L, ...]-sized all-reduces every layer. The repeat of the
    small replicated k/v is shard-local and costs no flops.

    Materializes the [Lq, S] logits — the oracle / short-sequence path."""
    B, Lq, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    logits = jnp.einsum("blhd,bshd->bhls", q, k, preferred_element_type=jnp.float32)
    logits = logits * scale
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhls,bshd->blhd", probs, v, preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def _pad_axis(x: jax.Array, axis: int, to: int) -> jax.Array:
    pad = to - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _attend_flash(
    q: jax.Array,  # [B, Lq, H, dh]
    k: jax.Array,  # [B, S, KV, dh]
    v: jax.Array,  # [B, S, KV, dv]
    q_pos: jax.Array,  # [Lq] int32
    kv_pos: jax.Array,  # [S] int32 (-1 = invalid)
    causal: bool,
    window: Optional[int],
    scale: float,
    q_chunk: int,
    kv_chunk: int,
    q_parallel: bool = False,
    ctx: ShardingCtx = NO_SHARDING,
) -> jax.Array:
    """Flash-style two-level scan: running (max, denom, acc) over KV blocks,
    outer scan over Q blocks. Never materializes more than one
    [B, KV, G, Qc, Kc] logits block — this is what makes the 32k-prefill and
    500k-decode shapes lowerable. The Pallas kernel (kernels/flash_attn.py)
    implements the same schedule for TPU; this is its jnp reference.
    """
    B, Lq, H, dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // KV
    ad = q.dtype

    Qc = min(q_chunk, Lq)
    Kc = min(kv_chunk, S)
    Lq_p = -(-Lq // Qc) * Qc
    S_p = -(-S // Kc) * Kc
    q = _pad_axis(q, 1, Lq_p)
    q_pos_p = _pad_axis(q_pos, 0, Lq_p)
    k = _pad_axis(k, 1, S_p)
    v = _pad_axis(v, 1, S_p)
    kv_pos_p = jnp.where(
        jnp.arange(S_p) < S, _pad_axis(kv_pos, 0, S_p), jnp.asarray(-1, jnp.int32)
    )
    nq, nk = Lq_p // Qc, S_p // Kc

    G = H // KV
    qb = jnp.moveaxis(q.reshape(B, nq, Qc, H, dh), 1, 0)  # [nq, B, Qc, H, dh]
    kb = jnp.moveaxis(k.reshape(B, nk, Kc, KV, dh), 1, 0)  # [nk, B, Kc, KV, dh]
    vb = jnp.moveaxis(v.reshape(B, nk, Kc, KV, dv), 1, 0)
    qpb = q_pos_p.reshape(nq, Qc)
    kpb = kv_pos_p.reshape(nk, Kc)

    @jax.checkpoint
    def q_body(_, qblk):
        # checkpointed: without it the backward saves every [B, H, Qc, Kc]
        # probability block of BOTH scans — the full attention matrix flash
        # exists to avoid. Backward recomputes the kv scan per q block.
        qi, qp = qblk  # [B, Qc, H, dh], [Qc]
        m0 = jnp.full((B, H, Qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, Qc), jnp.float32)
        o0 = jnp.zeros((B, Qc, H, dv), jnp.float32)

        def kv_body(carry, kvblk):
            m, l, o = carry
            kj, vj, kp = kvblk
            if G > 1:  # expand KV->H per block (see _attend_dense note)
                kj = jnp.repeat(kj, G, axis=2)
                vj = jnp.repeat(vj, G, axis=2)
            s = (
                jnp.einsum("bqhd,bshd->bhqs", qi, kj, preferred_element_type=jnp.float32)
                * scale
            )
            mask = attention_mask(qp, kp, causal, window)  # [Qc, Kc]
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None]) * mask[None, None]
            alpha = jnp.exp(m - m_new)  # [B, H, Qc]
            l = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bhqs,bshd->bqhd", p.astype(ad), vj, preferred_element_type=jnp.float32
            )
            o = o * alpha.transpose(0, 2, 1)[..., None] + pv
            return (m_new, l, o), None

        (m, l, o), _ = jax.lax.scan(kv_body, (m0, l0, o0), (kb, vb, kpb))
        denom = l.transpose(0, 2, 1)[..., None]  # [B, Qc, H, 1]
        out = jnp.where(denom > 0, o / jnp.maximum(denom, 1e-37), 0.0)
        return 0, out.astype(ad)

    if q_parallel and nq > 1:
        # SEQUENCE-PARALLEL prefill: q blocks are independent, so instead of
        # scanning them (which forces the sharded seq dim through
        # dynamic-slices and makes GSPMD replicate the whole attention), run
        # them vmapped with the block axis sharded over "model" — per-device
        # attention work drops by the model-axis width. Batch stays on
        # (pod, data); together the grid covers the full mesh.
        qb_c = ctx.constrain(qb, ("qblocks", "batch", None, "q_heads", "head_dim"))
        outs = jax.vmap(lambda qi, qp: q_body(0, (qi, qp))[1])(qb_c, qpb)
        outs = ctx.constrain(outs, ("qblocks", "batch", None, "q_heads", "head_dim"))
    else:
        _, outs = jax.lax.scan(q_body, 0, (qb, qpb))  # [nq, B, Qc, H, dv]
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Lq_p, H, dv)
    return out[:, :Lq]


def _attend(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    kv_pos: jax.Array,
    cfg: ModelConfig,
    scale: float,
    ctx: ShardingCtx = NO_SHARDING,
) -> jax.Array:
    """Dispatch: dense for short (Lq, S); flash-chunked beyond the thresholds."""
    Lq, S = q.shape[1], k.shape[1]
    if Lq <= cfg.attn_q_chunk and S <= cfg.attn_kv_chunk:
        mask = attention_mask(q_pos, kv_pos, cfg.causal, cfg.sliding_window)
        return _attend_dense(q, k, v, mask, scale, ctx)
    return _attend_flash(
        q, k, v, q_pos, kv_pos, cfg.causal, cfg.sliding_window, scale,
        cfg.attn_q_chunk, cfg.attn_kv_chunk, cfg.flash_q_parallel, ctx,
    )


def apply_attention(
    params: dict,
    x: jax.Array,  # [B, L, D]
    positions: jax.Array,  # [L] int32 absolute positions
    cfg: ModelConfig,
    ctx: ShardingCtx = NO_SHARDING,
    cache: Optional[KVCache] = None,
) -> tuple[jax.Array, Optional[KVCache]]:
    """GQA/MQA/SWA attention. With ``cache``, appends L tokens then attends
    over the cache (L=1 is the decode step); without, self-attends over x."""
    ad = cfg.dtype("act")
    B, L, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    x = x.astype(ad)

    qkv_axes = ("embed", "kv_heads", "head_dim")
    q = jnp.einsum("bld,dhk->blhk", x, ctx.weight(params["w_q"].astype(ad), ("embed", "q_heads", "head_dim")))
    k = jnp.einsum("bld,dhk->blhk", x, ctx.weight(params["w_k"].astype(ad), qkv_axes))
    v = jnp.einsum("bld,dhk->blhk", x, ctx.weight(params["w_v"].astype(ad), qkv_axes))
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = ctx.constrain(q, ("batch", "seq", "q_heads", "head_dim"))
    k = ctx.constrain(k, ("batch", "seq", "kv_heads", "head_dim"))
    scale = hd**-0.5

    if cache is None:
        out = _attend(q, k, v, positions, positions, cfg, scale, ctx)
        new_cache = None
    else:
        W = cache.window
        if cache.rolling:
            slots = positions % W
            k_cache = cache.k.at[:, slots].set(k.astype(cache.k.dtype))
            v_cache = cache.v.at[:, slots].set(v.astype(cache.v.dtype))
            next_pos = positions[-1] + 1
            kv_pos = rolling_slot_positions(next_pos, W)
        else:
            k_cache = jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, positions[0], 0, 0)
            )
            v_cache = jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, positions[0], 0, 0)
            )
            next_pos = positions[-1] + 1
            kv_pos = jnp.arange(W, dtype=jnp.int32)
            kv_pos = jnp.where(kv_pos < next_pos, kv_pos, -1)
        k_cache = ctx.constrain(k_cache, ("batch", "cache_seq", "kv_heads", "head_dim"))
        v_cache = ctx.constrain(v_cache, ("batch", "cache_seq", "kv_heads", "head_dim"))
        out = _attend(q, k_cache, v_cache, positions, kv_pos, cfg, scale, ctx)
        new_cache = KVCache(k=k_cache, v=v_cache, next_pos=next_pos, rolling=cache.rolling)

    y = jnp.einsum("blhk,hkd->bld", out, ctx.weight(params["w_o"].astype(ad), ("q_heads", "head_dim", "embed")))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (minicpm3 / deepseek-style latent attention)
# ---------------------------------------------------------------------------


def _mla_qkv(params: dict, x: jax.Array, positions: jax.Array, cfg: ModelConfig,
             ctx: ShardingCtx = NO_SHARDING):
    """Queries + new latent entries for x. Returns (q_nope, q_pe, ckv, kpe)."""
    ad = cfg.dtype("act")
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank > 0:
        cq = rms_norm(x @ ctx.weight(params["w_dq"].astype(ad), ("embed", "latent")), params["q_norm"])
        q = jnp.einsum("blr,rhk->blhk", cq, ctx.weight(params["w_uq"].astype(ad), ("latent", "q_heads", "head_dim")))
    else:
        q = jnp.einsum("bld,dhk->blhk", x, ctx.weight(params["w_q"].astype(ad), ("embed", "q_heads", "head_dim")))
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    ckv = x @ ctx.weight(params["w_dkv"].astype(ad), ("embed", "latent"))  # [B, L, r_kv]
    kpe = x @ ctx.weight(params["w_kpe"].astype(ad), ("embed", "head_dim"))  # [B, L, dr]
    kpe = apply_rope(kpe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_pe, ckv, kpe


def _mla_attend_dense(
    params: dict,
    q_nope: jax.Array,  # [B, Lq, H, dn]
    q_pe: jax.Array,  # [B, Lq, H, dr]
    ckv: jax.Array,  # [B, S, r_kv] (normalized below)
    kpe: jax.Array,  # [B, S, dr]
    mask: jax.Array,  # [Lq, S]
    cfg: ModelConfig,
    ctx: ShardingCtx = NO_SHARDING,
) -> jax.Array:
    ad = cfg.dtype("act")
    up_axes = ("latent", "q_heads", "head_dim")
    ckv_n = rms_norm(ckv, params["kv_norm"])
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv_n, ctx.weight(params["w_uk"].astype(ad), up_axes))
    v = jnp.einsum("bsr,rhk->bshk", ckv_n, ctx.weight(params["w_uv"].astype(ad), up_axes))
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    logits = jnp.einsum("blhk,bshk->bhls", q_nope, k_nope, preferred_element_type=jnp.float32)
    logits = logits + jnp.einsum("blhk,bsk->bhls", q_pe, kpe, preferred_element_type=jnp.float32)
    logits = logits * scale
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, -1).astype(v.dtype)
    out = jnp.einsum("bhls,bshk->blhk", probs, v, preferred_element_type=jnp.float32).astype(ad)
    return jnp.einsum("blhk,hkd->bld", out, ctx.weight(params["w_o"].astype(ad), ("q_heads", "head_dim", "embed")))


def _mla_attend_flash(
    params: dict,
    q_nope: jax.Array,  # [B, Lq, H, dn]
    q_pe: jax.Array,  # [B, Lq, H, dr]
    ckv: jax.Array,  # [B, S, r_kv]
    kpe: jax.Array,  # [B, S, dr]
    q_pos: jax.Array,
    kv_pos: jax.Array,
    cfg: ModelConfig,
    ctx: ShardingCtx = NO_SHARDING,
) -> jax.Array:
    """Chunked MLA with *matrix absorption*: w_uk is folded into the query
    (``q_eff = q_nope @ w_uk``) so attention runs entirely in the latent
    space — KV blocks are raw [Kc, r_kv] cache slices, no per-block
    key/value reconstruction. The value up-projection w_uv is applied once
    to the accumulated latent output. This is the standard MLA decode
    optimization; here it also bounds prefill memory.
    """
    ad = cfg.dtype("act")
    B, Lq, H, dn = q_nope.shape
    S, r = ckv.shape[1], ckv.shape[2]
    dr = q_pe.shape[-1]
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5

    ckv_n = rms_norm(ckv, params["kv_norm"])
    Qc = min(cfg.attn_q_chunk, Lq)
    Kc = min(cfg.attn_kv_chunk, S)
    Lq_p = -(-Lq // Qc) * Qc
    S_p = -(-S // Kc) * Kc
    q_nope = _pad_axis(q_nope, 1, Lq_p)
    q_pe = _pad_axis(q_pe, 1, Lq_p)
    q_pos_p = _pad_axis(q_pos, 0, Lq_p)
    ckv_n = _pad_axis(ckv_n, 1, S_p)
    kpe_p = _pad_axis(kpe, 1, S_p)
    kv_pos_p = jnp.where(
        jnp.arange(S_p) < S, _pad_axis(kv_pos, 0, S_p), jnp.asarray(-1, jnp.int32)
    )
    nq, nk = Lq_p // Qc, S_p // Kc

    qnb = jnp.moveaxis(q_nope.reshape(B, nq, Qc, H, dn), 1, 0)
    qpb = jnp.moveaxis(q_pe.reshape(B, nq, Qc, H, dr), 1, 0)
    qposb = q_pos_p.reshape(nq, Qc)
    cb = jnp.moveaxis(ckv_n.reshape(B, nk, Kc, r), 1, 0)
    kpeb = jnp.moveaxis(kpe_p.reshape(B, nk, Kc, dr), 1, 0)
    kposb = kv_pos_p.reshape(nk, Kc)
    w_uk = ctx.weight(params["w_uk"].astype(ad), ("latent", "q_heads", "head_dim"))

    @jax.checkpoint
    def q_body(_, qblk):  # checkpointed — see _attend_flash
        qn, qp, qpos = qblk
        q_eff = jnp.einsum("bqhk,rhk->bqhr", qn, w_uk)  # absorbed query
        m0 = jnp.full((B, H, Qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, Qc), jnp.float32)
        o0 = jnp.zeros((B, Qc, H, r), jnp.float32)  # latent-space accumulator

        def kv_body(carry, kvblk):
            m, l, o = carry
            cj, kj, kp = kvblk
            s = jnp.einsum("bqhr,bsr->bhqs", q_eff, cj, preferred_element_type=jnp.float32)
            s = s + jnp.einsum("bqhk,bsk->bhqs", qp, kj, preferred_element_type=jnp.float32)
            s = s * scale
            mask = attention_mask(qpos, kp, cfg.causal, cfg.sliding_window)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None]) * mask[None, None]
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            pc = jnp.einsum("bhqs,bsr->bqhr", p.astype(ad), cj, preferred_element_type=jnp.float32)
            o = o * alpha.transpose(0, 2, 1)[..., None] + pc
            return (m_new, l, o), None

        (m, l, o), _ = jax.lax.scan(kv_body, (m0, l0, o0), (cb, kpeb, kposb))
        denom = l.transpose(0, 2, 1)[..., None]
        out = jnp.where(denom > 0, o / jnp.maximum(denom, 1e-37), 0.0)
        return 0, out.astype(ad)

    _, outs = jax.lax.scan(q_body, 0, (qnb, qpb, qposb))  # [nq, B, Qc, H, r]
    o_latent = jnp.moveaxis(outs, 0, 1).reshape(B, Lq_p, H, r)[:, :Lq]
    out = jnp.einsum("blhr,rhk->blhk", o_latent, ctx.weight(params["w_uv"].astype(ad), ("latent", "q_heads", "head_dim")))
    return jnp.einsum("blhk,hkd->bld", out, ctx.weight(params["w_o"].astype(ad), ("q_heads", "head_dim", "embed")))


def _mla_attend_materialized(
    params: dict,
    q_nope: jax.Array,  # [B, Lq, H, dn]
    q_pe: jax.Array,  # [B, Lq, H, dr]
    ckv: jax.Array,  # [B, S, r_kv]
    kpe: jax.Array,  # [B, S, dr]
    q_pos: jax.Array,
    kv_pos: jax.Array,
    cfg: ModelConfig,
    ctx: ShardingCtx = NO_SHARDING,
) -> jax.Array:
    """Long-Lq (prefill/training) path: reconstruct per-head k/v ONCE and run
    the standard flash kernel. Absorption (latent-space attention) is a
    decode-time win, but at prefill it contracts every logits block over
    r_kv=256 instead of dn=64 — 4x the flops of just materializing
    [B, S, H, dn+dv] up front (0.7 GB/device at 32k)."""
    ad = cfg.dtype("act")
    up_axes = ("latent", "q_heads", "head_dim")
    ckv_n = rms_norm(ckv, params["kv_norm"])
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv_n, ctx.weight(params["w_uk"].astype(ad), up_axes))
    v = jnp.einsum("bsr,rhk->bshk", ckv_n, ctx.weight(params["w_uv"].astype(ad), up_axes))
    H = q_nope.shape[2]
    kpe_h = jnp.broadcast_to(kpe[:, :, None, :], (*kpe.shape[:2], H, kpe.shape[-1]))
    q = jnp.concatenate([q_nope, q_pe], axis=-1)  # [B, Lq, H, dn+dr]
    k = jnp.concatenate([k_nope, kpe_h], axis=-1)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    out = _attend_flash(
        q, k, v, q_pos, kv_pos, cfg.causal, cfg.sliding_window, scale,
        cfg.attn_q_chunk, cfg.attn_kv_chunk, cfg.flash_q_parallel, ctx,
    )
    return jnp.einsum("blhk,hkd->bld", out.astype(ad),
                      ctx.weight(params["w_o"].astype(ad), ("q_heads", "head_dim", "embed")))


def _mla_attend(
    params: dict,
    q_nope: jax.Array,
    q_pe: jax.Array,
    ckv: jax.Array,
    kpe: jax.Array,
    q_pos: jax.Array,
    kv_pos: jax.Array,
    cfg: ModelConfig,
    ctx: ShardingCtx = NO_SHARDING,
) -> jax.Array:
    Lq, S = q_nope.shape[1], ckv.shape[1]
    if Lq <= cfg.attn_q_chunk and S <= cfg.attn_kv_chunk:
        mask = attention_mask(q_pos, kv_pos, cfg.causal, cfg.sliding_window)
        return _mla_attend_dense(params, q_nope, q_pe, ckv, kpe, mask, cfg, ctx)
    if Lq > cfg.attn_q_chunk:  # prefill / training: k,v worth materializing
        return _mla_attend_materialized(params, q_nope, q_pe, ckv, kpe, q_pos, kv_pos, cfg, ctx)
    return _mla_attend_flash(params, q_nope, q_pe, ckv, kpe, q_pos, kv_pos, cfg, ctx)


def apply_mla(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    ctx: ShardingCtx = NO_SHARDING,
    cache: Optional[MLACache] = None,
) -> tuple[jax.Array, Optional[MLACache]]:
    ad = cfg.dtype("act")
    x = x.astype(ad)
    q_nope, q_pe, ckv, kpe = _mla_qkv(params, x, positions, cfg, ctx)

    if cache is None:
        return _mla_attend(params, q_nope, q_pe, ckv, kpe, positions, positions, cfg, ctx), None

    S = cache.ckv.shape[1]
    ckv_c = jax.lax.dynamic_update_slice(cache.ckv, ckv.astype(cache.ckv.dtype), (0, positions[0], 0))
    kpe_c = jax.lax.dynamic_update_slice(cache.kpe, kpe.astype(cache.kpe.dtype), (0, positions[0], 0))
    ckv_c = ctx.constrain(ckv_c, ("batch", "cache_seq", "latent"))
    kpe_c = ctx.constrain(kpe_c, ("batch", "cache_seq", None))
    next_pos = positions[-1] + 1
    kv_pos = jnp.arange(S, dtype=jnp.int32)
    kv_pos = jnp.where(kv_pos < next_pos, kv_pos, -1)
    y = _mla_attend(params, q_nope, q_pe, ckv_c, kpe_c, positions, kv_pos, cfg, ctx)
    return y, MLACache(ckv=ckv_c, kpe=kpe_c, next_pos=next_pos)
