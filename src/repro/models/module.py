"""Minimal pure-JAX parameter system with logical sharding axes.

Every weight is declared once as a :class:`TensorDesc` — shape, logical axis
names, init law. From one descriptor tree we derive, consistently:

  * materialized params            (``init_params``)
  * abstract params for the dry-run (``abstract_params`` — ShapeDtypeStruct,
    no allocation)
  * PartitionSpecs                  (``param_specs`` via :class:`ShardingRules`)

Logical axis names are mapped to physical mesh axes by ``ShardingRules``; a
dimension whose size does not divide the mapped mesh-axis product silently
falls back to replication for that dim (GSPMD would otherwise pad — we prefer
the explicit, predictable layout).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

Params = Any  # nested dict[str, ...] of jax.Array
Tree = Any


@dataclasses.dataclass(frozen=True)
class TensorDesc:
    """Declaration of one parameter tensor."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axis name per dim
    init: str = "normal"  # normal | zeros | ones | trunc_fan_in | scaled
    scale: float = 1.0  # stddev for normal/scaled init
    dtype: Any = jnp.float32

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


def desc(shape: tuple[int, ...], axes: tuple[str | None, ...], init: str = "normal",
         scale: float = 1.0, dtype: Any = jnp.float32) -> TensorDesc:
    return TensorDesc(tuple(shape), tuple(axes), init, scale, dtype)


def fan_in_desc(shape: tuple[int, ...], axes: tuple[str | None, ...], fan_in: int,
                dtype: Any = jnp.float32) -> TensorDesc:
    """He/LeCun-style 1/sqrt(fan_in) normal init."""
    return TensorDesc(tuple(shape), tuple(axes), "normal", 1.0 / math.sqrt(max(fan_in, 1)), dtype)


def stacked(tree: Tree, num: int, axis_name: str = "layers") -> Tree:
    """Prepend a stacking dim (for scan-over-layers) to every descriptor."""
    return jax.tree.map(
        lambda d: TensorDesc((num, *d.shape), (axis_name, *d.axes), d.init, d.scale, d.dtype),
        tree,
        is_leaf=lambda x: isinstance(x, TensorDesc),
    )


def _is_desc(x: Any) -> bool:
    return isinstance(x, TensorDesc)


def _init_leaf(key: jax.Array, d: TensorDesc) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init in ("normal", "scaled"):
        return (d.scale * jax.random.normal(key, d.shape, jnp.float32)).astype(d.dtype)
    raise ValueError(f"unknown init {d.init!r}")


def _path_str(path: tuple) -> str:
    out = []
    for p in path:
        out.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(out)


def init_params(key: jax.Array, descs: Tree) -> Params:
    """Materialize a descriptor tree. Each leaf gets a path-derived key."""
    flat = jax.tree_util.tree_flatten_with_path(descs, is_leaf=_is_desc)[0]

    def leaf(path, d):
        k = jax.random.fold_in(key, hash(_path_str(path)) % (2**31))
        return _init_leaf(k, d)

    leaves = {_path_str(p): leaf(p, d) for p, d in flat}
    treedef = jax.tree_util.tree_structure(descs, is_leaf=_is_desc)
    return jax.tree_util.tree_unflatten(treedef, [leaves[_path_str(p)] for p, _ in flat])


def abstract_params(descs: Tree) -> Params:
    """ShapeDtypeStruct tree — the dry-run stand-in, no allocation."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), descs, is_leaf=_is_desc
    )


# ---------------------------------------------------------------------------
# Logical -> physical sharding
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping from logical axis names to mesh axis (tuples).

    ``None`` value = replicate. Missing key = replicate. ``table`` shards
    parameter STORAGE and activations; ``use_table`` (optional) shards
    parameters at USE time (ShardingCtx.weight) — the storage/use split is
    what expresses ZeRO/FSDP: stored sharded over many axes, gathered (or
    partially gathered) right before the matmul. With ``use_table=None``,
    weight-use falls back to "storage spec minus the FSDP axes".
    Per-shape divisibility is checked at resolution time.
    """

    table: Mapping[str, tuple[str, ...] | str | None]
    use_table: Mapping[str, tuple[str, ...] | str | None] | None = None

    def mesh_axes(self, logical: str | None, use: bool = False) -> tuple[str, ...]:
        if logical is None:
            return ()
        if use and self.use_table is not None:
            v = self.use_table.get(logical)  # missing key = replicated at use
        else:
            v = self.table.get(logical)
        if v is None:
            return ()
        return (v,) if isinstance(v, str) else tuple(v)


# Default rules: 2D weight sharding ("fsdp" over data x "tensor" over model),
# batch data-parallel over (pod, data). See DESIGN.md §LM-sharding.
TRAIN_RULES = ShardingRules(
    table={
        "batch": ("pod", "data"),
        "seq": None,
        "vocab": ("model",),
        "embed": ("data",),
        "embed_out": ("data",),
        "mlp": ("model",),
        "q_heads": ("model",),
        "kv_heads": ("model",),
        "head_dim": None,
        "kv_head_dim": None,
        "experts": None,
        "layers": None,
        "inner": ("model",),  # mamba d_inner / ssm heads
        "ssm_heads": ("model",),
        "state": None,
        "conv": None,
        "latent": None,  # MLA lora ranks
        "act_embed": None,  # activation d_model axis
        "cache_seq": None,
        # the loss/head boundary: batch over (pod, data) ONLY — the vocab-TP
        # head needs the model axis free; constraining per loss CHUNK keeps
        # the reshard small (train.py)
        "loss_batch": ("pod", "data"),
        # flash q-block axis for sequence-parallel prefill (attention.py)
        "qblocks": ("model",),
    }
)

# Serving: no optimizer state; weights 2D-sharded the same way, batch over
# (pod, data). The KV cache is sequence-sharded over "model" — at decode this
# lowers to flash-decoding-style parallelism (partial softmax per shard +
# small all-reduces of the [B,1,H,dv] partials), and it is what bounds the
# 32k/500k cache to per-chip HBM (kv_heads often don't divide the model axis,
# so head-sharding alone would replicate multi-TB caches).
SERVE_RULES = ShardingRules(table={**TRAIN_RULES.table, "cache_seq": ("model",)})

# Decode: weights are used AS STORED (resident tensor-parallel, zero
# per-token gathers — use_table == table); the per-layer partial-sum
# all-reduces are [B, 1, D]-sized, i.e. negligible at one token. Prefill
# keeps SERVE_RULES (gather-at-use amortizes over the 32k-token prompt).
DECODE_RULES = ShardingRules(table=SERVE_RULES.table, use_table=SERVE_RULES.table)

# Pure-ZeRO training rules: the batch is sharded over EVERY mesh axis
# (1 sequence per chip at the assigned train shapes), weights are STORED
# 2D-sharded (same as TRAIN_RULES) and fully gathered at use — except the
# vocabulary head, which stays tensor-parallel so the [B, L, V] logits and
# the multi-GB head matmul never materialize unsharded. Rationale
# (EXPERIMENTS.md §Perf iterations 1-3): with batch over only (pod, data),
# tensor-parallel layers all-reduce [B_dev, L, D]-sized activations every
# layer (~430 GB wire/step for yi-6b); with one row per chip the layer
# weights (tens-hundreds of MB) are the only per-layer collective.
ZERO_RULES = ShardingRules(
    table={
        **TRAIN_RULES.table,
        "batch": ("pod", "data", "model"),
        "embed": ("data", "model"),  # storage: 256/512-way on the embed dim
        "mlp": None,
        "q_heads": None,
        "kv_heads": None,
        "inner": None,
        "ssm_heads": None,
        "latent": None,
    },
    use_table={"vocab": ("model",)},
)


def resolve_spec(
    shape: tuple[int, ...],
    axes: tuple[str | None, ...],
    rules: ShardingRules,
    mesh: Mesh,
    use: bool = False,
) -> P:
    """PartitionSpec for one tensor.

    A dim that does not divide the full mapped mesh-axis product falls back
    to progressively shorter PREFIXES of the axis tuple (e.g. batch=256 on
    ("pod","data","model")=512 devices resolves to ("pod","data")=32-way),
    and to replication only when no prefix divides."""
    used: set[str] = set()
    out: list[tuple[str, ...] | None] = []
    for dim, ax in zip(shape, axes):
        names = rules.mesh_axes(ax, use=use)
        names = tuple(n for n in names if n in mesh.shape and n not in used)
        chosen: tuple[str, ...] | None = None
        while names:
            prod = int(np.prod([mesh.shape[n] for n in names]))
            if dim > 0 and dim % prod == 0:
                chosen = names
                break
            names = names[:-1]
        if chosen:
            out.append(chosen)
            used.update(chosen)
        else:
            out.append(None)
    while out and out[-1] is None:
        out.pop()
    return P(*[o if o is None else (o[0] if len(o) == 1 else o) for o in out])


def param_specs(descs: Tree, rules: ShardingRules, mesh: Mesh) -> Tree:
    return jax.tree.map(
        lambda d: resolve_spec(d.shape, d.axes, rules, mesh), descs, is_leaf=_is_desc
    )


def param_shardings(descs: Tree, rules: ShardingRules, mesh: Mesh) -> Tree:
    return jax.tree.map(
        lambda d: NamedSharding(mesh, resolve_spec(d.shape, d.axes, rules, mesh)),
        descs,
        is_leaf=_is_desc,
    )


def shard_init(key: jax.Array, descs: Tree, rules: ShardingRules, mesh: Mesh) -> Params:
    """Materialize params directly with their target sharding (no host copy)."""
    shardings = param_shardings(descs, rules, mesh)
    return jax.jit(lambda k: init_params(k, descs), out_shardings=shardings)(key)


def logical(x: jax.Array, axes: tuple[str | None, ...], rules: ShardingRules | None,
            mesh: Mesh | None) -> jax.Array:
    """with_sharding_constraint by logical activation axes (no-op without mesh)."""
    if rules is None or mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve_spec(x.shape, axes, rules, mesh))
    )


FSDP_AXES = ("data", "pod")  # mesh axes weights are *stored* sharded over


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    """Threaded through model apply fns; None fields = single-device run."""

    mesh: Mesh | None = None
    rules: ShardingRules | None = None

    def constrain(self, x: jax.Array, axes: tuple[str | None, ...]) -> jax.Array:
        return logical(x, axes, self.rules, self.mesh)

    def weight(self, w: jax.Array, axes: tuple[str | None, ...]) -> jax.Array:
        """Manual FSDP: a weight is *stored* sharded over (data, model) but
        *used* gathered over the fsdp axes (tensor sharding kept). Without
        this, GSPMD resolves the contraction-dim sharding inside scan bodies
        by ALL-REDUCING activations ([B, L, ...] per layer — 100s of GB/step)
        instead of all-gathering the small weight. Call it on the weight
        already cast to the compute dtype so the gather moves bf16."""
        if self.rules is None or self.mesh is None:
            return w
        # First pin the STORAGE spec. Forward: a no-op (the sliced stacked
        # param already carries it). Backward: with_sharding_constraint's
        # transpose applies the SAME spec to the cotangent, so each layer's
        # weight gradient is reduce-scattered back to its shards right here —
        # without this, the replicated cotangents of the gathered weights
        # accumulate into a full-size stacked gradient buffer inside the
        # layer scan (260 GB for nemotron-340b).
        store = resolve_spec(w.shape, axes, self.rules, self.mesh)
        w = jax.lax.with_sharding_constraint(w, NamedSharding(self.mesh, store))
        if self.rules.use_table is not None:
            # explicit use-time table (ZeRO rules: replicated except the head)
            spec = resolve_spec(w.shape, axes, self.rules, self.mesh, use=True)
            return jax.lax.with_sharding_constraint(w, NamedSharding(self.mesh, spec))

        def drop(e):
            if e is None:
                return None
            names = (e,) if isinstance(e, str) else tuple(e)
            names = tuple(n for n in names if n not in FSDP_AXES)
            return None if not names else (names[0] if len(names) == 1 else names)

        gathered = P(*[drop(e) for e in store])
        return jax.lax.with_sharding_constraint(w, NamedSharding(self.mesh, gathered))


NO_SHARDING = ShardingCtx()
