"""Mamba2 / SSD (state-space duality) mixer, chunked for TPU.

The SSD recurrence  S_t = a_t S_{t-1} + dt_t x_t B_t^T,  y_t = C_t S_t + D x_t
(a_t = exp(dt_t * A_h), per-head scalar decay) is evaluated chunk-wise
(arXiv:2405.21060 §6): within a chunk of Q tokens the quadratic
"attention-like" form runs on the MXU; across chunks a cheap [H, P, N] state
is carried by ``lax.scan``.

TPU adaptation: the reference CUDA kernel materializes all [Q, Q] blocks at
once; here each chunk's quadratic intermediates live only inside the scan
body, bounding the working set to one chunk — the VMEM-sized tile the Pallas
kernel (kernels/ssd_chunk.py) implements, with this module as the jnp
reference semantics.

Decode is the O(1) recurrence step on a [B, H, P, N] state plus a depthwise
conv ring buffer — this is what makes the ``long_500k`` shape deployable.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rms_norm
from repro.models.module import NO_SHARDING, ShardingCtx, desc, fan_in_desc
from repro.utils import pytree_dataclass


@pytree_dataclass
class SSMState:
    """Per-layer decode state: SSD state + causal-conv ring buffer."""

    S: jax.Array  # [B, H, P, N] fp32
    conv: jax.Array  # [B, d_conv - 1, conv_dim] activation dtype
    next_pos: jax.Array  # [] int32


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state


def desc_mamba2(cfg: ModelConfig) -> dict:
    """The reference fused in_proj [D, 2*di + 2GN + H] is split into
    (w_z | w_xBC | w_dt): mathematically identical (independent columns,
    same init law), but the fused width is rarely divisible by the model
    axis (mamba2-130m: 3352 % 16 != 0) which silently replicates the
    layer's biggest matmul on every tensor shard — a 12x per-device flop
    regression found by the dry-run flop attribution."""
    pd = cfg.dtype("param")
    D, di = cfg.d_model, cfg.d_inner
    H, N, G = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_ngroups
    cd = conv_dim(cfg)
    return {
        "w_z": fan_in_desc((D, di), ("embed", "inner"), D, pd),
        "w_xBC": fan_in_desc((D, cd), ("embed", "inner"), D, pd),
        "w_dt": fan_in_desc((D, H), ("embed", "ssm_heads"), D, pd),
        "conv_w": desc((cfg.ssm_conv, cd), ("conv", "inner"), scale=0.5, dtype=pd),
        "conv_b": desc((cd,), ("inner",), init="zeros", dtype=pd),
        "A_log": desc((H,), ("ssm_heads",), init="normal", scale=0.5, dtype=jnp.float32),
        "dt_bias": desc((H,), ("ssm_heads",), init="zeros", dtype=jnp.float32),
        "D": desc((H,), ("ssm_heads",), init="ones", dtype=jnp.float32),
        "norm_scale": desc((di,), ("inner",), init="ones", dtype=pd),
        "out_proj": fan_in_desc((di, D), ("inner", "embed"), di, pd),
    }


def init_ssm_state(cfg: ModelConfig, batch: int) -> SSMState:
    return SSMState(
        S=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim(cfg)), cfg.dtype("act")),
        next_pos=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def ssd_chunked(
    x: jax.Array,  # [B, L, H, P] (activation dtype)
    dt: jax.Array,  # [B, L, H] fp32, post-softplus
    A: jax.Array,  # [H] fp32, negative
    Bm: jax.Array,  # [B, L, G, N]
    Cm: jax.Array,  # [B, L, G, N]
    chunk: int,
    initial_state: Optional[jax.Array] = None,  # [B, H, P, N] fp32
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [B, L, H, P], final_state [B, H, P, N]).

    L must be a multiple of ``chunk`` (callers pad). All decay math in fp32.
    """
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = chunk
    nc = L // Q
    ad = x.dtype

    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    # groups kept narrow here; the expansion to heads happens per chunk inside
    # the scan body — expanding [B, L, G, N] -> [B, L, H, N] up front would be
    # saved as scan inputs for the backward pass (19 GB at 1M tokens, G=1).
    Bg = Bm.reshape(Bsz, nc, Q, G, N)
    Cg = Cm.reshape(Bsz, nc, Q, G, N)

    log_a = dtc * A  # [B, nc, Q, H], negative
    ell = jnp.cumsum(log_a, axis=2)  # inclusive cumulative log-decay

    S0 = (
        initial_state
        if initial_state is not None
        else jnp.zeros((Bsz, H, P, N), jnp.float32)
    )

    tri = jnp.tril(jnp.ones((Q, Q), bool))  # i >= j

    def chunk_body(S, inp):
        xq, dtq, Bq, Cq, ellq = inp  # per-chunk slices, [B, Q, ...]
        Bq = jnp.repeat(Bq, rep, axis=2)  # [B, Q, H, N]
        Cq = jnp.repeat(Cq, rep, axis=2)
        # intra-chunk quadratic form
        seg = ellq[:, :, None, :] - ellq[:, None, :, :]  # [B, Q(i), Q(j), H]
        Lmat = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)  # fp32
        CB = jnp.einsum("bihn,bjhn->bijh", Cq.astype(jnp.float32), Bq.astype(jnp.float32))
        M = (CB * Lmat).astype(ad)  # [B, Q, Q, H]
        dtx = (dtq[..., None] * xq.astype(jnp.float32)).astype(ad)  # [B, Q, H, P]
        y_intra = jnp.einsum("bijh,bjhp->bihp", M, dtx, preferred_element_type=jnp.float32)
        # inter-chunk: previous state decayed to each position
        decay_in = jnp.exp(ellq)  # [B, Q, H]
        y_inter = jnp.einsum(
            "bqhn,bhpn,bqh->bqhp", Cq.astype(jnp.float32), S, decay_in,
            preferred_element_type=jnp.float32,
        )
        # state update
        ell_last = ellq[:, -1, :]  # [B, H]
        w = jnp.exp(ell_last[:, None, :] - ellq) * dtq  # [B, Q, H]
        S_chunk = jnp.einsum(
            "bqhn,bqh,bqhp->bhpn", Bq.astype(jnp.float32), w, xq.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        S_new = jnp.exp(ell_last)[..., None, None] * S + S_chunk
        return S_new, (y_intra + y_inter).astype(ad)

    xs = (
        jnp.moveaxis(xc, 1, 0),
        jnp.moveaxis(dtc, 1, 0),
        jnp.moveaxis(Bg, 1, 0),
        jnp.moveaxis(Cg, 1, 0),
        jnp.moveaxis(ell, 1, 0),
    )
    S_final, ys = jax.lax.scan(chunk_body, S0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, L, H, P)
    return y, S_final


def ssd_step(
    x: jax.Array,  # [B, H, P]
    dt: jax.Array,  # [B, H] fp32 post-softplus
    A: jax.Array,  # [H]
    Bm: jax.Array,  # [B, G, N]
    Cm: jax.Array,  # [B, G, N]
    S: jax.Array,  # [B, H, P, N] fp32
) -> tuple[jax.Array, jax.Array]:
    """Single-token recurrence. Returns (y [B, H, P], S')."""
    H = x.shape[1]
    rep = H // Bm.shape[1]
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)  # [B, H, N]
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    a = jnp.exp(dt * A)  # [B, H]
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt, x.astype(jnp.float32), Bh)
    S_new = a[..., None, None] * S + upd
    y = jnp.einsum("bhpn,bhn->bhp", S_new, Ch)
    return y.astype(x.dtype), S_new


# ---------------------------------------------------------------------------
# Naive reference (test oracle)
# ---------------------------------------------------------------------------


def ssd_reference(x, dt, A, Bm, Cm, initial_state=None):
    """Token-by-token recurrence in fp64-ish fp32 — oracle for the chunked form."""
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    S = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((Bsz, H, P, N), jnp.float32)
    )

    def step(S, t):
        y, S_new = ssd_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], S)
        return S_new, y

    S_final, ys = jax.lax.scan(step, S, jnp.arange(L))
    return jnp.moveaxis(ys, 0, 1), S_final


# ---------------------------------------------------------------------------
# Full mixer block
# ---------------------------------------------------------------------------


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over [B, L, C] with kernel [K, C]."""
    K = w.shape[0]
    ad = xBC.dtype
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    for k in range(K):  # K = 4: unrolled adds beat a conv call at this size
        out = out + pad[:, k : k + xBC.shape[1], :].astype(jnp.float32) * w[k].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(ad)


def apply_mamba2(
    params: dict,
    x: jax.Array,  # [B, L, D]
    cfg: ModelConfig,
    ctx: ShardingCtx = NO_SHARDING,
    state: Optional[SSMState] = None,
    return_state: bool = False,
) -> tuple[jax.Array, Optional[SSMState]]:
    """Full mixer. Without ``state``: chunked parallel form over L (train /
    prefill; pass return_state=True to also build the decode state). With
    ``state`` and L == 1: the O(1) decode step."""
    ad = cfg.dtype("act")
    Bsz, L, D = x.shape
    di, H, P, N, G = cfg.d_inner, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_ngroups
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    xa = x.astype(ad)
    z = ctx.constrain(xa @ ctx.weight(params["w_z"].astype(ad), ("embed", "inner")), ("batch", "seq", "inner"))
    xBC = ctx.constrain(xa @ ctx.weight(params["w_xBC"].astype(ad), ("embed", "inner")), ("batch", "seq", "inner"))
    dt_raw = xa @ ctx.weight(params["w_dt"].astype(ad), ("embed", "ssm_heads"))

    decode = state is not None and L == 1
    if decode:
        window = jnp.concatenate([state.conv, xBC], axis=1)  # [B, K, cd]
        conv_out = (
            jnp.sum(window.astype(jnp.float32) * params["conv_w"].astype(jnp.float32), axis=1)
            + params["conv_b"].astype(jnp.float32)
        ).astype(ad)[:, None, :]
        new_conv = window[:, 1:, :]
    else:
        conv_out = _causal_conv(xBC, params["conv_w"], params["conv_b"])
        new_conv = None
        if return_state:
            K = cfg.ssm_conv
            tail = xBC[:, -(K - 1) :, :]
            padlen = (K - 1) - tail.shape[1]
            new_conv = jnp.pad(tail, ((0, 0), (padlen, 0), (0, 0)))
    xBC = jax.nn.silu(conv_out)

    x_ssm = xBC[..., :di].reshape(Bsz, L, H, P)
    Bm = xBC[..., di : di + G * N].reshape(Bsz, L, G, N)
    Cm = xBC[..., di + G * N :].reshape(Bsz, L, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # [B, L, H]

    if decode:
        y, S_new = ssd_step(x_ssm[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0], state.S)
        y = y[:, None]
        new_state = SSMState(S=S_new, conv=new_conv, next_pos=state.next_pos + 1)
    else:
        S0 = state.S if state is not None else None
        pad_to = -(-L // cfg.ssm_chunk) * cfg.ssm_chunk
        if pad_to != L:
            padding = pad_to - L
            x_p = jnp.pad(x_ssm, ((0, 0), (0, padding), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, padding), (0, 0)))
            B_p = jnp.pad(Bm, ((0, 0), (0, padding), (0, 0), (0, 0)))
            C_p = jnp.pad(Cm, ((0, 0), (0, padding), (0, 0), (0, 0)))
            y, S_new = ssd_chunked(x_p, dt_p, A, B_p, C_p, cfg.ssm_chunk, S0)
            y = y[:, :L]
        else:
            y, S_new = ssd_chunked(x_ssm, dt, A, Bm, Cm, cfg.ssm_chunk, S0)
        new_state = (
            SSMState(
                S=S_new,
                conv=new_conv,
                next_pos=(state.next_pos if state is not None else 0) + L,
            )
            if return_state
            else None
        )

    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * x_ssm.astype(jnp.float32)
    y = y.reshape(Bsz, L, di).astype(ad)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(ad), params["norm_scale"])
    out = y @ ctx.weight(params["out_proj"].astype(ad), ("inner", "embed"))
    return out, new_state
