"""Top-2 Mixture-of-Experts with capacity-based einsum dispatch (GShard style).

TPU adaptation: ragged token->expert routing (the GPU/megablocks formulation)
becomes grouped one-hot contractions — tokens are split into fixed-size
groups, each group dispatches into a [E, C] capacity buffer via one-hot
matmuls that the MXU executes natively. Tokens overflowing an expert's
capacity are dropped (standard GShard semantics); the residual connection
carries them through.

The paper connection (DESIGN.md §5): expert capacity planning is the same
fixed-cost + per-item-cost balancing idea as BPMF's §IV-B workload model —
``capacity_factor`` plays the role of the padding the LPT partition bounds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.module import NO_SHARDING, ShardingCtx, fan_in_desc

MOE_GROUP = 2048  # tokens per dispatch group (divides every assigned seq_len)


def desc_moe(cfg: ModelConfig) -> dict:
    pd = cfg.dtype("param")
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    out = {
        "router": fan_in_desc((D, E), ("embed", None), D, pd),
        "w_up": fan_in_desc((E, D, F), ("experts", "embed", "mlp"), D, pd),
        "w_down": fan_in_desc((E, F, D), ("experts", "mlp", "embed"), F, pd),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        out["w_gate"] = fan_in_desc((E, D, F), ("experts", "embed", "mlp"), D, pd)
    return out


def _activation(h_gate: jax.Array | None, h_up: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.mlp == "swiglu":
        return jax.nn.silu(h_gate) * h_up
    if cfg.mlp == "geglu":
        return jax.nn.gelu(h_gate, approximate=True) * h_up
    if cfg.mlp == "relu2":
        return jnp.square(jax.nn.relu(h_up))
    return jax.nn.gelu(h_up, approximate=True)


def _moe_group(
    params: dict,
    xt: jax.Array,  # [g, D] one dispatch group
    cfg: ModelConfig,
    ctx: ShardingCtx,
) -> tuple[jax.Array, dict]:
    """Route + dispatch + expert-compute one token group."""
    ad = cfg.dtype("act")
    g, D = xt.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok

    # --- routing (fp32) ---
    logits = (xt @ params["router"].astype(ad)).astype(jnp.float32)  # [g, E]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, K)  # [g, K]
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)  # mixtral renormalizes

    C = int(g * K * cfg.capacity_factor / E)
    C = max(8, -(-C // 8) * 8)

    # --- capacity assignment: slot = rank of the token among same-expert picks
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)  # [g, K, E]
    # priority: slot k=0 first (GShard), then position in group
    flat = onehot.transpose(1, 0, 2).reshape(K * g, E)  # [K*g, E]
    pos_in_e = jnp.cumsum(flat, axis=0) - flat  # [K*g, E] rank among picks
    pos = jnp.sum(pos_in_e * flat, -1).astype(jnp.int32)  # [K*g] slot index per pick
    keep = (pos < C) & (jnp.sum(flat, -1) > 0)
    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]  # [K*g, C]
    disp_flat = flat[..., None] * pos_oh[..., None, :]  # [K*g, E, C]
    dispatch = disp_flat.reshape(K, g, E, C).transpose(1, 0, 2, 3)  # [g, K, E, C]

    gate_w = top_p[..., None, None] * jax.nn.one_hot(top_e, E, dtype=jnp.float32)[..., None]
    combine = jnp.sum(gate_w * dispatch, axis=1)  # [g, E, C]
    dispatch_b = jnp.sum(dispatch, axis=1).astype(ad)  # [g, E, C] 0/1

    # --- expert computation ---
    expert_in = jnp.einsum("tec,td->ecd", dispatch_b, xt)  # [E, C, D]
    h_up = jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"].astype(ad))
    h_gate = (
        jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"].astype(ad))
        if "w_gate" in params
        else None
    )
    h = _activation(h_gate, h_up, cfg)
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(ad))
    y = jnp.einsum("tec,ecd->td", combine.astype(ad), expert_out)

    # --- losses / metrics ---
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    top1 = jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32)
    ce = jnp.mean(top1, axis=0)  # fraction of tokens routed (top-1)
    aux_loss = E * jnp.sum(me * ce)
    router_z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, -1)))
    drop = 1.0 - jnp.sum(dispatch_b) / (g * K)
    metrics = {"aux_loss": aux_loss, "router_z": router_z, "drop_fraction": drop}
    return y, metrics


def apply_moe(
    params: dict,
    x: jax.Array,  # [B, L, D]
    cfg: ModelConfig,
    ctx: ShardingCtx = NO_SHARDING,
) -> tuple[jax.Array, dict]:
    """Returns (y [B, L, D], metrics{aux_loss, router_z, drop_fraction}).

    Memory bounding at long sequence: each batch row is split along L into
    MOE_GROUP-sized dispatch groups; the group axis runs under ``lax.scan``
    (unsharded — scanning a *batch*-sharded axis would serialize data
    parallelism), batch rows run vmapped. Only one [B_shard, g, E, C]
    dispatch/combine block is live at a time; the all-at-once formulation
    materializes TBs at 1M-token prefill. Groups never straddle batch rows,
    so routing is per-sequence (standard for inference too: at decode L=1
    each token is its own group, capacity >= K, no drops).
    """
    ad = cfg.dtype("act")
    B, L, D = x.shape
    if L >= MOE_GROUP and L % MOE_GROUP == 0:
        g, n = MOE_GROUP, L // MOE_GROUP
    else:
        g, n = L, 1
    xt = x.reshape(B, n, g, D).astype(ad)

    # manual-FSDP gather of the expert bank, once per layer, outside the
    # group scan (module.ShardingCtx.weight)
    pw = {"router": ctx.weight(params["router"].astype(ad), ("embed", None))}
    for name, axes in (("w_up", ("experts", "embed", "mlp")),
                       ("w_down", ("experts", "mlp", "embed")),
                       ("w_gate", ("experts", "embed", "mlp"))):
        if name in params:
            pw[name] = ctx.weight(params[name].astype(ad), axes)

    group_fn = jax.vmap(lambda xg: _moe_group(pw, xg, cfg, ctx))  # over batch

    if n == 1:
        y, metrics = group_fn(xt[:, 0])
    else:
        def body(_, xg):  # xg: [B, g, D]
            return 0, group_fn(xg)

        _, (ys, ms) = jax.lax.scan(body, 0, jnp.moveaxis(xt, 1, 0))
        y = jnp.moveaxis(ys, 0, 1)  # [B, n, g, D]
        metrics = ms
    metrics = jax.tree.map(jnp.mean, metrics)
    return y.reshape(B, L, D), metrics
