"""Model configuration covering every assigned architecture family.

One dataclass describes dense / MoE / SSM / hybrid / encoder LMs; the
per-arch files in ``repro/configs`` instantiate it with the exact published
numbers. ``reduced()`` produces the family-preserving smoke-test config.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encoder | vlm
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    # attention
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    attention: str = "gqa"  # gqa | mla | none
    qk_norm: bool = False
    causal: bool = True
    rope_theta: float = 10000.0
    sliding_window: int | None = None
    # mlp
    mlp: str = "swiglu"  # swiglu | geglu | relu2 | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 2
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    ssm_chunk: int = 256
    ssm_conv: int = 4
    # hybrid (zamba2): one shared attention block applied every k ssm layers
    shared_attn_every: int = 0
    # encoder (hubert)
    is_encoder: bool = False
    mask_prob: float = 0.08  # masked-prediction corruption rate
    # embeddings / head
    tie_embeddings: bool = False
    scale_embeddings: bool = False  # gemma: x *= sqrt(d_model)
    logits_softcap: float = 0.0
    # input frontend: tokens, or precomputed frame/patch embeddings (stub)
    input_mode: str = "tokens"  # tokens | frames
    frame_dim: int = 0
    # attention chunking (flash-style two-level scan; memory-bounds long seqs)
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    # prefill/inference: run q blocks vmapped with the block axis sharded over
    # "model" (sequence-parallel attention) instead of scanned — see §Perf H2
    flash_q_parallel: bool = False
    # embeddings pad to this multiple so vocab shards over the model axis
    vocab_pad_multiple: int = 128
    # numerics
    param_dtype: str = "float32"
    activation_dtype: str = "bfloat16"
    # remat: "full" = recompute the whole block in bwd (Megatron-style full
    # activation recomputation — the only policy that fits 16 GB/chip at the
    # assigned batch x seq); "block" saves matmul outputs; "none" saves all.
    remat: str = "full"
    # >1: checkpoint GROUPS of layers (scan-of-scans) so only L/group residual
    # carries are saved — needed when L x [B_dev, seq, d_model] alone blows
    # HBM (nemotron: 96 x 151 MB). Must divide num_layers.
    remat_group: int = 1
    scan_layers: bool = True

    # ------------------------------------------------------------------
    @property
    def uses_attention(self) -> bool:
        return self.attention != "none" or self.shared_attn_every > 0

    @property
    def uses_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.uses_ssm else 0

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def padded_vocab(self) -> int:
        m = max(self.vocab_pad_multiple, 1)
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def sub_quadratic(self) -> bool:
        """Whether a 500k-token decode is deployable (bounded per-token state)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def has_decode(self) -> bool:
        return not self.is_encoder

    def dtype(self, kind: str) -> Any:
        return jnp.dtype({"param": self.param_dtype, "act": self.activation_dtype}[kind])

    def reduced(self) -> "ModelConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        small: dict[str, Any] = dict(
            num_layers=min(self.num_layers, 4 if self.shared_attn_every == 0 else 2 * self.shared_attn_every),
            d_model=128,
            d_ff=256 if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
        )
        if self.uses_attention:
            small.update(num_heads=4, num_kv_heads=max(1, min(self.num_kv_heads, 4) if self.num_heads != self.num_kv_heads else 4), head_dim=32)
            if self.num_kv_heads == self.num_heads:
                small["num_kv_heads"] = 4
            elif self.num_kv_heads == 1:
                small["num_kv_heads"] = 1
            else:
                small["num_kv_heads"] = 2
        if self.attention == "mla":
            small.update(q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=16, v_head_dim=32, head_dim=32)
        if self.num_experts:
            small.update(num_experts=4)
        if self.uses_ssm:
            small.update(ssm_state=16, ssm_headdim=16, ssm_chunk=32)
        if self.sliding_window is not None:
            small = {**small, "sliding_window": 64}
        if self.input_mode == "frames":
            small["frame_dim"] = 128
        return dataclasses.replace(self, **small, name=self.name + "-smoke")

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
