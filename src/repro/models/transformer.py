"""Layer stacks for every assigned family: decoder, encoder, SSM, hybrid.

One homogeneous *layer body* per family, stacked with ``module.stacked`` and
iterated with ``lax.scan`` (``cfg.scan_layers``) so the lowered HLO stays one
layer deep regardless of depth — essential for the 96-layer dry-runs. Remat
(``cfg.remat``) wraps the scan body.

Families:

  * dense / vlm / moe / encoder — pre-norm attention (GQA/MQA/MLA/SWA) +
    pre-norm MLP or MoE; encoder runs with ``causal=False`` and no cache.
  * ssm — pre-norm mamba2 mixer only (mamba2-130m has no MLP sublayer).
  * hybrid (zamba2) — the layer stack is mamba2 blocks grouped into
    ``A = num_layers / shared_attn_every`` segments; ONE shared transformer
    block (single weight set) is applied at the start of every segment. Each
    application keeps its own KV cache (the activations differ per depth even
    though weights are shared). The zamba2 trick of concatenating the original
    embedding into the shared block input is simplified to a plain residual
    block — noted in DESIGN.md §6.

Decode caches are stacked pytrees scanned alongside the parameters.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.attention import (
    KVCache,
    MLACache,
    apply_attention,
    apply_mla,
    desc_attention,
    init_kv_cache,
    init_mla_cache,
)
from repro.models.config import ModelConfig
from repro.models.layers import apply_mlp, apply_norm, desc_mlp, desc_norm
from repro.models.mamba2 import SSMState, apply_mamba2, desc_mamba2, init_ssm_state
from repro.models.moe import apply_moe, desc_moe
from repro.models.module import NO_SHARDING, ShardingCtx, stacked
from repro.utils import pytree_dataclass

Tree = Any

ZERO_METRICS = {
    "aux_loss": jnp.zeros((), jnp.float32),
    "router_z": jnp.zeros((), jnp.float32),
    "drop_fraction": jnp.zeros((), jnp.float32),
}


# ---------------------------------------------------------------------------
# Per-layer descriptors
# ---------------------------------------------------------------------------


def desc_layer(cfg: ModelConfig) -> dict:
    """Descriptor tree for ONE layer of the homogeneous stack."""
    if cfg.family in ("ssm", "hybrid"):
        return {"ln": desc_norm(cfg), "mixer": desc_mamba2(cfg)}
    out = {"ln_attn": desc_norm(cfg), "attn": desc_attention(cfg), "ln_mlp": desc_norm(cfg)}
    if cfg.num_experts:
        out["moe"] = desc_moe(cfg)
    else:
        out["mlp"] = desc_mlp(cfg)
    return out


def desc_shared_block(cfg: ModelConfig) -> dict:
    """zamba2's single shared transformer block (attention + MLP)."""
    return {
        "ln_attn": desc_norm(cfg),
        "attn": desc_attention(cfg),
        "ln_mlp": desc_norm(cfg),
        "mlp": desc_mlp(cfg),
    }


def desc_stack(cfg: ModelConfig) -> dict:
    out = {"layers": stacked(desc_layer(cfg), cfg.num_layers)}
    if cfg.family == "hybrid" and cfg.shared_attn_every > 0:
        out["shared"] = desc_shared_block(cfg)
    return out


# ---------------------------------------------------------------------------
# Layer bodies
# ---------------------------------------------------------------------------


def _attn_fn(cfg: ModelConfig):
    return apply_mla if cfg.attention == "mla" else apply_attention


def apply_attn_layer(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    ctx: ShardingCtx,
    cache: Optional[KVCache | MLACache],
) -> tuple[jax.Array, Optional[KVCache | MLACache], dict]:
    """Pre-norm attention + MLP/MoE block. Returns (x, cache', moe_metrics)."""
    h = apply_norm(params["ln_attn"], x, cfg)
    a, new_cache = _attn_fn(cfg)(params["attn"], h, positions, cfg, ctx, cache)
    x = ctx.constrain(x + a, ("batch", "seq", "act_embed"))
    h = apply_norm(params["ln_mlp"], x, cfg)
    if cfg.num_experts:
        m, metrics = apply_moe(params["moe"], h, cfg, ctx)
    else:
        m, metrics = apply_mlp(params["mlp"], h, cfg, ctx), ZERO_METRICS
    x = ctx.constrain(x + m, ("batch", "seq", "act_embed"))
    return x, new_cache, metrics


def apply_ssm_layer(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    ctx: ShardingCtx,
    state: Optional[SSMState],
    return_state: bool,
) -> tuple[jax.Array, Optional[SSMState]]:
    h = apply_norm(params["ln"], x, cfg)
    y, new_state = apply_mamba2(params["mixer"], h, cfg, ctx, state, return_state)
    return ctx.constrain(x + y, ("batch", "seq", "act_embed")), new_state


def apply_shared_block(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    ctx: ShardingCtx,
    cache: Optional[KVCache],
) -> tuple[jax.Array, Optional[KVCache]]:
    h = apply_norm(params["ln_attn"], x, cfg)
    a, new_cache = apply_attention(params["attn"], h, positions, cfg, ctx, cache)
    x = x + a
    h = apply_norm(params["ln_mlp"], x, cfg)
    return ctx.constrain(x + apply_mlp(params["mlp"], h, cfg, ctx), ("batch", "seq", "act_embed")), new_cache


# ---------------------------------------------------------------------------
# Remat policy
# ---------------------------------------------------------------------------


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    # "block": save only the big matmul outputs without batch dims (weight-
    # stationary intermediates), recompute the rest — the standard LM policy.
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


# ---------------------------------------------------------------------------
# Attention-family stack
# ---------------------------------------------------------------------------


def _apply_attn_stack(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    ctx: ShardingCtx,
    caches,  # stacked cache pytree or None
):
    def body(x, layer):
        p, cache = layer
        x, new_cache, metrics = apply_attn_layer(p, x, positions, cfg, ctx, cache)
        return x, (new_cache, metrics)

    g = cfg.remat_group
    if (
        cfg.scan_layers
        and caches is None
        and g > 1
        and cfg.num_layers % g == 0
    ):
        # scan-of-scans: checkpoint whole groups of g layers; only L/g
        # residual carries are saved, the inner g layers recompute in bwd.
        grouped = jax.tree.map(
            lambda a: a.reshape(a.shape[0] // g, g, *a.shape[1:]), params["layers"]
        )
        layer_descs = desc_layer(cfg)

        def pin_group(pg):
            # storage-spec constraint at the checkpoint boundary: its
            # TRANSPOSE pins the group's weight-gradient cotangent to the
            # sharded layout — without it the remat boundary drops the
            # sharding and the outer scan accumulates FULL-size gradients
            # (3 x 24 GB for yi-6b, ~260 GB for nemotron). TensorDesc is an
            # unregistered dataclass, i.e. a natural tree leaf.
            return jax.tree.map(
                lambda p, d: ctx.constrain(p, ("layers", *d.axes)), pg, layer_descs
            )

        def group_body(x, pg):
            pg = pin_group(pg)

            def inner(xc, p):
                xc, (_, metrics) = body(xc, (p, None))
                return xc, metrics

            # nested remat: the group recompute re-runs g layer forwards —
            # each must itself be checkpointed, else its full linearization
            # residuals (~9 GB/layer at 4k seq) are all saved at once.
            x, mets = jax.lax.scan(_remat(inner, cfg), x, pg)
            return x, jax.tree.map(jnp.mean, mets)

        group_body = _remat(group_body, cfg)
        x, metrics = jax.lax.scan(group_body, x, grouped)
        return x, None, jax.tree.map(jnp.mean, metrics)

    body = _remat(body, cfg)

    if cfg.scan_layers:
        x, (new_caches, metrics) = jax.lax.scan(body, x, (params["layers"], caches))
        metrics = jax.tree.map(jnp.mean, metrics)
    else:
        new_list, mets = [], []
        for i in range(cfg.num_layers):
            p = jax.tree.map(lambda a: a[i], params["layers"])
            c = jax.tree.map(lambda a: a[i], caches) if caches is not None else None
            x, nc, m = apply_attn_layer(p, x, positions, cfg, ctx, c)
            new_list.append(nc)
            mets.append(m)
        new_caches = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *new_list) if caches is not None else None
        )
        metrics = jax.tree.map(lambda *xs: jnp.mean(jnp.stack(xs)), *mets)
    return x, new_caches, metrics


# ---------------------------------------------------------------------------
# SSM stack
# ---------------------------------------------------------------------------


def _apply_ssm_stack(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    ctx: ShardingCtx,
    states,  # stacked SSMState or None
    return_state: bool,
):
    def body(x, layer):
        p, st = layer
        x, new_st = apply_ssm_layer(p, x, cfg, ctx, st, return_state)
        return x, new_st

    body = _remat(body, cfg)

    if cfg.scan_layers:
        x, new_states = jax.lax.scan(body, x, (params["layers"], states))
    else:
        new_list = []
        for i in range(cfg.num_layers):
            p = jax.tree.map(lambda a: a[i], params["layers"])
            st = jax.tree.map(lambda a: a[i], states) if states is not None else None
            x, ns = apply_ssm_layer(p, x, cfg, ctx, st, return_state)
            new_list.append(ns)
        new_states = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *new_list) if new_list[0] is not None else None
        )
    return x, new_states


# ---------------------------------------------------------------------------
# Hybrid (zamba2) stack: segments of [shared attn block + k mamba layers]
# ---------------------------------------------------------------------------


@pytree_dataclass
class HybridCache:
    """Decode state for the hybrid stack: per-layer SSM states stacked
    [A, k, ...] + per-application shared-attention KV caches stacked [A, ...]."""

    ssm: SSMState
    attn: KVCache


def _segments(cfg: ModelConfig) -> tuple[int, int]:
    k = cfg.shared_attn_every
    assert cfg.num_layers % k == 0, "num_layers must divide into shared-attn segments"
    return cfg.num_layers // k, k


def _apply_hybrid_stack(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    ctx: ShardingCtx,
    caches: Optional[HybridCache],
    return_state: bool,
):
    A, k = _segments(cfg)
    seg_params = jax.tree.map(lambda a: a.reshape(A, k, *a.shape[1:]), params["layers"])
    shared = params["shared"]

    def seg_body(x, seg):
        p_seg, ssm_seg, attn_cache = seg
        x, new_attn = apply_shared_block(shared, x, positions, cfg, ctx, attn_cache)

        def inner(x, layer):
            p, st = layer
            x, ns = apply_ssm_layer(p, x, cfg, ctx, st, return_state)
            return x, ns

        # nested remat (same reason as the grouped attention stack): the
        # checkpointed segment recompute must not save every inner layer's
        # linearization residuals at once
        x, new_ssm = jax.lax.scan(_remat(inner, cfg), x, (p_seg, ssm_seg))
        return x, (new_ssm, new_attn)

    seg_body = _remat(seg_body, cfg)

    ssm_in = caches.ssm if caches is not None else None
    attn_in = caches.attn if caches is not None else None
    if cfg.scan_layers:
        x, (new_ssm, new_attn) = jax.lax.scan(seg_body, x, (seg_params, ssm_in, attn_in))
    else:
        ssm_list, attn_list = [], []
        for a in range(A):
            p = jax.tree.map(lambda t: t[a], seg_params)
            ssm_a = jax.tree.map(lambda t: t[a], ssm_in) if ssm_in is not None else None
            att_a = jax.tree.map(lambda t: t[a], attn_in) if attn_in is not None else None
            x, (ns, na) = seg_body(x, (p, ssm_a, att_a))
            ssm_list.append(ns)
            attn_list.append(na)
        new_ssm = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *ssm_list) if ssm_list[0] is not None else None
        )
        new_attn = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *attn_list) if attn_list[0] is not None else None
        )

    new_caches = None
    if new_ssm is not None and new_attn is not None:
        new_caches = HybridCache(ssm=new_ssm, attn=new_attn)
    return x, new_caches


# ---------------------------------------------------------------------------
# Public stack API
# ---------------------------------------------------------------------------


def apply_stack(
    params: dict,
    x: jax.Array,  # [B, L, D] embedded inputs
    positions: jax.Array,  # [L] int32
    cfg: ModelConfig,
    ctx: ShardingCtx = NO_SHARDING,
    caches: Optional[Tree] = None,
    return_state: bool = False,
) -> tuple[jax.Array, Optional[Tree], dict]:
    """Run the full layer stack. Returns (hidden, caches', metrics).

    ``caches`` semantics: None = stateless forward (training / encoder);
    a stacked cache pytree = prefill (L>1) or decode (L=1) step.
    For SSM/hybrid training, ``return_state=True`` builds the decode state
    from the parallel form (prefill path).
    """
    if cfg.family == "ssm":
        want_state = caches is not None or return_state
        x, new_states = _apply_ssm_stack(params, x, cfg, ctx, caches, want_state)
        return x, new_states, dict(ZERO_METRICS)
    if cfg.family == "hybrid":
        want_state = caches is not None or return_state
        x, new_caches = _apply_hybrid_stack(params, x, positions, cfg, ctx, caches, want_state)
        return x, new_caches, dict(ZERO_METRICS)
    x, new_caches, metrics = _apply_attn_stack(params, x, positions, cfg, ctx, caches)
    return x, new_caches, metrics


# ---------------------------------------------------------------------------
# Cache construction (stacked over layers / segments)
# ---------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> Optional[Tree]:
    """Zero-initialized stacked decode caches for the whole stack."""
    if cfg.is_encoder:
        return None

    def rep(make, n):
        one = make()
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n, *a.shape)).copy(), one)

    if cfg.family == "ssm":
        return rep(lambda: init_ssm_state(cfg, batch), cfg.num_layers)
    if cfg.family == "hybrid":
        A, k = _segments(cfg)
        ssm = rep(lambda: rep(lambda: init_ssm_state(cfg, batch), k), A)
        attn = rep(lambda: init_kv_cache(cfg, batch, max_len), A)
        return HybridCache(ssm=ssm, attn=attn)
    if cfg.attention == "mla":
        return rep(lambda: init_mla_cache(cfg, batch, max_len), cfg.num_layers)
    return rep(lambda: init_kv_cache(cfg, batch, max_len), cfg.num_layers)


def abstract_caches(cfg: ModelConfig, batch: int, max_len: int) -> Optional[Tree]:
    """ShapeDtypeStruct cache tree for the dry-run (no allocation)."""
    if cfg.is_encoder:
        return None
    return jax.eval_shape(lambda: init_caches(cfg, batch, max_len))
