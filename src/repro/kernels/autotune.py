"""Measured per-shape dispatch for the Gram hot path (DESIGN.md §8).

The paper's speedups live or die on the per-item Gram update loop; which
implementation wins — the fused multi-bucket Pallas kernel, the per-bucket
Pallas kernel, or the XLA gather — depends on the bucket shape, the shard
size and the hardware. This module owns that choice:

* :func:`decide` — resolve a :class:`ShapeKey` to a :class:`Decision` at
  trace time: exact cache hit first, deterministic heuristic otherwise.
  Decisions are keyed per bucket *class* as well as per step:
  ``ops.bpmf_gram_step`` consults the step key first, and when it misses
  (and the heuristic is not fused) each bucket resolves its own
  :func:`bucket_key` — so one sweep step can mix Gram implementations
  across pad classes from a warmed per-bucket cache.
  The heuristic **never times anything**, so CPU/CI runs never block on
  measurement, and it consults the fitted :class:`~repro.core.balance.CostModel`
  from the fig2 microbenchmark — the same regression that weighs items
  during partitioning also steers kernel choice.
* :func:`measure_step` — the measured sweep over
  ``(tb, pc) × {pallas_fused, pallas, xla}`` for one step shape, recording
  the winner (with its timings) into the persistent cache. Driven by
  ``benchmarks/fig2_item_update.py``.
* :class:`AutotuneCache` — JSON persistence under ``experiments/autotune/``
  (override with ``REPRO_AUTOTUNE_DIR``). Entries are keyed by the encoded
  :class:`ShapeKey`, which bakes in every input that changes the choice —
  shape, dtype, backend and (for step keys) the scatter capacity — so a
  cache warmed on one machine is simply ignored (falls through to the
  heuristic) for shapes it has never seen.

Cache schema (``gram.json``)::

    {"version": 1,
     "entries": {"<key>": {"impl": "pallas_fused" | "pallas" | "xla",
                           "tb": 8, "pc": 128, "ns_chunk": null,
                           "timings_us": {"xla": 12.3, ...},   # optional
                           "source": "measured" | "recorded"}}}

Unknown versions or malformed files are ignored (heuristic fallback), which
is also the invalidation story: bump ``_CACHE_VERSION`` when a kernel
change makes old measurements meaningless.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.kernels.bpmf_gram import vmem_bytes_estimate
from repro.utils import round_up

_CACHE_VERSION = 1
_VMEM_BUDGET = 12 * 2**20  # leave headroom below the ~16 MB/core VMEM

# Deterministic heuristic priors (overridden by any measured cache entry):
# the MXU runs the one-hot gather at roughly this multiple of the XLA
# gather's effective per-MAC throughput, and the fused kernel amortizes the
# per-dispatch fixed cost over all of a step's buckets.
_MXU_GATHER_ADVANTAGE = 32.0
_FUSED_DISPATCH_DISCOUNT = 8.0

_TB_CANDIDATES = (8, 4, 2, 1)
_PC_CANDIDATES = (512, 256, 128)


def _dtype_name(compute_dtype: Any) -> str:
    return jnp.dtype(compute_dtype).name


@dataclasses.dataclass(frozen=True)
class ShapeKey:
    """Everything that changes which Gram implementation wins.

    ``kind`` is ``"bucket"`` (one ``[B, P]`` bucket, per-row output) or
    ``"step"`` (all buckets of one ring step, scatter into ``[cap, K, K]``).
    For step keys, ``B`` is the total row count over the step's buckets and
    ``P`` the largest pad class.
    """

    kind: str  # "bucket" | "step"
    B: int
    P: int
    Ns: int
    K: int
    dtype: str
    backend: str
    cap: int = 0  # step keys only: scatter target rows

    def encode(self) -> str:
        """Stable string form used as the JSON cache key."""
        s = f"{self.kind}_B{self.B}_P{self.P}_Ns{self.Ns}_K{self.K}_{self.dtype}_{self.backend}"
        return f"{s}_cap{self.cap}" if self.kind == "step" else s


@dataclasses.dataclass(frozen=True)
class Decision:
    """Resolved implementation choice for one :class:`ShapeKey`.

    ``impl`` is ``"pallas_fused"`` (one fused kernel launch per step),
    ``"pallas"`` (per-bucket kernel) or ``"xla"`` (gather + einsum).
    Tiling fields are ``None`` for ``"xla"``; ``ns_chunk=None`` means the
    whole shard stays resident in VMEM.
    """

    impl: str
    tb: int | None = None
    pc: int | None = None
    ns_chunk: int | None = None


def bucket_key(
    B: int, P: int, Ns: int, K: int, compute_dtype: Any = jnp.float32, backend: str | None = None
) -> ShapeKey:
    """Key for a single-bucket ``bpmf_gram`` dispatch."""
    return ShapeKey(
        "bucket", B, P, Ns, K, _dtype_name(compute_dtype), backend or jax.default_backend()
    )


def step_key(
    bucket_shapes: Sequence[tuple[int, int]],
    Ns: int,
    K: int,
    cap: int,
    compute_dtype: Any = jnp.float32,
    backend: str | None = None,
) -> ShapeKey:
    """Key for a whole ring step (``bucket_shapes``: per-bucket ``(B, P)``)."""
    B = sum(b for b, _ in bucket_shapes)
    P = max((p for _, p in bucket_shapes), default=0)
    return ShapeKey(
        "step", B, P, Ns, K, _dtype_name(compute_dtype), backend or jax.default_backend(), cap
    )


def workload_step_keys(
    data, K: int, compute_dtype: Any = jnp.float32, backend: str | None = None
) -> list[tuple[ShapeKey, list[tuple[int, int]]]]:
    """Exact engine step keys for every ring step of a distributed layout.

    Inside the shard_map trace, ``ops.bpmf_gram_step`` sees the per-device
    *local* bucket slices, ``Ns`` = the opposite side's padded shard
    capacity and ``cap`` = the updated side's capacity. This derives the
    same keys host-side from a ``DistBPMFData``, so cache entries recorded
    for them (e.g. by the fig2 driver's workload sweep, or a user tuning
    their own dataset) actually engage when the engine runs that workload.

    Args:
        data: ``repro.core.distributed.DistBPMFData`` (host- or device-side).
        K: Latent rank the run will use.
        compute_dtype: Contraction dtype of the run.
        backend: Key backend (default: the current jax backend).

    Returns:
        ``(key, local_bucket_shapes)`` per (side, ring step), in order;
        duplicates across steps are *not* removed.
    """
    S = data.num_shards
    out: list[tuple[ShapeKey, list[tuple[int, int]]]] = []
    for side, opp in ((data.users, data.movies), (data.movies, data.users)):
        for step in side.steps:
            shapes = [(int(b.item_ids.shape[0]) // S, int(b.P)) for b in step]
            out.append(
                (step_key(shapes, opp.cap, K, side.cap, compute_dtype, backend), shapes)
            )
    return out


# --------------------------------------------------------------------------
# Persistent cache
# --------------------------------------------------------------------------


def default_cache_dir() -> str:
    """``$REPRO_AUTOTUNE_DIR`` or ``<repo>/experiments/autotune``."""
    env = os.environ.get("REPRO_AUTOTUNE_DIR")
    if env:
        return env
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.normpath(os.path.join(here, "..", "..", "..", "experiments", "autotune"))


class AutotuneCache:
    """JSON-backed ``ShapeKey -> Decision`` store (see module docstring)."""

    def __init__(self, path: str | None = None):
        self.path = path or os.path.join(default_cache_dir(), "gram.json")
        self._entries: dict[str, dict] | None = None

    def entries(self) -> dict[str, dict]:
        """Lazily-loaded entry dict; malformed/old files load as empty."""
        if self._entries is None:
            self._entries = {}
            try:
                with open(self.path) as f:
                    raw = json.load(f)
                if isinstance(raw, dict) and raw.get("version") == _CACHE_VERSION:
                    self._entries = dict(raw.get("entries", {}))
            except (OSError, ValueError):
                pass
        return self._entries

    def lookup(self, key: ShapeKey) -> Decision | None:
        """Exact-key decision, or ``None`` (caller falls back to heuristic)."""
        e = self.entries().get(key.encode())
        if not e or e.get("impl") not in ("pallas_fused", "pallas", "xla"):
            return None
        return Decision(e["impl"], e.get("tb"), e.get("pc"), e.get("ns_chunk"))

    def record(
        self,
        key: ShapeKey,
        decision: Decision,
        timings_us: dict[str, float] | None = None,
        source: str = "recorded",
    ) -> None:
        """Insert/overwrite one entry and persist immediately."""
        entry: dict[str, Any] = {
            "impl": decision.impl,
            "tb": decision.tb,
            "pc": decision.pc,
            "ns_chunk": decision.ns_chunk,
            "source": source,
        }
        if timings_us:
            entry["timings_us"] = {k: float(v) for k, v in timings_us.items()}
        self.entries()[key.encode()] = entry
        self.save()

    def save(self) -> None:
        """Write the cache file (creates the directory if needed)."""
        os.makedirs(os.path.dirname(self.path), exist_ok=True)
        with open(self.path, "w") as f:
            json.dump({"version": _CACHE_VERSION, "entries": self.entries()}, f, indent=1)


_CACHE: AutotuneCache | None = None


def get_cache() -> AutotuneCache:
    """Process-wide cache singleton (path resolved on first use)."""
    global _CACHE
    if _CACHE is None:
        _CACHE = AutotuneCache()
    return _CACHE


def set_cache(cache: AutotuneCache | None) -> None:
    """Replace the singleton (``None`` re-resolves the path on next use)."""
    global _CACHE
    _CACHE = cache


# --------------------------------------------------------------------------
# Cost model plumbing (fig2 → partitioning → kernel choice)
# --------------------------------------------------------------------------

_COST_MODEL = None  # lazily loaded; False = tried and failed


def load_fig2_cost_model():
    """The fitted fig2 :class:`~repro.core.balance.CostModel`, or defaults.

    Reads ``experiments/bench/fig2_item_update.json`` (written by the fig2
    autotune driver); falls back to ``CostModel()`` defaults when the
    artifact is missing, so the heuristic stays deterministic either way.
    """
    global _COST_MODEL
    from repro.core.balance import CostModel

    if _COST_MODEL is None:
        path = os.path.normpath(
            os.path.join(
                os.path.dirname(os.path.abspath(__file__)),
                "..", "..", "..", "experiments", "bench", "fig2_item_update.json",
            )
        )
        try:
            with open(path) as f:
                raw = json.load(f)
            cm = raw["cost_model"]
            _COST_MODEL = CostModel(
                fixed=float(cm["fixed_us"]), per_rating=float(cm["per_rating_us"])
            )
        except (OSError, ValueError, KeyError, TypeError):
            _COST_MODEL = False
    return _COST_MODEL if _COST_MODEL else CostModel()


# --------------------------------------------------------------------------
# Tiling + heuristic decision (deterministic, never measures)
# --------------------------------------------------------------------------


def pick_tiling(
    B: int, P: int, Ns: int, K: int, compute_dtype=jnp.float32, cap: int = 0
) -> tuple[int, int] | None:
    """Choose ``(tb, pc)`` with the whole shard VMEM-resident, or ``None``.

    Uses the post-restructure block estimate — ``nbr``/``val`` blocks are
    ``(tb, pc)`` regardless of P (the P axis is a grid dimension), so
    large-P buckets no longer undercount VMEM. ``None`` means the shard
    itself does not fit; callers then stream it via :func:`chunked_tiling`
    (or fall back to XLA).
    """
    for tb in _TB_CANDIDATES:
        for pc in _PC_CANDIDATES:
            if pc > round_up(max(P, 1), 128) and pc != _PC_CANDIDATES[-1]:
                continue  # don't tile wider than the (padded) row
            if vmem_bytes_estimate(tb, pc, Ns, K, None, compute_dtype, cap) <= _VMEM_BUDGET:
                return tb, pc
    return None


def chunked_tiling(
    B: int, P: int, Ns: int, K: int, compute_dtype=jnp.float32, cap: int = 0
) -> tuple[int, int, int] | None:
    """``(tb, pc, ns_chunk)`` streaming the shard through VMEM, or ``None``.

    Picks the largest power-of-two ``ns_chunk`` (≥ 128) whose working set
    fits the budget at a fixed ``(tb=8, pc=128)`` tile; ``None`` only when
    even the smallest chunk overflows (huge K·cap), in which case the
    caller must use XLA.
    """
    tb, pc = 8, 128
    ns = 1 << (max(int(Ns) - 1, 1)).bit_length()  # next pow2 >= Ns
    while ns >= 128:
        if (
            ns <= Ns
            and vmem_bytes_estimate(tb, pc, Ns, K, ns, compute_dtype, cap) <= _VMEM_BUDGET
        ):
            return tb, pc, ns
        ns //= 2
    return None


def heuristic(key: ShapeKey, cost_model=None) -> Decision:
    """Deterministic fallback decision — no timing, ever.

    Decision tree (DESIGN.md §8):

    1. Not on TPU → ``"xla"``. Interpret-mode Pallas exists for parity
       tests only; CI must never pay its cost by default.
    2. Cost-model gate: the fig2 fit estimates the XLA gather at
       ``fixed + per_rating·P`` µs/item; the one-hot kernel does
       ``Ns/K``× more MAC work at ``_MXU_GATHER_ADVANTAGE``× the
       throughput, with the fused kernel amortizing the fixed cost over
       the step (``_FUSED_DISPATCH_DISCOUNT``). XLA wins → ``"xla"``.
    3. Shard fits VMEM (:func:`pick_tiling`) → ``"pallas_fused"`` for step
       keys, ``"pallas"`` for bucket keys, with that tiling.
    4. Otherwise stream Ns (:func:`chunked_tiling`); if even that cannot
       fit, ``"xla"``.
    """
    if key.backend != "tpu":
        return Decision("xla")
    cm = cost_model or load_fig2_cost_model()
    fused = key.kind == "step"
    fixed = cm.fixed / (_FUSED_DISPATCH_DISCOUNT if fused else 1.0)
    est_xla = cm.fixed + cm.per_rating * key.P
    est_onehot = fixed + cm.per_rating * key.P * (key.Ns / max(key.K, 1)) / _MXU_GATHER_ADVANTAGE
    if est_onehot > est_xla:
        return Decision("xla")
    dtype = jnp.dtype(key.dtype)
    impls = [("pallas_fused", key.cap), ("pallas", 0)] if fused else [("pallas", 0)]
    for impl, cap in impls:
        # degrade fused -> per-bucket before xla: a scatter capacity too
        # large for the fused accumulator windows doesn't make the
        # per-bucket kernel (cap-independent working set) any less viable
        tiling = pick_tiling(key.B, key.P, key.Ns, key.K, dtype, cap)
        if tiling is not None:
            return Decision(impl, tiling[0], tiling[1], None)
        chunked = chunked_tiling(key.B, key.P, key.Ns, key.K, dtype, cap)
        if chunked is not None:
            return Decision(impl, chunked[0], chunked[1], chunked[2])
    return Decision("xla")


def decide(key: ShapeKey, cost_model=None, cache: AutotuneCache | None = None) -> Decision:
    """Trace-time dispatch decision: cache hit, else :func:`heuristic`."""
    cache = cache or get_cache()
    hit = cache.lookup(key)
    if hit is not None:
        return hit
    return heuristic(key, cost_model)


# --------------------------------------------------------------------------
# Measured sweep (the fig2 driver's workhorse)
# --------------------------------------------------------------------------


def _synthetic_step(bucket_shapes, Ns, K, cap, compute_dtype, seed=0):
    """Random but reproducible step data matching a :func:`step_key` shape."""
    import numpy as np

    from repro.core.types import Bucket

    rng = np.random.default_rng(seed)
    buckets = []
    slot = 0
    for B, P in bucket_shapes:
        nnz = rng.integers(1, P + 1, B).astype(np.int32)
        nbr = rng.integers(0, Ns, (B, P)).astype(np.int32)
        val = rng.normal(size=(B, P)).astype(np.float32)
        val[np.arange(P)[None, :] >= nnz[:, None]] = 0.0
        item_ids = (slot + np.arange(B)) % cap
        slot += B
        buckets.append(
            Bucket(
                item_ids=jnp.asarray(item_ids, jnp.int32),
                nbr=jnp.asarray(nbr),
                val=jnp.asarray(val),
                nnz=jnp.asarray(nnz),
            )
        )
    X = jnp.asarray(rng.normal(size=(Ns, K)), jnp.float32)
    G = jnp.zeros((cap, K, K), jnp.float32)
    g = jnp.zeros((cap, K), jnp.float32)
    return G, g, X, tuple(buckets)


def measure_step(
    bucket_shapes: Sequence[tuple[int, int]],
    Ns: int,
    K: int,
    cap: int | None = None,
    compute_dtype: Any = jnp.float32,
    alpha: float = 2.0,
    iters: int = 5,
    tilings: Sequence[tuple[int, int]] | None = None,
    cache: AutotuneCache | None = None,
) -> tuple[Decision, dict[str, float]]:
    """Time ``(tb, pc) × {pallas_fused, pallas, xla}`` for one step shape.

    Builds synthetic step data, times every candidate through the real
    dispatch path (``ops.bpmf_gram_step``), records the winner into the
    cache (``source="measured"``) and returns ``(winner, timings_us)``.
    Timing keys are ``"xla"``, ``"pallas_tb{tb}_pc{pc}"`` and
    ``"pallas_fused_tb{tb}_pc{pc}"``; the per-impl minima decide.

    Args:
        bucket_shapes: Per-bucket ``(B, P)`` of the step.
        Ns: Opposite-shard rows.
        K: Latent rank.
        cap: Scatter target rows (default: total B, rounded up to 8).
        compute_dtype: Contraction dtype.
        alpha: Noise precision folded into the fused kernel.
        iters: ``utils.timeit`` iterations per candidate (tiny budgets are
            fine — the cache only needs an ordering, not a clean number).
        tilings: Candidate ``(tb, pc)`` pairs (default: a small grid
            filtered by the VMEM estimate).
        cache: Cache to record into (default: the singleton).

    Returns:
        The winning :class:`Decision` and all candidate timings in µs. The
        winner is recorded into the cache unless an existing measured entry
        for the same key compared strictly more candidates (a tiny-budget
        smoke re-run must not degrade a full sweep's decision).
    """
    from repro.kernels import ops

    total_B = sum(b for b, _ in bucket_shapes)
    cap = cap or round_up(max(total_B, 1), 8)
    key = step_key(bucket_shapes, Ns, K, cap, compute_dtype)
    G, g, X, buckets = _synthetic_step(bucket_shapes, Ns, K, cap, compute_dtype)

    if tilings is None:
        tilings = [(tb, pc) for tb in (8, 4) for pc in (128, 256, 512)]

    import functools

    timings: dict[str, float] = {}
    candidates: dict[str, Decision] = {"xla": Decision("xla")}
    P_max = max((p for _, p in bucket_shapes), default=128)
    for tb, pc in tilings:
        # admit each candidate only if *its* working set fits — the fused
        # kernel additionally holds the (cap, K, K)/(cap, K) accumulator
        # windows (input + aliased output copy) resident
        if vmem_bytes_estimate(tb, pc, Ns, K, None, compute_dtype) <= _VMEM_BUDGET:
            candidates[f"pallas_tb{tb}_pc{pc}"] = Decision("pallas", tb, pc)
        if vmem_bytes_estimate(tb, pc, Ns, K, None, compute_dtype, cap) <= _VMEM_BUDGET:
            candidates[f"pallas_fused_tb{tb}_pc{pc}"] = Decision("pallas_fused", tb, pc)
    # shards too large to sit resident get one Ns-streaming candidate per
    # impl — otherwise the streaming mode could never win a measurement and
    # exactly the shapes it targets would record "xla" forever
    if not any(d.impl == "pallas" for d in candidates.values()):
        c = chunked_tiling(total_B, P_max, Ns, K, compute_dtype)
        if c is not None:
            candidates[f"pallas_tb{c[0]}_pc{c[1]}_ns{c[2]}"] = Decision("pallas", *c)
    if not any(d.impl == "pallas_fused" for d in candidates.values()):
        c = chunked_tiling(total_B, P_max, Ns, K, compute_dtype, cap)
        if c is not None:
            candidates[f"pallas_fused_tb{c[0]}_pc{c[1]}_ns{c[2]}"] = Decision(
                "pallas_fused", *c
            )

    import time

    import numpy as np

    fns = {}
    for label, dec in candidates.items():
        fns[label] = jax.jit(
            functools.partial(
                ops.bpmf_gram_step,
                alpha=alpha,
                compute_dtype=compute_dtype,
                gram_impl=dec.impl,
                tb=dec.tb,
                pc=dec.pc,
                ns_chunk=dec.ns_chunk,
            )
        )
        jax.block_until_ready(fns[label](G, g, X, buckets))  # compile + warm
    # interleave candidates round-robin so machine-level drift during the
    # sweep biases every candidate equally, then take per-candidate medians
    samples: dict[str, list[float]] = {label: [] for label in candidates}
    for _ in range(max(iters, 1)):
        for label, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(G, g, X, buckets))
            samples[label].append(time.perf_counter() - t0)
    timings = {label: float(np.median(ts)) * 1e6 for label, ts in samples.items()}

    best_label = min(timings, key=timings.get)
    best = candidates[best_label]
    store = cache or get_cache()
    prev = store.entries().get(key.encode())
    # never let a narrower sweep (e.g. the CI smoke's single tiling) clobber
    # a measured entry that compared more candidates for the same key
    if not (
        prev
        and prev.get("source") == "measured"
        and len(prev.get("timings_us", {})) > len(timings)
    ):
        store.record(key, best, timings_us=timings, source="measured")
    return best, timings
