"""Jit'd dispatch wrappers around the Pallas kernels.

Each op picks the best implementation for the current backend:
  - TPU: the Pallas kernel (one-hot MXU gather) when the shard fits VMEM,
  - CPU (this container): interpret-mode Pallas for tests, jnp path otherwise.
The jnp path in ``ref.py`` is the semantic ground truth everywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bpmf_gram import bpmf_gram_pallas, vmem_bytes_estimate
from repro.utils import round_up

_VMEM_BUDGET = 12 * 2**20  # leave headroom below the ~16 MB/core VMEM


def _pad_axis(x: jax.Array, axis: int, multiple: int, fill=0) -> jax.Array:
    size = x.shape[axis]
    target = round_up(max(size, 1), multiple)
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=fill)


def pick_tiling(B: int, P: int, Ns: int, K: int, compute_dtype=jnp.float32) -> tuple[int, int] | None:
    """Choose (tb, pc) fitting the VMEM budget, or None if the shard is too big."""
    for tb in (8, 4, 2, 1):
        for pc in (512, 256, 128):
            if vmem_bytes_estimate(tb, pc, Ns, K, min(P, 4096), compute_dtype) <= _VMEM_BUDGET:
                return tb, pc
    return None


def bpmf_gram(
    X: jax.Array,
    nbr: jax.Array,
    val: jax.Array,
    nnz: jax.Array,
    *,
    compute_dtype=jnp.float32,
    force_pallas: bool | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Dispatch the gather+Gram op; returns (G [B,K,K] f32, g [B,K] f32)."""
    B, P = nbr.shape
    Ns, K = X.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tiling = pick_tiling(B, P, Ns, K, compute_dtype)
    use_pallas = force_pallas if force_pallas is not None else (tiling is not None)
    if not use_pallas or tiling is None:
        return ref.bpmf_gram_ref(X, nbr, val, nnz, compute_dtype)

    tb, pc = tiling
    nbr_p = _pad_axis(_pad_axis(nbr, 1, pc), 0, tb)
    val_p = _pad_axis(_pad_axis(val, 1, pc), 0, tb)
    nnz_p = _pad_axis(nnz, 0, tb)
    G, g = bpmf_gram_pallas(
        X, nbr_p, val_p, nnz_p, tb=tb, pc=pc, compute_dtype=compute_dtype, interpret=interpret
    )
    return G[:B], g[:B]
