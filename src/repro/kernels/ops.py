"""Jit'd dispatch wrappers around the Pallas kernels.

Each op picks the best implementation for the current shape and backend via
``kernels.autotune`` (measured cache entry → deterministic heuristic):

  - ``"pallas_fused"``: one fused ``pallas_call`` per ring step, scatter-
    accumulating every bucket into the per-item ``(G, g)`` running sums;
  - ``"pallas"``: the per-bucket one-hot MXU kernel;
  - ``"xla"``: gather + einsum (``ref.py`` is the semantic ground truth).

On CPU (this container) the Pallas paths run in interpret mode for tests;
the heuristic therefore defaults to ``"xla"`` off-TPU and only a warmed
autotune cache (or an explicit ``gram_impl``) selects a kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import autotune, ref
from repro.kernels.bpmf_gram import bpmf_gram_fused, bpmf_gram_pallas, vmem_bytes_estimate
from repro.utils import round_up

# re-exported for back-compat: the tiling choice lives with the autotuner now
pick_tiling = autotune.pick_tiling
_VMEM_BUDGET = autotune._VMEM_BUDGET


def _pad_axis(x: jax.Array, axis: int, multiple: int, fill=0) -> jax.Array:
    size = x.shape[axis]
    target = round_up(max(size, 1), multiple)
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=fill)


def _bpmf_gram_xla(
    X: jax.Array, nbr: jax.Array, val: jax.Array, nnz: jax.Array, compute_dtype
) -> tuple[jax.Array, jax.Array]:
    """The production XLA path: gather once, one augmented contraction.

    The masked ``[B, P, K]`` neighbor block is materialized a single time
    and ``[Xn | val]`` is contracted against itself, so both the Gram
    matrix and the linear term come out of one einsum — XLA cannot
    rematerialize the gather per contraction (``ref.bpmf_gram_ref`` stays
    the naive two-einsum oracle)::

        Z = Y^T Y,  Y = [Xn, val]   →   G = Z[:K, :K],  g = Z[:K, K]
    """
    P = nbr.shape[1]
    mask = (jnp.arange(P, dtype=jnp.int32)[None, :] < nnz[:, None]).astype(compute_dtype)
    Xn = jnp.take(X, nbr, axis=0).astype(compute_dtype) * mask[..., None]
    Y = jnp.concatenate([Xn, val.astype(compute_dtype)[..., None]], axis=-1)
    Z = jnp.einsum("bpi,bpj->bij", Y, Y, preferred_element_type=jnp.float32)
    return Z[:, :-1, :-1].astype(jnp.float32), Z[:, :-1, -1].astype(jnp.float32)


def _fill_tiling(
    dec: autotune.Decision,
    B: int,
    P: int,
    Ns: int,
    K: int,
    compute_dtype,
    cap: int = 0,
) -> autotune.Decision:
    """Complete a pallas decision's missing (tb, pc, ns_chunk) fields.

    Returns ``None`` when the working set cannot fit the VMEM budget even
    streamed (``chunked_tiling``'s contract) — callers fall back to XLA.
    Explicit ``tb`` *and* ``pc`` are trusted verbatim (tests/benchmarks).
    """
    tb, pc, ns = dec.tb, dec.pc, dec.ns_chunk
    if tb is not None and pc is not None:
        return dec
    tiling = autotune.pick_tiling(B, P, Ns, K, compute_dtype, cap)
    if tiling is not None:
        return autotune.Decision(dec.impl, tb or tiling[0], pc or tiling[1], ns)
    chunked = autotune.chunked_tiling(B, P, Ns, K, compute_dtype, cap)
    if chunked is None:
        return None
    return autotune.Decision(
        dec.impl, tb or chunked[0], pc or chunked[1], ns or chunked[2]
    )


def bpmf_gram(
    X: jax.Array,
    nbr: jax.Array,
    val: jax.Array,
    nnz: jax.Array,
    *,
    compute_dtype=jnp.float32,
    impl: str = "auto",
    tb: int | None = None,
    pc: int | None = None,
    ns_chunk: int | None = None,
    force_pallas: bool | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Dispatch the per-bucket gather+Gram op; returns (G [B,K,K], g [B,K]).

    ``impl`` is ``"auto"`` (autotune cache → heuristic), ``"pallas"`` or
    ``"xla"``; explicit ``tb``/``pc``/``ns_chunk`` override the decision's
    tiling. ``force_pallas`` is the legacy boolean override (maps to
    ``impl``). When the shard exceeds the VMEM budget the kernel streams it
    in ``ns_chunk`` rows instead of falling back to XLA.
    """
    B, P = nbr.shape
    Ns, K = X.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if force_pallas is not None:
        impl = "pallas" if force_pallas else "xla"
    if impl == "auto":
        dec = autotune.decide(autotune.bucket_key(B, P, Ns, K, compute_dtype))
    elif impl in ("pallas", "pallas_fused"):  # fused degenerates to per-bucket here
        dec = autotune.Decision("pallas", tb, pc, ns_chunk)
    elif impl == "xla":
        dec = autotune.Decision("xla")
    else:
        raise ValueError(f"unknown impl {impl!r}; one of auto|pallas|xla")
    if dec.impl != "xla":
        dec = _fill_tiling(
            autotune.Decision(
                dec.impl, tb or dec.tb, pc or dec.pc, ns_chunk or dec.ns_chunk
            ),
            B, P, Ns, K, compute_dtype,
        )
    if dec is None or dec.impl == "xla":
        return _bpmf_gram_xla(X, nbr, val, nnz, compute_dtype)
    nbr_p = _pad_axis(_pad_axis(nbr, 1, dec.pc), 0, dec.tb)
    val_p = _pad_axis(_pad_axis(val, 1, dec.pc), 0, dec.tb)
    nnz_p = _pad_axis(nnz, 0, dec.tb)
    X_p = _pad_axis(X, 0, dec.ns_chunk) if dec.ns_chunk else X
    G, g = bpmf_gram_pallas(
        X_p, nbr_p, val_p, nnz_p,
        tb=dec.tb, pc=dec.pc, ns_chunk=dec.ns_chunk,
        compute_dtype=compute_dtype, interpret=interpret,
    )
    return G[:B], g[:B]


def flatten_step(buckets, pc: int, tb: int):
    """Flatten a ring step's buckets into the fused kernel's chunk layout.

    Every bucket row is split into ``ceil(P / pc)`` width-``pc`` chunks
    (rows pad to a ``pc`` multiple with dead entries); chunks carry their
    destination item row and their own valid-count so the kernel needs no
    per-bucket metadata. Pure reshapes/concats — XLA fuses this into the
    surrounding sweep, and the layout is identical every sweep so it
    jit-caches with the step.

    Args:
        buckets: The step's ``Bucket`` tuple (``item_ids`` may contain -1
            padding rows, which become dead chunks).
        pc: Chunk width (the fused kernel's P tile).
        tb: Chunk-tile height; the flat axis pads to a multiple of it.

    Returns:
        ``(nbr [C, pc], val [C, pc], item [C], cnt [C])`` with
        ``C % tb == 0``; dead chunks have ``item == -1`` and ``cnt == 0``.
    """
    nbrs, vals, items, cnts = [], [], [], []
    for b in buckets:
        B, P = b.nbr.shape
        ck = round_up(P, pc) // pc
        nbrs.append(_pad_axis(b.nbr, 1, pc).reshape(B * ck, pc))
        vals.append(_pad_axis(b.val, 1, pc).reshape(B * ck, pc))
        items.append(jnp.repeat(b.item_ids, ck))
        offs = jnp.arange(ck, dtype=jnp.int32) * pc
        cnts.append(jnp.clip(b.nnz[:, None] - offs[None, :], 0, pc).reshape(B * ck))
    nbr = _pad_axis(jnp.concatenate(nbrs), 0, tb)
    val = _pad_axis(jnp.concatenate(vals), 0, tb)
    item = _pad_axis(jnp.concatenate(items), 0, tb, fill=-1)
    cnt = _pad_axis(jnp.concatenate(cnts), 0, tb)
    return nbr, val, item.astype(jnp.int32), cnt.astype(jnp.int32)


def bpmf_gram_step(
    G: jax.Array,
    g: jax.Array,
    X_src: jax.Array,
    buckets,
    *,
    alpha: float,
    compute_dtype=jnp.float32,
    gram_impl: str = "auto",
    tb: int | None = None,
    pc: int | None = None,
    ns_chunk: int | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Accumulate one ring step's bucket contributions into ``(G, g)``.

    The distributed half-sweeps call this once per ring step.
    ``gram_impl="auto"`` resolves the step's :class:`~repro.kernels.autotune.ShapeKey`
    through the autotune cache/heuristic at trace time; a fused decision
    lowers the whole step to **one** ``pallas_call`` (flattened chunk
    layout + in-kernel scatter), while ``"pallas"``/``"xla"`` keep the
    per-bucket loop with ``at[].add`` scatters. ``"pallas_fused"`` forces
    the fused kernel (parity tests / benchmarks).

    When an auto step misses the step-key cache and the heuristic does not
    pick the fused kernel, each bucket re-resolves its **own** bucket-class
    key (``autotune.bucket_key``) instead of inheriting one step-wide
    choice — so a warmed per-bucket cache can mix implementations inside a
    single step (e.g. the big pad class on Pallas, the tail on XLA). An
    exact *step*-key cache hit still pins the whole step, so measured
    ``measure_step`` decisions keep their meaning.

    Args:
        G: ``[cap, K, K]`` f32 running Gram accumulator.
        g: ``[cap, K]`` f32 running linear-term accumulator.
        X_src: ``[Ns, K]`` opposite-side shard for this step.
        buckets: The step's ``Bucket`` tuple.
        alpha: Rating noise precision (scales both terms).
        compute_dtype: Contraction dtype.
        gram_impl: ``"auto" | "pallas_fused" | "pallas" | "xla"``.
        tb / pc / ns_chunk: Explicit tiling overrides (tests/benchmarks).
        interpret: Pallas interpret mode (default: off-TPU).

    Returns:
        Updated ``(G, g)``.
    """
    if not buckets:
        return G, g
    Ns, K = X_src.shape
    cap = G.shape[0]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shapes = [(b.B, b.P) for b in buckets]
    per_bucket_auto = False
    if gram_impl == "auto":
        skey = autotune.step_key(shapes, Ns, K, cap, compute_dtype)
        dec = autotune.get_cache().lookup(skey)
        if dec is None:
            # no measured step entry: take the heuristic only for the
            # fused-vs-not call, and let each bucket resolve its own
            # bucket-class key below (one step may mix impls)
            dec = autotune.heuristic(skey)
            per_bucket_auto = dec.impl != "pallas_fused"
    elif gram_impl == "pallas_fused":
        dec = autotune.Decision("pallas_fused", tb, pc, ns_chunk)
    elif gram_impl in ("pallas", "xla"):
        dec = autotune.Decision(gram_impl, tb, pc, ns_chunk)
    else:
        raise ValueError(
            f"unknown gram_impl {gram_impl!r}; one of auto|pallas_fused|pallas|xla"
        )

    if dec.impl == "pallas_fused":
        B_tot = sum(b for b, _ in shapes)
        P_max = max(p for _, p in shapes)
        dec = _fill_tiling(
            autotune.Decision(dec.impl, tb or dec.tb, pc or dec.pc, ns_chunk or dec.ns_chunk),
            B_tot, P_max, Ns, K, compute_dtype, cap,
        )
        if dec is None:
            # fused accumulator windows don't fit: degrade to the
            # per-bucket kernel (cap-independent), whose own dispatch
            # still falls back to XLA if even streaming cannot fit
            dec = autotune.Decision("pallas")
    if dec.impl == "pallas_fused":
        nbr, val, item, cnt = flatten_step(buckets, dec.pc, dec.tb)
        X_p = _pad_axis(X_src, 0, dec.ns_chunk) if dec.ns_chunk else X_src
        return bpmf_gram_fused(
            G, g, X_p, nbr, val, item, cnt,
            alpha=alpha, tb=dec.tb, ns_chunk=dec.ns_chunk,
            compute_dtype=compute_dtype, interpret=interpret,
        )

    a = jnp.asarray(alpha, jnp.float32)
    for b in buckets:
        if per_bucket_auto:
            # bucket-class dispatch: bpmf_gram resolves this bucket's own
            # autotune.bucket_key (cache hit or heuristic), so different
            # pad classes of the same step can take different impls
            Gb, gb = bpmf_gram(
                X_src, b.nbr, b.val, b.nnz,
                compute_dtype=compute_dtype, impl="auto",
                tb=tb, pc=pc, ns_chunk=ns_chunk, interpret=interpret,
            )
        else:
            # dispatch per bucket so the decision's (tb, pc, ns_chunk) —
            # from the cache or explicit overrides — reaches the kernel
            Gb, gb = bpmf_gram(
                X_src, b.nbr, b.val, b.nnz,
                compute_dtype=compute_dtype, impl=dec.impl,
                tb=tb or dec.tb, pc=pc or dec.pc,
                ns_chunk=ns_chunk or dec.ns_chunk, interpret=interpret,
            )
        G = G.at[b.item_ids].add(a * Gb, mode="drop")
        g = g.at[b.item_ids].add(a * gb, mode="drop")
    return G, g
