"""Pallas TPU kernels for the BPMF gather + Gram accumulation hot loop.

For a bucket of items, each with up to P neighbors indexed into the
opposite-side latent shard ``X [Ns, K]``, compute per item

    G[b] = sum_p m[b,p] * x_{nbr[b,p]} x_{nbr[b,p]}^T        [K, K]
    g[b] = sum_p m[b,p] * val[b,p] * x_{nbr[b,p]}            [K]

TPU adaptation (DESIGN.md §2): a ragged HBM gather is the natural GPU
formulation; on TPU we exploit that the *ring-distributed* layout keeps the
per-step shard small enough for VMEM, so the gather becomes a one-hot MXU
contraction:

    W[b]  = onehot(nbr[b]) * mask[b]        [P, Ns]   (built in VREGs)
    Xg[b] = W[b] @ X                        [P, K]    (MXU)
    G[b]  = Xg[b]^T @ Xg[b]                 [K, K]    (MXU)
    g[b]  = Xg[b]^T @ (val[b] * mask[b])    [K]       (MXU)

Two kernels share this formulation (DESIGN.md §8):

* :func:`bpmf_gram_pallas` — the per-bucket kernel: grid over
  ``(item tiles, P chunks, Ns chunks)``, emitting per-bucket-row ``(G, g)``.
* :func:`bpmf_gram_fused` — the fused multi-bucket kernel: one
  ``pallas_call`` per ring step over a *flattened chunk layout* (every
  bucket row pre-split into width-``pc`` chunks, see ``ops.flatten_step``),
  scatter-accumulating directly into the per-local-item ``(G [cap,K,K],
  g [cap,K])`` running sums via ``input_output_aliases`` — no per-bucket
  Python loop, no XLA ``at[].add`` scatters.

Both kernels stream the opposite-side shard through VMEM in ``ns_chunk``-row
slices when it is too large to be resident (the Ns axis becomes a grid
dimension; the gathered rows are accumulated in a VMEM scratch buffer, which
is exact because each neighbor index hits exactly one Ns chunk — all other
chunks contribute exact zeros). FLOPs per item: P*Ns*K (gather) + P*K^2
(Gram) — the one-hot gather is profitable only when Ns is small (the sharded
case, which is exactly the paper's distributed hot loop).
``kernels.autotune`` owns the measured / heuristic choice between these
kernels and the XLA gather path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_chunk(nbr, valid, x, base, compute_dtype):
    """One-hot MXU gather of one (rows, pc) chunk against one Ns slice.

    Args:
        nbr: ``[T, pc]`` int32 neighbor ids (global to the unchunked shard).
        valid: ``[T, pc]`` mask of in-range neighbor positions.
        x: ``[ns_chunk, K]`` slice of the shard, rows ``[base, base+ns_chunk)``.
        base: First shard row held in ``x``.
        compute_dtype: dtype of the one-hot contraction.

    Returns:
        ``[T, pc, K]`` f32 gathered rows; exact zeros where the neighbor lives
        in a different Ns chunk or the position is masked.
    """
    T, pc = nbr.shape
    ns = x.shape[0]
    row_ids = base + jax.lax.broadcasted_iota(jnp.int32, (T, pc, ns), 2)
    onehot = (nbr[:, :, None] == row_ids).astype(compute_dtype)
    onehot = onehot * valid.astype(compute_dtype)[:, :, None]
    return jax.lax.dot_general(
        onehot, x.astype(compute_dtype), (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _gram_kernel(
    nbr_ref,  # [TB, PC] int32 (VMEM)
    val_ref,  # [TB, PC] f32 (VMEM)
    nnz_ref,  # [TB, 1] int32 (VMEM)
    x_ref,  # [ns_chunk, K] (VMEM slice of the shard)
    G_ref,  # [TB, K, K] f32 out (revisited across the P and Ns grid dims)
    g_ref,  # [TB, K] f32 out
    xg_ref,  # [TB, PC, K] f32 scratch: gather accumulator across Ns chunks
    *,
    pc: int,
    ns_chunk: int,
    num_ns: int,
    compute_dtype,
):
    TB = nbr_ref.shape[0]
    p = pl.program_id(1)
    n = pl.program_id(2)

    @pl.when((p == 0) & (n == 0))
    def _init_outputs():
        G_ref[...] = jnp.zeros_like(G_ref)
        g_ref[...] = jnp.zeros_like(g_ref)

    @pl.when(n == 0)
    def _init_gather():
        xg_ref[...] = jnp.zeros_like(xg_ref)

    nnz = nnz_ref[...]  # [TB, 1]
    pos = p * pc + jax.lax.broadcasted_iota(jnp.int32, (TB, pc), 1)
    mask = pos < nnz  # [TB, pc] valid neighbor positions of this P chunk
    xg_ref[...] += _gather_chunk(nbr_ref[...], mask, x_ref[...], n * ns_chunk, compute_dtype)

    @pl.when(n == num_ns - 1)
    def _contract():
        xg = xg_ref[...].astype(compute_dtype)
        G_ref[...] += jax.lax.dot_general(
            xg, xg, (((1,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
        )
        vm = (val_ref[...] * mask.astype(val_ref.dtype)).astype(compute_dtype)
        g_ref[...] += jax.lax.dot_general(
            xg, vm[:, :, None], (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )[:, :, 0]


@functools.partial(
    jax.jit,
    static_argnames=("tb", "pc", "ns_chunk", "compute_dtype", "interpret"),
)
def bpmf_gram_pallas(
    X: jax.Array,  # [Ns, K]
    nbr: jax.Array,  # [B, P] int32, B % tb == 0, P % pc == 0
    val: jax.Array,  # [B, P]
    nnz: jax.Array,  # [B] int32
    *,
    tb: int = 8,
    pc: int = 128,
    ns_chunk: int | None = None,
    compute_dtype=jnp.float32,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Per-bucket gather+Gram kernel; returns ``(G [B,K,K], g [B,K])`` in f32.

    Grid: ``(B // tb, P // pc, Ns // ns_chunk)``. The ``(G, g)`` output tile
    is revisited across the last two grid dimensions; the gather is
    accumulated in VMEM scratch across Ns chunks so the shard streams
    through VMEM ``ns_chunk`` rows at a time (``ns_chunk=None`` keeps the
    whole shard resident — requires ``Ns % ns_chunk == 0``; ``ops.bpmf_gram``
    pads).
    """
    B, P = nbr.shape
    Ns, K = X.shape
    if ns_chunk is None:
        ns_chunk = Ns
    if B % tb:
        raise ValueError(f"B={B} not a multiple of tb={tb} (ops.py pads)")
    if P % pc:
        raise ValueError(f"P={P} not a multiple of pc={pc} (ops.py pads)")
    if Ns % ns_chunk:
        raise ValueError(f"Ns={Ns} not a multiple of ns_chunk={ns_chunk} (ops.py pads)")
    num_ns = Ns // ns_chunk
    grid = (B // tb, P // pc, num_ns)
    kernel = functools.partial(
        _gram_kernel, pc=pc, ns_chunk=ns_chunk, num_ns=num_ns, compute_dtype=compute_dtype
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, pc), lambda i, p, n: (i, p)),
            pl.BlockSpec((tb, pc), lambda i, p, n: (i, p)),
            pl.BlockSpec((tb, 1), lambda i, p, n: (i, 0)),
            pl.BlockSpec((ns_chunk, K), lambda i, p, n: (n, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb, K, K), lambda i, p, n: (i, 0, 0)),
            pl.BlockSpec((tb, K), lambda i, p, n: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, K, K), jnp.float32),
            jax.ShapeDtypeStruct((B, K), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((tb, pc, K), jnp.float32)],
        interpret=interpret,
    )(nbr, val, nnz[:, None], X)


def _fused_kernel(
    G_in_ref,  # [cap, K, K] f32 (aliased with G_ref)
    g_in_ref,  # [cap, K] f32 (aliased with g_ref)
    item_ref,  # [TB, 1] int32 destination row per chunk (-1 = dead)
    cnt_ref,  # [TB, 1] int32 valid neighbors per chunk
    nbr_ref,  # [TB, PC] int32
    val_ref,  # [TB, PC] f32
    x_ref,  # [ns_chunk, K]
    G_ref,  # [cap, K, K] f32 out (whole-array block, revisited every step)
    g_ref,  # [cap, K] f32 out
    xg_ref,  # [TB, PC, K] f32 scratch: gather accumulator across Ns chunks
    *,
    tb: int,
    ns_chunk: int,
    num_ns: int,
    alpha: float,
    compute_dtype,
):
    i = pl.program_id(0)
    n = pl.program_id(1)

    @pl.when((i == 0) & (n == 0))
    def _init_outputs():
        G_ref[...] = G_in_ref[...]
        g_ref[...] = g_in_ref[...]

    @pl.when(n == 0)
    def _init_gather():
        xg_ref[...] = jnp.zeros_like(xg_ref)

    TB, pc = nbr_ref.shape
    cnt = cnt_ref[...]  # [TB, 1]
    pos = jax.lax.broadcasted_iota(jnp.int32, (TB, pc), 1)
    mask = pos < cnt
    xg_ref[...] += _gather_chunk(nbr_ref[...], mask, x_ref[...], n * ns_chunk, compute_dtype)

    @pl.when(n == num_ns - 1)
    def _contract_and_scatter():
        a = jnp.asarray(alpha, jnp.float32)
        xg = xg_ref[...].astype(compute_dtype)
        Gp = a * jax.lax.dot_general(
            xg, xg, (((1,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
        )  # [TB, K, K]
        vm = (val_ref[...] * mask.astype(val_ref.dtype)).astype(compute_dtype)
        gp = a * jax.lax.dot_general(
            xg, vm[:, :, None], (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )[:, :, 0]  # [TB, K]
        items = item_ref[...]
        for j in range(tb):  # tb is small and static: unrolled scatter
            idx = items[j, 0]
            # dead chunks (idx == -1) add exact zeros at a clamped slot —
            # no divergent control flow, and x + 0.0 is exact in f32
            ok = (idx >= 0).astype(jnp.float32)
            slot = jnp.maximum(idx, 0)
            G_ref[pl.ds(slot, 1), :, :] += (ok * Gp[j])[None]
            g_ref[pl.ds(slot, 1), :] += (ok * gp[j])[None]


@functools.partial(
    jax.jit,
    static_argnames=("tb", "ns_chunk", "alpha", "compute_dtype", "interpret"),
)
def bpmf_gram_fused(
    G: jax.Array,  # [cap, K, K] f32 running accumulator
    g: jax.Array,  # [cap, K] f32 running accumulator
    X: jax.Array,  # [Ns, K] opposite-side shard
    nbr: jax.Array,  # [C, pc] int32 flattened chunk neighbors, C % tb == 0
    val: jax.Array,  # [C, pc] f32
    item: jax.Array,  # [C] int32 destination row in [0, cap), -1 = dead chunk
    cnt: jax.Array,  # [C] int32 valid neighbors per chunk
    *,
    alpha: float = 1.0,
    tb: int = 8,
    ns_chunk: int | None = None,
    compute_dtype=jnp.float32,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused multi-bucket Gram step: one ``pallas_call`` per ring step.

    Consumes the flattened chunk layout built by ``ops.flatten_step`` (every
    bucket row of the step pre-split into width-``pc`` chunks) and
    accumulates ``alpha``-scaled contributions of *all* buckets directly
    into the per-local-item running sums::

        G[item[c]] += alpha * Xg_c^T Xg_c      g[item[c]] += alpha * Xg_c^T v_c

    ``(G, g)`` are donated via ``input_output_aliases`` and updated with
    in-kernel dynamic-row scatters, so the per-bucket ``pallas_call`` +
    two-``at[].add`` dispatch pattern collapses into a single kernel launch.
    Grid: ``(C // tb, Ns // ns_chunk)``; the Ns axis streams the shard
    through VMEM exactly as in :func:`bpmf_gram_pallas`.

    Returns:
        Updated ``(G, g)``, same shapes/dtypes as the inputs.
    """
    cap, K = g.shape
    C, pc = nbr.shape
    Ns = X.shape[0]
    if ns_chunk is None:
        ns_chunk = Ns
    if C % tb:
        raise ValueError(f"C={C} not a multiple of tb={tb} (ops.flatten_step pads)")
    if Ns % ns_chunk:
        raise ValueError(f"Ns={Ns} not a multiple of ns_chunk={ns_chunk} (ops pads)")
    num_ns = Ns // ns_chunk
    grid = (C // tb, num_ns)
    kernel = functools.partial(
        _fused_kernel,
        tb=tb,
        ns_chunk=ns_chunk,
        num_ns=num_ns,
        alpha=alpha,
        compute_dtype=compute_dtype,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((cap, K, K), lambda i, n: (0, 0, 0)),
            pl.BlockSpec((cap, K), lambda i, n: (0, 0)),
            pl.BlockSpec((tb, 1), lambda i, n: (i, 0)),
            pl.BlockSpec((tb, 1), lambda i, n: (i, 0)),
            pl.BlockSpec((tb, pc), lambda i, n: (i, 0)),
            pl.BlockSpec((tb, pc), lambda i, n: (i, 0)),
            pl.BlockSpec((ns_chunk, K), lambda i, n: (n, 0)),
        ],
        out_specs=[
            pl.BlockSpec((cap, K, K), lambda i, n: (0, 0, 0)),
            pl.BlockSpec((cap, K), lambda i, n: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cap, K, K), jnp.float32),
            jax.ShapeDtypeStruct((cap, K), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((tb, pc, K), jnp.float32)],
        input_output_aliases={0: 0, 1: 1},
        interpret=interpret,
    )(G, g, item[:, None], cnt[:, None], nbr, val, X)


def vmem_bytes_estimate(
    tb: int,
    pc: int,
    Ns: int,
    K: int,
    ns_chunk: int | None = None,
    compute_dtype=jnp.float32,
    cap: int = 0,
) -> int:
    """VMEM working-set estimate for one grid step of either Gram kernel.

    Reflects the actual block structure: ``nbr``/``val`` blocks are
    ``(tb, pc)`` (the P axis is a grid dimension, so full-P rows are never
    resident — the pre-restructure estimate undercounted those for
    ``P > 4096``), the shard block is ``(ns_chunk, K)``, and the gather
    scratch is ``(tb, pc, K)`` f32. ``cap > 0`` adds the fused kernel's
    whole-array ``(G, g)`` accumulator blocks (input + aliased output copy).
    """
    itemsize = jnp.dtype(compute_dtype).itemsize
    ns = Ns if ns_chunk is None else ns_chunk
    onehot = tb * pc * ns * itemsize
    x = ns * K * itemsize
    xg = tb * pc * K * 4
    blocks = tb * pc * (4 + 4)  # nbr + val chunk blocks
    if cap:
        acc = 2 * (cap * K * K * 4 + cap * K * 4)  # fused: in + out (G, g) windows
    else:
        acc = tb * K * K * 4 + tb * K * 4  # per-bucket: (tb, K, K) out tile
    return onehot + x + xg + blocks + acc
