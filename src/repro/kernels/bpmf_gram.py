"""Pallas TPU kernel for the BPMF gather + Gram accumulation hot loop.

For a bucket of items, each with up to P neighbors indexed into the
opposite-side latent shard ``X [Ns, K]``, compute per item

    G[b] = sum_p m[b,p] * x_{nbr[b,p]} x_{nbr[b,p]}^T        [K, K]
    g[b] = sum_p m[b,p] * val[b,p] * x_{nbr[b,p]}            [K]

TPU adaptation (DESIGN.md §2): a ragged HBM gather is the natural GPU
formulation; on TPU we exploit that the *ring-distributed* layout keeps the
per-step shard small enough for VMEM, so the gather becomes a one-hot MXU
contraction:

    W[b]  = onehot(nbr[b]) * mask[b]        [P, Ns]   (built in VREGs)
    Xg[b] = W[b] @ X                        [P, K]    (MXU)
    G[b]  = Xg[b]^T @ Xg[b]                 [K, K]    (MXU)
    g[b]  = Xg[b]^T @ (val[b] * mask[b])    [K]       (MXU)

Everything stays in VMEM; the P axis is chunked so the one-hot tile
[TB, PC, Ns] fits. FLOPs per item: P*Ns*K (gather) + P*K^2 (Gram) — the
one-hot gather is profitable only when Ns is small (the sharded case, which
is exactly the paper's distributed hot loop). ``ops.bpmf_gram`` falls back to
the XLA gather path for large Ns.

Grid: one program per TB-item tile. Tiling knobs (TB, PC) are exposed for
the autotune sweep in benchmarks/fig2_item_update.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(
    nbr_ref,  # [TB, P] int32 (VMEM)
    val_ref,  # [TB, P] f32 (VMEM)
    nnz_ref,  # [TB, 1] int32 (VMEM)
    x_ref,  # [Ns, K] compute dtype (VMEM)
    G_ref,  # [TB, K, K] f32 out
    g_ref,  # [TB, K] f32 out
    *,
    pc: int,
    compute_dtype,
):
    TB, P = nbr_ref.shape
    Ns, K = x_ref.shape
    x = x_ref[...].astype(compute_dtype)
    nnz = nnz_ref[...]  # [TB, 1]

    num_chunks = P // pc
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (TB, pc, Ns), 2)

    def body(c, acc):
        G_acc, g_acc = acc
        start = c * pc
        nbr = jax.lax.dynamic_slice(nbr_ref[...], (0, start), (TB, pc))  # [TB, pc]
        val = jax.lax.dynamic_slice(val_ref[...], (0, start), (TB, pc))
        pos = start + jax.lax.broadcasted_iota(jnp.int32, (TB, pc), 1)
        mask = (pos < nnz).astype(compute_dtype)  # [TB, pc]
        onehot = (nbr[:, :, None] == row_ids).astype(compute_dtype) * mask[:, :, None]
        # gather via MXU: [TB, pc, Ns] @ [Ns, K] -> [TB, pc, K]
        xg = jax.lax.dot_general(
            onehot, x, (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32
        ).astype(compute_dtype)
        G_acc = G_acc + jax.lax.dot_general(
            xg, xg, (((1,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
        )
        g_acc = g_acc + jax.lax.dot_general(
            xg, (val.astype(compute_dtype) * mask)[:, :, None],
            (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )[:, :, 0]
        return G_acc, g_acc

    G0 = jnp.zeros((TB, K, K), jnp.float32)
    g0 = jnp.zeros((TB, K), jnp.float32)
    G, g = jax.lax.fori_loop(0, num_chunks, body, (G0, g0), unroll=(num_chunks <= 4))
    G_ref[...] = G
    g_ref[...] = g


@functools.partial(
    jax.jit,
    static_argnames=("tb", "pc", "compute_dtype", "interpret"),
)
def bpmf_gram_pallas(
    X: jax.Array,  # [Ns, K]
    nbr: jax.Array,  # [B, P] int32, B % tb == 0, P % pc == 0
    val: jax.Array,  # [B, P]
    nnz: jax.Array,  # [B] int32
    *,
    tb: int = 8,
    pc: int = 128,
    compute_dtype=jnp.float32,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    B, P = nbr.shape
    Ns, K = X.shape
    if B % tb:
        raise ValueError(f"B={B} not a multiple of tb={tb} (ops.py pads)")
    if P % pc:
        raise ValueError(f"P={P} not a multiple of pc={pc} (ops.py pads)")
    grid = (B // tb,)
    kernel = functools.partial(_gram_kernel, pc=pc, compute_dtype=compute_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, P), lambda i: (i, 0)),
            pl.BlockSpec((tb, P), lambda i: (i, 0)),
            pl.BlockSpec((tb, 1), lambda i: (i, 0)),
            pl.BlockSpec((Ns, K), lambda i: (0, 0)),  # whole shard resident in VMEM
        ],
        out_specs=[
            pl.BlockSpec((tb, K, K), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, K), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, K, K), jnp.float32),
            jax.ShapeDtypeStruct((B, K), jnp.float32),
        ],
        interpret=interpret,
    )(nbr, val, nnz[:, None], X)


def vmem_bytes_estimate(tb: int, pc: int, Ns: int, K: int, P: int, compute_dtype=jnp.float32) -> int:
    """Rough VMEM working-set estimate used by ops.py to pick (tb, pc)."""
    itemsize = jnp.dtype(compute_dtype).itemsize
    onehot = tb * pc * Ns * itemsize
    x = Ns * K * itemsize
    xg = tb * pc * K * 4
    blocks = tb * P * (4 + 4)  # nbr + val
    acc = tb * K * K * 4 + tb * K * 4
    return onehot + x + xg + blocks + acc
