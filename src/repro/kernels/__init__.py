"""Pallas TPU kernels for the paper's compute hot-spots.

bpmf_gram: the gather + Gram accumulation inside the per-item conditional
update (the dominant FLOPs of BPMF, paper SII). ops.py dispatches between
the Pallas kernel and the jnp reference path.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
