"""Pallas TPU kernels for the paper's compute hot-spots.

bpmf_gram: the gather + Gram accumulation inside the per-item conditional
update (the dominant FLOPs of BPMF, paper SII) — a per-bucket kernel and a
fused multi-bucket kernel that lowers a whole ring step to one
``pallas_call``. ops.py dispatches between the Pallas kernels and the jnp
reference path; autotune.py owns the measured per-shape decision and its
persistent cache (DESIGN.md §8).
"""
from repro.kernels import autotune, ops, ref

__all__ = ["autotune", "ops", "ref"]
