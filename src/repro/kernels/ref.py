"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax.numpy as jnp


def bpmf_gram_ref(
    X: jnp.ndarray,  # [Ns, K] opposite-side latents
    nbr: jnp.ndarray,  # [B, P] int32 padded neighbor indices into X
    val: jnp.ndarray,  # [B, P] f32 centered ratings (0 in padding)
    nnz: jnp.ndarray,  # [B] int32 true neighbor counts
    compute_dtype=jnp.float32,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """G[b] = sum_p x_{nbr[b,p]} x^T (masked), g[b] = sum_p x_{nbr[b,p]} val[b,p].

    Accumulation in f32 regardless of compute dtype (MXU semantics).
    """
    P = nbr.shape[1]
    mask = (jnp.arange(P, dtype=jnp.int32)[None, :] < nnz[:, None]).astype(compute_dtype)
    Xn = jnp.take(X, nbr, axis=0).astype(compute_dtype) * mask[..., None]
    G = jnp.einsum("bpk,bpl->bkl", Xn, Xn, preferred_element_type=jnp.float32)
    g = jnp.einsum("bpk,bp->bk", Xn, val.astype(compute_dtype), preferred_element_type=jnp.float32)
    return G.astype(jnp.float32), g.astype(jnp.float32)
