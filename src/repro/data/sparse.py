"""Sparse rating-matrix utilities: COO/CSR conversion and nnz-bucketing.

Bucketing is the SPMD replacement for the paper's work stealing: items are
grouped by rating count into power-of-two padded buckets so that each bucket
is one dense gather + Gram contraction. Padding waste is bounded by 2x per
item and is typically ~20-30% on MovieLens/ChEMBL-shaped skew (measured in
benchmarks/fig2_item_update.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterator, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.types import BPMFData, Bucket, BucketedSide, TestSet
from repro.utils import next_power_of_two


@dataclasses.dataclass(frozen=True)
class RatingsCOO:
    """Raw ratings in coordinate format (host numpy)."""

    rows: np.ndarray  # [nnz] int32 user ids
    cols: np.ndarray  # [nnz] int32 movie ids
    vals: np.ndarray  # [nnz] float32 ratings
    num_users: int
    num_movies: int

    @property
    def nnz(self) -> int:
        return int(self.rows.shape[0])

    def transpose(self) -> "RatingsCOO":
        return RatingsCOO(self.cols, self.rows, self.vals, self.num_movies, self.num_users)

    def chunked(self, chunk_rows: int = 1_000_000) -> "ChunkedRatings":
        """View this in-memory COO as a re-iterable chunk stream (for tests
        and synthetic datasets feeding the per-host loading path)."""

        def gen() -> Iterator[RatingsCOO]:
            for lo in range(0, max(self.nnz, 1), chunk_rows):
                hi = min(lo + chunk_rows, self.nnz)
                if hi > lo:
                    yield RatingsCOO(
                        self.rows[lo:hi], self.cols[lo:hi], self.vals[lo:hi],
                        self.num_users, self.num_movies,
                    )

        return ChunkedRatings(
            chunk_fn=gen, num_users=self.num_users, num_movies=self.num_movies,
            nnz=self.nnz, chunk_rows=chunk_rows,
        )


@dataclasses.dataclass(frozen=True)
class ChunkedRatings:
    """Re-iterable bounded-memory rating stream with known global dims.

    ``chunk_fn`` returns a *fresh* iterator of :class:`RatingsCOO` chunks on
    every call (the per-host plan builder makes two passes). Chunks must
    arrive in a deterministic order with at most ``chunk_rows`` ratings each
    — the chunk boundaries are part of the data contract, because the
    deterministic per-chunk train/test split consumes the seeded RNG stream
    sequentially.
    """

    chunk_fn: Callable[[], Iterator[RatingsCOO]]
    num_users: int
    num_movies: int
    nnz: int
    chunk_rows: int

    def chunks(self) -> Iterator[RatingsCOO]:
        return self.chunk_fn()

    def materialize(self) -> RatingsCOO:
        """Concatenate the stream (for backends without a per-host path)."""
        rows, cols, vals = [], [], []
        for c in self.chunks():
            rows.append(c.rows)
            cols.append(c.cols)
            vals.append(c.vals)
        empty = np.zeros(0)
        return RatingsCOO(
            np.concatenate(rows) if rows else empty.astype(np.int32),
            np.concatenate(cols) if cols else empty.astype(np.int32),
            np.concatenate(vals) if vals else empty.astype(np.float32),
            self.num_users, self.num_movies,
        )


#: Block size for :class:`StableMeanAccumulator` — the mean is defined as a
#: function of fixed value-position blocks, never of caller chunk boundaries.
MEAN_BLOCK = 1 << 20


class StableMeanAccumulator:
    """Streaming mean whose result is independent of feed chunk sizes.

    Values are regrouped into fixed ``MEAN_BLOCK``-sized position blocks;
    each complete block is summed with ``np.sum(..., dtype=float64)`` and the
    block sums are combined with ``math.fsum``. Any chunking of the same
    value sequence therefore produces bitwise-identical means — the property
    the per-host data loader needs to agree with the in-memory builder.
    """

    def __init__(self) -> None:
        self._buf: list[np.ndarray] = []
        self._pending = 0
        self._sums: list[float] = []
        self._count = 0

    def add(self, vals: np.ndarray) -> "StableMeanAccumulator":
        vals = np.asarray(vals, dtype=np.float32)
        self._count += len(vals)
        self._buf.append(vals)
        self._pending += len(vals)
        if self._pending >= MEAN_BLOCK:
            cat = np.concatenate(self._buf)
            while len(cat) >= MEAN_BLOCK:
                self._sums.append(float(np.sum(cat[:MEAN_BLOCK], dtype=np.float64)))
                cat = cat[MEAN_BLOCK:]
            self._buf = [cat]
            self._pending = len(cat)
        return self

    def mean(self) -> float:
        if not self._count:
            return 0.0
        sums = list(self._sums)
        if self._pending:
            tail = np.concatenate(self._buf)
            sums.append(float(np.sum(tail, dtype=np.float64)))
        return math.fsum(sums) / self._count


def stable_mean(vals: np.ndarray) -> float:
    """Chunking-invariant mean of a float32 array (see StableMeanAccumulator)."""
    return StableMeanAccumulator().add(vals).mean()


def csr_from_coo(
    rows: np.ndarray, cols: np.ndarray, vals: np.ndarray, num_items: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(indptr, indices, values) CSR over ``rows``; columns sorted within rows."""
    order = np.lexsort((cols, rows))
    r, c, v = rows[order], cols[order], vals[order]
    counts = np.bincount(r, minlength=num_items)
    indptr = np.zeros(num_items + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, c.astype(np.int32), v.astype(np.float32)


def _concat_ranges(lengths: np.ndarray) -> np.ndarray:
    """[0..l0-1, 0..l1-1, ...] without a python loop."""
    total = int(lengths.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    starts = np.zeros(len(lengths), dtype=np.int64)
    np.cumsum(lengths[:-1], out=starts[1:])
    return np.arange(total, dtype=np.int64) - np.repeat(starts, lengths)


def pad_group(
    ids: np.ndarray,
    indptr: np.ndarray,
    indices: np.ndarray,
    values: np.ndarray,
    pad: int,
) -> Bucket:
    """Densify the CSR rows ``ids`` into a [B, pad] padded bucket."""
    ids = np.asarray(ids, dtype=np.int64)
    B = len(ids)
    nnz = (indptr[ids + 1] - indptr[ids]).astype(np.int64)
    if np.any(nnz > pad):
        raise ValueError(f"item with nnz {nnz.max()} does not fit pad {pad}")
    nbr = np.zeros((B, pad), dtype=np.int32)
    val = np.zeros((B, pad), dtype=np.float32)
    within = _concat_ranges(nnz)
    flat_dst = np.repeat(np.arange(B, dtype=np.int64) * pad, nnz) + within
    src = np.repeat(indptr[ids], nnz) + within
    nbr.reshape(-1)[flat_dst] = indices[src]
    val.reshape(-1)[flat_dst] = values[src]
    return Bucket(
        item_ids=jnp.asarray(ids, jnp.int32),
        nbr=jnp.asarray(nbr),
        val=jnp.asarray(val),
        nnz=jnp.asarray(nnz, jnp.int32),
    )


def bucket_assignment(nnz: np.ndarray, pads: Sequence[int]) -> dict[int, np.ndarray]:
    """Map pad size -> item ids. Items above the largest pad get pow2 pads."""
    pads = sorted(pads)
    out: dict[int, list[np.ndarray]] = {}
    prev = -1
    for p in pads:
        sel = np.nonzero((nnz > prev) & (nnz <= p))[0]
        if sel.size:
            out.setdefault(p, []).append(sel)
        prev = p
    big = np.nonzero(nnz > pads[-1])[0]
    if big.size:
        for i in big:
            p = next_power_of_two(int(nnz[i]))
            out.setdefault(p, []).append(np.array([i]))
    return {p: np.concatenate(v) for p, v in out.items()}


def bucketize_side(
    indptr: np.ndarray,
    indices: np.ndarray,
    values: np.ndarray,
    pads: Sequence[int],
    *,
    include_empty: bool = True,
) -> BucketedSide:
    """Bucket every CSR row (item) by nnz into padded dense groups.

    Items with zero ratings still get sampled (from the prior conditional),
    so they are included in the smallest bucket by default.
    """
    num_items = len(indptr) - 1
    nnz = (indptr[1:] - indptr[:-1]).astype(np.int64)
    items = np.arange(num_items)
    if not include_empty:
        items = items[nnz > 0]
    assign = bucket_assignment(nnz[items], pads)
    buckets = []
    for pad in sorted(assign):
        ids = items[assign[pad]]
        buckets.append(pad_group(ids, indptr, indices, values, pad))
    return BucketedSide(buckets=tuple(buckets), num_items=num_items)


def train_test_split(
    coo: RatingsCOO, test_fraction: float, seed: int
) -> tuple[RatingsCOO, RatingsCOO]:
    rng = np.random.default_rng(seed)
    t = rng.random(coo.nnz) < test_fraction
    tr = ~t
    return (
        RatingsCOO(coo.rows[tr], coo.cols[tr], coo.vals[tr], coo.num_users, coo.num_movies),
        RatingsCOO(coo.rows[t], coo.cols[t], coo.vals[t], coo.num_users, coo.num_movies),
    )


def build_bpmf_data(
    coo: RatingsCOO,
    pads: Sequence[int] = (8, 32, 128, 512, 2048),
    test_fraction: float = 0.1,
    seed: int = 0,
    min_rating: float | None = None,
    max_rating: float | None = None,
) -> BPMFData:
    """Full host-side pipeline: split, center, bucket both sides."""
    train, test = train_test_split(coo, test_fraction, seed)
    lo = float(coo.vals.min()) if min_rating is None else min_rating
    hi = float(coo.vals.max()) if max_rating is None else max_rating
    return build_bpmf_data_presplit(train, test, pads, min_rating=lo, max_rating=hi)


def build_bpmf_data_presplit(
    train: RatingsCOO,
    test: RatingsCOO,
    pads: Sequence[int] = (8, 32, 128, 512, 2048),
    mean_rating: float | None = None,
    min_rating: float | None = None,
    max_rating: float | None = None,
) -> BPMFData:
    """Center and bucket an already-split (train, test) pair.

    The split-free tail of :func:`build_bpmf_data`, exposed so callers that
    partition the ratings *after* a global split — the ``posterior_merge``
    backend gives each chain a user-subset of one shared split — can build
    per-subset :class:`BPMFData` with globally consistent centering and
    clipping (pass the global ``mean_rating`` / ``min_rating`` /
    ``max_rating`` explicitly; defaults derive them from the pair given).
    """
    mean = (
        (float(train.vals.mean()) if train.nnz else 0.0)
        if mean_rating is None
        else float(mean_rating)
    )
    centered = train.vals - mean

    u_indptr, u_idx, u_val = csr_from_coo(train.rows, train.cols, centered, train.num_users)
    m_indptr, m_idx, m_val = csr_from_coo(train.cols, train.rows, centered, train.num_movies)

    all_vals = np.concatenate([train.vals, test.vals]) if train.nnz or test.nnz else None
    lo = (float(all_vals.min()) if all_vals is not None else -np.inf) \
        if min_rating is None else min_rating
    hi = (float(all_vals.max()) if all_vals is not None else np.inf) \
        if max_rating is None else max_rating
    return BPMFData(
        users=bucketize_side(u_indptr, u_idx, u_val, pads),
        movies=bucketize_side(m_indptr, m_idx, m_val, pads),
        test=TestSet(
            rows=jnp.asarray(test.rows, jnp.int32),
            cols=jnp.asarray(test.cols, jnp.int32),
            vals=jnp.asarray(test.vals, jnp.float32),
        ),
        mean_rating=jnp.asarray(mean, jnp.float32),
        num_users=train.num_users,
        num_movies=train.num_movies,
        min_rating=lo,
        max_rating=hi,
    )
