from repro.data.sparse import RatingsCOO, bucketize_side, build_bpmf_data, csr_from_coo
from repro.data.synthetic import synthetic_ratings

__all__ = [
    "RatingsCOO",
    "bucketize_side",
    "build_bpmf_data",
    "csr_from_coo",
    "synthetic_ratings",
]
