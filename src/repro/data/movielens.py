"""MovieLens / ChEMBL loaders with synthetic fallback (offline container).

``load_movielens`` parses the real ml-20m ``ratings.csv`` or ml-100k
``u.data`` formats when a path is given; otherwise it generates a
distribution-matched synthetic stand-in (documented in DESIGN.md §6).
"""
from __future__ import annotations

import itertools
import os

import numpy as np

from repro.data.sparse import RatingsCOO
from repro.data.synthetic import CHEMBL_LIKE, ML20M_LIKE, ML100K_LIKE, synthetic_ratings
from repro.utils import logger

_CSV_CHUNK_ROWS = 1_000_000  # ~72 MB peak per chunk vs ~GBs for one-shot parse


def _read_rating_chunks(
    path: str,
    *,
    delimiter: str | None,
    skip_header: int,
    chunk_rows: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stream a 3+-column rating file in bounded chunks.

    The previous one-shot ``np.genfromtxt`` materialized the whole file as an
    ``[nnz, ncols]`` float64 table (plus the raw text) before any downcast —
    a multi-GB transient on ml-20m-scale inputs. Parsing ``chunk_rows`` lines
    at a time and downcasting ids/values per chunk bounds peak memory by the
    chunk size regardless of file length, with byte-identical output.

    Returns:
        ``(col0, col1, vals)`` — raw int64 ids and float32 ratings.
    """
    id0, id1, vals = [], [], []
    with open(path) as f:
        for _ in range(skip_header):
            f.readline()
        while True:
            lines = list(itertools.islice(f, chunk_rows))
            if not lines:
                break
            lines = [ln for ln in lines if ln.strip()]
            if not lines:  # chunk of blank lines (e.g. trailing newlines)
                continue
            chunk = np.atleast_2d(
                np.genfromtxt(lines, delimiter=delimiter, usecols=(0, 1, 2), dtype=np.float64)
            )
            if chunk.size == 0:
                continue
            id0.append(chunk[:, 0].astype(np.int64))
            id1.append(chunk[:, 1].astype(np.int64))
            vals.append(chunk[:, 2].astype(np.float32))
    if not id0:
        raise ValueError(f"no ratings parsed from {path!r}")
    return np.concatenate(id0), np.concatenate(id1), np.concatenate(vals)


def _parse_ratings_csv(path: str, chunk_rows: int = _CSV_CHUNK_ROWS) -> RatingsCOO:
    """ml-20m ratings.csv: userId,movieId,rating,timestamp (with header)."""
    users_raw, movies_raw, vals = _read_rating_chunks(
        path, delimiter=",", skip_header=1, chunk_rows=chunk_rows
    )
    _, users = np.unique(users_raw, return_inverse=True)
    _, movies = np.unique(movies_raw, return_inverse=True)
    return RatingsCOO(
        users.astype(np.int32), movies.astype(np.int32), vals,
        int(users.max()) + 1, int(movies.max()) + 1,
    )


def _parse_udata(path: str, chunk_rows: int = _CSV_CHUNK_ROWS) -> RatingsCOO:
    """ml-100k u.data: user \t item \t rating \t timestamp."""
    users_raw, movies_raw, vals = _read_rating_chunks(
        path, delimiter=None, skip_header=0, chunk_rows=chunk_rows
    )
    users = users_raw - 1
    movies = movies_raw - 1
    return RatingsCOO(
        users.astype(np.int32), movies.astype(np.int32), vals,
        int(users.max()) + 1, int(movies.max()) + 1,
    )


def load_movielens(path: str | None = None, variant: str = "ml-100k") -> RatingsCOO:
    if path and os.path.exists(path):
        if path.endswith(".csv"):
            return _parse_ratings_csv(path)
        return _parse_udata(path)
    logger.info("movielens file not found, generating %s-shaped synthetic data", variant)
    spec = ML20M_LIKE if variant == "ml-20m" else ML100K_LIKE
    coo, _ = synthetic_ratings(spec)
    return coo


def load_chembl(path: str | None = None) -> RatingsCOO:
    """ChEMBL IC50 subset (compound x target pIC50). Synthetic fallback."""
    if path and os.path.exists(path):
        data = np.loadtxt(path, delimiter=",", dtype=np.float64)
        rows = data[:, 0].astype(np.int32)
        cols = data[:, 1].astype(np.int32)
        vals = data[:, 2].astype(np.float32)
        return RatingsCOO(rows, cols, vals, int(rows.max()) + 1, int(cols.max()) + 1)
    logger.info("chembl file not found, generating ChEMBL-shaped synthetic data")
    coo, _ = synthetic_ratings(CHEMBL_LIKE)
    return coo
