"""MovieLens / ChEMBL loaders with synthetic fallback (offline container).

``load_movielens`` parses the real ml-20m ``ratings.csv`` or ml-100k
``u.data`` formats when a path is given; otherwise it generates a
distribution-matched synthetic stand-in (documented in DESIGN.md §6).
"""
from __future__ import annotations

import os

import numpy as np

from repro.data.sparse import RatingsCOO
from repro.data.synthetic import CHEMBL_LIKE, ML20M_LIKE, ML100K_LIKE, synthetic_ratings
from repro.utils import logger


def _parse_ratings_csv(path: str) -> RatingsCOO:
    """ml-20m ratings.csv: userId,movieId,rating,timestamp (with header)."""
    data = np.genfromtxt(path, delimiter=",", skip_header=1, usecols=(0, 1, 2), dtype=np.float64)
    users_raw = data[:, 0].astype(np.int64)
    movies_raw = data[:, 1].astype(np.int64)
    vals = data[:, 2].astype(np.float32)
    _, users = np.unique(users_raw, return_inverse=True)
    _, movies = np.unique(movies_raw, return_inverse=True)
    return RatingsCOO(
        users.astype(np.int32), movies.astype(np.int32), vals,
        int(users.max()) + 1, int(movies.max()) + 1,
    )


def _parse_udata(path: str) -> RatingsCOO:
    """ml-100k u.data: user \t item \t rating \t timestamp."""
    data = np.loadtxt(path, dtype=np.float64)
    users = data[:, 0].astype(np.int64) - 1
    movies = data[:, 1].astype(np.int64) - 1
    vals = data[:, 2].astype(np.float32)
    return RatingsCOO(
        users.astype(np.int32), movies.astype(np.int32), vals,
        int(users.max()) + 1, int(movies.max()) + 1,
    )


def load_movielens(path: str | None = None, variant: str = "ml-100k") -> RatingsCOO:
    if path and os.path.exists(path):
        if path.endswith(".csv"):
            return _parse_ratings_csv(path)
        return _parse_udata(path)
    logger.info("movielens file not found, generating %s-shaped synthetic data", variant)
    spec = ML20M_LIKE if variant == "ml-20m" else ML100K_LIKE
    coo, _ = synthetic_ratings(spec)
    return coo


def load_chembl(path: str | None = None) -> RatingsCOO:
    """ChEMBL IC50 subset (compound x target pIC50). Synthetic fallback."""
    if path and os.path.exists(path):
        data = np.loadtxt(path, delimiter=",", dtype=np.float64)
        rows = data[:, 0].astype(np.int32)
        cols = data[:, 1].astype(np.int32)
        vals = data[:, 2].astype(np.float32)
        return RatingsCOO(rows, cols, vals, int(rows.max()) + 1, int(cols.max()) + 1)
    logger.info("chembl file not found, generating ChEMBL-shaped synthetic data")
    coo, _ = synthetic_ratings(CHEMBL_LIKE)
    return coo
