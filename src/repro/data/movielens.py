"""MovieLens / ChEMBL loaders with synthetic fallback (offline container).

``load_movielens`` parses the real ml-20m ``ratings.csv`` or ml-100k
``u.data`` formats when a path is given; otherwise it generates a
distribution-matched synthetic stand-in (documented in DESIGN.md §6).
"""
from __future__ import annotations

import itertools
import os

import numpy as np

from repro.data.sparse import ChunkedRatings, RatingsCOO
from repro.data.synthetic import CHEMBL_LIKE, ML20M_LIKE, ML100K_LIKE, synthetic_ratings
from repro.utils import logger

_CSV_CHUNK_ROWS = 1_000_000  # ~72 MB peak per chunk vs ~GBs for one-shot parse


def _iter_rating_chunks(
    path: str,
    *,
    delimiter: str | None,
    skip_header: int,
    chunk_rows: int,
):
    """Yield ``(col0, col1, vals)`` raw-id chunks of a 3+-column rating file.

    Parsing ``chunk_rows`` lines at a time bounds peak memory by the chunk
    size regardless of file length; chunk boundaries are deterministic
    (every ``chunk_rows`` non-blank source lines), which the per-host data
    loader relies on for its seeded per-chunk train/test split.
    """
    with open(path) as f:
        for _ in range(skip_header):
            f.readline()
        while True:
            lines = list(itertools.islice(f, chunk_rows))
            if not lines:
                break
            lines = [ln for ln in lines if ln.strip()]
            if not lines:  # chunk of blank lines (e.g. trailing newlines)
                continue
            chunk = np.atleast_2d(
                np.genfromtxt(lines, delimiter=delimiter, usecols=(0, 1, 2), dtype=np.float64)
            )
            if chunk.size == 0:
                continue
            yield (
                chunk[:, 0].astype(np.int64),
                chunk[:, 1].astype(np.int64),
                chunk[:, 2].astype(np.float32),
            )


def _read_rating_chunks(
    path: str,
    *,
    delimiter: str | None,
    skip_header: int,
    chunk_rows: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Materialize :func:`_iter_rating_chunks` into full arrays.

    The previous one-shot ``np.genfromtxt`` materialized the whole file as an
    ``[nnz, ncols]`` float64 table (plus the raw text) before any downcast —
    a multi-GB transient on ml-20m-scale inputs. Chunked parsing bounds the
    transient by the chunk size, with byte-identical output.

    Returns:
        ``(col0, col1, vals)`` — raw int64 ids and float32 ratings.
    """
    id0, id1, vals = [], [], []
    for c0, c1, v in _iter_rating_chunks(
        path, delimiter=delimiter, skip_header=skip_header, chunk_rows=chunk_rows
    ):
        id0.append(c0)
        id1.append(c1)
        vals.append(v)
    if not id0:
        raise ValueError(f"no ratings parsed from {path!r}")
    return np.concatenate(id0), np.concatenate(id1), np.concatenate(vals)


def _parse_ratings_csv(path: str, chunk_rows: int = _CSV_CHUNK_ROWS) -> RatingsCOO:
    """ml-20m ratings.csv: userId,movieId,rating,timestamp (with header)."""
    users_raw, movies_raw, vals = _read_rating_chunks(
        path, delimiter=",", skip_header=1, chunk_rows=chunk_rows
    )
    _, users = np.unique(users_raw, return_inverse=True)
    _, movies = np.unique(movies_raw, return_inverse=True)
    return RatingsCOO(
        users.astype(np.int32), movies.astype(np.int32), vals,
        int(users.max()) + 1, int(movies.max()) + 1,
    )


def _parse_udata(path: str, chunk_rows: int = _CSV_CHUNK_ROWS) -> RatingsCOO:
    """ml-100k u.data: user \t item \t rating \t timestamp."""
    users_raw, movies_raw, vals = _read_rating_chunks(
        path, delimiter=None, skip_header=0, chunk_rows=chunk_rows
    )
    users = users_raw - 1
    movies = movies_raw - 1
    return RatingsCOO(
        users.astype(np.int32), movies.astype(np.int32), vals,
        int(users.max()) + 1, int(movies.max()) + 1,
    )


def load_movielens_chunked(
    path: str | None = None,
    variant: str = "ml-100k",
    chunk_rows: int = _CSV_CHUNK_ROWS,
) -> ChunkedRatings:
    """Streaming loader for the per-host data path: no full rating arrays.

    Two-pass protocol over the file: a scan pass derives the global id maps
    (the sorted set of raw user/movie ids, matching ``np.unique``'s inverse
    mapping in the one-shot loader bitwise) and the rating count; the
    returned :class:`ChunkedRatings` then re-reads the file in bounded
    chunks on every iteration, remapping raw ids per chunk via
    ``np.searchsorted``. Peak memory is O(chunk + num ids) per process.
    Falls back to chunking the synthetic stand-in when ``path`` is missing.
    """
    if not (path and os.path.exists(path)):
        logger.info("movielens file not found, generating %s-shaped synthetic data", variant)
        spec = ML20M_LIKE if variant == "ml-20m" else ML100K_LIKE
        coo, _ = synthetic_ratings(spec)
        return coo.chunked(chunk_rows)

    is_csv = path.endswith(".csv")
    delimiter = "," if is_csv else None
    skip_header = 1 if is_csv else 0

    uniq_u = np.zeros(0, dtype=np.int64)
    uniq_m = np.zeros(0, dtype=np.int64)
    nnz = 0
    for c0, c1, _ in _iter_rating_chunks(
        path, delimiter=delimiter, skip_header=skip_header, chunk_rows=chunk_rows
    ):
        uniq_u = np.union1d(uniq_u, c0)
        uniq_m = np.union1d(uniq_m, c1)
        nnz += len(c0)
    if not nnz:
        raise ValueError(f"no ratings parsed from {path!r}")

    if is_csv:  # ml-20m: dense remap via the sorted id set (== np.unique inverse)
        num_users, num_movies = len(uniq_u), len(uniq_m)

        def remap(c0, c1):
            return (
                np.searchsorted(uniq_u, c0).astype(np.int32),
                np.searchsorted(uniq_m, c1).astype(np.int32),
            )
    else:  # ml-100k u.data: ids are 1-based and already dense
        num_users, num_movies = int(uniq_u.max()), int(uniq_m.max())

        def remap(c0, c1):
            return (c0 - 1).astype(np.int32), (c1 - 1).astype(np.int32)

    def gen():
        for c0, c1, v in _iter_rating_chunks(
            path, delimiter=delimiter, skip_header=skip_header, chunk_rows=chunk_rows
        ):
            rows, cols = remap(c0, c1)
            yield RatingsCOO(rows, cols, v, num_users, num_movies)

    return ChunkedRatings(
        chunk_fn=gen, num_users=num_users, num_movies=num_movies,
        nnz=nnz, chunk_rows=chunk_rows,
    )


def load_movielens(path: str | None = None, variant: str = "ml-100k") -> RatingsCOO:
    if path and os.path.exists(path):
        if path.endswith(".csv"):
            return _parse_ratings_csv(path)
        return _parse_udata(path)
    logger.info("movielens file not found, generating %s-shaped synthetic data", variant)
    spec = ML20M_LIKE if variant == "ml-20m" else ML100K_LIKE
    coo, _ = synthetic_ratings(spec)
    return coo


def load_chembl(path: str | None = None) -> RatingsCOO:
    """ChEMBL IC50 subset (compound x target pIC50). Synthetic fallback."""
    if path and os.path.exists(path):
        data = np.loadtxt(path, delimiter=",", dtype=np.float64)
        rows = data[:, 0].astype(np.int32)
        cols = data[:, 1].astype(np.int32)
        vals = data[:, 2].astype(np.float32)
        return RatingsCOO(rows, cols, vals, int(rows.max()) + 1, int(cols.max()) + 1)
    logger.info("chembl file not found, generating ChEMBL-shaped synthetic data")
    coo, _ = synthetic_ratings(CHEMBL_LIKE)
    return coo
