"""Synthetic rating generators with MovieLens/ChEMBL-shaped degree skew.

The container is offline, so benchmark datasets are generated with the same
scale parameters as the paper's (ml-20m: 138493 x 27278, 20M ratings;
ChEMBL IC50 subset: 483500 x 5775, ~1M ratings) and a ground-truth low-rank
structure so RMSE convergence is checkable against the generative noise.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.sparse import RatingsCOO


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    num_users: int
    num_movies: int
    nnz: int
    true_rank: int = 8
    noise_std: float = 0.5
    # popularity skew of movies (zipf-ish exponent) and user-activity lognormal sigma
    popularity_exponent: float = 0.8
    activity_sigma: float = 1.0
    discretize: bool = True  # round to 1..5 stars
    seed: int = 0


ML20M_LIKE = SyntheticSpec(num_users=138_493, num_movies=27_278, nnz=20_000_000)
ML100K_LIKE = SyntheticSpec(num_users=943, num_movies=1_682, nnz=100_000)
CHEMBL_LIKE = SyntheticSpec(
    num_users=483_500, num_movies=5_775, nnz=1_023_952, discretize=False, noise_std=0.6
)


def synthetic_ratings(spec: SyntheticSpec) -> tuple[RatingsCOO, dict]:
    """Generate sparse ratings R = U* V*^T + noise with skewed observation pattern.

    Returns the COO plus ground-truth info (U*, V*, noise_std) for validation.
    """
    rng = np.random.default_rng(spec.seed)
    K = spec.true_rank
    U = rng.normal(size=(spec.num_users, K)).astype(np.float32) / np.sqrt(K)
    V = rng.normal(size=(spec.num_movies, K)).astype(np.float32)

    # movie popularity ~ zipf, user activity ~ lognormal; expected pair weight
    # is the product -> sample pairs by independent categorical draws, dedupe.
    pop = 1.0 / np.arange(1, spec.num_movies + 1) ** spec.popularity_exponent
    rng.shuffle(pop)
    pop /= pop.sum()
    act = rng.lognormal(sigma=spec.activity_sigma, size=spec.num_users)
    act /= act.sum()

    target = spec.nnz
    rows_list, cols_list = [], []
    seen: np.ndarray | None = None
    got = 0
    # oversample then dedupe; a couple of rounds suffice at these densities
    for _ in range(6):
        need = int((target - got) * 1.3) + 1
        r = rng.choice(spec.num_users, size=need, p=act).astype(np.int64)
        c = rng.choice(spec.num_movies, size=need, p=pop).astype(np.int64)
        keys = r * spec.num_movies + c
        keys = np.unique(keys) if seen is None else np.setdiff1d(np.unique(keys), seen, assume_unique=True)
        seen = keys if seen is None else np.union1d(seen, keys)
        rows_list.append((keys // spec.num_movies).astype(np.int32))
        cols_list.append((keys % spec.num_movies).astype(np.int32))
        got = sum(len(x) for x in rows_list)
        if got >= target:
            break
    rows = np.concatenate(rows_list)[:target]
    cols = np.concatenate(cols_list)[:target]

    vals = np.einsum("nk,nk->n", U[rows], V[cols]) + rng.normal(
        scale=spec.noise_std, size=len(rows)
    ).astype(np.float32)
    if spec.discretize:
        # shift to a 1..5 star scale like MovieLens
        vals = np.clip(np.round(vals * 1.2 + 3.0), 1.0, 5.0)
    coo = RatingsCOO(rows, cols, vals.astype(np.float32), spec.num_users, spec.num_movies)
    truth = {"U": U, "V": V, "noise_std": spec.noise_std, "spec": spec}
    return coo, truth


def small_test_ratings(
    num_users: int = 64,
    num_movies: int = 48,
    nnz: int = 1500,
    true_rank: int = 4,
    noise_std: float = 0.3,
    seed: int = 0,
) -> tuple[RatingsCOO, dict]:
    """Tiny deterministic dataset for unit tests (continuous ratings)."""
    spec = SyntheticSpec(
        num_users=num_users,
        num_movies=num_movies,
        nnz=nnz,
        true_rank=true_rank,
        noise_std=noise_std,
        discretize=False,
        seed=seed,
    )
    return synthetic_ratings(spec)
