"""Benchmark harness entry point: one benchmark per paper figure/claim.

    PYTHONPATH=src python -m benchmarks.run [--full]

Default is smoke scale (CI-sized, minutes); --full runs the paper-scale
variants. Multi-device benchmarks (fig4/fig5/rmse) run in subprocesses with
forced host device counts. The roofline table aggregates whatever dry-run
artifacts exist under experiments/dryrun.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks.common import run_with_devices


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale (slow)")
    ap.add_argument("--only", help="comma list: fig2,fig3,fig4,fig5,rmse,roofline")
    args = ap.parse_args(argv)
    smoke = not args.full
    only = set(args.only.split(",")) if args.only else None

    failures = []

    def section(name: str):
        print(f"\n=== {name} {'(smoke)' if smoke else '(full)'} ===", flush=True)
        return time.time()

    def done(name: str, t0: float):
        print(f"=== {name} done in {time.time()-t0:.1f}s ===", flush=True)

    if only is None or "fig2" in only:
        t0 = section("fig2: per-item update cost vs nnz")
        try:
            from benchmarks import fig2_item_update

            r = fig2_item_update.run(smoke=smoke)
            print("cost model:", r["cost_model"])
        except Exception:
            failures.append("fig2")
            traceback.print_exc()
        done("fig2", t0)

    if only is None or "fig3" in only:
        t0 = section("fig3: single-node updates/s (bucketing variants)")
        try:
            from benchmarks import fig3_multicore

            r = fig3_multicore.run(smoke=smoke)
            print("bucketed-vs-maxpad speedup: "
                  f"{r['results']['speedup_bucketed_vs_maxpad']:.2f}x")
        except Exception:
            failures.append("fig3")
            traceback.print_exc()
        done("fig3", t0)

    if only is None or "fig4" in only:
        t0 = section("fig4: distributed strong scaling (8 host devices)")
        try:
            print(run_with_devices("benchmarks.fig4_scaling", 8, smoke=smoke)[-1200:])
        except Exception:
            failures.append("fig4")
            traceback.print_exc()
        done("fig4", t0)

    if only is None or "fig5" in only:
        t0 = section("fig5: compute/comm overlap (ring vs allgather)")
        try:
            print(run_with_devices("benchmarks.fig5_overlap", 8, smoke=smoke)[-800:])
        except Exception:
            failures.append("fig5")
            traceback.print_exc()
        done("fig5", t0)

    if only is None or "rmse" in only:
        t0 = section("rmse: accuracy parity across all versions")
        try:
            print(run_with_devices("benchmarks.rmse_convergence", 4, smoke=smoke)[-800:])
        except Exception:
            failures.append("rmse")
            traceback.print_exc()
        done("rmse", t0)

    if only is None or "roofline" in only:
        t0 = section("roofline: dry-run aggregation")
        try:
            from benchmarks import roofline

            rows, md = roofline.table("pod16x16")
            ok = sum(1 for r in rows if r.get("status") == "ok")
            print(f"{ok}/{len(rows)} cells aggregated (full table: "
                  "experiments/bench/roofline_pod16x16.json)")
        except Exception:
            failures.append("roofline")
            traceback.print_exc()
        done("roofline", t0)

    print("\n==== benchmark summary ====")
    print("FAILURES:", failures or "none")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
