"""Paper Figure 4: distributed strong scaling — updates/s vs node count.

Runs the distributed Gibbs sampler over ring meshes of 1/2/4/8 forced host
devices (subsets of one 8-device process) on an ml-100k-shaped synthetic and
reports updates (user+movie resamples) per second, for both comm modes:

  * ring      — the paper's async pipelined version (ppermute overlap)
  * allgather — the synchronous GraphLab-like baseline

The paper's >32-node degradation (BlueGene rack boundary) corresponds here
to the pod boundary; the projection to 256/512 chips comes from the dry-run
roofline terms (benchmarks/roofline.py), not wall time.

Run me via: python -m benchmarks.fig4_scaling (inside an
XLA_FLAGS=--xla_force_host_platform_device_count=8 process; benchmarks.run
does this automatically).
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from benchmarks.common import save_result
from repro.core.distributed import build_distributed_data, make_ring_mesh, run_distributed
from repro.core.types import BPMFConfig
from repro.data.synthetic import SyntheticSpec, synthetic_ratings


def run(smoke: bool = False) -> dict:
    spec = SyntheticSpec(
        num_users=600 if smoke else 3_000,
        num_movies=300 if smoke else 900,
        nnz=8_000 if smoke else 90_000,
        discretize=False,
    )
    coo, _ = synthetic_ratings(spec)
    K = 8 if smoke else 16
    sweeps = 2 if smoke else 5
    devices = jax.devices()
    widths = [w for w in (1, 2, 4, 8) if w <= len(devices)]

    results: dict = {"widths": widths, "modes": {}}
    for mode in ("ring", "allgather"):
        rows = []
        for w in widths:
            cfg = BPMFConfig(K=K, num_sweeps=sweeps, burn_in=1, comm_mode=mode)
            data, _plan = build_distributed_data(coo, num_shards=w, seed=0)
            mesh = make_ring_mesh(devices[:w])
            t0 = time.time()  # includes first-sweep compile; subtract below
            state, pred, hist = run_distributed(jax.random.key(0), data, cfg, mesh)
            t_total = time.time() - t0
            # steady-state: time sweeps after compile
            t0 = time.time()
            state, pred, hist = run_distributed(jax.random.key(1), data, cfg, mesh)
            t_steady = time.time() - t0
            ups = (coo.num_users + coo.num_movies) * sweeps / t_steady
            rows.append({
                "devices": w, "seconds": t_steady, "updates_per_s": ups,
                "rmse_final": hist[-1].rmse_avg, "compile_plus_run_s": t_total,
            })
            print(f"[fig4] {mode} w={w}: {ups:,.0f} updates/s rmse={hist[-1].rmse_avg:.4f}")
        base = rows[0]["updates_per_s"]
        for r in rows:
            r["speedup"] = r["updates_per_s"] / base
        results["modes"][mode] = rows

    save_result("fig4_scaling", results)
    return results


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv)
