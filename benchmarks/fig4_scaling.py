"""Paper Figure 4: distributed strong scaling — updates/s vs node count.

Two sweeps on an ml-100k-shaped synthetic:

  * in-process width sweep — ring meshes of 1/2/4/8 forced host devices
    (subsets of one 8-device process), updates/s for both comm modes
    (``ring`` = the paper's async pipelined version, ``allgather`` = the
    synchronous GraphLab-like baseline);
  * process-count sweep — the *same global device total* re-split across
    1/2/4 OS processes via ``scripts/launch_multiproc.py`` (DESIGN.md §14),
    sweeps/s per layout plus modelled vs trace-measured ring bytes per
    sweep. The compiled program is layout-independent (the multi-process
    parity claim), so the wire bytes are modelled once per global width and
    only the *cross-process* share varies with the process count.

The paper's >32-node degradation (BlueGene rack boundary) corresponds here
to the pod boundary; the projection to 256/512 chips comes from the dry-run
roofline terms (benchmarks/roofline.py), not wall time.

Run me via: python -m benchmarks.fig4_scaling (inside an
XLA_FLAGS=--xla_force_host_platform_device_count=8 process; benchmarks.run
does this automatically).
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
import time

import jax
import numpy as np

from benchmarks.common import save_result, smoke_out_path
from repro.core.distributed import build_distributed_data, make_ring_mesh, run_distributed
from repro.core.types import BPMFConfig
from repro.data.synthetic import SyntheticSpec, synthetic_ratings

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_FINAL_RE = re.compile(
    r"after (\d+) sweeps \((\d+) this run\) in ([0-9.]+)s"
)


def _ring_bytes_per_sweep(coo, K: int, S: int) -> dict:
    """Modelled vs trace-measured ``ppermute`` traffic of one ring sweep.

    Modelled: each half-sweep issues ``S - 1`` rotations of the opposite
    side's shard buffer on every device, so one sweep moves
    ``S * (S-1) * (cap_u + cap_v) * K * 4`` bytes around the ring. Measured:
    ``jax.lax.ppermute`` is metered during a fresh trace of the sweep — each
    traced call rotates every device's local block once, i.e.
    ``S * block_bytes`` on the wire — then the patch is removed. The two
    must agree; ``model_matches`` records that they do.
    """
    data, plan = build_distributed_data(coo, num_shards=S, seed=0)
    mesh = make_ring_mesh(jax.devices()[:S])
    cap_u, cap_v = plan.part_users.cap, plan.part_movies.cap
    modelled = S * (S - 1) * (cap_u + cap_v) * K * 4

    meter = {"bytes": 0, "calls": 0}
    real_ppermute = jax.lax.ppermute

    def metered(x, axis_name, perm):
        for leaf in jax.tree_util.tree_leaves(x):
            meter["bytes"] += int(np.prod(leaf.shape)) * leaf.dtype.itemsize * S
        meter["calls"] += 1
        return real_ppermute(x, axis_name, perm)

    # a 1-sweep cfg is a fresh jit static key, so the trace (and the meter
    # hits) actually happen even if the width sweep compiled other cfgs
    cfg = BPMFConfig(K=K, num_sweeps=1, burn_in=0, comm_mode="ring")
    jax.lax.ppermute = metered
    try:
        run_distributed(jax.random.key(0), data, cfg, mesh)
    finally:
        jax.lax.ppermute = real_ppermute
    measured = meter["bytes"]
    return {
        "cap_u": int(cap_u),
        "cap_v": int(cap_v),
        "ppermute_calls_traced": meter["calls"],
        "modelled": int(modelled),
        "measured": int(measured),
        "model_matches": bool(measured == modelled),
    }


def _run_layout(procs: int, dev_per_proc: int, spec: SyntheticSpec, K: int,
                sweeps: int, timeout: float) -> dict:
    """One launcher run at ``procs x dev_per_proc``; parse sweeps/s."""
    cmd = [
        sys.executable, os.path.join(REPO_ROOT, "scripts", "launch_multiproc.py"),
        "--num-processes", str(procs), "--devices-per-process", str(dev_per_proc),
        "--timeout", str(timeout), "--",
        "--backend", "ring", "--dataset", "synthetic",
        "--users", str(spec.num_users), "--movies", str(spec.num_movies),
        "--nnz", str(spec.nnz), "--K", str(K), "--sweeps", str(sweeps),
        "--burn-in", "1", "--log-every", "0",
    ]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO_ROOT, "src"), env.get("PYTHONPATH", "")]
    ).rstrip(os.pathsep)
    t0 = time.time()
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=timeout + 60)
    wall = time.time() - t0
    if r.returncode != 0:
        raise RuntimeError(
            f"layout {procs}x{dev_per_proc} failed rc={r.returncode}:\n"
            f"{r.stdout[-2000:]}\n{r.stderr[-1000:]}"
        )
    m = _FINAL_RE.search(r.stdout)
    if not m:
        raise RuntimeError(
            f"layout {procs}x{dev_per_proc}: no final line in\n{r.stdout[-2000:]}"
        )
    total, this_run, seconds = int(m.group(1)), int(m.group(2)), float(m.group(3))
    return {
        "processes": procs,
        "devices_per_process": dev_per_proc,
        "sweeps": this_run,
        "seconds": seconds,
        # in-loop time of a cold process: first-sweep compile included
        # (documented in experiments/bench/README.md), so layouts compare
        # like-for-like — every child compiles its own program
        "sweeps_per_s": this_run / max(seconds, 1e-9),
        "wall_s": wall,
    }


def run(smoke: bool = False, out: str | None = None) -> dict:
    spec = SyntheticSpec(
        num_users=600 if smoke else 3_000,
        num_movies=300 if smoke else 900,
        nnz=8_000 if smoke else 90_000,
        discretize=False,
    )
    coo, _ = synthetic_ratings(spec)
    K = 8 if smoke else 16
    sweeps = 2 if smoke else 5
    devices = jax.devices()
    widths = [w for w in (1, 2, 4, 8) if w <= len(devices)]

    results: dict = {"widths": widths, "modes": {}, "smoke": bool(smoke)}
    for mode in ("ring", "allgather"):
        rows = []
        for w in widths:
            cfg = BPMFConfig(K=K, num_sweeps=sweeps, burn_in=1, comm_mode=mode)
            data, _plan = build_distributed_data(coo, num_shards=w, seed=0)
            mesh = make_ring_mesh(devices[:w])
            t0 = time.time()  # includes first-sweep compile; subtract below
            state, pred, hist = run_distributed(jax.random.key(0), data, cfg, mesh)
            t_total = time.time() - t0
            # steady-state: time sweeps after compile
            t0 = time.time()
            state, pred, hist = run_distributed(jax.random.key(1), data, cfg, mesh)
            t_steady = time.time() - t0
            ups = (coo.num_users + coo.num_movies) * sweeps / t_steady
            rows.append({
                "devices": w, "seconds": t_steady, "updates_per_s": ups,
                "rmse_final": hist[-1].rmse_avg, "compile_plus_run_s": t_total,
            })
            print(f"[fig4] {mode} w={w}: {ups:,.0f} updates/s rmse={hist[-1].rmse_avg:.4f}")
        base = rows[0]["updates_per_s"]
        for r in rows:
            r["speedup"] = r["updates_per_s"] / base
        results["modes"][mode] = rows

    # ---- process-count sweep: same global width, re-split across processes
    S = 4 if smoke else 8
    S = min(S, len(devices))
    proc_spec = SyntheticSpec(
        num_users=240 if smoke else 800,
        num_movies=160 if smoke else 400,
        nnz=3_000 if smoke else 12_000,
        discretize=False,
    )
    proc_coo, _ = synthetic_ratings(proc_spec)
    proc_sweeps = 2 if smoke else 4
    bytes_info = _ring_bytes_per_sweep(proc_coo, K, S)
    per_edge = bytes_info["modelled"] // S  # one ring edge's bytes per sweep
    layouts = [(p, S // p) for p in (1, 2, 4) if p <= S and S % p == 0]
    rows = []
    for procs, dev in layouts:
        row = _run_layout(procs, dev, proc_spec, K, proc_sweeps,
                          timeout=240 if smoke else 600)
        # process-major contiguous blocks: exactly `procs` of the S ring
        # edges cross a process boundary (none for a single process — the
        # wraparound edge stays on-host)
        row["cross_process_bytes_per_sweep"] = per_edge * procs if procs > 1 else 0
        rows.append(row)
        print(f"[fig4] procs={procs}x{dev}: {row['sweeps_per_s']:.3f} sweeps/s "
              f"cross-proc {row['cross_process_bytes_per_sweep']:,} B/sweep")
    results["process_sweep"] = {
        "global_devices": S,
        "K": K,
        "dataset": {"num_users": proc_coo.num_users,
                    "num_movies": proc_coo.num_movies, "nnz": int(proc_coo.nnz)},
        "ring_bytes_per_sweep": bytes_info,
        "layouts": rows,
    }

    path = save_result("fig4_scaling", results, out=out)
    print(f"[fig4] wrote {path}")
    return results


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    out = None
    if "--out" in sys.argv:
        out = sys.argv[sys.argv.index("--out") + 1]
    run(smoke=smoke, out=smoke_out_path("fig4_scaling", smoke, out))
