"""§Roofline aggregator: experiments/dryrun JSONs -> the per-cell table.

    python -m benchmarks.roofline [--mesh pod16x16] [--markdown]

Prints (and saves) per (arch x shape): the three roofline terms in seconds,
the dominant term, MODEL_FLOPS/HLO_FLOPS, HBM fit, and the roofline
fraction. No jax needed — pure JSON aggregation.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from benchmarks.common import save_result

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_cells(mesh: str) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, mesh, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def table(mesh: str = "pod16x16") -> tuple[list[dict], str]:
    cells = load_cells(mesh)
    rows, lines = [], []
    hdr = (f"| arch | shape | compute s | memory s | collective s | dominant | "
           f"useful | HBM GB | fits | roofline frac |")
    lines += [hdr, "|" + "---|" * 10]
    for c in cells:
        if c.get("status") != "ok":
            lines.append(f"| {c['arch']} | {c['shape']} | FAILED: {c.get('error','')[:60]} |" + " |" * 7)
            rows.append({"arch": c["arch"], "shape": c["shape"], "status": "error"})
            continue
        r = c["roofline"]
        mem_gb = r["memory"]["peak_bytes_est"] / 1e9
        rows.append({
            "arch": c["arch"], "shape": c["shape"], "status": "ok",
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "useful_flops_ratio": r["useful_flops_ratio"],
            "hbm_gb": mem_gb, "fits_hbm": r["fits_hbm"],
            "roofline_fraction": r["roofline_fraction"],
        })
        u = r["useful_flops_ratio"]
        lines.append(
            f"| {c['arch']} | {c['shape']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant'].replace('_s','')} "
            f"| {u:.3f} | {mem_gb:.2f} | {'Y' if r['fits_hbm'] else 'N'} "
            f"| {r['roofline_fraction']:.4f} |"
        )
    return rows, "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args(argv)
    rows, md = table(args.mesh)
    print(md)
    save_result(f"roofline_{args.mesh}", {"rows": rows, "markdown": md})
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
