"""Serving latency benchmarks: isolated batch sweep + closed-loop load.

Two modes over the posterior serving path:

* default — trains (or reuses) a serving artifact, loads it through
  ``repro.serve.PosteriorPredictor``, and measures end-to-end isolated
  query latency per batch size plus a top-k catalog probe. Writes
  ``experiments/bench/serve_latency.json``.
* ``--load`` — the persistent-server benchmark (DESIGN.md §11): builds a
  synthetic artifact at the recorded catalog size, measures the
  item-sharded vs replicated top-k paths head-to-head, then runs
  closed-loop concurrent clients (each thread issues requests
  back-to-back through ``repro.serve.ServeClient``) against a live
  ``BPMFServer`` and records offered qps, p50/p99 under load and
  micro-batcher occupancy per client count. Writes
  ``experiments/bench/serve_load.json``.

Smoke runs (``--smoke``) never overwrite the committed JSON: without an
explicit ``--out`` they write to a temp path (printed). Schemas in
``experiments/bench/README.md``, validated by
``scripts/check_bench_schema.py serve_latency`` / ``serve_load``.

    python -m benchmarks.serve_latency              # full isolated sweep
    python -m benchmarks.serve_latency --load       # full load benchmark
    python -m benchmarks.serve_latency --smoke --load --out /tmp/x.json
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import threading
import time

import numpy as np

from benchmarks.common import OUT_DIR, save_result, smoke_out_path


def _percentiles(times_s: list[float], batch: int) -> dict:
    arr = np.asarray(times_s) * 1e3
    return {
        "p50_ms": float(np.percentile(arr, 50)),
        "p99_ms": float(np.percentile(arr, 99)),
        "mean_ms": float(arr.mean()),
        "qps": float(batch / max(arr.mean() / 1e3, 1e-12)),
    }


def build_artifact(args) -> str:
    """Train a small synthetic run and export its serving artifact."""
    from repro.bpmf import BPMFConfig, BPMFEngine, load_dataset

    coo = load_dataset(
        "synthetic", num_users=args.users, num_movies=args.movies, nnz=args.nnz,
        noise_std=0.3, seed=0,
    )
    cfg = BPMFConfig().replace(
        name=args.backend, K=args.K, num_sweeps=args.sweeps,
        burn_in=max(1, args.sweeps // 3), bucket_pads=(8, 32, 128),
    )
    engine = BPMFEngine(cfg).fit(coo)
    return engine.export(tempfile.mkdtemp(prefix="bpmf-serve-bench-") + "/artifact")


def build_random_artifact(users: int, movies: int, K: int, seed: int = 0) -> str:
    """Random-factor artifact at a given catalog size (no training) — the
    serving path only sees arrays, so load benchmarks skip the sampler."""
    from repro.serve import ArtifactMeta, save_artifact

    rng = np.random.default_rng(seed)
    meta = ArtifactMeta(
        num_users=users, num_movies=movies, K=K, mean_rating=3.5,
        min_rating=1.0, max_rating=5.0, num_mean_samples=8,
        num_kept_samples=0, backend="synthetic", num_sweeps_done=0, seed=seed,
    )
    arrays = {
        "U_mean": rng.normal(scale=0.5, size=(users, K)).astype(np.float32),
        "V_mean": rng.normal(scale=0.5, size=(movies, K)).astype(np.float32),
        "U_samples": np.zeros((0, users, K), np.float32),
        "V_samples": np.zeros((0, movies, K), np.float32),
    }
    directory = tempfile.mkdtemp(prefix="bpmf-serve-load-") + "/artifact"
    return save_artifact(directory, meta, arrays)


def _time_topk(predictor, users_pool, k, repeats, sharded) -> dict:
    for _ in range(3):
        predictor.top_k(users_pool[0], k, sharded=sharded)
    times = []
    for i in range(repeats):
        u = users_pool[i % len(users_pool)]
        t0 = time.perf_counter()
        predictor.top_k(u, k, sharded=sharded)
        times.append(time.perf_counter() - t0)
    return {"k": k, **_percentiles(times, 1)}


def _recorded_topk_p99() -> float | None:
    """p99 of the committed full-catalog top-k probe, if present."""
    try:
        with open(os.path.join(OUT_DIR, "serve_latency.json")) as f:
            payload = json.load(f)
        return float(payload["top_k"]["p99_ms"])
    except (OSError, ValueError, KeyError, TypeError):
        return None


class _ClosedLoopClient(threading.Thread):
    """One closed-loop client: issue mixed requests back-to-back until told
    to stop, recording per-request wall latency."""

    def __init__(self, address, meta, seed, stop_event):
        super().__init__(daemon=True)
        self.address = address
        self.meta = meta
        self.rng = np.random.default_rng(seed)
        self.stop_event = stop_event
        self.latencies: list[float] = []
        self.errors = 0
        self.issued = 0

    def run(self):
        from repro.serve import ServeClient

        client = ServeClient(self.address)
        n_users, n_movies = self.meta.num_users, self.meta.num_movies
        while not self.stop_event.is_set():
            # 4:1 predict (batch 4) : top-k — a recommender-shaped mix
            if self.rng.integers(0, 5) < 4:
                req = {
                    "rows": self.rng.integers(0, n_users, 4).tolist(),
                    "cols": self.rng.integers(0, n_movies, 4).tolist(),
                }
            else:
                req = {"user": int(self.rng.integers(0, n_users)), "k": 10}
            self.issued += 1
            t0 = time.perf_counter()
            try:
                resp = client.request(req)
                if "error" in resp:
                    self.errors += 1
            except Exception:
                self.errors += 1
            self.latencies.append(time.perf_counter() - t0)
        client.close()


def _load_level(address, meta, clients, duration_s) -> dict:
    from repro.serve import ServeClient

    probe = ServeClient(address)
    before = probe.stats()["batcher"]
    stop = threading.Event()
    threads = [
        _ClosedLoopClient(address, meta, seed=i, stop_event=stop)
        for i in range(clients)
    ]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    time.sleep(duration_s)
    stop.set()
    for t in threads:
        t.join(timeout=30)
    wall = time.perf_counter() - t0
    after = probe.stats()["batcher"]
    probe.close()

    lats = [x for t in threads for x in t.latencies]
    errors = sum(t.errors for t in threads)
    issued = sum(t.issued for t in threads)
    d_req = after["requests"] - before["requests"]
    d_cyc = after["cycles"] - before["cycles"]
    entry = {
        "clients": clients,
        "requests": len(lats),
        "errors": errors,
        # issued-but-never-completed (a hung client thread); 0 in a healthy run
        "dropped": issued - len(lats),
        "offered_qps": len(lats) / wall,
        "batcher_occupancy": d_req / d_cyc if d_cyc else 0.0,
        "coalesced_share": (
            (after["coalesced_requests"] - before["coalesced_requests"]) / d_req
            if d_req else 0.0
        ),
        **_percentiles(lats, 1),
    }
    return entry


def run_load(args) -> int:
    """The --load mode: sharded-vs-replicated top-k + closed-loop qps."""
    import jax

    from repro.serve import BPMFServer, PosteriorPredictor

    artifact = args.artifact or build_random_artifact(args.users, args.movies, args.K)
    predictor = PosteriorPredictor.load(artifact)
    meta = predictor.meta
    rng = np.random.default_rng(1)
    users_pool = [int(u) for u in rng.integers(0, meta.num_users, 64)]

    k = min(args.top_k, meta.num_movies)
    topk = {
        "replicated": _time_topk(predictor, users_pool, k, args.repeats, sharded=False),
        "sharded": _time_topk(predictor, users_pool, k, args.repeats, sharded=True),
    }
    topk["sharded_vs_replicated_p99_ratio"] = (
        topk["sharded"]["p99_ms"] / topk["replicated"]["p99_ms"]
    )
    recorded = _recorded_topk_p99()
    if recorded is not None:
        topk["recorded_full_catalog_p99_ms"] = recorded
        topk["sharded_beats_recorded"] = topk["sharded"]["p99_ms"] < recorded
    for name in ("replicated", "sharded"):
        e = topk[name]
        print(f"top_{k} {name:10s}: p50 {e['p50_ms']:.3f} ms  p99 {e['p99_ms']:.3f} ms")

    server = BPMFServer(
        artifact, deadline_ms=args.deadline_ms, topk_mode="auto",
        watch=False,
    )
    host, port = server.start()
    address = f"{host}:{port}"
    load = {}
    try:
        for clients in [int(c) for c in args.clients.split(",")]:
            entry = _load_level(address, meta, clients, args.duration)
            load[str(clients)] = entry
            print(
                f"clients {clients:3d}: {entry['offered_qps']:8.0f} req/s  "
                f"p50 {entry['p50_ms']:.3f} ms  p99 {entry['p99_ms']:.3f} ms  "
                f"occupancy {entry['batcher_occupancy']:.2f}  "
                f"errors {entry['errors']}"
            )
    finally:
        server.shutdown()

    payload = {
        "device": jax.default_backend(),
        "num_devices": len(jax.devices()),
        "smoke": bool(args.smoke),
        "repeats": args.repeats,
        "deadline_ms": args.deadline_ms,
        "duration_s": args.duration,
        "artifact": {
            "num_users": meta.num_users,
            "num_movies": meta.num_movies,
            "K": meta.K,
            "num_mean_samples": meta.num_mean_samples,
            "num_kept_samples": meta.num_kept_samples,
            "backend": meta.backend,
        },
        "top_k": topk,
        "load": load,
    }
    path = save_result("serve_load", payload, out=smoke_out_path(
        "serve_load", args.smoke, args.out))
    print(f"wrote {path}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny run for CI smoke")
    ap.add_argument("--load", action="store_true",
                    help="closed-loop concurrent-client benchmark against a "
                         "live BPMFServer (writes serve_load.json)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: the committed "
                         "experiments/bench file; smoke runs default to a "
                         "temp path instead)")
    ap.add_argument("--artifact", default=None,
                    help="existing artifact directory (skips training)")
    ap.add_argument("--backend", default="sequential")
    ap.add_argument("--users", type=int, default=2000)
    ap.add_argument("--movies", type=int, default=800)
    ap.add_argument("--nnz", type=int, default=40_000)
    ap.add_argument("--K", type=int, default=16)
    ap.add_argument("--sweeps", type=int, default=8)
    ap.add_argument("--batches", default="1,8,64,512",
                    help="comma-separated query batch sizes")
    ap.add_argument("--repeats", type=int, default=200)
    ap.add_argument("--top-k", type=int, default=10)
    ap.add_argument("--clients", default="1,4,16",
                    help="closed-loop client counts (--load)")
    ap.add_argument("--duration", type=float, default=3.0,
                    help="seconds per client-count level (--load)")
    ap.add_argument("--deadline-ms", type=float, default=2.0,
                    help="server micro-batch deadline (--load)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.users, args.movies, args.nnz = 200, 100, 3000
        args.K, args.sweeps = 6, 3
        args.batches, args.repeats = "1,8,64", 25
        args.clients, args.duration = "1,4", 1.0

    if args.load:
        return run_load(args)

    import jax

    from repro.serve import PosteriorPredictor

    artifact = args.artifact or build_artifact(args)
    predictor = PosteriorPredictor.load(artifact)
    meta = predictor.meta
    rng = np.random.default_rng(0)

    batches = {}
    for batch in [int(b) for b in args.batches.split(",")]:
        rows = rng.integers(0, meta.num_users, batch).astype(np.int32)
        cols = rng.integers(0, meta.num_movies, batch).astype(np.int32)
        for _ in range(3):  # warmup: compile + cache the pad class
            predictor.predict(rows, cols)
        times = []
        for _ in range(args.repeats):
            t0 = time.perf_counter()
            predictor.predict(rows, cols)  # returns host numpy: fully synced
            times.append(time.perf_counter() - t0)
        batches[str(batch)] = _percentiles(times, batch)
        print(f"batch {batch:5d}: p50 {batches[str(batch)]['p50_ms']:.3f} ms  "
              f"p99 {batches[str(batch)]['p99_ms']:.3f} ms  "
              f"{batches[str(batch)]['qps']:,.0f} preds/s")

    k = min(args.top_k, meta.num_movies)
    user = np.int32(0)
    for _ in range(3):
        predictor.top_k(user, k)
    times = []
    for _ in range(args.repeats):
        t0 = time.perf_counter()
        predictor.top_k(user, k)
        times.append(time.perf_counter() - t0)
    top_k = {"k": k, **_percentiles(times, 1)}
    print(f"top_{k}: p50 {top_k['p50_ms']:.3f} ms  p99 {top_k['p99_ms']:.3f} ms")

    payload = {
        "device": jax.default_backend(),
        "num_devices": len(jax.devices()),
        "smoke": bool(args.smoke),
        "repeats": args.repeats,
        "artifact": {
            "num_users": meta.num_users,
            "num_movies": meta.num_movies,
            "K": meta.K,
            "num_mean_samples": meta.num_mean_samples,
            "num_kept_samples": meta.num_kept_samples,
            "backend": meta.backend,
        },
        "batches": batches,
        "top_k": top_k,
    }
    path = save_result("serve_latency", payload, out=smoke_out_path(
        "serve_latency", args.smoke, args.out))
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
