"""Serving latency benchmark: batch-size sweep over the posterior predictor.

Trains (or reuses) a serving artifact, loads it through
``repro.serve.PosteriorPredictor``, and measures end-to-end query latency —
host batch prep + padded device dispatch + host gather — per batch size,
plus a top-k catalog-scoring probe. Writes
``experiments/bench/serve_latency.json`` (schema in
``experiments/bench/README.md``, validated by
``scripts/check_bench_schema.py serve_latency``).

    python -m benchmarks.serve_latency            # full sweep
    python -m benchmarks.serve_latency --smoke    # tiny, for scripts/test.sh
    python -m benchmarks.serve_latency --artifact /tmp/art   # reuse artifact
"""
from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np

from benchmarks.common import save_result


def _percentiles(times_s: list[float], batch: int) -> dict:
    arr = np.asarray(times_s) * 1e3
    return {
        "p50_ms": float(np.percentile(arr, 50)),
        "p99_ms": float(np.percentile(arr, 99)),
        "mean_ms": float(arr.mean()),
        "qps": float(batch / max(arr.mean() / 1e3, 1e-12)),
    }


def build_artifact(args) -> str:
    """Train a small synthetic run and export its serving artifact."""
    from repro.bpmf import BPMFConfig, BPMFEngine, load_dataset

    coo = load_dataset(
        "synthetic", num_users=args.users, num_movies=args.movies, nnz=args.nnz,
        noise_std=0.3, seed=0,
    )
    cfg = BPMFConfig().replace(
        name=args.backend, K=args.K, num_sweeps=args.sweeps,
        burn_in=max(1, args.sweeps // 3), bucket_pads=(8, 32, 128),
    )
    engine = BPMFEngine(cfg).fit(coo)
    return engine.export(tempfile.mkdtemp(prefix="bpmf-serve-bench-") + "/artifact")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny run for CI smoke")
    ap.add_argument("--artifact", default=None,
                    help="existing artifact directory (skips training)")
    ap.add_argument("--backend", default="sequential")
    ap.add_argument("--users", type=int, default=2000)
    ap.add_argument("--movies", type=int, default=800)
    ap.add_argument("--nnz", type=int, default=40_000)
    ap.add_argument("--K", type=int, default=16)
    ap.add_argument("--sweeps", type=int, default=8)
    ap.add_argument("--batches", default="1,8,64,512",
                    help="comma-separated query batch sizes")
    ap.add_argument("--repeats", type=int, default=200)
    ap.add_argument("--top-k", type=int, default=10)
    args = ap.parse_args(argv)
    if args.smoke:
        args.users, args.movies, args.nnz = 200, 100, 3000
        args.K, args.sweeps = 6, 3
        args.batches, args.repeats = "1,8,64", 25

    import jax

    from repro.serve import PosteriorPredictor

    artifact = args.artifact or build_artifact(args)
    predictor = PosteriorPredictor.load(artifact)
    meta = predictor.meta
    rng = np.random.default_rng(0)

    batches = {}
    for batch in [int(b) for b in args.batches.split(",")]:
        rows = rng.integers(0, meta.num_users, batch).astype(np.int32)
        cols = rng.integers(0, meta.num_movies, batch).astype(np.int32)
        for _ in range(3):  # warmup: compile + cache the pad class
            predictor.predict(rows, cols)
        times = []
        for _ in range(args.repeats):
            t0 = time.perf_counter()
            predictor.predict(rows, cols)  # returns host numpy: fully synced
            times.append(time.perf_counter() - t0)
        batches[str(batch)] = _percentiles(times, batch)
        print(f"batch {batch:5d}: p50 {batches[str(batch)]['p50_ms']:.3f} ms  "
              f"p99 {batches[str(batch)]['p99_ms']:.3f} ms  "
              f"{batches[str(batch)]['qps']:,.0f} preds/s")

    k = min(args.top_k, meta.num_movies)
    user = np.int32(0)
    for _ in range(3):
        predictor.top_k(user, k)
    times = []
    for _ in range(args.repeats):
        t0 = time.perf_counter()
        predictor.top_k(user, k)
        times.append(time.perf_counter() - t0)
    top_k = {"k": k, **_percentiles(times, 1)}
    print(f"top_{k}: p50 {top_k['p50_ms']:.3f} ms  p99 {top_k['p99_ms']:.3f} ms")

    payload = {
        "device": jax.default_backend(),
        "num_devices": len(jax.devices()),
        "smoke": bool(args.smoke),
        "repeats": args.repeats,
        "artifact": {
            "num_users": meta.num_users,
            "num_movies": meta.num_movies,
            "K": meta.K,
            "num_mean_samples": meta.num_mean_samples,
            "num_kept_samples": meta.num_kept_samples,
            "backend": meta.backend,
        },
        "batches": batches,
        "top_k": top_k,
    }
    path = save_result("serve_latency", payload)
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
