"""Paper Figure 5: time in compute / communication / both (overlap).

Two complementary measurements:

1. Wall-clock (this CPU host, 8 forced devices): sweep time at equal work
   for comm_mode=allgather (synchronous barrier), ring (one rotation in
   flight) and ring_async at pipeline_depth in {1, 2, 4} (d rotations in
   flight, DESIGN.md §7). The ring/allgather gap IS the overlap the
   paper's Isend/Irecv buys; the ring_async depth sweep shows how much
   further latency pipelining (arXiv:1705.10633) pushes it, since every
   mode moves the same factor bytes.

2. Roofline (TPU target, from the BPMF dry-run artifact): per ring step the
   ICI time of one shard rotation vs the MXU time of one shard's gram
   accumulation — overlap potential = min(comm, compute)/max(comm, compute).
   Derived in EXPERIMENTS.md §Roofline from experiments/dryrun JSONs.

Emits machine-readable JSON to ``experiments/bench/fig5_overlap.json``
(schema in experiments/bench/README.md). Run inside an 8-device process
(benchmarks.run handles this).
"""
from __future__ import annotations

import sys
import time

import jax

import numpy as np

from benchmarks.common import save_result
from repro.core.distributed import (
    build_distributed_data,
    gather_factors,
    make_ring_mesh,
    run_distributed,
)
from repro.core.types import BPMFConfig
from repro.data.synthetic import SyntheticSpec, synthetic_ratings

PIPELINE_DEPTHS = (1, 2, 4)


def run(smoke: bool = False) -> dict:
    spec = SyntheticSpec(
        num_users=600 if smoke else 4_000,
        num_movies=300 if smoke else 1_000,
        nnz=8_000 if smoke else 120_000,
        discretize=False,
    )
    coo, _ = synthetic_ratings(spec)
    K = 8 if smoke else 32
    sweeps = 2 if smoke else 6
    devices = jax.devices()
    w = min(8, len(devices))
    mesh = make_ring_mesh(devices[:w])
    data, plan = build_distributed_data(coo, num_shards=w, seed=0)

    variants = [("ring", "ring", 1), ("allgather", "allgather", 1)]
    variants += [(f"ring_async_d{d}", "ring_async", d) for d in PIPELINE_DEPTHS]

    out: dict = {
        "devices": w,
        "workload": {"users": spec.num_users, "movies": spec.num_movies,
                     "nnz": spec.nnz, "K": K, "sweeps": sweeps},
        "modes": {},
    }
    factors: dict[str, tuple] = {}
    for label, mode, depth in variants:
        cfg = BPMFConfig(K=K, num_sweeps=sweeps, burn_in=1, comm_mode=mode,
                         pipeline_depth=depth)
        run_distributed(jax.random.key(0), data, cfg, mesh)  # compile
        t0 = time.time()
        state, _, hist = run_distributed(jax.random.key(1), data, cfg, mesh)
        t = time.time() - t0
        factors[label] = gather_factors(state, plan)
        out["modes"][label] = {
            "comm_mode": mode,
            "pipeline_depth": depth,
            "seconds": t,
            "seconds_per_sweep": t / sweeps,
            "rmse": hist[-1].rmse_avg,
        }
        print(f"[fig5] {label}: {t:.3f}s rmse={hist[-1].rmse_avg:.4f}")

    ag = out["modes"]["allgather"]["seconds"]
    out["speedup_vs_allgather"] = {
        label: ag / m["seconds"] for label, m in out["modes"].items()
    }
    out["ring_vs_allgather_speedup"] = out["speedup_vs_allgather"]["ring"]
    rmses = [m["rmse"] for m in out["modes"].values()]
    out["parity_ok"] = max(rmses) - min(rmses) < 1e-3  # reduction-order slack
    # pipelining must not change the samples at all (DESIGN.md §7):
    # compare the gathered factor matrices themselves, not a derived RMSE
    out["ring_async_bitwise"] = all(
        np.array_equal(factors[f"ring_async_d{d}"][i], factors["ring"][i])
        for d in PIPELINE_DEPTHS
        for i in (0, 1)
    )
    save_result("fig5_overlap", out)
    return out


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv)
