"""Paper Figure 5: time in compute / communication / both (overlap).

Two complementary measurements:

1. Wall-clock (this CPU host, 8 forced devices): sweep time for
   comm_mode=ring (async, overlap-friendly) vs allgather (synchronous
   barrier) at equal work — the ring/allgather gap IS the overlap the
   paper's Isend/Irecv buys, since both move the same factor bytes.

2. Roofline (TPU target, from the BPMF dry-run artifact): per ring step the
   ICI time of one shard rotation vs the MXU time of one shard's gram
   accumulation — overlap potential = min(comm, compute)/max(comm, compute).
   Derived in EXPERIMENTS.md §Roofline from experiments/dryrun JSONs.

Run inside an 8-device process (benchmarks.run handles this).
"""
from __future__ import annotations

import sys
import time

import jax

from benchmarks.common import save_result
from repro.core.distributed import build_distributed_data, make_ring_mesh, run_distributed
from repro.core.types import BPMFConfig
from repro.data.synthetic import SyntheticSpec, synthetic_ratings


def run(smoke: bool = False) -> dict:
    spec = SyntheticSpec(
        num_users=600 if smoke else 4_000,
        num_movies=300 if smoke else 1_000,
        nnz=8_000 if smoke else 120_000,
        discretize=False,
    )
    coo, _ = synthetic_ratings(spec)
    K = 8 if smoke else 32
    sweeps = 2 if smoke else 6
    devices = jax.devices()
    w = min(8, len(devices))
    mesh = make_ring_mesh(devices[:w])

    out: dict = {"devices": w, "modes": {}}
    for mode in ("ring", "allgather"):
        cfg = BPMFConfig(K=K, num_sweeps=sweeps, burn_in=1, comm_mode=mode)
        data, _ = build_distributed_data(coo, num_shards=w, seed=0)
        run_distributed(jax.random.key(0), data, cfg, mesh)  # compile
        t0 = time.time()
        _, _, hist = run_distributed(jax.random.key(1), data, cfg, mesh)
        t = time.time() - t0
        out["modes"][mode] = {"seconds": t, "rmse": hist[-1].rmse_avg}
        print(f"[fig5] {mode}: {t:.3f}s rmse={hist[-1].rmse_avg:.4f}")

    ring_t = out["modes"]["ring"]["seconds"]
    ag_t = out["modes"]["allgather"]["seconds"]
    out["ring_vs_allgather_speedup"] = ag_t / ring_t
    save_result("fig5_overlap", out)
    return out


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv)
