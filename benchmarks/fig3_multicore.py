"""Paper Figure 3: single-node updates/second vs parallel width.

Paper variants -> this repo:
  * TBB (work stealing)      -> bucketed batched sweep, LPT-balanced buckets
  * OpenMP (static split)    -> bucketed sweep with naive contiguous buckets
  * GraphLab (generic graph) -> unbucketed vmap over max-padded items

"Parallel width" on one CPU host device maps to the batch dimension the MXU
(or CPU vector unit) sweeps per launch; we report updates/s for the full
half-sweep on a ChEMBL-shaped synthetic at several scales.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.core import posterior
from repro.core.types import BPMFConfig, HyperParams
from repro.data.sparse import build_bpmf_data
from repro.data.synthetic import SyntheticSpec, synthetic_ratings
from repro.utils import timeit


def run(smoke: bool = False) -> dict:
    spec = SyntheticSpec(
        num_users=2_000 if smoke else 20_000,
        num_movies=400 if smoke else 1_200,
        nnz=20_000 if smoke else 400_000,
        discretize=False,
    )
    coo, _ = synthetic_ratings(spec)
    K = 16 if smoke else 32
    cfg = BPMFConfig(K=K)
    iters = 3 if smoke else 8

    key = jax.random.key(0)
    hyper = HyperParams.init(K)

    results = {}
    for mode, pads in (
        ("bucketed_lpt", (8, 32, 128, 512, 2048)),   # TBB-like: size-classed buckets
        ("bucketed_coarse", (2048,)),                # OpenMP-like: one static class
    ):
        data = build_bpmf_data(coo, pads=pads, test_fraction=0.1, seed=0)
        U = jax.random.normal(key, (coo.num_users, K), jnp.float32)
        V = jax.random.normal(key, (coo.num_movies, K), jnp.float32)
        half = jax.jit(
            lambda V, U, d: posterior.update_side(key, V, U, d.movies, hyper, cfg.alpha)
        )
        t = timeit(half, V, U, data, iters=iters)
        results[mode] = {
            "seconds_per_halfsweep": t,
            "updates_per_s": coo.num_movies / t,
            "pads": list(pads),
        }

    # GraphLab-like: every item padded to the global max nnz (one giant launch,
    # no size classes) — the generic-framework overhead the paper measures
    import numpy as _np
    from repro.data.sparse import csr_from_coo as _csr
    indptr, _, _ = _csr(coo.cols, coo.rows, coo.vals, coo.num_movies)
    max_nnz = int((indptr[1:] - indptr[:-1]).max())
    maxpad = 1 << int(_np.ceil(_np.log2(max(max_nnz, 8))))
    data1 = build_bpmf_data(coo, pads=(maxpad,), test_fraction=0.1, seed=0)
    U = jax.random.normal(key, (coo.num_users, K), jnp.float32)
    V = jax.random.normal(key, (coo.num_movies, K), jnp.float32)
    half = jax.jit(
        lambda V, U, d: posterior.update_side(key, V, U, d.movies, hyper, cfg.alpha)
    )
    t = timeit(half, V, U, data1, iters=max(2, iters // 2))
    results["maxpad_graphlab_like"] = {
        "seconds_per_halfsweep": t,
        "updates_per_s": coo.num_movies / t,
    }

    results["speedup_bucketed_vs_maxpad"] = (
        results["maxpad_graphlab_like"]["seconds_per_halfsweep"]
        / results["bucketed_lpt"]["seconds_per_halfsweep"]
    )
    out = {"spec": vars(spec) | {"K": K}, "results": results}
    save_result("fig3_multicore", out)
    return out


if __name__ == "__main__":
    r = run()
    for k, v in r["results"].items():
        print(k, v)
