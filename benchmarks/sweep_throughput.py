"""Blocked sweep-loop throughput: sweeps/sec and host bytes per sweep.

Measures what the device-resident blocked run loop (DESIGN.md §10) buys
over the per-sweep host round-trip it replaced, across
``backends × sweeps_per_block ∈ {1, 4, 8}``:

* ``sweeps_per_sec`` — engine wall-clock after a compile warmup;
* ``host_bytes_per_sweep`` — bytes the engine actually fetched from device
  per sweep (the engine's ``host_metric_bytes`` counter: one stacked
  ``[block, 3]`` f32 metrics array per block, nothing else);
* ``legacy_emulated`` — the pre-block engine loop, reproduced faithfully:
  per-sweep dispatch plus a full ``(U, V)`` factor gather to the host after
  every post-burn-in sweep (what the old host-side posterior accumulator
  cost). The gap between its ``host_bytes_per_sweep`` and any blocked
  entry's is ≥ the factor-gather size — the acceptance bar of the refactor.

The overlapped pipeline (DESIGN.md §13) gets its own columns per backend:
``overlap_off`` / ``overlap_on`` time the same blocked run at
``pipeline_blocks`` 1 vs 2 and record ``host_blocked_s_per_block`` — the
wall-clock the engine spent blocked on metric materialization per block,
the time the pipeline exists to hide. ``save_return_latency`` times how
fast ``engine.save()`` returns with async vs sync checkpoint commits.
``overlap_speedup_ok`` records whether overlap-on beat overlap-off; on CPU
host meshes the mechanisms share the same cores, so the schema check warns
rather than fails when it is False — CPU numbers order mechanisms only.

Bitwise parity across block sizes and pipeline depths is re-checked on the
gathered factors (``parity_ok``). Emits
``experiments/bench/sweep_throughput.json`` (schema
in experiments/bench/README.md, validated by
``scripts/check_bench_schema.py sweep_throughput``). Run inside a forced
multi-device process, e.g.::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src:. \
        python -m benchmarks.sweep_throughput --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import save_result, smoke_out_path

BLOCK_SIZES = (1, 4, 8)
BACKENDS = ("sequential", "ring", "ring_async", "allgather")


def _fit_timed(cfg, coo):
    """(engine, seconds) for one fit, compile excluded via a warmup fit."""
    from repro.bpmf import BPMFEngine

    BPMFEngine(cfg).fit(coo)  # compile
    engine = BPMFEngine(cfg)
    engine.prepare(coo)
    t0 = time.time()
    engine.fit()
    return engine, time.time() - t0


def _legacy_emulated(cfg, coo):
    """The pre-block run loop: per-sweep blocks + per-sweep factor gather.

    ``sweeps_per_block=1`` reproduces the old dispatch cadence; the explicit
    ``engine.factors()`` per post-burn-in sweep reproduces the old host-side
    posterior accumulation traffic. Bytes are counted from the arrays
    actually gathered.
    """
    from repro.bpmf import BPMFEngine

    cfg = cfg.replace(sweeps_per_block=1)
    BPMFEngine(cfg).fit(coo)  # compile
    engine = BPMFEngine(cfg)
    engine.prepare(coo)
    gathered = 0
    t0 = time.time()
    for m in engine.sample():
        if int(m.sweep) > cfg.run.burn_in:
            U, V = engine.factors()
            gathered += U.nbytes + V.nbytes
    t = time.time() - t0
    return engine, t, gathered + engine.host_metric_bytes


def _save_latency(cfg, coo):
    """Measured ``engine.save()`` return latency: async vs sync commit.

    Same state size as the benchmark workload (latency scales with the
    snapshot), few sweeps (latency does not). ``async_returns_faster`` is
    recorded, not asserted — for tiny checkpoints the thread handoff can
    rival the write itself.
    """
    import shutil
    import tempfile

    from repro.bpmf import BPMFEngine

    out: dict = {}
    for label, async_w in (("async_s", True), ("sync_s", False)):
        d = tempfile.mkdtemp(prefix="bpmf-savelat-")
        try:
            engine = BPMFEngine(cfg.replace(
                num_sweeps=2, burn_in=1, checkpoint_dir=d,
                async_checkpoint_writes=async_w,
            ))
            engine.fit(coo)
            t0 = time.perf_counter()
            engine.save()
            out[label] = time.perf_counter() - t0
            engine._ckpt.close()  # join the writer before removing the dir
        finally:
            shutil.rmtree(d, ignore_errors=True)
    out["async_returns_faster"] = out["async_s"] <= out["sync_s"]
    return out


def run(smoke: bool = False, out_path: str | None = None) -> dict:
    from repro.bpmf import BPMFConfig, load_dataset

    users = 400 if smoke else 2_000
    movies = 200 if smoke else 800
    nnz = 6_000 if smoke else 80_000
    K = 8 if smoke else 32
    sweeps = 8 if smoke else 24
    burn_in = 2 if smoke else 8
    coo = load_dataset("synthetic", num_users=users, num_movies=movies, nnz=nnz)
    base = BPMFConfig().replace(
        K=K, num_sweeps=sweeps, burn_in=burn_in, keep_factor_samples=4
    )
    num_devices = len(jax.devices())

    out: dict = {
        "devices": num_devices,
        "smoke": smoke,
        "workload": {"users": users, "movies": movies, "nnz": nnz,
                     "K": K, "sweeps": sweeps, "burn_in": burn_in},
        # what the old loop gathered per post-burn-in sweep: full f32 (U, V)
        "factor_gather_bytes": (users + movies) * K * 4,
        "backends": {},
    }

    parity = True
    for name in BACKENDS:
        entries: dict = {}
        factors0 = None
        for spb in BLOCK_SIZES:
            cfg = base.replace(name=name, sweeps_per_block=spb)
            engine, t = _fit_timed(cfg, coo)
            if factors0 is None:
                factors0 = engine.factors()
            else:
                U, V = engine.factors()
                parity = parity and np.array_equal(U, factors0[0]) \
                    and np.array_equal(V, factors0[1])
            entries[f"block_{spb}"] = {
                "sweeps_per_block": spb,
                "seconds": t,
                "sweeps_per_sec": sweeps / t,
                "host_bytes_per_sweep": engine.host_metric_bytes / sweeps,
                "rmse": engine.rmse,
            }
            print(f"[sweep_throughput] {name} block={spb}: {t:.3f}s "
                  f"({sweeps / t:.2f} sweeps/s, "
                  f"{engine.host_metric_bytes / sweeps:.0f} B/sweep)")
        engine, t, legacy_bytes = _legacy_emulated(base.replace(name=name), coo)
        post = sweeps - burn_in
        entries["legacy_emulated"] = {
            "seconds": t,
            "sweeps_per_sec": sweeps / t,
            "host_bytes_per_sweep": legacy_bytes / sweeps,
            "host_bytes_per_post_burn_in_sweep":
                (legacy_bytes - sweeps * 12) / post + 12,
            "rmse": engine.rmse,
        }
        print(f"[sweep_throughput] {name} legacy: {t:.3f}s "
              f"({legacy_bytes / sweeps:.0f} B/sweep)")
        # overlap columns (DESIGN.md §13): same blocked run at pipeline
        # depth 1 vs 2, spb=4 so several blocks are actually in flight
        spb_ov = 4
        nblocks = -(-sweeps // spb_ov)
        for label, depth in (("overlap_off", 1), ("overlap_on", 2)):
            cfg = base.replace(name=name, sweeps_per_block=spb_ov,
                               pipeline_blocks=depth)
            engine, t = _fit_timed(cfg, coo)
            U, V = engine.factors()
            parity = parity and np.array_equal(U, factors0[0]) \
                and np.array_equal(V, factors0[1])
            entries[label] = {
                "pipeline_blocks": depth,
                "seconds": t,
                "sweeps_per_sec": sweeps / t,
                "host_bytes_per_sweep": engine.host_metric_bytes / sweeps,
                "host_blocked_s_per_block": engine.host_blocked_s / nblocks,
                "rmse": engine.rmse,
            }
            print(f"[sweep_throughput] {name} {label}: {t:.3f}s "
                  f"({engine.host_blocked_s / nblocks * 1e6:.0f} us "
                  f"host-blocked/block)")
        out["backends"][name] = entries

    out["parity_ok"] = parity
    # recorded, warn-only in the schema check: on CPU host meshes the
    # overlapped mechanisms contend for the same cores
    out["overlap_speedup_ok"] = all(
        e["overlap_on"]["seconds"] <= e["overlap_off"]["seconds"]
        for e in out["backends"].values()
    )
    out["save_return_latency"] = _save_latency(base.replace(name="sequential"), coo)
    print(f"[sweep_throughput] save() return latency: "
          f"async {out['save_return_latency']['async_s'] * 1e3:.2f} ms, "
          f"sync {out['save_return_latency']['sync_s'] * 1e3:.2f} ms")
    # acceptance: for block > 1 the per-post-burn-in-sweep host traffic
    # drops vs the legacy loop by at least the factor-gather size
    gather = out["factor_gather_bytes"]
    out["block_transfer_drop_ok"] = all(
        e["legacy_emulated"]["host_bytes_per_post_burn_in_sweep"]
        - e[f"block_{spb}"]["host_bytes_per_sweep"] >= gather
        for e in out["backends"].values()
        for spb in BLOCK_SIZES
        if spb > 1
    )
    path = save_result(
        "sweep_throughput", out,
        out=smoke_out_path("sweep_throughput", smoke, out_path),
    )
    print(f"[sweep_throughput] wrote {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload; writes to a temp path unless --out")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: the committed "
                         "experiments/bench file; smoke runs default to a "
                         "temp path instead)")
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out)
