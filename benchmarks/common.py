"""Shared benchmark utilities: result IO, argument scaling."""
from __future__ import annotations

import json
import os
from typing import Any

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")


def save_result(name: str, payload: dict[str, Any], out: str | None = None) -> str:
    """Write a benchmark payload as JSON.

    Default target is the committed ``experiments/bench/<name>.json``; pass
    ``out`` to redirect (smoke runs MUST redirect so they never clobber the
    committed full-size numbers — see smoke_out_path)."""
    path = out or os.path.join(OUT_DIR, f"{name}.json")
    os.makedirs(os.path.dirname(os.path.abspath(path)) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return os.path.normpath(path)


def smoke_out_path(name: str, smoke: bool, out: str | None) -> str | None:
    """Resolve a benchmark's output path honouring the smoke contract.

    Smoke runs never write the committed ``experiments/bench/<name>.json``:
    with ``smoke`` set and no explicit ``--out``, results go to a temp file
    (path printed by the benchmark). An explicit ``out`` always wins.
    """
    if out:
        return out
    if smoke:
        import tempfile

        return os.path.join(tempfile.mkdtemp(prefix=f"bench-{name}-"), f"{name}.json")
    return None


def run_with_devices(module: str, num_devices: int, timeout: int = 1200, smoke: bool = False) -> str:
    """Run ``python -m <module>`` in a subprocess with N forced host devices
    (the device count is locked at jax init, so multi-device benchmarks need
    their own process)."""
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={num_devices}"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         os.path.join(os.path.dirname(__file__), ".."),
         env.get("PYTHONPATH", "")]
    )
    cmd = [sys.executable, "-m", module] + (["--smoke"] if smoke else [])
    r = subprocess.run(cmd, env=env, capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError(f"{module} failed:\n{r.stdout[-2000:]}\n{r.stderr[-2000:]}")
    return r.stdout
