"""RMSE vs inter-device communication: ring family vs posterior_merge.

The trade the limited-communication papers (arXiv:1703.00734 / 2004.02561)
make explicit, measured on this repo's backends over the statistical
harness's synthetic reference task:

* ``rmse`` — the engine's running posterior-mean RMSE (per-chain and
  un-merged for ``posterior_merge``);
* ``rmse_artifact`` — RMSE of the *exported* predictor over the global
  held-out split: for ``posterior_merge`` this is the merged subset
  posterior, the number that answers "what did partitioning cost";
* ``bytes_per_sweep`` — modelled inter-device traffic per Gibbs sweep.
  Ring/allgather rotate the opposite-side factor shard ``S-1`` times per
  half-sweep across ``S`` devices: ``S * (S-1) * (cap_u + cap_v) * K * 4``
  bytes. Sequential and posterior_merge move nothing between devices
  during sampling — the merge backend's chains are fully independent;
* ``collective_ops`` — *measured*: occurrences of collective-op mnemonics
  (collective-permute / all-gather / all-reduce / reduce-scatter /
  all-to-all) in the optimized HLO of each backend's compiled sweep-block
  program. The acceptance claim "~0 bytes per sweep" is checked here
  structurally: every posterior_merge chain program must contain **zero**
  collectives, while the ring programs must contain at least one.

Emits ``experiments/bench/fig_merge_comm.json`` (schema in
experiments/bench/README.md, validated by ``scripts/check_bench_schema.py
fig_merge_comm``), with acceptance booleans (``beats_baseline``,
``within_band``, ``zero_comm_ok``) enforced on the committed full-size
run. Run inside a forced multi-device process, e.g.::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src:. \
        python -m benchmarks.fig_merge_comm --smoke
"""
from __future__ import annotations

import argparse
import re
import time

import jax
import numpy as np

from benchmarks.common import save_result, smoke_out_path

_COLLECTIVE_RE = re.compile(
    r"(?i)(collective.?permute|all.?gather|all.?reduce|reduce.?scatter|all.?to.?all)"
)


def _collective_ops(compiled_text: str) -> int:
    """Count collective-op mnemonics in optimized HLO text."""
    return len(_COLLECTIVE_RE.findall(compiled_text))


def _sweep_block_hlo_collectives(engine) -> int:
    """Collectives in the backend's compiled one-sweep block program.

    Lowers the same jitted function the engine's run loop dispatches, with
    the backend's real data/state arguments, and counts collective ops in
    the optimized HLO — per *chain program* for posterior_merge (summed;
    each must independently compile to zero collectives for the merge
    backend's claim to hold).
    """
    from repro.core import distributed as dist
    from repro.core import gibbs, subset_merge

    backend = engine.backend
    key = jax.random.key(0)
    state = backend.init_state(key)
    pred = backend.init_pred()
    accum = backend.init_accum()
    if engine.cfg.backend.name == "posterior_merge":
        total = 0
        for c in range(backend.num_partitions):
            lowered = gibbs.gibbs_sweep_block.lower(
                subset_merge.chain_key(key, c), state[c], pred[c],
                accum.chains[c], backend.chain_data[c], backend.core_cfg, 1,
            )
            total += _collective_ops(lowered.compile().as_text())
        return total
    if engine.cfg.backend.name == "sequential":
        lowered = gibbs.gibbs_sweep_block.lower(
            key, state, pred, accum, backend.data, backend.core_cfg, 1
        )
        return _collective_ops(lowered.compile().as_text())
    lowered = dist.dist_gibbs_sweep_block.lower(
        key, state, pred, accum, backend.data, backend.core_cfg, backend.mesh, 1
    )
    return _collective_ops(lowered.compile().as_text())


def _bytes_per_sweep(engine) -> int:
    """Modelled inter-device bytes per sweep (see module docstring)."""
    name = engine.cfg.backend.name
    if name in ("ring", "ring_async", "allgather"):
        backend = engine.backend
        S = backend.num_shards
        cap_u = backend.plan.part_users.cap
        cap_v = backend.plan.part_movies.cap
        K = engine.cfg.model.K
        return S * (S - 1) * (cap_u + cap_v) * K * 4
    return 0  # sequential: one device; posterior_merge: independent chains


def _artifact_rmse(engine, coo) -> float:
    """Exported-predictor RMSE over the engine's own global held-out split."""
    from repro.data.sparse import train_test_split

    _, test = train_test_split(
        coo, engine.cfg.run.test_fraction, engine.cfg.run.seed
    )
    preds = engine.predict(test.rows, test.cols)
    return float(np.sqrt(np.mean((preds - test.vals) ** 2)))


def _fit_timed(cfg, coo):
    """(engine, seconds) for one fit, compile excluded via a warmup fit."""
    from repro.bpmf import BPMFEngine

    BPMFEngine(cfg).fit(coo)  # compile
    engine = BPMFEngine(cfg)
    engine.prepare(coo)
    t0 = time.time()
    engine.fit()
    return engine, time.time() - t0


def run(smoke: bool = False, out_path: str | None = None) -> dict:
    from repro.bpmf import BPMFConfig, load_dataset
    from repro.core import subset_merge

    if smoke:
        users, movies, nnz, K = 80, 40, 800, 4
        sweeps, burn_in, keep = 4, 1, 2
        pads = (8, 32)
        partitions = (2,)
    else:
        # the statistical harness's reference task (tests/test_posterior_quality.py)
        users, movies, nnz, K = 150, 80, 4000, 8
        sweeps, burn_in, keep = 10, 3, 4
        pads = (8, 32, 128)
        partitions = (2, 4)
    coo = load_dataset(
        "synthetic", num_users=users, num_movies=movies, nnz=nnz,
        noise_std=0.3, seed=7,
    )
    base = BPMFConfig().replace(
        K=K, num_sweeps=sweeps, burn_in=burn_in,
        keep_factor_samples=keep, bucket_pads=pads,
    )

    configs = [("sequential", base.replace(name="sequential")),
               ("ring", base.replace(name="ring")),
               ("ring_async", base.replace(name="ring_async", pipeline_depth=2))]
    for P in partitions:
        configs.append(
            (f"posterior_merge_p{P}",
             base.replace(name="posterior_merge", num_partitions=P))
        )

    baseline = subset_merge.column_mean_rmse(
        coo, base.run.test_fraction, base.run.seed
    )
    out: dict = {
        "devices": len(jax.devices()),
        "smoke": smoke,
        "workload": {"users": users, "movies": movies, "nnz": nnz, "K": K,
                     "sweeps": sweeps, "burn_in": burn_in,
                     "keep_factor_samples": keep},
        "baseline_rmse": baseline,
        "merge_band": list(subset_merge.MERGE_RMSE_BAND[max(partitions)]),
        "backends": {},
    }

    for name, cfg in configs:
        engine, seconds = _fit_timed(cfg, coo)
        entry = {
            "rmse": engine.rmse,
            "rmse_artifact": _artifact_rmse(engine, coo),
            "bytes_per_sweep": _bytes_per_sweep(engine),
            "collective_ops": _sweep_block_hlo_collectives(engine),
            "seconds": seconds,
        }
        out["backends"][name] = entry
        print(f"[fig_merge_comm] {name}: rmse={entry['rmse']:.4f} "
              f"artifact={entry['rmse_artifact']:.4f} "
              f"{entry['bytes_per_sweep']} B/sweep "
              f"{entry['collective_ops']} collectives ({seconds:.2f}s)")

    # acceptance (ISSUE 7): the largest partition count must beat the
    # column-mean baseline, land inside the recorded band, and its compiled
    # chain programs must contain zero collectives (vs the ring's > 0)
    merged = out["backends"][f"posterior_merge_p{max(partitions)}"]
    lo, hi = out["merge_band"]
    out["beats_baseline"] = bool(merged["rmse_artifact"] < baseline)
    out["within_band"] = bool(lo <= merged["rmse_artifact"] <= hi)
    out["zero_comm_ok"] = bool(
        all(e["collective_ops"] == 0 and e["bytes_per_sweep"] == 0
            for n, e in out["backends"].items()
            if n.startswith("posterior_merge") or n == "sequential")
        and all(e["collective_ops"] > 0 and e["bytes_per_sweep"] > 0
                for n, e in out["backends"].items()
                if n in ("ring", "ring_async", "allgather"))
    )
    print(f"[fig_merge_comm] baseline={baseline:.4f} "
          f"beats_baseline={out['beats_baseline']} "
          f"within_band={out['within_band']} zero_comm_ok={out['zero_comm_ok']}")

    path = save_result(
        "fig_merge_comm", out,
        out=smoke_out_path("fig_merge_comm", smoke, out_path),
    )
    print(f"[fig_merge_comm] wrote {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload; writes to a temp path unless --out")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: the committed "
                         "experiments/bench file; smoke runs default to a "
                         "temp path instead)")
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out)
