"""Paper §V-B claim: every parallel version reaches the same RMSE.

Runs the same ``(seed, data)`` through all three backends of the
``repro.bpmf`` engine facade (sequential, ring, allgather; the distributed
ones on up to 4 shards) and reports RMSE trajectories. The samplers share
per-item fold_in randomness, so trajectories agree to float reduction
order — asserted to ~1e-3 here; bitwise-level parity is in
tests/test_distributed.py and tests/test_engine.py.

Run inside a >=4-device process (benchmarks.run handles this).
"""
from __future__ import annotations

import sys

import jax

from benchmarks.common import save_result
from repro.bpmf import BPMFConfig, BPMFEngine, load_dataset


def run(smoke: bool = False) -> dict:
    coo = load_dataset(
        "synthetic",
        num_users=300 if smoke else 1_500,
        num_movies=200 if smoke else 600,
        nnz=5_000 if smoke else 50_000,
        noise_std=0.4,
    )
    K = 8 if smoke else 16
    sweeps = 4 if smoke else 20
    w = min(4, len(jax.devices()))
    cfg = BPMFConfig().replace(
        K=K, num_sweeps=sweeps, burn_in=max(1, sweeps // 4), seed=7, num_shards=w
    )

    curves = {}
    for name in ("sequential", "ring", "allgather"):
        engine = BPMFEngine(cfg.replace(name=name)).fit(coo)
        label = name if name == "sequential" else f"distributed_{name}_{w}dev"
        curves[label] = [m.rmse_avg for m in engine.history]

    finals = {k: v[-1] for k, v in curves.items()}
    spread = max(finals.values()) - min(finals.values())
    out = {
        "curves": curves,
        "final_rmse": finals,
        "spread": spread,
        "noise_floor": 0.4,
        "parity_ok": bool(spread < 5e-3),
    }
    print(f"[rmse] finals={ {k: round(v,4) for k,v in finals.items()} } spread={spread:.2e}")
    save_result("rmse_convergence", out)
    return out


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv)
