"""Paper §V-B claim: every parallel version reaches the same RMSE.

Runs the sequential oracle and the distributed sampler (ring + allgather,
4 shards) on the same synthetic data/key and reports RMSE trajectories.
The samplers share per-item fold_in randomness, so trajectories agree to
float reduction order — asserted to ~1e-3 here, bitwise-level parity is in
tests/test_distributed.py.

Run inside a >=4-device process (benchmarks.run handles this).
"""
from __future__ import annotations

import sys

import jax
import numpy as np

from benchmarks.common import save_result
from repro.core.distributed import build_distributed_data, make_ring_mesh, run_distributed
from repro.core.gibbs import run as run_sequential
from repro.core.types import BPMFConfig
from repro.data.sparse import build_bpmf_data
from repro.data.synthetic import SyntheticSpec, synthetic_ratings


def run(smoke: bool = False) -> dict:
    spec = SyntheticSpec(
        num_users=300 if smoke else 1_500,
        num_movies=200 if smoke else 600,
        nnz=5_000 if smoke else 50_000,
        discretize=False,
        noise_std=0.4,
    )
    coo, truth = synthetic_ratings(spec)
    K = 8 if smoke else 16
    sweeps = 4 if smoke else 20
    cfg = BPMFConfig(K=K, num_sweeps=sweeps, burn_in=max(1, sweeps // 4))
    key = jax.random.key(7)

    seq_data = build_bpmf_data(coo, test_fraction=0.1, seed=0)
    _, _, hist_seq = run_sequential(key, seq_data, cfg)
    curves = {"sequential": [m.rmse_avg for m in hist_seq]}

    devices = jax.devices()
    w = min(4, len(devices))
    mesh = make_ring_mesh(devices[:w])
    for mode in ("ring", "allgather"):
        dcfg = BPMFConfig(K=K, num_sweeps=sweeps, burn_in=cfg.burn_in, comm_mode=mode)
        ddata, _ = build_distributed_data(coo, num_shards=w, test_fraction=0.1, seed=0)
        _, _, hist = run_distributed(key, ddata, dcfg, mesh)
        curves[f"distributed_{mode}_{w}dev"] = [m.rmse_avg for m in hist]

    finals = {k: v[-1] for k, v in curves.items()}
    spread = max(finals.values()) - min(finals.values())
    out = {
        "curves": curves,
        "final_rmse": finals,
        "spread": spread,
        "noise_floor": spec.noise_std,
        "parity_ok": bool(spread < 5e-3),
    }
    print(f"[rmse] finals={ {k: round(v,4) for k,v in finals.items()} } spread={spread:.2e}")
    save_result("rmse_convergence", out)
    return out


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv)
