"""Paper Figure 2: time to update one item vs number of ratings.

Paper methods -> this repo (TPU/SPMD adaptation, DESIGN.md §2):
  * sequential rank-one update  -> per-item naive update (posterior.update_item_naive)
  * sequential Cholesky         -> single-item bucket (B=1) batched update
  * parallel Cholesky           -> bucketed batch update amortized per item
                                   (many items of the same pad class at once —
                                   the SPMD replacement for splitting one huge
                                   item across threads)

The fitted (fixed, per_rating) cost model parameterizes core/balance.py —
the same Figure-2-driven methodology the paper uses for load balancing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.core import posterior
from repro.core.balance import fit_cost_model
from repro.core.types import Bucket, HyperParams
from repro.utils import timeit


def _bucket_for(nnz: int, num_items: int, num_opposite: int, K: int, seed: int = 0) -> Bucket:
    rng = np.random.default_rng(seed)
    pad = max(8, 1 << int(np.ceil(np.log2(max(nnz, 1)))))
    nbr = rng.integers(0, num_opposite, size=(num_items, pad), dtype=np.int32)
    val = rng.normal(size=(num_items, pad)).astype(np.float32)
    val[:, nnz:] = 0.0
    return Bucket(
        item_ids=jnp.arange(num_items, dtype=jnp.int32),
        nbr=jnp.asarray(nbr),
        val=jnp.asarray(val),
        nnz=jnp.full((num_items,), nnz, jnp.int32),
    )


def run(smoke: bool = False) -> dict:
    K = 16 if smoke else 32
    num_opposite = 2_000
    nnz_grid = [8, 32, 128, 512] if smoke else [8, 16, 32, 64, 128, 256, 512, 1024, 2048]
    iters = 3 if smoke else 10
    key = jax.random.key(0)
    X = jax.random.normal(key, (num_opposite, K), jnp.float32)
    hyper = HyperParams.init(K)
    X_side1 = jnp.zeros((1, K), jnp.float32)

    naive = jax.jit(
        lambda nbr, val: posterior.update_item_naive(key, 0, nbr, val, X, hyper, 2.0)
    )
    upd1 = jax.jit(
        lambda b: posterior.update_bucket(key, X_side1, X, b, hyper, 2.0, jnp.float32, False)
    )

    rows: list[dict] = []
    B = 64
    X_sideB = jnp.zeros((B, K), jnp.float32)
    updB = jax.jit(
        lambda b: posterior.update_bucket(key, X_sideB, X, b, hyper, 2.0, jnp.float32, False)
    )
    rng = np.random.default_rng(1)
    for nnz in nnz_grid:
        nbr = jnp.asarray(rng.integers(0, num_opposite, size=nnz, dtype=np.int32))
        val = jnp.asarray(rng.normal(size=nnz).astype(np.float32))
        t_naive = timeit(naive, nbr, val, iters=iters)
        t_single = timeit(upd1, _bucket_for(nnz, 1, num_opposite, K), iters=iters)
        t_batch = timeit(updB, _bucket_for(nnz, B, num_opposite, K), iters=iters) / B
        rows.append({"nnz": nnz, "t_naive_s": t_naive, "t_single_chol_s": t_single,
                     "t_batched_per_item_s": t_batch})

    nnzs = np.array([r["nnz"] for r in rows], dtype=np.float64)
    tb = np.array([r["t_batched_per_item_s"] for r in rows])
    cm = fit_cost_model(nnzs, tb * 1e6)  # microseconds => well-scaled coefficients
    out = {
        "rows": rows,
        "cost_model": {"fixed_us": cm.fixed, "per_rating_us": cm.per_rating},
        "batched_speedup_at_min_nnz": rows[0]["t_single_chol_s"] / max(rows[0]["t_batched_per_item_s"], 1e-12),
    }
    save_result("fig2_item_update", out)
    return out


if __name__ == "__main__":
    r = run()
    for row in r["rows"]:
        print({k: (f"{v:.2e}" if isinstance(v, float) else v) for k, v in row.items()})
    print("cost model:", r["cost_model"])
