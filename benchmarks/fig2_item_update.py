"""Paper Figure 2 — the cost-model fit — and the Gram autotune driver.

Paper methods -> this repo (TPU/SPMD adaptation, DESIGN.md §2):
  * sequential rank-one update  -> per-item naive update (posterior.update_item_naive)
  * sequential Cholesky         -> single-item bucket (B=1) batched update
  * parallel Cholesky           -> bucketed batch update amortized per item
                                   (many items of the same pad class at once —
                                   the SPMD replacement for splitting one huge
                                   item across threads)

The fitted (fixed, per_rating) cost model parameterizes core/balance.py —
the same Figure-2-driven methodology the paper uses for load balancing —
and, since the autotuned hot path landed, also the deterministic heuristic
in ``repro.kernels.autotune`` (the regression that weighs partitioning
steers kernel choice too).

This script is additionally the **autotune driver** (ISSUE 3): for every
step shape in ``STEP_SHAPES`` it measures
``(tb, pc) × {pallas_fused, pallas, xla}`` through the real dispatch path
(``autotune.measure_step``), records the winners into the persistent cache
under ``experiments/autotune/`` and writes the per-shape timings to
``experiments/bench/fig2_item_update.json`` (schema:
``experiments/bench/README.md``). ``--smoke`` measures only the first two
shapes with a tiny budget and *merges* into an existing artifact instead of
shrinking it.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import OUT_DIR, save_result, smoke_out_path
from repro.core import posterior
from repro.core.balance import fit_cost_model
from repro.core.types import Bucket, HyperParams
from repro.kernels import autotune
from repro.utils import timeit

# (name, per-bucket (B, P) shapes, Ns, K) — one entry per autotuned step
# shape. The first two are the bench-smoke shapes: K=32 multi-bucket steps
# where the fused kernel's single launch + in-kernel scatter beats the
# per-bucket dispatch + two XLA `at[].add` per bucket (whose cost scales
# with B·K²) even in interpret mode.
STEP_SHAPES: list[tuple[str, list[tuple[int, int]], int, int]] = [
    ("multi_med", [(48, 64), (16, 32)], 128, 32),
    ("multi_wide", [(32, 128), (16, 64)], 192, 32),
    ("multi_small", [(32, 32), (16, 128)], 128, 16),
    ("one_tall", [(64, 32)], 256, 32),
    ("one_wide", [(8, 512)], 512, 32),
    ("many_tiny", [(128, 8)], 128, 16),
    ("two_big", [(16, 128), (16, 128)], 1024, 32),
    ("small_rank", [(64, 64)], 512, 8),
]


def _bucket_for(nnz: int, num_items: int, num_opposite: int, K: int, seed: int = 0) -> Bucket:
    rng = np.random.default_rng(seed)
    pad = max(8, 1 << int(np.ceil(np.log2(max(nnz, 1)))))
    nbr = rng.integers(0, num_opposite, size=(num_items, pad), dtype=np.int32)
    val = rng.normal(size=(num_items, pad)).astype(np.float32)
    val[:, nnz:] = 0.0
    return Bucket(
        item_ids=jnp.arange(num_items, dtype=jnp.int32),
        nbr=jnp.asarray(nbr),
        val=jnp.asarray(val),
        nnz=jnp.full((num_items,), nnz, jnp.int32),
    )


def _fig2_rows(smoke: bool) -> list[dict]:
    """The paper's Fig 2 curves: per-item update time vs rating count."""
    K = 16 if smoke else 32
    num_opposite = 2_000
    nnz_grid = [8, 32, 128, 512] if smoke else [8, 16, 32, 64, 128, 256, 512, 1024, 2048]
    iters = 3 if smoke else 10
    key = jax.random.key(0)
    X = jax.random.normal(key, (num_opposite, K), jnp.float32)
    hyper = HyperParams.init(K)
    X_side1 = jnp.zeros((1, K), jnp.float32)

    naive = jax.jit(
        lambda nbr, val: posterior.update_item_naive(key, 0, nbr, val, X, hyper, 2.0)
    )
    upd1 = jax.jit(
        lambda b: posterior.update_bucket(key, X_side1, X, b, hyper, 2.0, jnp.float32, "xla")
    )

    rows: list[dict] = []
    B = 64
    X_sideB = jnp.zeros((B, K), jnp.float32)
    updB = jax.jit(
        lambda b: posterior.update_bucket(key, X_sideB, X, b, hyper, 2.0, jnp.float32, "xla")
    )
    rng = np.random.default_rng(1)
    for nnz in nnz_grid:
        nbr = jnp.asarray(rng.integers(0, num_opposite, size=nnz, dtype=np.int32))
        val = jnp.asarray(rng.normal(size=nnz).astype(np.float32))
        t_naive = timeit(naive, nbr, val, iters=iters)
        t_single = timeit(upd1, _bucket_for(nnz, 1, num_opposite, K), iters=iters)
        t_batch = timeit(updB, _bucket_for(nnz, B, num_opposite, K), iters=iters) / B
        rows.append({"nnz": nnz, "t_naive_s": t_naive, "t_single_chol_s": t_single,
                     "t_batched_per_item_s": t_batch})
    return rows


def _impl_of(label: str) -> str:
    if label == "xla":
        return "xla"
    return "pallas_fused" if label.startswith("pallas_fused") else "pallas"


def _sweep_entry(dec, timings: dict, bucket_shapes, Ns: int, K: int) -> dict:
    """One JSON entry from a measure_step result (shared by both sweeps)."""
    per_impl: dict[str, float] = {}
    for label, t in timings.items():
        impl = _impl_of(label)
        per_impl[impl] = min(per_impl.get(impl, float("inf")), t)
    entry = {
        "buckets": [list(s) for s in bucket_shapes],
        "Ns": Ns,
        "K": K,
        "timings_us": {k: round(v, 3) for k, v in per_impl.items()},
        "winner": dec.impl,
        "tb": dec.tb,
        "pc": dec.pc,
        "ns_chunk": dec.ns_chunk,
    }
    if "pallas" in per_impl and "pallas_fused" in per_impl:
        entry["fused_vs_bucket_speedup"] = round(
            per_impl["pallas"] / max(per_impl["pallas_fused"], 1e-9), 4
        )
    return entry


def _kernel_sweep(smoke: bool) -> dict[str, dict]:
    """Measured (tb, pc) x impl sweep per step shape, via the autotuner."""
    shapes = STEP_SHAPES[:2] if smoke else STEP_SHAPES
    tilings = [(8, 128)] if smoke else [(8, 128), (8, 256), (4, 512)]
    # smoke's budget is tiny via its candidate count (2 shapes × 1 tiling vs
    # 8 shapes × 3 tilings + workload keys); the extra per-candidate iters
    # buy a stable interleaved median for the fused-vs-bucket comparison
    iters = 48 if smoke else 16
    sweep: dict[str, dict] = {}
    for name, bucket_shapes, Ns, K in shapes:
        dec, timings = autotune.measure_step(
            bucket_shapes, Ns, K, iters=iters, tilings=tilings
        )
        sweep[name] = _sweep_entry(dec, timings, bucket_shapes, Ns, K)
        print(f"  {name}: winner={dec.impl} timings_us={sweep[name]['timings_us']}")
    return sweep


WORKLOAD = dict(num_users=400, num_movies=300, nnz=12_000, seed=0)
WORKLOAD_SHARDS = 4
WORKLOAD_K = 16


def _workload_sweep(smoke: bool, max_keys: int = 6) -> dict:
    """Measure the *exact* step keys a real engine run will look up.

    The synthetic ``STEP_SHAPES`` sweep characterizes the kernels; this one
    makes the cache engage: it builds the reference workload's distributed
    layout, derives each ring step's engine key via
    ``autotune.workload_step_keys`` and records measured winners for those
    keys, so ``gram_impl="auto"`` on this workload hits the cache at trace
    time. Skipped in smoke mode (layout build + per-key compiles dominate).
    """
    if smoke:
        return {}
    from repro.bpmf import load_dataset
    from repro.core.distributed import build_distributed_data

    coo = load_dataset("synthetic", **WORKLOAD)
    data, _ = build_distributed_data(coo, num_shards=WORKLOAD_SHARDS)
    uniq: dict[str, tuple] = {}
    for key, shapes in autotune.workload_step_keys(data, WORKLOAD_K):
        uniq.setdefault(key.encode(), (key, shapes))
    dropped = max(len(uniq) - max_keys, 0)
    if dropped:
        print(f"  workload sweep: measuring {max_keys} of {len(uniq)} distinct keys "
              f"({dropped} dropped)")
    entries: dict[str, dict] = {}
    for enc, (key, shapes) in list(uniq.items())[:max_keys]:
        dec, timings = autotune.measure_step(
            shapes, key.Ns, key.K, cap=key.cap, iters=8, tilings=[(8, 128)]
        )
        entries[enc] = dict(_sweep_entry(dec, timings, shapes, key.Ns, key.K), cap=key.cap)
        print(f"  {enc}: winner={dec.impl}")
    return {
        "workload": {**WORKLOAD, "num_shards": WORKLOAD_SHARDS, "K": WORKLOAD_K},
        "distinct_keys": len(uniq),
        "measured_keys": len(entries),
        "entries": entries,
    }


def run(smoke: bool = False, out_path: str | None = None) -> dict:
    """Fig2 curves + cost-model fit + kernel autotune sweep; writes JSON."""
    rows = _fig2_rows(smoke)
    nnzs = np.array([r["nnz"] for r in rows], dtype=np.float64)
    tb = np.array([r["t_batched_per_item_s"] for r in rows])
    cm = fit_cost_model(nnzs, tb * 1e6)  # microseconds => well-scaled coefficients

    print(f"kernel sweep ({'smoke: 2' if smoke else len(STEP_SHAPES)} step shapes):")
    sweep = _kernel_sweep(smoke)
    workload = _workload_sweep(smoke)

    out = {
        "device": jax.default_backend(),
        "smoke": bool(smoke),
        "rows": rows,
        "cost_model": {"fixed_us": cm.fixed, "per_rating_us": cm.per_rating},
        "batched_speedup_at_min_nnz": rows[0]["t_single_chol_s"] / max(rows[0]["t_batched_per_item_s"], 1e-12),
        "kernel_sweep": sweep,
        "workload_sweep": workload,
        "autotune_cache": os.path.relpath(
            autotune.get_cache().path, os.path.join(OUT_DIR, "..", "..")
        ),
    }
    if smoke:
        # merge on top of the committed (fuller) artifact instead of
        # shrinking it — keep its Fig-2 curves / cost model, update only
        # re-measured entries. The merged result still goes to the smoke
        # temp path (or --out), never back into the committed JSON.
        path = os.path.join(OUT_DIR, "fig2_item_update.json")
        try:
            with open(path) as f:
                old = json.load(f)
            merged_sweep = dict(old.get("kernel_sweep", {}))
            merged_sweep.update(sweep)
            keep = {
                k: old[k]
                for k in ("rows", "cost_model", "batched_speedup_at_min_nnz",
                          "workload_sweep")
                if k in old
            }
            old.update(out)
            old.update(keep)
            old["kernel_sweep"] = merged_sweep
            out = old
        except (OSError, ValueError):
            pass
        out["smoke"] = True  # even when merged over a full artifact
    path = save_result(
        "fig2_item_update", out,
        out=smoke_out_path("fig2_item_update", smoke, out_path),
    )
    print(f"[fig2_item_update] wrote {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="2 shapes, tiny timing budget; merges over the "
                         "committed JSON, writes to a temp path unless --out")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: the committed "
                         "experiments/bench file; smoke runs default to a "
                         "temp path instead)")
    args = ap.parse_args()
    r = run(smoke=args.smoke, out_path=args.out)
    for row in r["rows"]:
        print({k: (f"{v:.2e}" if isinstance(v, float) else v) for k, v in row.items()})
    print("cost model:", r["cost_model"])
    winners = {k: v["winner"] for k, v in r["kernel_sweep"].items()}
    print("kernel winners:", winners)
