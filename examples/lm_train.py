"""Train a reduced assigned-architecture LM end-to-end (framework substrate).

    PYTHONPATH=src python examples/lm_train.py --arch zamba2-2.7b --steps 60

Uses the same train_step/launcher path the production mesh uses; see
``python -m repro.launch.train --help`` for all knobs.
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=60)
    args, extra = ap.parse_known_args()
    rc = train_main([
        "--arch", args.arch, "--reduced", "--steps", str(args.steps),
        "--batch", "8", "--seq", "64", *extra,
    ])
    sys.exit(rc)


if __name__ == "__main__":
    main()
