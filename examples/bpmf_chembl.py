"""End-to-end driver: BPMF on a ChEMBL-IC50-scale dataset (the paper's
drug-discovery benchmark), a few hundred Gibbs sweeps with checkpointing.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/bpmf_chembl.py --scale 0.05 --sweeps 40

``--scale 1.0`` is the full 483500 x 5775 / 1M-ratings shape (minutes/sweep
on CPU; the real target is the 256-chip pod of the dry-run).
"""
import argparse
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.distributed import (
    build_distributed_data,
    dist_gibbs_sweep,
    init_dist_state,
    make_ring_mesh,
    shard_data,
)
from repro.core.prediction import PredictionState
from repro.core.types import BPMFConfig
from repro.data.synthetic import CHEMBL_LIKE, SyntheticSpec, synthetic_ratings


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05, help="fraction of ChEMBL size")
    ap.add_argument("--sweeps", type=int, default=40)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--checkpoint-dir", default="/tmp/bpmf_chembl_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=10)
    args = ap.parse_args()

    base = CHEMBL_LIKE
    spec = SyntheticSpec(
        num_users=max(64, int(base.num_users * args.scale)),
        num_movies=max(32, int(base.num_movies * args.scale)),
        nnz=max(2000, int(base.nnz * args.scale)),
        discretize=False,
        noise_std=base.noise_std,
    )
    print(f"ChEMBL-shaped: {spec.num_users} compounds x {spec.num_movies} targets, "
          f"{spec.nnz} activities (scale={args.scale})")
    coo, _ = synthetic_ratings(spec)

    S = len(jax.devices())
    mesh = make_ring_mesh()
    cfg = BPMFConfig(K=args.k, num_sweeps=args.sweeps, burn_in=max(2, args.sweeps // 5))
    t0 = time.time()
    data, plan = build_distributed_data(coo, num_shards=S, seed=0)
    print(f"partition+bucket: {time.time()-t0:.1f}s; LPT balance "
          f"{plan.part_users.balance_ratio():.3f}/{plan.part_movies.balance_ratio():.3f}")

    key = jax.random.key(0)
    data = shard_data(data, mesh)
    state = init_dist_state(key, data, cfg, mesh)
    pred = PredictionState.init(data.test.rows.shape[0])
    manager = CheckpointManager(args.checkpoint_dir, keep=2)

    t0 = time.time()
    for sweep in range(args.sweeps):
        state, pred, metrics = dist_gibbs_sweep(key, state, pred, data, cfg, mesh)
        if (sweep + 1) % 10 == 0 or sweep == 0:
            ups = (coo.num_users + coo.num_movies) * (sweep + 1) / (time.time() - t0)
            print(f"sweep {sweep+1:4d} rmse(avg)={float(metrics.rmse_avg):.4f} "
                  f"({ups:,.0f} updates/s)")
        if (sweep + 1) % args.checkpoint_every == 0:
            manager.save(sweep + 1, {"U": state.U, "V": state.V, "sweep": state.sweep})
    manager.close()
    final = float(metrics.rmse_avg)
    print(f"done: rmse={final:.4f} noise floor ~{spec.noise_std}; "
          f"checkpoints in {args.checkpoint_dir}")
    assert final < 2.5 * spec.noise_std
    print("ok")


if __name__ == "__main__":
    main()
