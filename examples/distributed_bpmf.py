"""Distributed BPMF (the paper's contribution) on multiple devices.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_bpmf.py

Runs the *same* ``(seed, data)`` through all registered backends of the
``repro.bpmf`` engine — sequential oracle, ring rotation with compute/comm
overlap (paper §IV-C), depth-2 pipelined async ring (arXiv:1705.10633,
DESIGN.md §7), synchronous all-gather baseline — by flipping one config
field, and checks they reach the same RMSE (paper §V-B).
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import jax

from repro.bpmf import BPMFConfig, BPMFEngine, load_dataset


def main():
    coo = load_dataset("synthetic", num_users=2_000, num_movies=500, nnz=40_000)
    cfg = BPMFConfig().replace(K=16, num_sweeps=10, burn_in=2)
    S = len(jax.devices())
    print(f"{S} devices; R: {coo.num_users} x {coo.num_movies}, {coo.nnz} ratings")

    rmses = {}
    variants = (
        ("sequential", {}),
        ("ring", {}),
        ("ring_async", {"pipeline_depth": 2}),
        ("allgather", {}),
    )
    for name, extra in variants:
        engine = BPMFEngine(cfg.replace(name=name, **extra))
        engine.prepare(coo)
        if name == "ring":
            plan = engine.backend.plan
            ratios = [f"{p.balance_ratio():.3f}" for p in (plan.part_users, plan.part_movies)]
            print(f"LPT balance ratios (max/mean cost, 1.0=perfect): "
                  f"users={ratios[0]} movies={ratios[1]}")
        engine.fit()  # includes compile
        timed = BPMFEngine(cfg.replace(name=name, **extra))
        timed.prepare(coo)
        t0 = time.time()
        timed.fit()  # jit cache warm: measures the sweep loop itself
        dt = time.time() - t0
        rmses[name] = engine.rmse
        print(f"{name:10s} rmse={engine.rmse:.4f}  {dt:.2f}s "
              f"({(coo.num_users + coo.num_movies) * cfg.run.num_sweeps / dt:,.0f} updates/s)")

    spread = max(rmses.values()) - min(rmses.values())
    assert spread < 5e-3, f"parity broken! {rmses}"
    print("ok — all versions reach the same RMSE (paper §V-B)")


if __name__ == "__main__":
    main()
