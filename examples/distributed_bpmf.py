"""Distributed BPMF (the paper's contribution) on multiple devices.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_bpmf.py

Shows: the cost-model load balancing (paper §IV-B), the ring rotation with
compute/comm overlap (paper §IV-C) vs the synchronous all-gather baseline,
and that both reach the same RMSE as the sequential sampler (paper §V-B).
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import jax

from repro.core.distributed import build_distributed_data, make_ring_mesh, run_distributed
from repro.core.gibbs import run as run_sequential
from repro.core.types import BPMFConfig
from repro.data.sparse import build_bpmf_data
from repro.data.synthetic import SyntheticSpec, synthetic_ratings


def main():
    spec = SyntheticSpec(num_users=2_000, num_movies=500, nnz=40_000, discretize=False)
    coo, _ = synthetic_ratings(spec)
    cfg = BPMFConfig(K=16, num_sweeps=10, burn_in=2)
    key = jax.random.key(0)
    S = len(jax.devices())
    print(f"{S} devices; R: {coo.num_users} x {coo.num_movies}, {coo.nnz} ratings")

    seq_data = build_bpmf_data(coo, test_fraction=0.1, seed=0)
    _, _, hist = run_sequential(key, seq_data, cfg)
    print(f"sequential oracle     rmse={hist[-1].rmse_avg:.4f}")

    mesh = make_ring_mesh()
    for mode in ("ring", "allgather"):
        dcfg = BPMFConfig(K=16, num_sweeps=10, burn_in=2, comm_mode=mode)
        data, plan = build_distributed_data(coo, num_shards=S, seed=0)
        if mode == "ring":
            ratios = [f"{p.balance_ratio():.3f}" for p in (plan.part_users, plan.part_movies)]
            print(f"LPT balance ratios (max/mean cost, 1.0=perfect): users={ratios[0]} movies={ratios[1]}")
        run_distributed(key, data, dcfg, mesh)  # compile
        t0 = time.time()
        _, _, dh = run_distributed(key, data, dcfg, mesh)
        dt = time.time() - t0
        print(f"distributed {mode:9s} rmse={dh[-1].rmse_avg:.4f}  {dt:.2f}s "
              f"({(coo.num_users + coo.num_movies) * cfg.num_sweeps / dt:,.0f} updates/s)")
        assert abs(dh[-1].rmse_avg - hist[-1].rmse_avg) < 5e-3, "parity broken!"
    print("ok — all versions reach the same RMSE (paper §V-B)")


if __name__ == "__main__":
    main()
