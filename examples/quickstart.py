"""Quickstart: BPMF on a synthetic MovieLens-100k-shaped dataset.

    PYTHONPATH=src python examples/quickstart.py

Factorizes R ~ U V^T with the Gibbs sampler (paper Algorithm 1) through the
``repro.bpmf`` engine facade and shows the RMSE dropping toward the
generative noise floor, then exports the posterior and serves a few
queries from it (DESIGN.md §9). The same script runs distributed by
changing ``name="sequential"`` to ``"ring"`` — see
examples/distributed_bpmf.py.
"""
import tempfile

import numpy as np

from repro.bpmf import BPMFConfig, BPMFEngine
from repro.data.synthetic import small_test_ratings
from repro.serve import PosteriorPredictor


def main():
    coo, truth = small_test_ratings(num_users=400, num_movies=300, nnz=12_000, noise_std=0.35)
    cfg = BPMFConfig().replace(name="sequential", K=16, num_sweeps=25, burn_in=5)

    print(f"R: {coo.num_users} x {coo.num_movies}, {coo.nnz} ratings; K={cfg.model.K}")
    engine = BPMFEngine(cfg)
    for m in engine.sample(coo):
        if int(m.sweep) % 5 == 0:
            print(
                f"  sweep {int(m.sweep):3d}  rmse(sample)={m.rmse_sample:.4f}  "
                f"rmse(avg)={m.rmse_avg:.4f}"
            )
    print(f"final averaged-prediction RMSE: {engine.rmse:.4f} "
          f"(generative noise floor ~{truth['noise_std']})")
    assert engine.rmse < 2.5 * truth["noise_std"], "did not converge"

    # posterior-mean serving: export the artifact, load it back, query it
    artifact = engine.export(tempfile.mkdtemp(prefix="bpmf-quickstart-") + "/artifact")
    predictor = PosteriorPredictor.load(artifact)
    rows, cols = np.arange(5), np.arange(5)
    preds, std = predictor.predict(rows, cols, return_std=True)
    assert np.array_equal(preds, engine.predict(rows, cols)), "served != in-process"
    items, scores = predictor.top_k(user=0, k=3)
    print(f"served predictions {np.round(preds, 3)} (std {np.round(std, 3)})")
    print(f"top-3 movies for user 0: {items.tolist()} scores {np.round(scores, 3)}")
    print("ok")


if __name__ == "__main__":
    main()
