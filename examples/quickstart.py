"""Quickstart: BPMF on a synthetic MovieLens-100k-shaped dataset.

    PYTHONPATH=src python examples/quickstart.py

Factorizes R ~ U V^T with the Gibbs sampler (paper Algorithm 1) and shows
the RMSE dropping toward the generative noise floor.
"""
import jax

from repro.core.gibbs import run
from repro.core.types import BPMFConfig
from repro.data.sparse import build_bpmf_data
from repro.data.synthetic import small_test_ratings


def main():
    coo, truth = small_test_ratings(num_users=400, num_movies=300, nnz=12_000, noise_std=0.35)
    data = build_bpmf_data(coo, test_fraction=0.1, seed=0)
    cfg = BPMFConfig(K=16, num_sweeps=25, burn_in=5)

    print(f"R: {coo.num_users} x {coo.num_movies}, {coo.nnz} ratings; K={cfg.K}")
    state, pred, history = run(
        jax.random.key(0), data, cfg,
        callback=lambda s, m: print(
            f"  sweep {int(m.sweep):3d}  rmse(sample)={float(m.rmse_sample):.4f}  "
            f"rmse(avg)={float(m.rmse_avg):.4f}"
        ) if int(m.sweep) % 5 == 0 else None,
    )
    final = history[-1].rmse_avg
    print(f"final averaged-prediction RMSE: {final:.4f} "
          f"(generative noise floor ~{truth['noise_std']})")
    assert final < 2.5 * truth["noise_std"], "did not converge"
    print("ok")


if __name__ == "__main__":
    main()
