"""Quickstart: BPMF on a synthetic MovieLens-100k-shaped dataset.

    PYTHONPATH=src python examples/quickstart.py

Factorizes R ~ U V^T with the Gibbs sampler (paper Algorithm 1) through the
``repro.bpmf`` engine facade and shows the RMSE dropping toward the
generative noise floor. The same script runs distributed by changing
``name="sequential"`` to ``"ring"`` — see examples/distributed_bpmf.py.
"""
from repro.bpmf import BPMFConfig, BPMFEngine
from repro.data.synthetic import small_test_ratings


def main():
    coo, truth = small_test_ratings(num_users=400, num_movies=300, nnz=12_000, noise_std=0.35)
    cfg = BPMFConfig().replace(name="sequential", K=16, num_sweeps=25, burn_in=5)

    print(f"R: {coo.num_users} x {coo.num_movies}, {coo.nnz} ratings; K={cfg.model.K}")
    engine = BPMFEngine(cfg)
    for m in engine.sample(coo):
        if int(m.sweep) % 5 == 0:
            print(
                f"  sweep {int(m.sweep):3d}  rmse(sample)={m.rmse_sample:.4f}  "
                f"rmse(avg)={m.rmse_avg:.4f}"
            )
    print(f"final averaged-prediction RMSE: {engine.rmse:.4f} "
          f"(generative noise floor ~{truth['noise_std']})")
    assert engine.rmse < 2.5 * truth["noise_std"], "did not converge"
    print("ok")


if __name__ == "__main__":
    main()
